//! The Fig 9 experiment as a standalone scenario: mid-run the MAN
//! bandwidth collapses from 1 Gbps to 30 Mbps. Anveshak's budget-driven
//! dynamic batching reacts by shrinking batches and stays within γ;
//! the Near-Optimal Baseline's lookup table was built for the old
//! network and destabilizes.
//!
//! Run: `cargo run --release --example network_variation`

use anveshak::config::preset;
use anveshak::coordinator::des;

fn main() {
    println!("bandwidth drops 1 Gbps -> 30 Mbps at t = 300 s\n");
    for (label, name) in
        [("Anveshak DB-25", "fig9_anv"), ("NOB-25 baseline", "fig9_nob")]
    {
        let r = des::run(preset(name));
        let s = &r.summary;
        // Count seconds whose 1-s mean latency exceeds gamma, before
        // and after the drop.
        let rows = r.timeline.rows();
        let (mut pre, mut post) = (0, 0);
        for (sec, row) in rows.iter().enumerate() {
            if row.mean_latency_s > 15.0 {
                if sec < 300 {
                    pre += 1;
                } else {
                    post += 1;
                }
            }
        }
        println!("{label}:");
        println!(
            "  delayed events {} ({:.1}%), max latency {:.1}s",
            s.delayed,
            100.0 * s.delay_rate(),
            s.latency.max
        );
        println!(
            "  seconds over gamma: {pre} before the drop, {post} after\n"
        );
    }
}
