//! Multi-query tracking service in ~40 lines: several concurrent
//! queries share one camera network and one VA/CR deployment.
//!
//! Runs the multi-query DES mode — queries arrive as a Poisson
//! process, admission control protects the cluster, and the fair-share
//! scheduler composes cross-query batches — then prints the per-query
//! recall/latency report from the per-query ledgers.
//!
//! Run: `cargo run --release --example multi_query`

use anveshak::config::ExperimentConfig;
use anveshak::coordinator::des::run_multi;

fn main() {
    // 1. Describe the deployment: a 200-camera network, shared by all
    //    queries (defaults otherwise follow the paper's setup).
    let mut cfg = ExperimentConfig::default();
    cfg.name = "multi-query-example".into();
    cfg.num_cameras = 200;
    cfg.workload.vertices = 200;
    cfg.workload.edges = 560;

    // 2. Describe the query workload: 6 queries, ~15 s apart, each
    //    tracking its own entity for 2 minutes; at most 4 run at once
    //    (the rest wait or are rejected).
    cfg.multi_query.num_queries = 6;
    cfg.multi_query.mean_interarrival_secs = 15.0;
    cfg.multi_query.lifetime_secs = 120.0;
    cfg.multi_query.max_active = 4;
    cfg.multi_query.queue_capacity = 2;

    // 3. Run (virtual time: finishes in seconds) and report per query.
    let r = run_multi(cfg);
    println!(
        "peak concurrent queries: {} (rejected {}, wait-listed {})",
        r.peak_concurrent, r.rejected, r.queued
    );
    for q in &r.queries {
        match &q.summary {
            Some(s) => println!(
                "  {:<4} prio {} {:<10} events {:>6}  on-time {:>6}  \
                 dropped {:>5}  recall {:>5.1}%  median {:.2}s  \
                 peak-cams {}",
                q.label,
                q.priority,
                format!("{:?}", q.status),
                s.generated,
                s.on_time,
                s.dropped,
                100.0 * q.recall(),
                s.latency.median,
                q.peak_active
            ),
            None => println!(
                "  {:<4} prio {} {:<10} (never activated)",
                q.label,
                q.priority,
                format!("{:?}", q.status)
            ),
        }
    }
    let agg = &r.aggregate;
    println!(
        "aggregate: {} events, {} on-time, {} dropped, conserved: {}",
        agg.generated,
        agg.on_time,
        agg.dropped,
        agg.conserved()
    );
}
