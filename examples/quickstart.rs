//! Quickstart: compose and run a tracking application in ~20 lines.
//!
//! Simulates App 1 (HoG-like VA → re-id CR → WBFS spotlight TL) on a
//! 100-camera network for 2 simulated minutes and prints what the UV
//! module would show: detections, latency and the tuning outcome.
//!
//! Run: `cargo run --release --example quickstart`

use anveshak::config::{BatchingKind, ExperimentConfig, TlKind};
use anveshak::coordinator::des;

fn main() {
    // 1. Describe the deployment (defaults follow the paper's setup).
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.num_cameras = 100;
    cfg.workload.vertices = 100;
    cfg.workload.edges = 280;
    cfg.duration_secs = 120.0;
    cfg.tl = TlKind::Wbfs; // spotlight with exact road lengths
    cfg.batching = BatchingKind::Dynamic { max: 25 };

    // 2. Run the dataflow (virtual time: finishes in milliseconds).
    let r = des::run(cfg);

    // 3. Inspect the tracking outcome.
    let s = &r.summary;
    println!("frames into the dataflow : {}", s.generated);
    println!(
        "processed within gamma   : {} ({:.1}%)",
        s.on_time,
        100.0 * s.on_time as f64 / s.generated.max(1) as f64
    );
    println!("delayed / dropped        : {} / {}", s.delayed, s.dropped);
    println!(
        "end-to-end latency       : median {:.2}s, p99 {:.2}s",
        s.latency.median, s.latency.p99
    );
    println!("entity detections at UV  : {}", r.detections);
    println!("peak active cameras      : {}", r.peak_active);
    assert!(r.detections > 0, "the spotlight should find the entity");
}
