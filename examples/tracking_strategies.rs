//! The Tracking-Logic knob in isolation: run the same 1000-camera
//! workload under the four spotlight strategies and compare the active
//! camera-set sizes and the work they induce — the paper's scalability
//! argument (a smarter TL supports more total cameras on the same
//! resources).
//!
//! Run: `cargo run --release --example tracking_strategies`

use anveshak::config::{BatchingKind, ExperimentConfig, TlKind};
use anveshak::coordinator::des;

fn main() {
    println!(
        "{:<16} {:>9} {:>10} {:>9} {:>9} {:>11}",
        "TL strategy", "frames", "on-time %", "peak-cams", "median-s", "detections"
    );
    for (label, tl, cams) in [
        ("Base (all on)", TlKind::Base, 200), // full network melts down
        ("BFS", TlKind::Bfs, 1000),
        ("WBFS", TlKind::Wbfs, 1000),
        ("WBFS+speed", TlKind::WbfsSpeed, 1000),
        ("Probabilistic", TlKind::Probabilistic, 1000),
    ] {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("tl-{label}");
        cfg.tl = tl;
        cfg.num_cameras = cams;
        cfg.workload.vertices = cams;
        cfg.workload.edges = (cams as f64 * 2.817) as usize;
        cfg.batching = BatchingKind::Dynamic { max: 25 };
        let r = des::run(cfg);
        let s = &r.summary;
        println!(
            "{:<16} {:>9} {:>9.1}% {:>9} {:>9.2} {:>11}",
            label,
            s.generated,
            100.0 * s.on_time as f64 / s.generated.max(1) as f64,
            r.peak_active,
            s.latency.median,
            r.detections
        );
    }
    println!(
        "\nSmarter spotlights process orders of magnitude fewer frames at\n\
         the same tracking quality — the knob that lets 1000 cameras run\n\
         on resources that cannot even sustain 200 always-on feeds."
    );
}
