//! End-to-end serving driver — the full three-layer stack on a real
//! (small) workload:
//!
//!   L1/L2: the Pallas/JAX re-id models, AOT-compiled to HLO in
//!          `artifacts/` (`make artifacts`), executed via PJRT;
//!   L3:    the Rust coordinator — camera feeds, FC gating, VA/CR
//!          workers with dynamic batching + budgets, TL spotlight, UV.
//!
//! Serves a 24-camera network for 12 wall-clock seconds, tracking a
//! real query identity through real model inference, and reports
//! latency/throughput — proving all layers compose with Python nowhere
//! on the request path.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use anveshak::config::{BatchingKind, ExperimentConfig, TlKind};
use anveshak::coordinator::LiveEngine;
use anveshak::runtime::default_dir;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "e2e-serving".into();
    cfg.num_cameras = 24;
    cfg.workload.vertices = 80;
    cfg.workload.edges = 200;
    cfg.workload.fov_m = 25.0;
    cfg.duration_secs = 12.0;
    cfg.fps = 2.0;
    cfg.gamma_ms = 4_000.0;
    cfg.cluster.va_instances = 2;
    cfg.cluster.cr_instances = 2;
    cfg.tl = TlKind::Wbfs;
    cfg.batching = BatchingKind::Dynamic { max: 16 };

    println!("loading AOT artifacts + compiling PJRT executables...");
    // App 1's composition (HoG VA + small re-id CR) with the config's
    // WBFS spotlight — typed model variants, no artifact-name strings.
    let app = anveshak::apps::resolve(&cfg);
    let eng = LiveEngine::new(cfg, default_dir(), app);
    let r = eng.run()?;

    println!("\n=== end-to-end serving report ===");
    println!("wall time            : {:.1}s", r.wall_secs);
    println!(
        "frames served        : {} ({:.1} frames/s)",
        r.summary.on_time + r.summary.delayed,
        r.throughput
    );
    println!(
        "latency              : median {:.0}ms  p99 {:.0}ms  max {:.0}ms",
        r.summary.latency.median * 1e3,
        r.summary.latency.p99 * 1e3,
        r.summary.latency.max * 1e3
    );
    println!(
        "on-time / delayed    : {} / {}",
        r.summary.on_time, r.summary.delayed
    );
    println!("entity detections    : {}", r.detections);
    println!("peak active cameras  : {}", r.peak_active);
    assert!(
        r.detections > 0,
        "real re-id models must confirm the entity"
    );
    assert!(r.summary.conserved());
    Ok(())
}
