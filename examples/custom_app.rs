//! A user-defined tracking application built **entirely on the public
//! block API** — no engine code, no crate internals. This is the §2.2
//! contract end to end: we implement two custom blocks (an FC and a
//! TL), compose them with stock VA/CR via `AppBuilder`, and the
//! platform runs them with its own batching, dropping and budget
//! adaptation.
//!
//! Custom blocks here:
//!  * `DutyCycleFc` — forwards every k-th frame per camera (a crude
//!    power-saving duty cycle), independent of the spotlight policy.
//!  * `FixedRadiusTl` — a spotlight that always keeps a fixed-radius
//!    ball around the last sighting live (no time-based expansion):
//!    simpler than the paper's policies, and expressible without
//!    touching `coordinator/` at all.
//!
//! Run: `cargo run --release --example custom_app [-- --smoke]`
//! (`--smoke` shrinks the workload so CI can run it in seconds).

use anveshak::apps::{AppBuilder, SimDetector, SimReid};
use anveshak::config::{BatchingKind, ExperimentConfig};
use anveshak::coordinator::des;
use anveshak::dataflow::{
    FilterControl, ModelVariant, QueryId, TlEnv, TrackingLogic,
};
use anveshak::roadnet::{
    wbfs_spotlight_into, Camera, Graph, SpotlightWorkspace, VertexId,
};
use anveshak::util::Micros;

/// Custom FC: admit every `stride`-th frame of an active camera.
#[derive(Clone)]
struct DutyCycleFc {
    stride: u64,
}

impl FilterControl for DutyCycleFc {
    fn admit(
        &mut self,
        _query: QueryId,
        _camera: usize,
        frame_no: u64,
        _now: Micros,
        active: bool,
    ) -> bool {
        active && frame_no % self.stride == 0
    }

    fn label(&self) -> &'static str {
        "duty-cycle"
    }
}

/// Custom TL: keep a fixed-radius ball around the last sighting live.
struct FixedRadiusTl {
    radius_m: f64,
    num_cameras: usize,
    /// vertex -> camera ids mounted there.
    cam_at: Vec<(usize, Vec<usize>)>,
    cam_vertex: Vec<usize>,
    last_seen: Option<(usize, Micros)>,
    ws: SpotlightWorkspace,
    verts: Vec<VertexId>,
}

impl FixedRadiusTl {
    fn new(radius_m: f64, cameras: &[Camera]) -> Self {
        let mut cam_at: Vec<(usize, Vec<usize>)> = Vec::new();
        for c in cameras {
            match cam_at.iter_mut().find(|(v, _)| *v == c.vertex) {
                Some((_, ids)) => ids.push(c.id),
                None => cam_at.push((c.vertex, vec![c.id])),
            }
        }
        Self {
            radius_m,
            num_cameras: cameras.len(),
            cam_at,
            cam_vertex: cameras.iter().map(|c| c.vertex).collect(),
            last_seen: None,
            ws: SpotlightWorkspace::new(),
            verts: Vec::new(),
        }
    }
}

impl TrackingLogic for FixedRadiusTl {
    fn on_detection(
        &mut self,
        camera: usize,
        captured: Micros,
        detected: bool,
    ) {
        if detected {
            match self.last_seen {
                Some((_, t)) if captured < t => {}
                _ => {
                    self.last_seen =
                        Some((self.cam_vertex[camera], captured))
                }
            }
        }
    }

    fn active_set_into(
        &mut self,
        g: &Graph,
        _now: Micros,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        let Some((vertex, _)) = self.last_seen else {
            out.extend(0..self.num_cameras); // bootstrap all-active
            return;
        };
        let mut verts = std::mem::take(&mut self.verts);
        wbfs_spotlight_into(
            g,
            vertex,
            self.radius_m,
            &mut self.ws,
            &mut verts,
        );
        for v in &verts {
            if let Some((_, ids)) =
                self.cam_at.iter().find(|(cv, _)| cv == v)
            {
                out.extend_from_slice(ids);
            }
        }
        self.verts = verts;
        out.sort_unstable();
        out.dedup();
    }

    fn last_seen(&self) -> Option<(usize, Micros)> {
        self.last_seen
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // Compose the app from the two custom blocks plus stock VA/CR.
    let app = AppBuilder::new("custom-duty-cycle")
        .describe(
            "Duty-cycled FC + fixed-radius spotlight, stock detector \
             and re-id — built on the public block API only.",
        )
        .filter_control(DutyCycleFc { stride: 2 })
        .video_analytics(SimDetector::new(ModelVariant::Va))
        .contention_resolver(SimReid::small())
        .tracking_logic_with(|env: &TlEnv<'_>| {
            Box::new(FixedRadiusTl::new(300.0, env.cameras))
        })
        .build();

    let mut cfg = ExperimentConfig::default();
    cfg.name = "custom-app".into();
    if smoke {
        cfg.num_cameras = 60;
        cfg.workload.vertices = 60;
        cfg.workload.edges = 160;
        cfg.duration_secs = 30.0;
    } else {
        cfg.num_cameras = 200;
        cfg.workload.vertices = 200;
        cfg.workload.edges = 560;
        cfg.duration_secs = 120.0;
    }
    cfg.batching = BatchingKind::Dynamic { max: 25 };
    app.apply(&mut cfg, true);

    let r = des::run_app(cfg, &app);
    let s = &r.summary;
    println!("app                      : {}", app.name);
    println!("frames into the dataflow : {}", s.generated);
    println!(
        "on-time / delayed / drop : {} / {} / {}",
        s.on_time, s.delayed, s.dropped
    );
    println!("entity detections at UV  : {}", r.detections);
    println!("peak active cameras      : {}", r.peak_active);

    assert!(s.conserved(), "event conservation: {s:?}");
    assert!(s.generated > 0, "the duty-cycled FC still admits frames");
    assert!(
        r.detections > 0,
        "the fixed-radius spotlight must keep the entity acquirable"
    );
    println!("OK: custom blocks ran through the stock platform.");
}
