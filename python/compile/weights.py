"""Deterministic model weights for the Anveshak-RS analytics variants.

The reproduction has no training loop (the paper uses off-the-shelf
pretrained HoG / re-id models); instead each variant gets seeded random
projection weights.  Because the synthetic frames are generated as an
identity embedding broadcast across patches plus noise (see
``rust/src/sim/images.rs`` and :func:`make_identity_image`), a shared
random projection maps same-identity frames to nearby embeddings and
different identities far apart — giving the same TP/FP behaviour the
CUHK03 labels provided, with controllable margins.

Weights are exported to ``artifacts/weights.bin`` (little-endian f32,
concatenated in manifest order) and passed to the HLO executables as
runtime parameters, keeping the HLO text small and the weight data in one
binary blob the Rust runtime uploads once.
"""

import numpy as np

# Model geometry — mirrored in rust/src/runtime/manifest.rs via manifest.json.
IMG_PATCHES = 64  # P: patches per frame
PATCH_SIZE = 128  # S: pixels per patch
IMG_DIM = IMG_PATCHES * PATCH_SIZE  # flattened frame length (= 8192)
FEAT_DIM = 128  # re-id embedding dimension

SEED = 42

# Hidden widths per variant.  cr_large carries one extra 512-wide layer —
# the paper's App 2 CR is ~63% slower per frame than App 1's (§5.3).
VA_DIMS = [IMG_PATCHES, 128, FEAT_DIM]
CR_SMALL_DIMS = [IMG_PATCHES, 256, 256, FEAT_DIM]
CR_LARGE_DIMS = [IMG_PATCHES, 512, 512, 512, FEAT_DIM]


def _mlp_weights(rng, prefix, dims):
    """Xavier-scaled dense stack; biases only on hidden (tanh) layers."""
    out = []
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        w = (rng.randn(din, dout) * np.sqrt(1.0 / din)).astype(np.float32)
        out.append((f"{prefix}/W{i}", w))
        if i < len(dims) - 2:  # hidden layer bias
            out.append((f"{prefix}/b{i}", np.zeros(dout, np.float32)))
    return out


def get_weights(variant: str):
    """Ordered ``[(name, array)]`` for a model variant.

    Order is the parameter order of the lowered HLO after
    ``(images, query)`` and must stay in sync with ``model.py``.
    """
    rng = np.random.RandomState(SEED)
    # Draw in a fixed global order so each variant's weights are stable
    # regardless of which variants are exported.
    all_w = {
        "va": _mlp_weights(rng, "va", VA_DIMS),
        "cr_small": _mlp_weights(rng, "cr_small", CR_SMALL_DIMS),
        "cr_large": _mlp_weights(rng, "cr_large", CR_LARGE_DIMS),
        "qf": [],  # query fusion has no trainable parameters
    }
    if variant not in all_w:
        raise KeyError(f"unknown variant {variant!r}")
    return all_w[variant]


def make_identity_embedding(identity: int) -> np.ndarray:
    """Unit-norm P-dim identity code; deterministic per identity id."""
    rng = np.random.RandomState(0xC0FFEE ^ identity)
    e = rng.randn(IMG_PATCHES).astype(np.float32)
    return e / np.linalg.norm(e)


def make_identity_image(identity: int, frame: int, noise: float = 0.25):
    """Synthetic CUHK03 substitute: identity code broadcast across patches
    plus per-frame Gaussian noise.  ``patch_pool`` recovers ~the code."""
    e = make_identity_embedding(identity)
    rng = np.random.RandomState((identity * 1_000_003 + frame) & 0x7FFFFFFF)
    img = np.repeat(e, PATCH_SIZE) + noise * rng.randn(IMG_DIM).astype(
        np.float32
    )
    return img.astype(np.float32)
