"""Layer-1 Pallas kernels for the Anveshak-RS analytics models.

All kernels are authored TPU-idiomatically (MXU-shaped tiles, VMEM-sized
blocks expressed through BlockSpec) but lowered with ``interpret=True`` so
the resulting HLO runs on any PJRT backend, including the Rust CPU client
on the request path.  Correctness oracles live in :mod:`.ref`.
"""

from .matmul import matmul
from .cosine_sim import cosine_sim
from .patch_pool import patch_pool

__all__ = ["matmul", "cosine_sim", "patch_pool"]
