"""Fused batched cosine-similarity Pallas kernel.

Computes ``sim[b] = <f_b, q> / (|f_b| * |q|)`` for a gallery of feature
rows against a single query feature.  Normalisation and the dot product
are fused in one VMEM-resident pass so the normalised gallery never takes
an HBM round-trip — the paper's CR stage evaluates exactly this
query-vs-candidates match on every batch, making it a request-path
hot-spot.

Block layout: a ``(bb, D)`` tile of the gallery plus the ``(1, D)`` query
(replicated across the grid via a constant index map).  At the default
``bb=8, D=128`` that is < 5 KiB of VMEM per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cosine_sim"]

_EPS = 1e-6


def _cosine_kernel(f_ref, q_ref, o_ref):
    f = f_ref[...]
    q = q_ref[...]
    fn = jnp.sqrt(jnp.sum(f * f, axis=1, keepdims=True)) + _EPS
    qn = jnp.sqrt(jnp.sum(q * q, axis=1, keepdims=True)) + _EPS
    o_ref[...] = (f @ q.T) / (fn * qn)


@functools.partial(jax.named_call, name="pallas_cosine_sim")
def cosine_sim(feats, query, *, bb: int = 8):
    """Cosine similarity of each row of ``feats`` against ``query``.

    Args:
      feats: ``(B, D)`` float32 gallery features.
      query: ``(D,)`` float32 query feature.
      bb: batch tile size.

    Returns:
      ``(B,)`` float32 similarities in ``[-1, 1]``.
    """
    B, D = feats.shape
    if query.shape != (D,):
        raise ValueError(f"query shape {query.shape} != ({D},)")
    pb = (-B) % bb
    fp = jnp.pad(feats, ((0, pb), (0, 0)))
    out = pl.pallas_call(
        _cosine_kernel,
        grid=((B + pb) // bb,),
        in_specs=[
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pb, 1), jnp.float32),
        interpret=True,
    )(fp, query.reshape(1, D))
    return out[:B, 0]
