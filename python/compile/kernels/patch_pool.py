"""Patch mean-pool Pallas kernel.

First stage of the VA/CR feature extractors: a flattened frame of
``P * S`` pixels is reduced to a ``P``-dim patch-mean vector.  The
BlockSpec expresses the HBM -> VMEM schedule: a ``(bb, P*S)`` strip of
frames is staged in, reduced along the patch axis, and the ``(bb, P)``
result written back — the same role the paper's HoG/stem convolution
plays before the dense re-id layers.

VMEM per step at ``bb=4, P=64, S=128``: 4 * 8192 * 4 B = 128 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["patch_pool"]


def _pool_kernel(x_ref, o_ref, *, P: int, S: int):
    x = x_ref[...]
    o_ref[...] = x.reshape(x.shape[0], P, S).mean(axis=2)


@functools.partial(jax.named_call, name="pallas_patch_pool")
def patch_pool(x, P: int, *, bb: int = 4):
    """Mean over ``S = D/P`` contiguous pixels per patch.

    Args:
      x: ``(B, D)`` float32 flattened frames, ``D`` divisible by ``P``.
      P: number of patches.
      bb: batch tile size.

    Returns:
      ``(B, P)`` float32 patch means.
    """
    B, D = x.shape
    if D % P != 0:
        raise ValueError(f"pixel dim {D} not divisible by P={P}")
    S = D // P
    pb = (-B) % bb
    xp = jnp.pad(x, ((0, pb), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_pool_kernel, P=P, S=S),
        grid=((B + pb) // bb,),
        in_specs=[pl.BlockSpec((bb, D), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, P), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pb, P), jnp.float32),
        interpret=True,
    )(xp)
    return out[:B]
