"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

These are deliberately written in the most obvious way possible; pytest
asserts the Pallas kernels match them via ``assert_allclose`` across
hypothesis-driven shape sweeps.
"""

import jax.numpy as jnp

_EPS = 1e-6


def matmul_ref(x, w):
    """(M,K) @ (K,N) -> (M,N)."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def cosine_sim_ref(feats, query):
    """(B,D),(D,) -> (B,) cosine similarity with the kernel's epsilon."""
    fn = jnp.sqrt(jnp.sum(feats * feats, axis=1)) + _EPS
    qn = jnp.sqrt(jnp.sum(query * query)) + _EPS
    return feats @ query / (fn * qn)


def patch_pool_ref(x, P):
    """(B, P*S) -> (B, P) patch means."""
    B, D = x.shape
    return x.reshape(B, P, D // P).mean(axis=2)
