"""Tiled matrix-multiply Pallas kernel.

The hot loop of every analytics model (VA feature projection, CR re-id
MLPs) is a dense ``x @ w``.  On a real TPU this kernel would keep one
``(bm, bk)`` tile of ``x`` and one ``(bk, bn)`` tile of ``w`` resident in
VMEM and drive the 128x128 MXU systolic array; the K axis is the innermost
grid dimension so the output tile is revisited and accumulated in place
(the index map for the output block is independent of ``k``, which Pallas
treats as an "arbitrary"/accumulation dimension).

VMEM footprint per step at the default (8, 128, 128) blocking:
``bm*bk + bk*bn + bm*bn`` f32 = (1024 + 16384 + 1024) * 4 B = 72 KiB,
far under the ~16 MiB VMEM budget; at the MXU-square (128, 128, 128)
blocking it is 192 KiB.  ``interpret=True`` keeps the lowering executable
on the CPU PJRT plugin (real-TPU lowering emits a Mosaic custom-call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["matmul"]


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Grid = (M/bm, N/bn, K/bk); accumulate partial products into o_ref."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.named_call, name="pallas_matmul")
def matmul(x, w, *, bm: int = 8, bk: int = 128, bn: int = 128):
    """``x @ w`` via the tiled Pallas kernel.

    Inputs of arbitrary (M, K) x (K, N) shape are zero-padded up to the
    block grid and the result is sliced back, so callers never need to
    think about tile alignment.

    Args:
      x: ``(M, K)`` float32 activations.
      w: ``(K, N)`` float32 weights.
      bm/bk/bn: block sizes; defaults favour small serving batches
        (``bm=8``) with MXU-width ``bk = bn = 128``.

    Returns:
      ``(M, N)`` float32 product.
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"matmul inner dims mismatch: {K} vs {K2}")
    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn
    nk = Kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:M, :N]
