"""AOT export: lower every (variant, batch-bucket) model to HLO text.

The interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Because HLO is static-shape, one executable is exported per *batch
bucket*; the Rust dynamic batcher pads a formed batch up to the nearest
bucket.  Weights are exported once to ``weights.bin`` and passed as
runtime parameters (keeps HLO text small, single upload on the Rust side).

Usage::

    python -m compile.aot --out ../artifacts   # from python/
"""

import argparse
import hashlib
import json
import pathlib

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from . import weights as W

BUCKETS = [1, 2, 4, 8, 16, 25, 32]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple*)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_variant(name, fn, wts, out_dir, buckets):
    """Lower ``fn(images, query, *weights)`` for every bucket."""
    files = {}
    w_specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in wts]
    for b in buckets:
        img = jax.ShapeDtypeStruct((b, W.IMG_DIM), np.float32)
        q = jax.ShapeDtypeStruct((W.FEAT_DIM,), np.float32)
        lowered = jax.jit(fn).lower(img, q, *w_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}_b{b}.hlo.txt"
        (out_dir / fname).write_text(text)
        files[str(b)] = fname
    return files


def export_qf(out_dir, buckets):
    files = {}
    for b in buckets:
        q = jax.ShapeDtypeStruct((W.FEAT_DIM,), np.float32)
        e = jax.ShapeDtypeStruct((b, W.FEAT_DIM), np.float32)
        c = jax.ShapeDtypeStruct((b,), np.float32)
        lowered = jax.jit(model.qf_fuse).lower(q, e, c)
        fname = f"qf_b{b}.hlo.txt"
        (out_dir / fname).write_text(to_hlo_text(lowered))
        files[str(b)] = fname
    return files


def export_weights(out_dir):
    """Concatenate all variant weights into weights.bin + manifest entries."""
    entries = []
    blobs = []
    offset = 0
    for variant in ("va", "cr_small", "cr_large"):
        for name, arr in W.get_weights(variant):
            flat = np.ascontiguousarray(arr, np.float32)
            entries.append(
                {
                    "name": name,
                    "variant": variant,
                    "shape": list(arr.shape),
                    "offset": offset,
                    "len": int(flat.size),
                }
            )
            blobs.append(flat.tobytes())
            offset += flat.size
    blob = b"".join(blobs)
    (out_dir / "weights.bin").write_bytes(blob)
    return entries, hashlib.sha256(blob).hexdigest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--buckets", default=",".join(map(str, BUCKETS)),
        help="comma-separated batch buckets",
    )
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    buckets = [int(b) for b in args.buckets.split(",")]

    manifest = {
        "img_dim": W.IMG_DIM,
        "img_patches": W.IMG_PATCHES,
        "patch_size": W.PATCH_SIZE,
        "feat_dim": W.FEAT_DIM,
        "buckets": buckets,
        "variants": {},
    }

    for name, (fn, _dims) in model.VARIANTS.items():
        wts = W.get_weights(name)
        files = export_variant(name, fn, wts, out_dir, buckets)
        manifest["variants"][name] = {
            "files": files,
            "weights": [n for n, _ in wts],
            "params": ["images", "query"] + [n for n, _ in wts],
            "outputs": ["scores", "embeddings"],
        }
        print(f"exported {name}: {len(files)} buckets")

    manifest["variants"]["qf"] = {
        "files": export_qf(out_dir, buckets),
        "weights": [],
        "params": ["query", "embeddings", "confidences"],
        "outputs": ["fused_query"],
    }
    print("exported qf")

    entries, digest = export_weights(out_dir)
    manifest["weights"] = {
        "file": "weights.bin",
        "sha256": digest,
        "entries": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote manifest + weights.bin ({len(entries)} tensors) to {out_dir}")


if __name__ == "__main__":
    main()
