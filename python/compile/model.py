"""Layer-2 JAX analytics models for Anveshak-RS, built on the L1 kernels.

Each function is the compute graph of one dataflow module from the paper
(Table 1), expressed over the Pallas kernels so the hot loops lower into
the same HLO module:

* :func:`va_features`   — VA stage (HoG-substitute): patch-pool stem +
  2-layer projection + query match score.
* :func:`cr_reid_small` — CR stage, App 1 (OpenReid substitute).
* :func:`cr_reid_large` — CR stage, App 2 (Ahmed et al. substitute,
  ~63% more per-frame compute via an extra 512-wide layer).
* :func:`qf_fuse`       — QF stage: confidence-gated query fusion
  (RNN-fusion substitute, [42] in the paper).

Every model takes ``(images, query_emb, *weights)`` and returns
``(scores, embeddings)``; passing ``query_emb = 0`` turns the score head
off, which is how the Rust runtime bootstraps the query embedding from the
query *image* using the same executable (no separate embed artifact).

``*_ref`` twins are pure-jnp oracles over :mod:`.kernels.ref` used by
pytest to validate the full Pallas compositions.
"""

import jax.numpy as jnp

from .kernels import cosine_sim, matmul, patch_pool
from .kernels import ref
from . import weights as W


def _mlp(z, wts, dims, mm):
    """Dense stack matching weights._mlp_weights layout."""
    i = 0
    n_layers = len(dims) - 1
    for layer in range(n_layers):
        w = wts[i]
        i += 1
        z = mm(z, w)
        if layer < n_layers - 1:
            b = wts[i]
            i += 1
            # tanh keeps hidden features zero-centred, so embeddings of
            # unrelated identities stay near-orthogonal (a ReLU stack
            # pushes every embedding into the positive orthant and
            # inflates negative-pair cosine scores).
            z = jnp.tanh(z + b)
    assert i == len(wts), f"consumed {i} of {len(wts)} weights"
    return z


def _model(images, query_emb, wts, dims, *, mm, pool, cos):
    z = pool(images, W.IMG_PATCHES)
    emb = _mlp(z, wts, dims, mm)
    scores = cos(emb, query_emb)
    return scores, emb


def va_features(images, query_emb, *wts):
    """VA: (B, IMG_DIM), (FEAT_DIM,) -> ((B,), (B, FEAT_DIM))."""
    return _model(
        images, query_emb, wts, W.VA_DIMS,
        mm=matmul, pool=patch_pool, cos=cosine_sim,
    )


def cr_reid_small(images, query_emb, *wts):
    """CR App 1: deeper re-id head over the same stem."""
    return _model(
        images, query_emb, wts, W.CR_SMALL_DIMS,
        mm=matmul, pool=patch_pool, cos=cosine_sim,
    )


def cr_reid_large(images, query_emb, *wts):
    """CR App 2: widest head; ~1.6x the per-frame compute of cr_small."""
    return _model(
        images, query_emb, wts, W.CR_LARGE_DIMS,
        mm=matmul, pool=patch_pool, cos=cosine_sim,
    )


def qf_fuse(query_emb, embs, confs):
    """Confidence-gated query fusion.

    High-confidence detections pull the query embedding toward their
    mean; the gate ``sigmoid(8 * (conf - 0.5))`` suppresses low-confidence
    evidence.  Output is re-normalised to unit length.
    """
    gate = 1.0 / (1.0 + jnp.exp(-8.0 * (confs - 0.5)))  # (B,)
    delta = jnp.sum(gate[:, None] * (embs - query_emb), axis=0)
    # Normalise by batch size (not sum(gate)): a batch of low-confidence
    # detections must barely move the query, not be re-amplified.
    delta = delta / confs.shape[0]
    fused = query_emb + 0.3 * delta
    return (fused / (jnp.linalg.norm(fused) + 1e-6),)


# ---------------------------------------------------------------------------
# Pure-jnp reference twins (oracle path, no Pallas).
# ---------------------------------------------------------------------------

def _pool_ref(x, P):
    return ref.patch_pool_ref(x, P)


def va_features_ref(images, query_emb, *wts):
    return _model(
        images, query_emb, wts, W.VA_DIMS,
        mm=ref.matmul_ref, pool=_pool_ref, cos=ref.cosine_sim_ref,
    )


def cr_reid_small_ref(images, query_emb, *wts):
    return _model(
        images, query_emb, wts, W.CR_SMALL_DIMS,
        mm=ref.matmul_ref, pool=_pool_ref, cos=ref.cosine_sim_ref,
    )


def cr_reid_large_ref(images, query_emb, *wts):
    return _model(
        images, query_emb, wts, W.CR_LARGE_DIMS,
        mm=ref.matmul_ref, pool=_pool_ref, cos=ref.cosine_sim_ref,
    )


VARIANTS = {
    "va": (va_features, W.VA_DIMS),
    "cr_small": (cr_reid_small, W.CR_SMALL_DIMS),
    "cr_large": (cr_reid_large, W.CR_LARGE_DIMS),
}

REF_VARIANTS = {
    "va": va_features_ref,
    "cr_small": cr_reid_small_ref,
    "cr_large": cr_reid_large_ref,
}
