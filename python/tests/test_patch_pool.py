"""Pallas patch-pool kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import patch_pool
from compile.kernels.ref import patch_pool_ref


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 33),
    p=st.sampled_from([1, 4, 16, 64]),
    s=st.sampled_from([1, 8, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pool_matches_ref(b, p, s, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, p * s)), jnp.float32)
    assert_allclose(patch_pool(x, p), patch_pool_ref(x, p),
                    rtol=1e-5, atol=1e-6)


def test_pool_constant_patches():
    # patch p filled with value p -> mean is exactly p
    P, S = 8, 16
    x = jnp.repeat(jnp.arange(P, dtype=jnp.float32), S)[None, :]
    out = np.asarray(patch_pool(x, P))
    assert_allclose(out[0], np.arange(P, dtype=np.float32), atol=0)


def test_pool_indivisible_raises():
    with pytest.raises(ValueError, match="not divisible"):
        patch_pool(jnp.zeros((1, 10), jnp.float32), 3)


def test_pool_single_patch_is_row_mean():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    assert_allclose(np.asarray(patch_pool(x, 1))[:, 0],
                    np.asarray(x).mean(axis=1), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bb", [1, 2, 4, 16])
def test_pool_tile_sizes(bb):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((9, 256)), jnp.float32)
    assert_allclose(patch_pool(x, 16, bb=bb), patch_pool_ref(x, 16),
                    rtol=1e-5, atol=1e-6)
