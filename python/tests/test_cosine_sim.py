"""Pallas cosine-similarity kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import cosine_sim
from compile.kernels.ref import cosine_sim_ref


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 40),
    d=st.integers(1, 256),
    seed=st.integers(0, 2**31 - 1),
)
def test_cosine_matches_ref(b, d, seed):
    rng = np.random.default_rng(seed)
    f, q = _rand(rng, b, d), _rand(rng, d)
    assert_allclose(cosine_sim(f, q), cosine_sim_ref(f, q),
                    rtol=1e-4, atol=1e-5)


def test_cosine_self_similarity_is_one():
    rng = np.random.default_rng(0)
    q = _rand(rng, 128)
    f = jnp.stack([q, 2.0 * q, -q])
    out = np.asarray(cosine_sim(f, q))
    assert_allclose(out[:2], [1.0, 1.0], atol=1e-3)
    assert_allclose(out[2], -1.0, atol=1e-3)


def test_cosine_orthogonal_is_zero():
    f = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
    q = jnp.asarray([0.0, 1.0], jnp.float32)
    out = np.asarray(cosine_sim(f, q))
    assert abs(out[0]) < 1e-5 and abs(out[1] - 1.0) < 1e-3


def test_cosine_bounded():
    rng = np.random.default_rng(7)
    out = np.asarray(cosine_sim(_rand(rng, 33, 64), _rand(rng, 64)))
    assert np.all(out <= 1.0 + 1e-5) and np.all(out >= -1.0 - 1e-5)


def test_cosine_zero_vectors_safe():
    f = jnp.zeros((3, 16), jnp.float32)
    q = jnp.zeros(16, jnp.float32)
    out = np.asarray(cosine_sim(f, q))
    assert np.all(np.isfinite(out)) and assert_allclose(out, 0.0, atol=1e-6) is None


def test_cosine_query_shape_mismatch_raises():
    with pytest.raises(ValueError, match="query shape"):
        cosine_sim(jnp.zeros((2, 8), jnp.float32), jnp.zeros(9, jnp.float32))


@pytest.mark.parametrize("bb", [1, 2, 8, 16])
def test_cosine_tile_sizes(bb):
    rng = np.random.default_rng(9)
    f, q = _rand(rng, 11, 32), _rand(rng, 32)
    assert_allclose(cosine_sim(f, q, bb=bb), cosine_sim_ref(f, q),
                    rtol=1e-4, atol=1e-5)
