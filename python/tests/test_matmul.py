"""Pallas matmul kernel vs pure-jnp oracle (hypothesis shape sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import matmul
from compile.kernels.ref import matmul_ref


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 300),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(1, 128, 128), (8, 128, 128),
                                   (25, 64, 512), (32, 512, 128),
                                   (3, 1, 1), (1, 1, 1), (128, 128, 128)])
def test_matmul_block_boundaries(m, k, n):
    rng = np.random.default_rng(0)
    x, w = _rand(rng, m, k), _rand(rng, k, n)
    assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bk,bn", [(4, 32, 32), (8, 128, 128),
                                      (16, 64, 256), (1, 256, 8)])
def test_matmul_custom_blocking(bm, bk, bn):
    rng = np.random.default_rng(1)
    x, w = _rand(rng, 13, 200), _rand(rng, 200, 70)
    out = matmul(x, w, bm=bm, bk=bk, bn=bn)
    assert_allclose(out, matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    eye = jnp.eye(64, dtype=jnp.float32)
    x = _rand(np.random.default_rng(2), 7, 64)
    assert_allclose(matmul(x, eye), x, rtol=1e-5, atol=1e-5)


def test_matmul_zeros():
    x = jnp.zeros((5, 33), jnp.float32)
    w = jnp.zeros((33, 9), jnp.float32)
    assert_allclose(matmul(x, w), jnp.zeros((5, 9)), atol=0)


def test_matmul_shape_mismatch_raises():
    x = jnp.zeros((4, 8), jnp.float32)
    w = jnp.zeros((9, 4), jnp.float32)
    with pytest.raises(ValueError, match="inner dims"):
        matmul(x, w)


def test_matmul_result_dtype_f32():
    rng = np.random.default_rng(3)
    out = matmul(_rand(rng, 2, 2), _rand(rng, 2, 2))
    assert out.dtype == jnp.float32
