"""AOT export tests: manifest integrity, weights.bin layout, HLO text
well-formedness (parseable header, expected parameter count)."""

import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from compile import weights as W

ROOT = pathlib.Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--buckets", "1,4"],
        cwd=ROOT, check=True, capture_output=True, text=True,
    )
    return out


def test_manifest_written(export_dir):
    man = json.loads((export_dir / "manifest.json").read_text())
    assert man["img_dim"] == W.IMG_DIM
    assert man["feat_dim"] == W.FEAT_DIM
    assert man["buckets"] == [1, 4]
    assert set(man["variants"]) == {"va", "cr_small", "cr_large", "qf"}


def test_all_hlo_files_exist_and_parse_header(export_dir):
    man = json.loads((export_dir / "manifest.json").read_text())
    for v, spec in man["variants"].items():
        for b, fname in spec["files"].items():
            text = (export_dir / fname).read_text()
            assert text.startswith("HloModule"), f"{v} b{b} bad header"
            assert "ENTRY" in text


def test_weights_bin_layout(export_dir):
    man = json.loads((export_dir / "manifest.json").read_text())
    blob = np.fromfile(export_dir / "weights.bin", dtype=np.float32)
    total = sum(e["len"] for e in man["weights"]["entries"])
    assert blob.size == total
    # Each entry round-trips to the generator's array.
    for e in man["weights"]["entries"]:
        arr = blob[e["offset"]:e["offset"] + e["len"]].reshape(e["shape"])
        src = dict(W.get_weights(e["variant"]))[e["name"]]
        np.testing.assert_allclose(arr, src, atol=0)


def test_weight_order_matches_params(export_dir):
    man = json.loads((export_dir / "manifest.json").read_text())
    for v in ("va", "cr_small", "cr_large"):
        spec = man["variants"][v]
        assert spec["params"][:2] == ["images", "query"]
        assert spec["params"][2:] == spec["weights"]
        assert [n for n, _ in W.get_weights(v)] == spec["weights"]


def test_batch_bucket_shapes_in_hlo(export_dir):
    man = json.loads((export_dir / "manifest.json").read_text())
    text = (export_dir / man["variants"]["va"]["files"]["4"]).read_text()
    assert f"f32[4,{W.IMG_DIM}]" in text  # images param at bucket 4
