"""L2 model tests: Pallas composition vs pure-jnp oracle, shape contracts,
and the identity-separation property the whole tracking pipeline rests on."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile import weights as W


def _wts(variant):
    return [jnp.asarray(a) for _, a in W.get_weights(variant)]


def _imgs(identities, frames0=0):
    return jnp.stack([
        jnp.asarray(W.make_identity_image(i, frames0 + k))
        for k, i in enumerate(identities)
    ])


@pytest.mark.parametrize("variant", ["va", "cr_small", "cr_large"])
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_model_shapes(variant, batch):
    fn, _ = model.VARIANTS[variant]
    imgs = _imgs([7] * batch)
    q = jnp.zeros(W.FEAT_DIM, jnp.float32)
    scores, embs = fn(imgs, q, *_wts(variant))
    assert scores.shape == (batch,)
    assert embs.shape == (batch, W.FEAT_DIM)
    assert scores.dtype == jnp.float32 and embs.dtype == jnp.float32


@pytest.mark.parametrize("variant", ["va", "cr_small", "cr_large"])
def test_model_matches_ref(variant):
    fn, _ = model.VARIANTS[variant]
    ref_fn = model.REF_VARIANTS[variant]
    imgs = _imgs([1, 2, 3, 1])
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal(W.FEAT_DIM), jnp.float32)
    wts = _wts(variant)
    s1, e1 = fn(imgs, q, *wts)
    s2, e2 = ref_fn(imgs, q, *wts)
    assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-4)
    assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("variant", ["va", "cr_small", "cr_large"])
def test_identity_separation(variant):
    """Same-identity frames must score well above different-identity ones
    against a query embedding bootstrapped from the query image."""
    fn, _ = model.VARIANTS[variant]
    wts = _wts(variant)
    zero_q = jnp.zeros(W.FEAT_DIM, jnp.float32)
    # Bootstrap query embedding exactly as the Rust runtime does.
    _, q_emb = fn(_imgs([42]), zero_q, *wts)
    q_emb = q_emb[0]

    pos = _imgs([42, 42, 42, 42], frames0=10)
    neg = _imgs([7, 99, 13, 55], frames0=10)
    pos_scores, _ = fn(pos, q_emb, *wts)
    neg_scores, _ = fn(neg, q_emb, *wts)
    assert float(jnp.min(pos_scores)) > 0.7, np.asarray(pos_scores)
    assert float(jnp.max(neg_scores)) < 0.5, np.asarray(neg_scores)


def test_score_head_off_with_zero_query():
    fn, _ = model.VARIANTS["va"]
    scores, _ = fn(_imgs([1, 2]), jnp.zeros(W.FEAT_DIM, jnp.float32),
                   *_wts("va"))
    assert_allclose(np.asarray(scores), 0.0, atol=1e-5)


def test_qf_fuse_moves_toward_confident_embeddings():
    rng = np.random.default_rng(1)
    q = rng.standard_normal(W.FEAT_DIM).astype(np.float32)
    q /= np.linalg.norm(q)
    target = rng.standard_normal(W.FEAT_DIM).astype(np.float32)
    target /= np.linalg.norm(target)
    embs = jnp.asarray(np.stack([target] * 4))
    high = jnp.asarray([0.95, 0.9, 0.99, 0.92], jnp.float32)
    low = jnp.asarray([0.05, 0.1, 0.02, 0.08], jnp.float32)
    (fused_hi,) = model.qf_fuse(jnp.asarray(q), embs, high)
    (fused_lo,) = model.qf_fuse(jnp.asarray(q), embs, low)
    d0 = float(np.asarray(target) @ q)
    d_hi = float(np.asarray(fused_hi) @ np.asarray(target))
    d_lo = float(np.asarray(fused_lo) @ np.asarray(target))
    assert d_hi > d0 + 0.05      # confident evidence pulls query to target
    assert abs(d_lo - d0) < 0.05  # low-confidence evidence barely moves it


def test_qf_fuse_output_unit_norm():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal(W.FEAT_DIM), jnp.float32)
    embs = jnp.asarray(rng.standard_normal((5, W.FEAT_DIM)), jnp.float32)
    confs = jnp.asarray(rng.uniform(0, 1, 5), jnp.float32)
    (fused,) = model.qf_fuse(q, embs, confs)
    assert abs(float(jnp.linalg.norm(fused)) - 1.0) < 1e-3


def test_cr_large_has_more_flops_than_cr_small():
    """App 2's CR must carry more per-frame compute (paper: ~63% more)."""
    def flops(dims):
        return sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    assert flops(W.CR_LARGE_DIMS) > 1.4 * flops(W.CR_SMALL_DIMS)


def test_identity_embedding_deterministic_and_unit():
    e1 = W.make_identity_embedding(5)
    e2 = W.make_identity_embedding(5)
    e3 = W.make_identity_embedding(6)
    assert_allclose(e1, e2, atol=0)
    assert abs(np.linalg.norm(e1) - 1.0) < 1e-5
    assert abs(float(e1 @ e3)) < 0.5
