//! Property suite for the sharded DES merge (`engine::sharded`).
//!
//! The contract under test, end to end:
//!
//! * **K=1 bit-identity** — a [`ShardedDes`] with one shard produces
//!   exactly the single-[`EventCore`] pop stream, and a full
//!   `coordinator::des::run` at `shards = 1` is the unsharded engine.
//! * **K-invariance** — for *any* generated shard plan (K ∈ [1, 8],
//!   inline or threaded backend, degenerate single-vertex shards),
//!   every user-visible output of both engines — `Summary`, per-query
//!   `QueryLedgers` rows, `fusion_updates`, detections, dispatch count
//!   and RNG draws — is identical to the K=1 run of the same seed.
//!   Routing only decides which heap holds an event; the merge
//!   serialises dispatch in global `(time, seq)` order.
//! * **Merge determinism** — the merged stream does not depend on
//!   shard assignment, backend, or the order in which shards complete
//!   their pops (threaded workers answer in nondeterministic wall
//!   order; virtual order must not notice).
//! * **Shard-crash conservation** — under generated fault schedules
//!   (dead shard = node crash) with cross-shard orphan migration, the
//!   event ledger still conserves:
//!   `generated = on_time + delayed + dropped + lost_to_fault +
//!   in_flight`.
//!
//! Failures shrink toward the canonical unsharded plan
//! (`{shards: 1, threads: 0}`) and persist `seed case` pairs in
//! `rust/tests/regressions/shard.seeds`.

use anveshak::check::domain::{
    arrival_order, fault_schedule, shard_plan, ShardPlan,
};
use anveshak::check::runner::regression_seeds;
use anveshak::check::{check, generate_case, CheckConfig};
use anveshak::config::{BatchingKind, ExperimentConfig, TlKind};
use anveshak::coordinator::des;
use anveshak::engine::{EventCore, ShardedDes};
use anveshak::service::engine as mq_engine;
use anveshak::util::Micros;

// ---------------------------------------------------------------------------
// Raw merge properties (no simulation on top).
// ---------------------------------------------------------------------------

/// Drain a sharded core to exhaustion.
fn drain(d: &mut ShardedDes<u32>) -> Vec<(Micros, u32)> {
    let mut out = Vec::new();
    while let Some(p) = d.pop_until(Micros::MAX) {
        out.push(p);
    }
    out
}

#[test]
fn prop_merge_matches_single_core_for_any_shard_assignment() {
    // For an arbitrary arrival order, the merged stream of every
    // (K, backend, shard-assignment) combination equals the single
    // EventCore's stream — including events scheduled mid-drain, which
    // is where cross-shard envelopes appear. This is the K=1
    // bit-identity *and* merge-determinism-under-reordered-completion
    // property at the engine level: threaded workers complete pops in
    // arbitrary wall order, shard assignment is permuted per case, and
    // the virtual-time order must never notice.
    let n = 24usize;
    check(
        "shard_merge",
        &CheckConfig::with_cases(48),
        &arrival_order(n),
        |order| {
            let run_reference = || {
                let mut single = EventCore::new();
                for (i, &x) in order.iter().enumerate() {
                    single.schedule(x as Micros * 10, i as u32);
                }
                let mut out = Vec::new();
                // Mid-drain schedules: pop half, inject a second wave
                // (times interleave with the first), drain the rest.
                for _ in 0..n / 2 {
                    out.extend(single.pop_until(Micros::MAX));
                }
                for (i, &x) in order.iter().enumerate() {
                    single
                        .schedule(x as Micros * 10 + 5, (n + i) as u32);
                }
                while let Some(p) = single.pop_until(Micros::MAX) {
                    out.push(p);
                }
                out
            };
            let want = run_reference();

            for k in [1usize, 2, 4, 8] {
                for threads in [0, k] {
                    // Two distinct shard assignments per combination:
                    // round-robin by schedule index, and one salted by
                    // the permutation itself.
                    for salt in [0usize, 1] {
                        let assign = |i: usize| {
                            ((i + salt * order[i % n]) % k) as u32
                        };
                        let mut d =
                            ShardedDes::with_threads(k, threads);
                        for (i, &x) in order.iter().enumerate() {
                            d.schedule(
                                x as Micros * 10,
                                assign(i),
                                i as u32,
                            );
                        }
                        let mut got = Vec::new();
                        for _ in 0..n / 2 {
                            got.extend(d.pop_until(Micros::MAX));
                        }
                        for (i, &x) in order.iter().enumerate() {
                            d.schedule(
                                x as Micros * 10 + 5,
                                assign(n + i),
                                (n + i) as u32,
                            );
                        }
                        got.extend(drain(&mut d));
                        if got != want {
                            return Err(format!(
                                "merge diverged at k={k} \
                                 threads={threads} salt={salt}: \
                                 {got:?} != {want:?}"
                            ));
                        }
                        // The merged stream is non-decreasing in time
                        // (the strict-invariants build also asserts
                        // full (time, seq, shard) order inside).
                        if got.windows(2).any(|w| w[1].0 < w[0].0) {
                            return Err(format!(
                                "merge emitted out of time order: \
                                 {got:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Full-engine K-invariance.
// ---------------------------------------------------------------------------

/// Small-but-busy single-query config under a shard plan. The
/// workload's vertex count tracks the plan's camera count, so
/// degenerate plans (K above the vertex count) exercise the clamped,
/// all-boundary partition.
fn plan_cfg(plan: &ShardPlan) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.name = format!("prop_shard_k{}", plan.shards);
    c.seed = 1302;
    c.num_cameras = plan.cameras;
    c.workload.vertices = plan.cameras;
    c.workload.edges = plan.cameras * 3;
    c.duration_secs = 20.0;
    c.tl = TlKind::Base;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c.drops_enabled = true;
    c.sharding.shards = plan.shards;
    c.sharding.threads = plan.threads;
    c
}

#[test]
fn prop_runs_are_k_invariant() {
    // The headline contract: per-seed bit-identity of the single-query
    // engine across shard plans. `shard.seeds` persists regression
    // pairs for this property.
    check(
        "shard",
        &CheckConfig::with_cases(3),
        &shard_plan(),
        |plan| {
            let sharded = des::run(plan_cfg(plan));
            let baseline = des::run(plan_cfg(&ShardPlan {
                shards: 1,
                threads: 0,
                cameras: plan.cameras,
            }));
            if sharded.summary != baseline.summary {
                return Err(format!(
                    "summary diverged under {plan:?}: {:?} != {:?}",
                    sharded.summary, baseline.summary
                ));
            }
            if sharded.detections != baseline.detections
                || sharded.fusion_updates != baseline.fusion_updates
                || sharded.core_events != baseline.core_events
                || sharded.rng_draws != baseline.rng_draws
            {
                return Err(format!(
                    "per-seed outputs diverged under {plan:?}"
                ));
            }
            if !sharded.summary.conserved() {
                return Err(format!(
                    "conservation violated: {:?}",
                    sharded.summary
                ));
            }
            if baseline.metrics.cross_shard_msgs != 0 {
                return Err("K=1 run recorded cross-shard traffic"
                    .to_string());
            }
            if sharded.metrics.shards == 1
                && sharded.metrics.cross_shard_msgs != 0
            {
                return Err(
                    "single-shard layout recorded cross-shard traffic"
                        .to_string(),
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_multi_query_ledgers_are_k_invariant() {
    // Same contract on the service-layer engine, down to the per-query
    // ledger rows: aggregate Summary, each query's Summary, fusion
    // updates and RNG draws are identical for any shard plan.
    let mq = || anveshak::config::MultiQueryConfig {
        num_queries: 3,
        mean_interarrival_secs: 4.0,
        lifetime_secs: 30.0,
        max_active: 8,
        max_active_cameras: 10_000,
        queue_capacity: 4,
        priority_levels: 2,
    };
    check(
        "shard_mq",
        &CheckConfig::with_cases(2),
        &shard_plan(),
        |plan| {
            let sharded = mq_engine::run(plan_cfg(plan), mq());
            let baseline = mq_engine::run(
                plan_cfg(&ShardPlan {
                    shards: 1,
                    threads: 0,
                    cameras: plan.cameras,
                }),
                mq(),
            );
            if sharded.aggregate != baseline.aggregate {
                return Err(format!(
                    "aggregate diverged under {plan:?}: {:?} != {:?}",
                    sharded.aggregate, baseline.aggregate
                ));
            }
            if sharded.fusion_updates != baseline.fusion_updates
                || sharded.core_events != baseline.core_events
                || sharded.rng_draws != baseline.rng_draws
                || sharded.peak_concurrent != baseline.peak_concurrent
            {
                return Err(format!(
                    "mq outputs diverged under {plan:?}"
                ));
            }
            if sharded.queries.len() != baseline.queries.len() {
                return Err("query report counts diverged".into());
            }
            for (a, b) in
                sharded.queries.iter().zip(baseline.queries.iter())
            {
                if a.summary != b.summary
                    || a.status != b.status
                    || a.detections != b.detections
                {
                    return Err(format!(
                        "query {} ledger diverged under {plan:?}",
                        a.id
                    ));
                }
            }
            if !sharded.aggregate.conserved() {
                return Err(format!(
                    "conservation violated: {:?}",
                    sharded.aggregate
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Shard-crash conservation.
// ---------------------------------------------------------------------------

#[test]
fn prop_shard_crash_conserves_every_event() {
    // A dead shard is a node crash: its orphans migrate to adjacent
    // shards (or are written off as lost_to_fault when recovery is
    // off / no survivor exists). Whatever the generated fault schedule
    // and shard plan, the ledger conserves —
    // generated = on_time + delayed + dropped + lost_to_fault +
    // in_flight — and the metrics registry agrees with it. Camera
    // indices are drawn below the smallest plan size so every schedule
    // is valid for every plan.
    let strat = (shard_plan(), fault_schedule(3, 3, 10));
    check(
        "shard_crash",
        &CheckConfig::with_cases(2),
        &strat,
        |(plan, faults)| {
            for recovery in [true, false] {
                let mut cfg = plan_cfg(plan);
                cfg.service.fault_events = faults.clone();
                cfg.service.recovery.enabled = recovery;
                let a = des::run(cfg.clone());
                if !a.summary.conserved() {
                    return Err(format!(
                        "conservation violated (recovery={recovery}) \
                         under {plan:?} + {faults:?}: {:?}",
                        a.summary
                    ));
                }
                if a.metrics.lost_to_fault != a.summary.lost_to_fault {
                    return Err(
                        "registry and ledger disagree on fault losses"
                            .into(),
                    );
                }
                // Faulted runs stay per-seed deterministic too.
                let b = des::run(cfg);
                if a.summary != b.summary
                    || a.rng_draws != b.rng_draws
                {
                    return Err(format!(
                        "faulted rerun diverged under {plan:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Persisted regressions.
// ---------------------------------------------------------------------------

#[test]
fn shard_seed_file_replays_deterministically() {
    // The committed pairs replay first on every `check("shard", ...)`
    // run; pin the file's presence and the generator's determinism so
    // the replay path cannot silently rot.
    let seeds = regression_seeds("shard");
    assert!(
        !seeds.is_empty(),
        "rust/tests/regressions/shard.seeds is missing or empty"
    );
    let strat = shard_plan();
    for (seed, case) in seeds {
        let a = generate_case(&strat, seed, case);
        assert_eq!(a, generate_case(&strat, seed, case));
        assert!((1..=8).contains(&a.shards), "{a:?}");
    }
}
