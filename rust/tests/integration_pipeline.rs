//! Integration: the live engine serves a small camera network with real
//! PJRT models end-to-end — frames in, batched model execution, TL
//! spotlight control, latency accounting out. Requires `make artifacts`
//! and the `pjrt` feature (compiled out otherwise).
#![cfg(feature = "pjrt")]

use anveshak::config::{BatchingKind, ExperimentConfig, TlKind};
use anveshak::coordinator::LiveEngine;
use anveshak::runtime::default_dir;

fn live_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.num_cameras = 8;
    c.workload.vertices = 40;
    c.workload.edges = 100;
    c.duration_secs = 4.0;
    c.gamma_ms = 5_000.0;
    c.fps = 2.0;
    c.cluster.va_instances = 2;
    c.cluster.cr_instances = 2;
    c.tl = TlKind::Wbfs;
    c.batching = BatchingKind::Dynamic { max: 8 };
    c
}

#[test]
fn live_engine_serves_and_tracks() {
    let cfg = live_cfg();
    let app = anveshak::apps::resolve(&cfg);
    let eng = LiveEngine::new(cfg, default_dir(), app);
    let r = eng.run().expect("live run");
    // Frames flowed through the whole pipeline.
    assert!(r.summary.generated > 10, "{:?}", r.summary);
    let done = r.summary.on_time + r.summary.delayed;
    assert!(done > 0, "nothing completed: {:?}", r.summary);
    assert!(r.summary.conserved());
    assert!(r.throughput > 1.0, "throughput {}", r.throughput);
    // The entity starts in camera 0's FOV: real re-id must confirm it.
    assert!(r.detections > 0, "no detections: {:?}", r.summary);
}

#[test]
fn live_engine_static_batching_runs() {
    let mut c = live_cfg();
    c.batching = BatchingKind::Static { size: 2 };
    let app = anveshak::apps::resolve(&c);
    let r = LiveEngine::new(c, default_dir(), app)
        .run()
        .expect("live run");
    assert!(r.summary.on_time + r.summary.delayed > 0, "{:?}", r.summary);
}
