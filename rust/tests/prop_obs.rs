//! Property tests over the observability layer (hand-rolled generator
//! loops; see `prop_tuning.rs` for the house style).
//!
//! The contract under test is the flight recorder's reason for
//! existing: *observation must not perturb the observed run*.
//!
//! * Attaching `NullSink` (the default) or a `RingSink` flight
//!   recorder to either DES engine leaves the run bit-identical per
//!   seed — every summary field, the detection count, the core
//!   event count and the RNG draw count all equal the plain build's.
//! * A JSONL trace reconciles *exactly* with the run's ledger:
//!   trace-implied generated/completed/dropped/in-flight counts equal
//!   the `Ledger`/`QueryLedgers` totals, and conservation holds per
//!   event (exactly one terminal per generated event, never two).
//! * `RingSink` wraparound never aliases slots or loses the newest
//!   events, for any emission count and any (prime) capacity.

use anveshak::config::{BatchingKind, ExperimentConfig, TlKind};
use anveshak::coordinator::des;
use anveshak::metrics::Summary;
use anveshak::obs::{
    validate_trace, JsonlSink, NullSink, RingSink, TraceEvent,
};
use anveshak::service::engine;
use anveshak::util::{rng, Micros, Rng};

fn cases(seed: u64, n: usize) -> impl Iterator<Item = Rng> {
    (0..n).map(move |i| rng(seed, i as u64))
}

/// A small-but-busy single-query workload: big enough to exercise
/// batching, drops and the budget loop, small enough to run many
/// seeds in a test.
fn small_cfg(seed: u64, drops: bool) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.name = format!("prop_obs_{seed}");
    c.seed = seed;
    c.num_cameras = 50;
    c.workload.vertices = 50;
    c.workload.edges = 140;
    c.duration_secs = 30.0;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c.drops_enabled = drops;
    c
}

fn mq_cfg(seed: u64) -> ExperimentConfig {
    let mut c = small_cfg(seed, true);
    c.tl = TlKind::Wbfs;
    c.multi_query.num_queries = 3;
    c.multi_query.mean_interarrival_secs = 5.0;
    c.multi_query.lifetime_secs = 15.0;
    c.multi_query.max_active = 8;
    c.multi_query.max_active_cameras = 10_000;
    c
}

/// `Summary` carries floats and no `PartialEq`; the determinism claim
/// is *bit* identity, so every field — percentiles included — must
/// compare exactly equal.
fn assert_summaries_eq(a: &Summary, b: &Summary, ctx: &str) {
    assert_eq!(a.generated, b.generated, "{ctx}: generated");
    assert_eq!(a.on_time, b.on_time, "{ctx}: on_time");
    assert_eq!(a.delayed, b.delayed, "{ctx}: delayed");
    assert_eq!(a.dropped, b.dropped, "{ctx}: dropped");
    assert_eq!(
        a.lost_to_fault, b.lost_to_fault,
        "{ctx}: lost_to_fault"
    );
    assert_eq!(a.in_flight, b.in_flight, "{ctx}: in_flight");
    assert_eq!(
        a.true_positives, b.true_positives,
        "{ctx}: true_positives"
    );
    assert_eq!(
        a.positives_dropped, b.positives_dropped,
        "{ctx}: positives_dropped"
    );
    assert_eq!(
        a.positives_generated, b.positives_generated,
        "{ctx}: positives_generated"
    );
    assert_eq!(a.latency.median, b.latency.median, "{ctx}: median");
    assert_eq!(a.latency.p25, b.latency.p25, "{ctx}: p25");
    assert_eq!(a.latency.p75, b.latency.p75, "{ctx}: p75");
    assert_eq!(a.latency.p99, b.latency.p99, "{ctx}: p99");
    assert_eq!(a.latency.max, b.latency.max, "{ctx}: max");
}

// ---------------------------------------------------------------------------
// (a) Observation does not perturb the observed run.
// ---------------------------------------------------------------------------

#[test]
fn prop_sinks_do_not_perturb_single_query_des() {
    for seed in [11u64, 29] {
        for drops in [false, true] {
            let base = des::run(small_cfg(seed, drops));
            let null =
                des::run_with_sink(small_cfg(seed, drops), NullSink);
            let recorder = RingSink::new(251);
            let ring = des::run_with_sink(
                small_cfg(seed, drops),
                recorder.clone(),
            );
            for (label, r) in [("null", &null), ("ring", &ring)] {
                let ctx = format!("seed {seed} drops {drops} {label}");
                assert_summaries_eq(&base.summary, &r.summary, &ctx);
                assert_eq!(base.detections, r.detections, "{ctx}");
                assert_eq!(base.peak_active, r.peak_active, "{ctx}");
                assert_eq!(
                    base.fusion_updates, r.fusion_updates,
                    "{ctx}"
                );
                assert_eq!(base.core_events, r.core_events, "{ctx}");
                assert_eq!(base.rng_draws, r.rng_draws, "{ctx}");
            }
            // The recorder really observed the run it didn't perturb.
            assert!(recorder.total() > 0, "ring recorded nothing");
        }
    }
}

#[test]
fn prop_sinks_do_not_perturb_multi_query_des() {
    for seed in [7u64, 19] {
        let cfg = mq_cfg(seed);
        let base = des::run_multi(cfg.clone());
        let null = engine::run_with_sink(
            cfg.clone(),
            cfg.multi_query.clone(),
            NullSink,
        );
        let recorder = RingSink::new(251);
        let ring = engine::run_with_sink(
            cfg.clone(),
            cfg.multi_query.clone(),
            recorder.clone(),
        );
        for (label, r) in [("null", &null), ("ring", &ring)] {
            let ctx = format!("seed {seed} mq {label}");
            assert_summaries_eq(&base.aggregate, &r.aggregate, &ctx);
            assert_eq!(base.queries.len(), r.queries.len(), "{ctx}");
            for (bq, rq) in base.queries.iter().zip(&r.queries) {
                match (&bq.summary, &rq.summary) {
                    (Some(a), Some(b)) => assert_summaries_eq(
                        a,
                        b,
                        &format!("{ctx} query {}", bq.label),
                    ),
                    (None, None) => {}
                    _ => panic!(
                        "{ctx}: query {} summary presence differs",
                        bq.label
                    ),
                }
            }
            assert_eq!(
                base.peak_concurrent, r.peak_concurrent,
                "{ctx}"
            );
            assert_eq!(base.rejected, r.rejected, "{ctx}");
            assert_eq!(base.queued, r.queued, "{ctx}");
            assert_eq!(base.fusion_updates, r.fusion_updates, "{ctx}");
            assert_eq!(base.core_events, r.core_events, "{ctx}");
            assert_eq!(base.rng_draws, r.rng_draws, "{ctx}");
        }
        assert!(recorder.total() > 0, "ring recorded nothing");
    }
}

// ---------------------------------------------------------------------------
// (b) The trace reconciles exactly with the ledger.
// ---------------------------------------------------------------------------

#[test]
fn prop_trace_reconciles_with_single_query_ledger() {
    for seed in [5u64, 23] {
        for drops in [false, true] {
            let sink = JsonlSink::in_memory();
            let r = des::run_with_sink(
                small_cfg(seed, drops),
                sink.clone(),
            );
            let text = sink.contents().unwrap();
            let check = validate_trace(&text)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let ctx = format!("seed {seed} drops {drops}");
            let s = &r.summary;
            assert_eq!(check.generated, s.generated, "{ctx}");
            assert_eq!(
                check.completed,
                s.on_time + s.delayed,
                "{ctx}"
            );
            assert_eq!(check.on_time, s.on_time, "{ctx}");
            assert_eq!(check.dropped_total(), s.dropped, "{ctx}");
            assert_eq!(
                check.lost_to_fault, s.lost_to_fault,
                "{ctx}"
            );
            assert_eq!(check.unterminated(), s.in_flight, "{ctx}");
            assert_eq!(check.detections, r.detections, "{ctx}");
            assert!(
                check.violations().is_empty(),
                "{ctx}: conservation violations {:?}",
                check.violations()
            );
            if drops && s.dropped > 0 {
                assert!(
                    check.drops_gate.iter().sum::<u64>() > 0,
                    "{ctx}: drops not attributed to gates"
                );
            }
        }
    }
}

#[test]
fn prop_trace_reconciles_with_multi_query_ledgers() {
    for seed in [13u64, 31] {
        let cfg = mq_cfg(seed);
        let sink = JsonlSink::in_memory();
        let r = engine::run_with_sink(
            cfg.clone(),
            cfg.multi_query.clone(),
            sink.clone(),
        );
        let text = sink.contents().unwrap();
        let check = validate_trace(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let ctx = format!("seed {seed} mq");
        let s = &r.aggregate;
        assert_eq!(check.generated, s.generated, "{ctx}");
        assert_eq!(check.completed, s.on_time + s.delayed, "{ctx}");
        assert_eq!(check.on_time, s.on_time, "{ctx}");
        assert_eq!(check.dropped_total(), s.dropped, "{ctx}");
        assert_eq!(check.lost_to_fault, s.lost_to_fault, "{ctx}");
        assert_eq!(check.unterminated(), s.in_flight, "{ctx}");
        assert!(
            check.violations().is_empty(),
            "{ctx}: conservation violations {:?}",
            check.violations()
        );
    }
}

// ---------------------------------------------------------------------------
// (c) RingSink wraparound: no aliasing, no lost newest events.
// ---------------------------------------------------------------------------

#[test]
fn prop_ring_wraparound_never_aliases_or_loses_newest() {
    const PRIMES: [usize; 8] = [2, 3, 5, 7, 13, 31, 97, 251];
    for mut r in cases(7, 200) {
        let cap = PRIMES[r.range_u(0, PRIMES.len())];
        let n = r.range_u(0, 4 * cap + 2) as u64;
        let s = RingSink::new(cap);
        for i in 0..n {
            s.emit(
                i as Micros,
                &TraceEvent::Generated {
                    event: i,
                    query: 0,
                    camera: (i % 7) as u32,
                },
            );
        }
        assert_eq!(s.total(), n, "cap {cap} n {n}: total");
        let evs = s.events();
        assert_eq!(
            evs.len(),
            (n as usize).min(cap),
            "cap {cap} n {n}: retained count"
        );
        // Exactly the newest min(n, cap) events, oldest first,
        // consecutive — any aliasing or loss breaks the sequence.
        let first = n.saturating_sub(cap as u64);
        for (k, (t, ev)) in evs.iter().enumerate() {
            let want = first + k as u64;
            assert_eq!(*t, want as Micros, "cap {cap} n {n} slot {k}");
            match ev {
                TraceEvent::Generated { event, camera, .. } => {
                    assert_eq!(
                        *event, want,
                        "cap {cap} n {n} slot {k}: event id"
                    );
                    assert_eq!(
                        *camera,
                        (want % 7) as u32,
                        "cap {cap} n {n} slot {k}: payload"
                    );
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
    }
}
