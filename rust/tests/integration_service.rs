//! Integration: the multi-query service layer end to end — admission
//! decisions, query lifecycle, fair-share batch composition across
//! concurrent queries, and per-query accounting on the shared
//! deployment (DES mode; no PJRT required).

use anveshak::config::{ExperimentConfig, MultiQueryConfig};
use anveshak::coordinator::des::run_multi;
use anveshak::dataflow::QueryId;
use anveshak::service::engine;
use anveshak::service::{
    Admission, AdmissionController, AdmissionPolicy, FairShareBatcher,
    QueryRegistry, QuerySpec, QueryStatus,
};
use anveshak::tuning::budget::BUDGET_INF;
use anveshak::tuning::{BatcherPoll, QueuedEvent, XiModel};
use anveshak::util::SEC;

fn base_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.num_cameras = 80;
    c.workload.vertices = 80;
    c.workload.edges = 220;
    c
}

fn mq(n: usize) -> MultiQueryConfig {
    MultiQueryConfig {
        num_queries: n,
        mean_interarrival_secs: 4.0,
        lifetime_secs: 80.0,
        max_active: 16,
        max_active_cameras: 10_000,
        queue_capacity: 8,
        priority_levels: 3,
    }
}

// ---------------------------------------------------------------------------
// Admission decisions drive the registry lifecycle.
// ---------------------------------------------------------------------------

#[test]
fn admission_and_lifecycle_compose() {
    let ctl = AdmissionController::new(AdmissionPolicy {
        max_active: 2,
        max_active_cameras: 1_000,
        queue_capacity: 1,
    });
    let mut reg = QueryRegistry::new();
    let mut active_cams = 0usize;

    let mut submit = |reg: &mut QueryRegistry,
                      active_cams: &mut usize,
                      cam: usize,
                      now: i64|
     -> (QueryId, QueryStatus) {
        let spec = QuerySpec::new(format!("q{cam}"), cam);
        let id = reg.submit(spec.clone(), now);
        match ctl.decide(
            &spec,
            reg.num_active(),
            reg.num_queued(),
            *active_cams,
            1_000,
        ) {
            Admission::Admit => {
                reg.activate(id, now).unwrap();
                *active_cams += spec.initial_camera_estimate(1_000);
                (id, QueryStatus::Active)
            }
            Admission::Queue => {
                reg.enqueue(id).unwrap();
                (id, QueryStatus::Queued)
            }
            Admission::Reject(_) => {
                reg.reject(id, now).unwrap();
                (id, QueryStatus::Rejected)
            }
        }
    };

    let (a, sa) = submit(&mut reg, &mut active_cams, 0, 0);
    let (_b, sb) = submit(&mut reg, &mut active_cams, 1, SEC);
    let (c, sc) = submit(&mut reg, &mut active_cams, 2, 2 * SEC);
    let (d, sd) = submit(&mut reg, &mut active_cams, 3, 3 * SEC);
    assert_eq!(sa, QueryStatus::Active);
    assert_eq!(sb, QueryStatus::Active);
    assert_eq!(sc, QueryStatus::Queued);
    assert_eq!(sd, QueryStatus::Rejected);

    // Completing an active query frees a slot; the queued one fits.
    reg.complete(a, 10 * SEC).unwrap();
    assert_eq!(reg.next_pending(), Some(c));
    reg.activate(c, 10 * SEC).unwrap();
    assert_eq!(reg.status(c), Some(QueryStatus::Active));
    assert_eq!(reg.num_active(), 2);
    assert_eq!(reg.status(d), Some(QueryStatus::Rejected));
}

// ---------------------------------------------------------------------------
// Fair-share batch composition across ≥3 concurrent queries.
// ---------------------------------------------------------------------------

#[test]
fn fair_share_composes_cross_query_batches() {
    let xi = XiModel::affine_ms(20.0, 10.0);
    let mut b: FairShareBatcher<u64> = FairShareBatcher::new(9);
    // Three backlogged queries with equal priority.
    for q in [10u32, 20, 30] {
        b.register(q, 1);
        for k in 0..50 {
            assert!(b.push(
                    q,
                    QueuedEvent {
                        item: (q as u64) * 1_000 + k,
                        id: k,
                        arrival: 0,
                        deadline: 60 * SEC,
                    },
                ).is_none());
        }
    }
    // Several consecutive batches: each mixes all three queries with
    // equal shares (9 slots -> 3 each).
    for _ in 0..5 {
        let batch = match b.poll(0, &xi) {
            BatcherPoll::Ready(batch) => batch,
            other => panic!("{other:?}"),
        };
        assert_eq!(batch.len(), 9);
        for q in [10u64, 20, 30] {
            let share = batch
                .iter()
                .filter(|e| e.item / 1_000 == q)
                .count();
            assert_eq!(share, 3, "query {q} share in cross-query batch");
        }
    }
}

#[test]
fn one_collapsed_query_cannot_starve_the_rest() {
    // Query 99's budget collapsed: its events carry immediate
    // deadlines and are released solo/dropped, while queries 1 and 2
    // keep their full fair share of batch slots.
    let xi = XiModel::affine_ms(20.0, 10.0);
    let mut b: FairShareBatcher<u64> = FairShareBatcher::new(8);
    for q in [1u32, 2, 99] {
        b.register(q, 1);
    }
    for k in 0..20 {
        assert!(b.push(
                1,
                QueuedEvent {
                    item: 1_000 + k,
                    id: k,
                    arrival: 0,
                    deadline: 60 * SEC,
                },
            ).is_none());
        assert!(b.push(
                2,
                QueuedEvent {
                    item: 2_000 + k,
                    id: k,
                    arrival: 0,
                    deadline: 60 * SEC,
                },
            ).is_none());
        assert!(b.push(
                99,
                QueuedEvent {
                    item: 99_000 + k,
                    id: k,
                    arrival: 0,
                    deadline: 1, // collapsed budget: already past due
                },
            ).is_none());
    }
    let mut healthy = 0usize;
    let mut collapsed = 0usize;
    for _ in 0..12 {
        match b.poll(10 * SEC, &xi) {
            BatcherPoll::Ready(batch) => {
                for e in &batch {
                    if e.item >= 99_000 {
                        collapsed += 1;
                    } else {
                        healthy += 1;
                    }
                }
            }
            _ => break,
        }
    }
    // The collapsed query's past-due events release solo (headed for
    // drop point 2) without blocking the healthy queries' batches.
    assert!(
        healthy >= 10,
        "healthy queries kept flowing: healthy {healthy}, \
         collapsed {collapsed}"
    );
    assert!(
        collapsed >= 2,
        "collapsed query still drains solo: {collapsed}"
    );
}

// ---------------------------------------------------------------------------
// Whole-engine: shared deployment, per-query ledgers, concurrency.
// ---------------------------------------------------------------------------

#[test]
fn multi_query_engine_tracks_concurrently() {
    let mut cfg = base_cfg();
    cfg.multi_query = mq(5);
    let r = run_multi(cfg);
    assert!(r.peak_concurrent >= 3, "{}", r.peak_concurrent);
    let activated: Vec<_> = r.activated().collect();
    assert_eq!(activated.len(), 5);
    for q in &activated {
        let s = q.summary.as_ref().unwrap();
        assert!(s.conserved(), "query {}: {:?}", q.id, s);
        assert!(s.generated > 0);
    }
    // The per-query ledgers partition the aggregate exactly.
    let sum_gen: u64 = activated
        .iter()
        .map(|q| q.summary.as_ref().unwrap().generated)
        .sum();
    assert_eq!(sum_gen, r.aggregate.generated);
}

#[test]
fn engine_and_run_multi_agree() {
    let mut cfg = base_cfg();
    cfg.multi_query = mq(3);
    let a = run_multi(cfg.clone());
    let b = engine::run(cfg.clone(), cfg.multi_query.clone());
    assert_eq!(a.aggregate.generated, b.aggregate.generated);
    assert_eq!(a.aggregate.on_time, b.aggregate.on_time);
    assert_eq!(a.peak_concurrent, b.peak_concurrent);
}

#[test]
fn bootstrap_deadline_sentinel_streams() {
    // Events with no budget yet must stream (batch of 1), same as the
    // single-query dynamic batcher.
    let xi = XiModel::affine_ms(20.0, 10.0);
    let mut b: FairShareBatcher<u64> = FairShareBatcher::new(16);
    b.register(1, 1);
    assert!(b
        .push(
            1,
            QueuedEvent {
                item: 1,
                id: 1,
                arrival: 0,
                deadline: BUDGET_INF,
            },
        )
        .is_none());
    match b.poll(0, &xi) {
        BatcherPoll::Ready(batch) => assert_eq!(batch.len(), 1),
        other => panic!("{other:?}"),
    }
    // Unregistered (finished) queries bounce events back to the caller.
    assert!(b
        .push(
            9,
            QueuedEvent {
                item: 9,
                id: 9,
                arrival: 0,
                deadline: BUDGET_INF,
            },
        )
        .is_some());
}
