//! Properties of the QF → VA/CR feedback loop and per-query apps.
//!
//! 1. **Exactly-once application** — a routed refinement changes an
//!    executor's scoring target once; duplicate/stale deliveries are
//!    discarded ([`FeedbackState`]), and the refined target measurably
//!    changes [`SimBackend`] scores.
//! 2. **NoFusion inertness** — with no refinements the feedback
//!    plumbing leaves per-seed metrics bit-identical (config path vs.
//!    explicit-app path, and repeated runs), on both DES engines.
//! 3. **Fusion alters the dataflow deterministically** — under
//!    semantics tuned so the refined error rates must flip some coin,
//!    a fusing App 2 run diverges from the same composition with
//!    `NoFusion`, while remaining bit-identical across repeats.
//! 4. **Per-query apps** — two concurrent queries with different
//!    `QuerySpec.app`s run their own blocks: only the App 2 query
//!    fuses, and the report records each query's app.

use std::sync::Arc;

use anveshak::apps::{self, AppBuilder, SimDetector, SimReid};
use anveshak::config::{AppKind, BatchingKind, ExperimentConfig};
use anveshak::coordinator::des;
use anveshak::dataflow::{
    Event, FeedbackRouter, FeedbackState, Header, ModelVariant, Payload,
    Stage,
};
use anveshak::service::engine::MultiQueryDes;
use anveshak::service::{ScoreBackend, ScoreCtx, SimBackend};

fn base_cfg(seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.seed = seed;
    c.num_cameras = 60;
    c.workload.vertices = 60;
    c.workload.edges = 160;
    c.duration_secs = 60.0;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c
}

// ---------------------------------------------------------------------------
// 1. Exactly-once application + scores actually move.
// ---------------------------------------------------------------------------

#[test]
fn refinement_changes_scores_exactly_once() {
    // A refined target must change SimBackend's verdict for at least
    // one event (boost 1.0 ⇒ every present-entity frame scores high).
    let backend = SimBackend {
        tp: 0.5,
        fusion_boost: 1.0,
        ..SimBackend::default()
    };
    let events: Vec<Event> = (0..64)
        .map(|i| Event::frame(i, (i % 8) as usize, i, 0, true))
        .collect();
    let emb = vec![0.25f32; 8];
    let base_ctx = ScoreCtx {
        stage: Stage::Cr,
        variant: ModelVariant::CrLarge,
        query: 3,
        refined: None,
    };
    let refined_ctx = ScoreCtx {
        refined: Some(&emb),
        ..base_ctx
    };
    let before = backend.score(&base_ctx, &events);
    let after = backend.score(&refined_ctx, &events);
    assert_ne!(
        before, after,
        "a refinement must measurably change scores"
    );
    // Deterministic: scoring again with the same refinement state
    // reproduces the same scores (the change happened "once", when the
    // update was applied — not per call).
    assert_eq!(after, backend.score(&refined_ctx, &events));

    // The executor-side discard: the same update applies exactly once.
    let mut st = FeedbackState::new();
    let mut router = FeedbackRouter::new();
    let r = router.refine(3, Arc::new(emb.clone()));
    assert!(st.apply(r.query, r.seq, Arc::clone(&r.embedding)));
    assert!(
        !st.apply(r.query, r.seq, Arc::clone(&r.embedding)),
        "duplicate delivery discarded"
    );
    assert_eq!(st.refined(3), Some(&emb[..]));
    // A stale (lower-seq) update after a fresher one is discarded too.
    let r2 = router.refine(3, Arc::new(vec![1.0; 8]));
    assert!(st.apply(r2.query, r2.seq, Arc::clone(&r2.embedding)));
    assert!(!st.apply(r.query, r.seq, Arc::clone(&r.embedding)));
    assert_eq!(st.refined(3), Some(&[1.0f32; 8][..]));
}

#[test]
fn update_events_carry_seq_and_are_not_data() {
    let mut router = FeedbackRouter::new();
    let r = router.refine(0, Arc::new(vec![0.5]));
    let ev = r.into_event(42, 7, 1_000);
    assert_eq!(ev.header.update_seq, 1);
    assert_eq!(ev.payload.entity_present(), None);
    // Data headers never carry an update seq.
    assert_eq!(Header::new(1, 0, 0, 0).update_seq, 0);
    assert!(matches!(ev.payload, Payload::QueryUpdate(_)));
}

// ---------------------------------------------------------------------------
// 2. NoFusion runs: plumbing is inert, per-seed identical.
// ---------------------------------------------------------------------------

#[test]
fn nofusion_runs_stay_per_seed_identical() {
    for seed in [2019u64, 7] {
        let cfg = base_cfg(seed); // App 1: NoFusion composition
        let a = des::run(cfg.clone());
        let b = des::run_app(
            cfg.clone(),
            &apps::table1(cfg.app).with_tl_kind(cfg.tl),
        );
        assert_eq!(a.summary.generated, b.summary.generated, "{seed}");
        assert_eq!(a.summary.on_time, b.summary.on_time, "{seed}");
        assert_eq!(a.detections, b.detections, "{seed}");
        assert_eq!(a.core_events, b.core_events, "{seed}");
        assert_eq!(a.fusion_updates, 0);

        // Multi-query engine, same property per query.
        let mut mcfg = base_cfg(seed);
        mcfg.multi_query.num_queries = 3;
        mcfg.multi_query.mean_interarrival_secs = 5.0;
        mcfg.multi_query.lifetime_secs = 40.0;
        let mq = mcfg.multi_query.clone();
        let ma = anveshak::service::engine::run(mcfg.clone(), mq.clone());
        let mb = anveshak::service::engine::run(mcfg, mq);
        assert_eq!(ma.aggregate.generated, mb.aggregate.generated);
        assert_eq!(ma.aggregate.on_time, mb.aggregate.on_time);
        assert_eq!(ma.fusion_updates, 0);
        for (qa, qb) in ma.queries.iter().zip(mb.queries.iter()) {
            assert_eq!(qa.detections, qb.detections);
            assert_eq!(qa.fusion_updates, 0);
        }
    }
}

// ---------------------------------------------------------------------------
// 3. Fusion deterministically alters DES detections.
// ---------------------------------------------------------------------------

#[test]
fn fusion_feedback_alters_des_outcomes_deterministically() {
    // Semantics tuned so a refinement must flip coins: cr_tp 0.7 with
    // boost 1.0 ⇒ refined queries confirm every true candidate; ~30%
    // of post-refinement confirm draws land in the widened window.
    let mut cfg = base_cfg(2019);
    cfg.semantics.cr_tp = 0.7;
    cfg.semantics.fusion_boost = 1.0;
    let on = apps::table1(AppKind::App2).with_tl_kind(cfg.tl);
    let off = AppBuilder::new("app2-fusion-off")
        .video_analytics(SimDetector::hog())
        .contention_resolver(SimReid::large())
        .tracking_logic(cfg.tl)
        .build();

    let r_on = des::run_app(cfg.clone(), &on);
    let r_off = des::run_app(cfg.clone(), &off);
    assert!(r_on.fusion_updates > 0, "fusion fired");
    assert!(
        r_on.detections != r_off.detections
            || r_on.summary.generated != r_off.summary.generated
            || r_on.summary.on_time != r_off.summary.on_time,
        "the feedback edge must alter the dataflow: on {:?}/{} vs \
         off {:?}/{}",
        r_on.summary,
        r_on.detections,
        r_off.summary,
        r_off.detections,
    );
    // …deterministically: repeat runs are bit-identical.
    let r_on2 = des::run_app(cfg, &on);
    assert_eq!(r_on.summary.generated, r_on2.summary.generated);
    assert_eq!(r_on.summary.on_time, r_on2.summary.on_time);
    assert_eq!(r_on.detections, r_on2.detections);
    assert_eq!(r_on.fusion_updates, r_on2.fusion_updates);
    assert_eq!(r_on.core_events, r_on2.core_events);
}

// ---------------------------------------------------------------------------
// 4. Per-query apps in the multi-query engine.
// ---------------------------------------------------------------------------

#[test]
fn concurrent_queries_run_their_own_apps() {
    let mut cfg = base_cfg(2019);
    cfg.multi_query.num_queries = 4;
    cfg.multi_query.mean_interarrival_secs = 5.0;
    cfg.multi_query.lifetime_secs = 60.0;
    cfg.multi_query.max_active = 16;
    let mq = cfg.multi_query.clone();
    let mut engine = MultiQueryDes::new(cfg, mq);
    // Queries alternate App2 (fusing) / App1 (not).
    engine.set_app_cycle(&[AppKind::App2, AppKind::App1]);
    let r = engine.run();

    assert!(r.aggregate.conserved(), "{:?}", r.aggregate);
    let mut app2_fusions = 0u64;
    for q in r.queries.iter() {
        match q.app {
            AppKind::App2 => app2_fusions += q.fusion_updates,
            _ => assert_eq!(
                q.fusion_updates, 0,
                "non-fusing app must not fuse: query {} ({:?})",
                q.id, q.app
            ),
        }
    }
    assert_eq!(r.queries[0].app, AppKind::App2);
    assert_eq!(r.queries[1].app, AppKind::App1);
    assert!(
        app2_fusions > 0,
        "App 2 queries fuse on their detections: {:?}",
        r.queries
            .iter()
            .map(|q| (q.id, q.app, q.detections, q.fusion_updates))
            .collect::<Vec<_>>()
    );
    assert_eq!(
        app2_fusions, r.fusion_updates,
        "aggregate fusion count is the per-query sum"
    );
    // Determinism with a heterogeneous mix.
    let mut cfg2 = base_cfg(2019);
    cfg2.multi_query.num_queries = 4;
    cfg2.multi_query.mean_interarrival_secs = 5.0;
    cfg2.multi_query.lifetime_secs = 60.0;
    cfg2.multi_query.max_active = 16;
    let mq2 = cfg2.multi_query.clone();
    let mut engine2 = MultiQueryDes::new(cfg2, mq2);
    engine2.set_app_cycle(&[AppKind::App2, AppKind::App1]);
    let r2 = engine2.run();
    assert_eq!(r.aggregate.generated, r2.aggregate.generated);
    assert_eq!(r.aggregate.on_time, r2.aggregate.on_time);
    assert_eq!(r.fusion_updates, r2.fusion_updates);
}
