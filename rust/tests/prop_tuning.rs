//! Property-based tests over the tuning invariants (hand-rolled
//! generator loop — the offline environment has no proptest crate, so
//! the proptest-style properties are driven by seeded `Rng` loops; each
//! property runs across hundreds of random cases and shrinking is
//! replaced by printing the offending inputs in the assert message).
//!
//! Covers, among others: the three drop points' skew invariance and
//! budget monotonicity, the §4.3.3 exemption rule (avoid-drop/probe
//! events are never dropped at any point), batcher FIFO/deadline
//! monotonicity, fair-share weight proportionality, signal-order
//! resilience of budgets, and ledger conservation.

use anveshak::config::{
    BatchingKind, ComputeEvent, ExperimentConfig, TlKind,
};
use anveshak::coordinator::des;
use anveshak::dataflow::Partitioner;
use anveshak::metrics::Ledger;
use anveshak::tuning::budget::BUDGET_INF;
use anveshak::tuning::{
    drop_at_exec, drop_at_queue, drop_at_transmit, drop_before_exec,
    drop_before_queue, drop_before_transmit, Batcher, BatcherPoll,
    BudgetManager, EventRecord, FairShare, QueuedEvent, Signal, XiModel,
};
use anveshak::util::{rng, Micros, Rng, MS, SEC};

fn cases(seed: u64, n: usize) -> impl Iterator<Item = Rng> {
    (0..n).map(move |i| rng(seed, i as u64))
}

// ---------------------------------------------------------------------------
// Partitioner: total, stable, reasonably spread.
// ---------------------------------------------------------------------------

#[test]
fn prop_partitioner_total_and_stable() {
    for mut r in cases(1, 200) {
        let n = r.range_u(1, 64);
        let p = Partitioner::new(n);
        for _ in 0..50 {
            let k = r.range_u(0, 1 << 20);
            let a = p.route(k);
            assert!(a < n);
            assert_eq!(a, p.route(k));
        }
    }
}

// ---------------------------------------------------------------------------
// Drop points: skew invariance and monotonicity in the budget.
// ---------------------------------------------------------------------------

#[test]
fn prop_drop_points_skew_invariant() {
    for mut r in cases(2, 500) {
        let u = r.range_i64(0, 30 * SEC);
        let q = r.range_i64(0, 10 * SEC);
        let x = r.range_i64(1, 3 * SEC);
        let b = r.range_i64(0, 40 * SEC);
        let skew = r.range_i64(-2 * SEC, 2 * SEC);
        // Observed u and the budget both absorb the same -sigma (§4.6.2).
        assert_eq!(
            drop_before_queue(u, x, b),
            drop_before_queue(u + skew, x, b + skew)
        );
        assert_eq!(
            drop_before_exec(u, q, x, b),
            drop_before_exec(u + skew, q, x, b + skew)
        );
        assert_eq!(
            drop_before_transmit(u, q + x, b),
            drop_before_transmit(u + skew, q + x, b + skew)
        );
    }
}

#[test]
fn prop_drop_monotone_in_budget() {
    // A bigger budget never drops an event a smaller budget kept.
    for mut r in cases(3, 500) {
        let u = r.range_i64(0, 30 * SEC);
        let q = r.range_i64(0, 10 * SEC);
        let x = r.range_i64(1, 3 * SEC);
        let b1 = r.range_i64(0, 40 * SEC);
        let b2 = b1 + r.range_i64(0, 10 * SEC);
        if !drop_before_exec(u, q, x, b1) {
            assert!(!drop_before_exec(u, q, x, b2));
        }
        if !drop_before_queue(u, x, b1) {
            assert!(!drop_before_queue(u, x, b2));
        }
    }
}

// ---------------------------------------------------------------------------
// Exemption invariant (§4.3.3 + §4.5.2): avoid-drop and probe events
// are never dropped at ANY of the three drop points, no matter how
// stale — both engines route every decision through the drop_at_*
// gates, so the invariant is provable here once.
// ---------------------------------------------------------------------------

#[test]
fn prop_exempt_events_never_dropped_at_any_point() {
    for mut r in cases(20, 500) {
        // Adversarial inputs: hugely stale events against tiny (even
        // zero) budgets, where the non-exempt decision is surely Drop.
        let u = r.range_i64(0, 120 * SEC);
        let q = r.range_i64(0, 60 * SEC);
        let x = r.range_i64(1, 5 * SEC);
        let budget = r.range_i64(0, 2 * SEC);
        // Exempt events (avoid_drop or probe) always survive.
        assert!(!drop_at_queue(true, u, x, budget));
        assert!(!drop_at_exec(true, u, q, x, budget));
        assert!(!drop_at_transmit(true, u, q + x, budget));
        // Non-exempt gates agree exactly with the raw drop points.
        assert_eq!(
            drop_at_queue(false, u, x, budget),
            drop_before_queue(u, x, budget)
        );
        assert_eq!(
            drop_at_exec(false, u, q, x, budget),
            drop_before_exec(u, q, x, budget)
        );
        assert_eq!(
            drop_at_transmit(false, u, q + x, budget),
            drop_before_transmit(u, q + x, budget)
        );
    }
}

// ---------------------------------------------------------------------------
// Fair-share: weighted DRR service proportions over random workloads.
// ---------------------------------------------------------------------------

#[test]
fn prop_fair_share_service_proportional_to_weights() {
    for mut r in cases(21, 100) {
        let n = r.range_u(2, 6);
        let weights: Vec<u32> =
            (0..n).map(|_| r.range_u(1, 5) as u32).collect();
        let mut fs = FairShare::new();
        for (q, &w) in weights.iter().enumerate() {
            fs.ensure(q as u32, w);
        }
        let total_w: u32 = weights.iter().sum();
        // Serve several whole refill cycles with everyone backlogged.
        let cycles = r.range_u(2, 8) as u32;
        let rounds = (total_w * cycles) as usize;
        let mut counts = vec![0u32; n];
        for _ in 0..rounds {
            let q = fs.pick(|_| true).expect("everyone has work");
            fs.charge(q, 1);
            counts[q as usize] += 1;
        }
        // Over whole cycles, service is exactly weight-proportional.
        for (q, &w) in weights.iter().enumerate() {
            assert_eq!(
                counts[q],
                w * cycles,
                "weights {weights:?} counts {counts:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Batcher invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_dynamic_batcher_respects_max_and_fifo() {
    for mut r in cases(4, 100) {
        let max = r.range_u(1, 26);
        let xi = XiModel::affine_ms(
            r.range_f64(1.0, 80.0),
            r.range_f64(1.0, 80.0),
        );
        let mut b: Batcher<u64> = Batcher::dynamic(max);
        let n = r.range_u(1, 60);
        let mut now: Micros = 0;
        let mut next_expected = 0u64;
        let mut pushed = 0u64;
        loop {
            // Random interleave of pushes and polls.
            if pushed < n as u64 && r.bool(0.6) {
                now += r.range_i64(0, 500 * MS);
                let deadline = if r.bool(0.1) {
                    BUDGET_INF
                } else {
                    now + r.range_i64(100 * MS, 30 * SEC)
                };
                b.push(QueuedEvent {
                    item: pushed,
                    id: pushed,
                    arrival: now,
                    deadline,
                });
                pushed += 1;
            }
            match b.poll(now, &xi) {
                BatcherPoll::Ready(batch) => {
                    assert!(!batch.is_empty());
                    assert!(batch.len() <= max, "batch over max");
                    for e in &batch {
                        assert_eq!(
                            e.id, next_expected,
                            "FIFO order violated"
                        );
                        next_expected += 1;
                    }
                }
                BatcherPoll::Timer(at) => {
                    assert!(at >= now, "timer in the past");
                    now = at;
                }
                BatcherPoll::Idle => {
                    if pushed >= n as u64 {
                        break;
                    }
                }
            }
        }
        // Everything that was pushed eventually left in order.
        // (Remaining current batch drains via the far-future poll.)
        loop {
            match b.poll(now + BUDGET_INF / 2, &xi) {
                BatcherPoll::Ready(batch) => {
                    for e in &batch {
                        assert_eq!(e.id, next_expected);
                        next_expected += 1;
                    }
                }
                _ => break,
            }
        }
        assert_eq!(next_expected, pushed, "events lost in batcher");
    }
}

#[test]
fn prop_dynamic_batch_deadline_is_min() {
    // Whenever a batch is submitted via the timer path, the timer equals
    // (min member deadline) - xi(m).
    for mut r in cases(5, 200) {
        let xi = XiModel::affine_ms(20.0, 30.0);
        let mut b: Batcher<u64> = Batcher::dynamic(32);
        let n = r.range_u(1, 10);
        let mut min_dl = BUDGET_INF;
        for k in 0..n {
            let dl = r.range_i64(20 * SEC, 40 * SEC);
            min_dl = min_dl.min(dl);
            b.push(QueuedEvent {
                item: k as u64,
                id: k as u64,
                arrival: 0,
                deadline: dl,
            });
        }
        match b.poll(0, &xi) {
            BatcherPoll::Timer(at) => {
                assert_eq!(at, min_dl - xi.xi(n));
            }
            BatcherPoll::Ready(batch) => {
                // Possible only if adding all was infeasible; then the
                // batch must still satisfy its own deadline test breaks.
                assert!(!batch.is_empty());
            }
            BatcherPoll::Idle => panic!("events pending but idle"),
        }
    }
}

#[test]
fn prop_batch_deadlines_monotone_in_arrival_order() {
    // Events enter a task in arrival order with non-decreasing
    // deadlines (deadline = budget + src_arrival and FIFO arrival).
    // Then (a) each formed batch's deadline Δp is its *first* member's
    // deadline (the min), and (b) successive batches have non-
    // decreasing deadlines — batching never reorders urgency.
    for mut r in cases(22, 200) {
        let xi = XiModel::affine_ms(
            r.range_f64(5.0, 60.0),
            r.range_f64(5.0, 60.0),
        );
        let max = r.range_u(2, 26);
        let mut b: Batcher<u64> = Batcher::dynamic(max);
        let n = r.range_u(2, 40);
        let mut deadline = r.range_i64(5 * SEC, 10 * SEC);
        let mut now: Micros = 0;
        let mut pushed = 0u64;
        let mut batch_deadlines: Vec<Micros> = Vec::new();
        let mut drain = |b: &mut Batcher<u64>,
                         now: &mut Micros,
                         out: &mut Vec<Micros>| {
            loop {
                match b.poll(*now, &xi) {
                    BatcherPoll::Ready(batch) => {
                        let min = batch
                            .iter()
                            .map(|e| e.deadline)
                            .min()
                            .unwrap();
                        assert_eq!(
                            min, batch[0].deadline,
                            "batch deadline is the first (earliest) \
                             member's"
                        );
                        out.push(min);
                    }
                    BatcherPoll::Timer(at) => {
                        if *now >= at {
                            break;
                        }
                        *now = at;
                    }
                    BatcherPoll::Idle => break,
                }
            }
        };
        while pushed < n as u64 {
            now += r.range_i64(0, 300 * MS);
            deadline += r.range_i64(0, 2 * SEC); // non-decreasing
            b.push(QueuedEvent {
                item: pushed,
                id: pushed,
                arrival: now,
                deadline,
            });
            pushed += 1;
            if r.bool(0.5) {
                drain(&mut b, &mut now, &mut batch_deadlines);
            }
        }
        drain(&mut b, &mut now, &mut batch_deadlines);
        for w in batch_deadlines.windows(2) {
            assert!(
                w[0] <= w[1],
                "batch deadlines regressed: {batch_deadlines:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Budget manager: signal-order resilience.
// ---------------------------------------------------------------------------

#[test]
fn prop_rejects_commute() {
    // Any permutation of a set of reject signals yields the same final
    // budget (min-resilience, §4.5.1).
    for mut r in cases(6, 150) {
        let xi = XiModel::affine_ms(52.5, 67.5);
        let n = r.range_u(1, 10);
        let recs: Vec<(u64, EventRecord)> = (0..n)
            .map(|k| {
                (
                    k as u64,
                    EventRecord {
                        departure: r.range_i64(SEC, 20 * SEC),
                        queue: r.range_i64(0, 5 * SEC),
                        batch: r.range_u(1, 26),
                        sent_to: 0,
                    },
                )
            })
            .collect();
        let sigs: Vec<Signal> = (0..n)
            .map(|k| Signal::Reject {
                event: k as u64,
                eps: r.range_i64(0, 5 * SEC),
                sum_queue: r.range_i64(1, 10 * SEC),
            })
            .collect();

        let run = |order: &[usize]| {
            let mut bm = BudgetManager::new(1, 25, 64);
            for (k, rec) in &recs {
                bm.record(*k, *rec);
            }
            for &i in order {
                bm.apply(sigs[i], &xi);
            }
            bm.budget_for(0)
        };
        let fwd: Vec<usize> = (0..n).collect();
        let mut shuffled = fwd.clone();
        r.shuffle(&mut shuffled);
        assert_eq!(run(&fwd), run(&shuffled));
    }
}

#[test]
fn prop_accepts_commute() {
    for mut r in cases(7, 150) {
        let xi = XiModel::affine_ms(52.5, 67.5);
        let n = r.range_u(1, 10);
        let recs: Vec<(u64, EventRecord)> = (0..n)
            .map(|k| {
                (
                    k as u64,
                    EventRecord {
                        departure: r.range_i64(SEC, 20 * SEC),
                        queue: r.range_i64(0, 5 * SEC),
                        batch: r.range_u(1, 26),
                        sent_to: 0,
                    },
                )
            })
            .collect();
        let sigs: Vec<Signal> = (0..n)
            .map(|k| Signal::Accept {
                event: k as u64,
                eps: r.range_i64(0, 10 * SEC),
                sum_exec: r.range_i64(1, 10 * SEC),
            })
            .collect();
        let run = |order: &[usize]| {
            let mut bm = BudgetManager::new(1, 25, 64);
            for (k, rec) in &recs {
                bm.record(*k, *rec);
            }
            for &i in order {
                bm.apply(sigs[i], &xi);
            }
            bm.budget_for(0)
        };
        let fwd: Vec<usize> = (0..n).collect();
        let mut shuffled = fwd.clone();
        r.shuffle(&mut shuffled);
        assert_eq!(run(&fwd), run(&shuffled));
    }
}

#[test]
fn prop_reject_never_raises_accept_never_lowers() {
    for mut r in cases(8, 300) {
        let xi = XiModel::affine_ms(52.5, 67.5);
        let mut bm = BudgetManager::new(1, 25, 64);
        for k in 0..20u64 {
            bm.record(
                k,
                EventRecord {
                    departure: r.range_i64(SEC, 20 * SEC),
                    queue: r.range_i64(0, 5 * SEC),
                    batch: r.range_u(1, 26),
                    sent_to: 0,
                },
            );
        }
        let mut last = None;
        for _ in 0..30 {
            let k = r.range_u(0, 20) as u64;
            let before = bm.budget_for(0);
            if r.bool(0.5) {
                bm.apply(
                    Signal::Reject {
                        event: k,
                        eps: r.range_i64(0, 5 * SEC),
                        sum_queue: r.range_i64(1, 10 * SEC),
                    },
                    &xi,
                );
                if before < BUDGET_INF {
                    assert!(bm.budget_for(0) <= before);
                }
            } else {
                bm.apply(
                    Signal::Accept {
                        event: k,
                        eps: r.range_i64(0, 10 * SEC),
                        sum_exec: r.range_i64(1, 10 * SEC),
                    },
                    &xi,
                );
                if before < BUDGET_INF {
                    assert!(bm.budget_for(0) >= before);
                }
            }
            last = Some(bm.budget_for(0));
        }
        let _ = last;
    }
}

// ---------------------------------------------------------------------------
// Ledger conservation.
// ---------------------------------------------------------------------------

#[test]
fn prop_ledger_conservation() {
    use anveshak::dataflow::Stage;
    for mut r in cases(9, 200) {
        let mut l = Ledger::new();
        let n = r.range_u(1, 500) as u64;
        for id in 0..n {
            l.generated(id, r.bool(0.2));
        }
        for id in 0..n {
            match r.range_u(0, 4) {
                0 => l.completed(
                    id,
                    r.range_i64(0, 30 * SEC),
                    15 * SEC,
                    r.bool(0.1),
                ),
                1 => l.dropped(id, Stage::Va),
                2 => l.dropped(id, Stage::Cr),
                _ => {} // stays in flight
            }
        }
        let s = l.summary();
        assert!(s.conserved(), "{s:?}");
        assert_eq!(s.generated, n);
    }
}

// ---------------------------------------------------------------------------
// Whole-engine properties (small random configs).
// ---------------------------------------------------------------------------

#[test]
fn prop_des_conserves_and_is_deterministic() {
    for (i, mut r) in cases(10, 6).enumerate() {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = 100 + i as u64;
        cfg.num_cameras = r.range_u(20, 80);
        cfg.workload.vertices = cfg.num_cameras.max(30);
        cfg.workload.edges = cfg.workload.vertices * 5 / 2;
        cfg.duration_secs = 40.0;
        cfg.batching = match r.range_u(0, 3) {
            0 => BatchingKind::Static {
                size: r.range_u(1, 20),
            },
            1 => BatchingKind::Dynamic {
                max: r.range_u(2, 26),
            },
            _ => BatchingKind::Nob {
                max: r.range_u(2, 26),
            },
        };
        cfg.drops_enabled = r.bool(0.5);
        let a = des::run(cfg.clone());
        let b = des::run(cfg);
        assert!(a.summary.conserved(), "{:?}", a.summary);
        assert_eq!(a.summary.generated, b.summary.generated);
        assert_eq!(a.summary.on_time, b.summary.on_time);
        assert_eq!(a.summary.dropped, b.summary.dropped);
    }
}

// ---------------------------------------------------------------------------
// Compute dynamism + online ξ recalibration.
// ---------------------------------------------------------------------------

#[test]
fn prop_online_xi_converges_to_scaled_cost_frozen_does_not() {
    // A slowdown multiplies the true cost by `factor`. An EMA-refined
    // model converges to the scaled cost at the observed batch size; a
    // frozen model ignores every observation — the unit-level core of
    // the frozen-vs-online engine A/B.
    for mut r in cases(30, 100) {
        let alpha = r.range_f64(10.0, 80.0);
        let beta = r.range_f64(10.0, 80.0);
        let factor = r.range_f64(1.5, 6.0);
        let b = r.range_u(1, 26);
        let mut online =
            XiModel::affine_ms(alpha, beta).with_ema(0.1);
        let mut frozen = XiModel::affine_ms(alpha, beta);
        let truth =
            XiModel::affine_ms(alpha * factor, beta * factor);
        for _ in 0..400 {
            let actual = truth.xi(b);
            online.observe(b, actual);
            frozen.observe(b, actual);
        }
        let est = online.xi(b) as f64;
        let target = truth.xi(b) as f64;
        assert!(
            ((est - target) / target).abs() < 0.05,
            "alpha={alpha} beta={beta} factor={factor} b={b}: \
             est {est} vs target {target}"
        );
        assert_eq!(
            frozen.xi(b),
            XiModel::affine_ms(alpha, beta).xi(b),
            "frozen ξ must ignore observations"
        );
    }
}

#[test]
fn prop_compute_slowdown_runs_deterministic() {
    // Per-seed bit-identical summaries with a compute schedule in
    // play, frozen and online ξ alike, on both DES engines — the
    // slowdown scales durations without touching RNG draw counts.
    for (i, mut r) in cases(31, 4).enumerate() {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = 300 + i as u64;
        cfg.num_cameras = r.range_u(20, 60);
        cfg.workload.vertices = cfg.num_cameras.max(30);
        cfg.workload.edges = cfg.workload.vertices * 5 / 2;
        cfg.duration_secs = 40.0;
        cfg.batching = BatchingKind::Dynamic {
            max: r.range_u(2, 26),
        };
        cfg.drops_enabled = r.bool(0.5);
        cfg.service.online_xi = r.bool(0.5);
        cfg.service.compute_events.push(ComputeEvent {
            at_sec: 15.0,
            node: None,
            factor: r.range_f64(1.5, 5.0),
        });
        let a = des::run(cfg.clone());
        let b = des::run(cfg.clone());
        assert!(a.summary.conserved(), "{:?}", a.summary);
        assert_eq!(a.summary.generated, b.summary.generated);
        assert_eq!(a.summary.on_time, b.summary.on_time);
        assert_eq!(a.summary.delayed, b.summary.delayed);
        assert_eq!(a.summary.dropped, b.summary.dropped);
        assert_eq!(a.detections, b.detections);

        cfg.multi_query.num_queries = 3;
        cfg.multi_query.mean_interarrival_secs = 5.0;
        cfg.multi_query.lifetime_secs = 30.0;
        let ma = des::run_multi(cfg.clone());
        let mb = des::run_multi(cfg);
        assert!(ma.aggregate.conserved(), "{:?}", ma.aggregate);
        assert_eq!(ma.aggregate.generated, mb.aggregate.generated);
        assert_eq!(ma.aggregate.on_time, mb.aggregate.on_time);
        assert_eq!(ma.aggregate.dropped, mb.aggregate.dropped);
    }
}

#[test]
fn prop_fault_schedule_composes_with_compute_dynamism() {
    // Faults are the limiting case of the dynamism machinery
    // (factor -> infinity): a run carrying BOTH a compute slowdown
    // and a fault schedule stays per-seed deterministic and conserves
    // through the lost_to_fault terminal.
    use anveshak::config::{FaultEvent, FaultKind};
    for (i, mut r) in cases(32, 3).enumerate() {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = 800 + i as u64;
        cfg.num_cameras = 40;
        cfg.workload.vertices = 40;
        cfg.workload.edges = 100;
        cfg.duration_secs = 40.0;
        cfg.tl = TlKind::Base;
        cfg.batching = BatchingKind::Dynamic { max: 25 };
        cfg.drops_enabled = r.bool(0.5);
        cfg.service.online_xi = r.bool(0.5);
        cfg.service.compute_events.push(ComputeEvent {
            at_sec: 10.0,
            node: None,
            factor: r.range_f64(1.5, 3.0),
        });
        cfg.service.fault_events.push(FaultEvent {
            at_sec: 20.0,
            kind: FaultKind::NodeCrash {
                node: r.range_u(0, 10),
                down_secs: Some(10.0),
            },
        });
        let a = des::run(cfg.clone());
        let b = des::run(cfg);
        assert!(a.summary.conserved(), "{:?}", a.summary);
        assert_eq!(a.summary.generated, b.summary.generated);
        assert_eq!(a.summary.on_time, b.summary.on_time);
        assert_eq!(a.summary.delayed, b.summary.delayed);
        assert_eq!(a.summary.dropped, b.summary.dropped);
        assert_eq!(
            a.summary.lost_to_fault,
            b.summary.lost_to_fault
        );
        assert_eq!(a.rng_draws, b.rng_draws);
        assert_eq!(a.detections, b.detections);
    }
}

#[test]
fn unit_factor_compute_schedule_is_bit_identical_to_none() {
    // A scheduled factor of exactly 1.0 multiplies every duration by
    // 1.0 — an f64 identity — so the run must match a schedule-free
    // run bit for bit (the fixed-draw-count determinism contract).
    let mut base = ExperimentConfig::default();
    base.num_cameras = 50;
    base.workload.vertices = 50;
    base.workload.edges = 125;
    base.duration_secs = 40.0;
    base.batching = BatchingKind::Dynamic { max: 25 };
    base.drops_enabled = true;
    let r0 = des::run(base.clone());
    let mut c = base;
    c.service.compute_events.push(ComputeEvent {
        at_sec: 10.0,
        node: None,
        factor: 1.0,
    });
    let r1 = des::run(c);
    assert_eq!(r0.summary.generated, r1.summary.generated);
    assert_eq!(r0.summary.on_time, r1.summary.on_time);
    assert_eq!(r0.summary.delayed, r1.summary.delayed);
    assert_eq!(r0.summary.dropped, r1.summary.dropped);
    assert_eq!(r0.detections, r1.detections);
}

#[test]
fn online_xi_outperforms_frozen_under_compute_slowdown() {
    // The §6/Fig 9 claim, compute edition (the ISSUE 5 acceptance
    // scenario): every compute node slows 4x at t = 150 s of a 300 s
    // run with all 60 cameras held active (Base TL). CR capacity falls
    // to ~3.6 ev/s per instance against ~6 ev/s offered — sustained
    // overload. Frozen ξ keeps batching and dropping against a cost
    // model 4x too optimistic (batches submit seconds past their
    // deadlines, stale events are admitted and waste capacity); online
    // ξ re-estimates within a few batches, so the deadline math and
    // the drop gates track the slowed machine and the events that do
    // complete arrive within γ. Identical seeds, identical workloads.
    let mk = |online: bool| {
        let mut c = ExperimentConfig::default();
        c.num_cameras = 60;
        c.workload.vertices = 60;
        c.workload.edges = 160;
        c.duration_secs = 300.0;
        c.tl = TlKind::Base;
        c.batching = BatchingKind::Dynamic { max: 25 };
        c.drops_enabled = true;
        c.service.online_xi = online;
        c.service.compute_events.push(ComputeEvent {
            at_sec: 150.0,
            node: None,
            factor: 4.0,
        });
        c
    };
    let frozen = des::run(mk(false));
    let online = des::run(mk(true));
    assert!(frozen.summary.conserved(), "{:?}", frozen.summary);
    assert!(online.summary.conserved(), "{:?}", online.summary);
    assert_eq!(
        frozen.summary.generated, online.summary.generated,
        "identical workloads by construction"
    );
    // In-time recall: the online-ξ batcher completes more events
    // within γ than the frozen-ξ baseline under the same slowdown.
    assert!(
        online.summary.on_time > frozen.summary.on_time,
        "online ξ should beat frozen ξ on in-time completions: \
         online {:?} vs frozen {:?}",
        online.summary,
        frozen.summary
    );
}

#[test]
fn prop_des_skew_invariant_outcomes() {
    // With clock skews on interior nodes (kappa_1 = kappa_n fixed), the
    // drop/batch decisions — and hence the event outcomes — match the
    // unskewed run (§4.6.2).
    let mut base = ExperimentConfig::default();
    base.num_cameras = 50;
    base.workload.vertices = 50;
    base.workload.edges = 125;
    base.duration_secs = 40.0;
    base.batching = BatchingKind::Dynamic { max: 25 };
    base.drops_enabled = true;

    let r0 = des::run(base.clone());
    for skew_ms in [100.0, 500.0, 2_000.0] {
        let mut cfg = base.clone();
        cfg.cluster.clock_skew_ms = skew_ms;
        let r = des::run(cfg);
        assert_eq!(
            r.summary.generated, r0.summary.generated,
            "skew {skew_ms}ms changed workload"
        );
        assert_eq!(
            r.summary.on_time, r0.summary.on_time,
            "skew {skew_ms}ms changed on-time count"
        );
        assert_eq!(
            r.summary.dropped, r0.summary.dropped,
            "skew {skew_ms}ms changed drops"
        );
        assert_eq!(r.summary.delayed, r0.summary.delayed);
    }
}
