//! Property tests for the CSR road graph and the workspace-backed
//! spotlight expansions (hand-rolled generator loops, same idiom as
//! `prop_tuning.rs` — the offline environment has no proptest crate).
//!
//! The reference implementations below are the pre-CSR adjacency-list
//! algorithms, verbatim: hop-BFS over `Vec<Vec<(v, len)>>`, a full
//! Dijkstra distance vector, and the filter-enumerate WBFS. Properties
//! assert that the CSR + epoch-stamped-workspace implementations are
//! permutation-equal to them on random graphs, radii and sources, and
//! that workspace reuse across expansions (including across graphs of
//! different sizes) never leaks state.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anveshak::roadnet::{
    bfs_spotlight, bfs_spotlight_into, dijkstra_distances,
    probabilistic_spotlight, probabilistic_spotlight_into,
    wbfs_spotlight, wbfs_spotlight_into, Graph, GraphBuilder,
    SpotlightWorkspace,
};
use anveshak::util::{rng, Rng};

fn cases(seed: u64, n: usize) -> impl Iterator<Item = Rng> {
    (0..n).map(move |i| rng(seed, i as u64))
}

/// Legacy adjacency-list representation, rebuilt from the CSR graph.
fn adjacency(g: &Graph) -> Vec<Vec<(usize, f64)>> {
    (0..g.num_vertices())
        .map(|v| g.neighbors(v).to_vec())
        .collect()
}

/// Random graph + its mirror adjacency list built by replaying the
/// same accepted insertions on both representations.
fn random_graph(r: &mut Rng) -> (Graph, Vec<Vec<(usize, f64)>>) {
    let n = r.range_u(2, 60);
    let pos = (0..n)
        .map(|_| (r.range_f64(0.0, 1000.0), r.range_f64(0.0, 1000.0)))
        .collect();
    let mut b = GraphBuilder::new(pos);
    let mut adj: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    let attempts = r.range_u(1, 4 * n);
    for _ in 0..attempts {
        let x = r.range_u(0, n);
        let y = r.range_u(0, n);
        let len = r.range_f64(10.0, 200.0);
        if b.add_edge(x, y, len) {
            adj[x].push((y, len));
            adj[y].push((x, len));
        }
    }
    (b.finalize(), adj)
}

// ---- reference implementations (pre-CSR, verbatim) -------------------

fn ref_bfs(
    adj: &[Vec<(usize, f64)>],
    src: usize,
    radius_m: f64,
    fixed_len_m: f64,
) -> Vec<usize> {
    let max_hops = if fixed_len_m <= 0.0 {
        0
    } else {
        (radius_m / fixed_len_m).floor() as usize
    };
    let mut dist = vec![usize::MAX; adj.len()];
    let mut queue = std::collections::VecDeque::new();
    dist[src] = 0;
    queue.push_back(src);
    let mut out = vec![src];
    while let Some(v) = queue.pop_front() {
        if dist[v] >= max_hops {
            continue;
        }
        for &(u, _) in &adj[v] {
            if dist[u] == usize::MAX {
                dist[u] = dist[v] + 1;
                out.push(u);
                queue.push_back(u);
            }
        }
    }
    out
}

#[derive(PartialEq)]
struct HeapItem(f64, usize);
impl Eq for HeapItem {}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
    }
}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

fn ref_dijkstra(
    adj: &[Vec<(usize, f64)>],
    src: usize,
    max_m: f64,
) -> Vec<f64> {
    let mut dist = vec![f64::INFINITY; adj.len()];
    let mut heap = BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(HeapItem(0.0, src));
    while let Some(HeapItem(d, v)) = heap.pop() {
        if d > dist[v] || d > max_m {
            continue;
        }
        for &(u, len) in &adj[v] {
            let nd = d + len;
            if nd < dist[u] && nd <= max_m {
                dist[u] = nd;
                heap.push(HeapItem(nd, u));
            }
        }
    }
    dist
}

fn ref_wbfs(
    adj: &[Vec<(usize, f64)>],
    src: usize,
    radius_m: f64,
) -> Vec<usize> {
    ref_dijkstra(adj, src, radius_m)
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d.is_finite())
        .map(|(v, _)| v)
        .collect()
}

fn ref_probabilistic(
    adj: &[Vec<(usize, f64)>],
    src: usize,
    es_mps: f64,
    elapsed_s: f64,
    mass: f64,
) -> Vec<usize> {
    let mu = es_mps * elapsed_s;
    let sigma = (0.35 * mu).max(30.0);
    let dist = ref_dijkstra(adj, src, mu + 4.0 * sigma);
    let mut lik: Vec<(f64, usize)> = dist
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d.is_finite())
        .map(|(v, &d)| {
            let l = if d <= mu {
                1.0
            } else {
                (-((d - mu) / sigma).powi(2) / 2.0).exp()
            };
            (l, v)
        })
        .collect();
    lik.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let total: f64 = lik.iter().map(|&(l, _)| l).sum();
    let mut acc = 0.0;
    let mut out = Vec::new();
    for (l, v) in lik {
        out.push(v);
        acc += l;
        if acc >= mass * total {
            break;
        }
    }
    out
}

fn sorted(mut v: Vec<usize>) -> Vec<usize> {
    v.sort_unstable();
    v
}

// ---- properties ------------------------------------------------------

#[test]
fn prop_csr_neighbors_match_adjacency_mirror() {
    for mut r in cases(11, 200) {
        let (g, adj) = random_graph(&mut r);
        assert_eq!(
            g.num_edges(),
            adj.iter().map(|a| a.len()).sum::<usize>() / 2
        );
        for v in 0..g.num_vertices() {
            assert_eq!(
                g.neighbors(v),
                adj[v].as_slice(),
                "vertex {v}: CSR must preserve insertion order"
            );
        }
    }
}

#[test]
fn prop_builder_dedup_rejects_duplicates_and_loops() {
    for mut r in cases(12, 200) {
        let n = r.range_u(2, 40);
        let pos = (0..n).map(|_| (0.0, 0.0)).collect();
        let mut b = GraphBuilder::new(pos);
        let mut unique = std::collections::BTreeSet::new();
        for _ in 0..r.range_u(1, 200) {
            let x = r.range_u(0, n);
            let y = r.range_u(0, n);
            let accepted = b.add_edge(x, y, 1.0);
            let fresh =
                x != y && unique.insert((x.min(y), x.max(y)));
            assert_eq!(accepted, fresh, "edge ({x},{y})");
        }
        assert_eq!(b.num_edges(), unique.len());
        let g = b.finalize();
        assert_eq!(g.num_edges(), unique.len());
    }
}

#[test]
fn prop_wbfs_matches_reference_on_random_graphs() {
    for mut r in cases(13, 300) {
        let (g, adj) = random_graph(&mut r);
        let src = r.range_u(0, g.num_vertices());
        let radius = r.range_f64(0.0, 800.0);
        let got = sorted(wbfs_spotlight(&g, src, radius));
        let want = sorted(ref_wbfs(&adj, src, radius));
        assert_eq!(got, want, "src {src} radius {radius}");
    }
}

#[test]
fn prop_bfs_matches_reference_on_random_graphs() {
    for mut r in cases(14, 300) {
        let (g, adj) = random_graph(&mut r);
        let src = r.range_u(0, g.num_vertices());
        let radius = r.range_f64(0.0, 800.0);
        let fixed = r.range_f64(1.0, 150.0);
        // BFS discovery order is identical, not just the set.
        assert_eq!(
            bfs_spotlight(&g, src, radius, fixed),
            ref_bfs(&adj, src, radius, fixed),
            "src {src} radius {radius} fixed {fixed}"
        );
    }
}

#[test]
fn prop_dijkstra_matches_reference_exactly() {
    for mut r in cases(15, 200) {
        let (g, adj) = random_graph(&mut r);
        let src = r.range_u(0, g.num_vertices());
        let max = if r.bool(0.5) {
            f64::INFINITY
        } else {
            r.range_f64(0.0, 600.0)
        };
        assert_eq!(
            dijkstra_distances(&g, src, max),
            ref_dijkstra(&adj, src, max),
            "src {src} max {max}"
        );
    }
}

#[test]
fn prop_probabilistic_matches_reference_exactly() {
    for mut r in cases(16, 200) {
        let (g, adj) = random_graph(&mut r);
        let src = r.range_u(0, g.num_vertices());
        let es = r.range_f64(0.5, 8.0);
        let elapsed = r.range_f64(1.0, 120.0);
        let mass = r.range_f64(0.3, 0.99);
        // The likelihood sort is a total order (id tie-break), so the
        // output sequence — not just the set — must match.
        assert_eq!(
            probabilistic_spotlight(&g, src, es, elapsed, mass),
            ref_probabilistic(&adj, src, es, elapsed, mass),
            "src {src} es {es} elapsed {elapsed} mass {mass}"
        );
    }
}

#[test]
fn prop_workspace_reuse_never_leaks_state() {
    // One workspace, many interleaved expansions over two graphs of
    // different sizes and all three algorithms: every result must
    // equal the fresh-workspace computation.
    for mut r in cases(17, 60) {
        let (g1, _) = random_graph(&mut r);
        let (g2, _) = random_graph(&mut r);
        let mut ws = SpotlightWorkspace::new();
        let mut out = Vec::new();
        for _ in 0..20 {
            let g = if r.bool(0.5) { &g1 } else { &g2 };
            let src = r.range_u(0, g.num_vertices());
            match r.range_u(0, 3) {
                0 => {
                    let radius = r.range_f64(0.0, 600.0);
                    wbfs_spotlight_into(g, src, radius, &mut ws, &mut out);
                    assert_eq!(
                        sorted(out.clone()),
                        sorted(wbfs_spotlight(g, src, radius)),
                    );
                }
                1 => {
                    let radius = r.range_f64(0.0, 600.0);
                    let fixed = r.range_f64(1.0, 150.0);
                    bfs_spotlight_into(
                        g, src, radius, fixed, &mut ws, &mut out,
                    );
                    assert_eq!(
                        out,
                        bfs_spotlight(g, src, radius, fixed),
                    );
                }
                _ => {
                    let es = r.range_f64(0.5, 8.0);
                    let elapsed = r.range_f64(1.0, 120.0);
                    probabilistic_spotlight_into(
                        g, src, es, elapsed, 0.9, &mut ws, &mut out,
                    );
                    assert_eq!(
                        out,
                        probabilistic_spotlight(g, src, es, elapsed, 0.9),
                    );
                }
            }
        }
    }
}

#[test]
fn prop_workspace_epoch_wrap_is_safe() {
    // Force many expansions on a tiny graph so the epoch counter
    // advances far; results must stay correct throughout. (A full u32
    // wrap is impractical in a test; this at least exercises heavy
    // epoch churn on the same arrays.)
    let mut r = rng(18, 0);
    let (g, adj) = random_graph(&mut r);
    let mut ws = SpotlightWorkspace::new();
    let mut out = Vec::new();
    for i in 0..5_000 {
        let src = i % g.num_vertices();
        wbfs_spotlight_into(&g, src, 300.0, &mut ws, &mut out);
        assert_eq!(
            sorted(out.clone()),
            sorted(ref_wbfs(&adj, src, 300.0)),
            "iteration {i}"
        );
    }
}
