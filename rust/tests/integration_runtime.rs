//! Integration: AOT artifacts load + execute on the PJRT CPU client and
//! reproduce the Python models' semantics (identity separation, query
//! bootstrap, batch-bucket padding). Requires `make artifacts` and the
//! `pjrt` feature (the whole file is compiled out otherwise, so the
//! default test run is green on machines without PJRT).
#![cfg(feature = "pjrt")]

use anveshak::runtime::ModelPool;
use anveshak::sim::{identity_image, FEAT_DIM, IMG_DIM};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts"
    ))
}

fn pool(variants: &[&str], buckets: &[usize]) -> ModelPool {
    ModelPool::load(&artifacts_dir(), variants, Some(buckets))
        .expect("run `make artifacts` before cargo test")
}

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    dot / (na * nb).max(1e-9)
}

#[test]
fn va_model_executes_and_scores() {
    let p = pool(&["va"], &[1, 4]);
    assert_eq!(p.img_dim(), IMG_DIM);
    assert_eq!(p.feat_dim(), FEAT_DIM);

    // Bootstrap the query embedding from identity 42's image.
    let qimg = identity_image(42, 0, 0.25);
    let qemb = p.embed_query("va", &qimg).unwrap();
    assert_eq!(qemb.len(), FEAT_DIM);

    // Batch: two frames of identity 42, two of other identities.
    let mut images = Vec::new();
    for (ident, frame) in [(42, 1), (42, 2), (7, 1), (99, 1)] {
        images.extend(identity_image(ident, frame, 0.25));
    }
    let out = p.execute("va", &images, &qemb).unwrap();
    assert_eq!(out.scores.len(), 4);
    assert_eq!(out.embeddings.len(), 4 * FEAT_DIM);
    assert!(
        out.scores[0] > 0.7 && out.scores[1] > 0.7,
        "positives {:?}",
        out.scores
    );
    assert!(
        out.scores[2] < 0.5 && out.scores[3] < 0.5,
        "negatives {:?}",
        out.scores
    );
}

#[test]
fn cr_models_separate_identities() {
    for variant in ["cr_small", "cr_large"] {
        let p = pool(&[variant], &[1, 4]);
        let qemb = p
            .embed_query(variant, &identity_image(11, 0, 0.25))
            .unwrap();
        let mut images = Vec::new();
        for (ident, frame) in [(11, 5), (23, 5)] {
            images.extend(identity_image(ident, frame, 0.25));
        }
        let out = p.execute(variant, &images, &qemb).unwrap();
        assert!(
            out.scores[0] > out.scores[1] + 0.3,
            "{variant}: {:?}",
            out.scores
        );
    }
}

#[test]
fn bucket_padding_is_transparent() {
    let p = pool(&["va"], &[1, 4, 8]);
    let qemb = p.embed_query("va", &identity_image(1, 0, 0.25)).unwrap();

    // Batch of 3 -> bucket 4; batch of 5 -> bucket 8. Scores for the
    // same frames must agree regardless of padding.
    let frames: Vec<Vec<f32>> =
        (0..5).map(|f| identity_image(1, f, 0.25)).collect();
    let b3: Vec<f32> = frames[..3].concat();
    let b5: Vec<f32> = frames.concat();
    let o3 = p.execute("va", &b3, &qemb).unwrap();
    let o5 = p.execute("va", &b5, &qemb).unwrap();
    assert_eq!(o3.scores.len(), 3);
    assert_eq!(o5.scores.len(), 5);
    for i in 0..3 {
        assert!(
            (o3.scores[i] - o5.scores[i]).abs() < 1e-4,
            "score {i}: {} vs {}",
            o3.scores[i],
            o5.scores[i]
        );
    }
}

#[test]
fn embeddings_cluster_by_identity() {
    let p = pool(&["cr_small"], &[4]);
    let q = vec![0f32; FEAT_DIM];
    let mut images = Vec::new();
    for (ident, frame) in [(5, 0), (5, 1), (9, 0), (9, 1)] {
        images.extend(identity_image(ident, frame, 0.25));
    }
    let out = p.execute("cr_small", &images, &q).unwrap();
    let e: Vec<&[f32]> = out.embeddings.chunks(FEAT_DIM).collect();
    let same_a = cosine(e[0], e[1]);
    let same_b = cosine(e[2], e[3]);
    let cross = cosine(e[0], e[2]);
    assert!(same_a > 0.8, "same_a {same_a}");
    assert!(same_b > 0.8, "same_b {same_b}");
    assert!(cross < 0.5, "cross {cross}");
}

#[test]
fn xi_calibration_monotone() {
    let p = pool(&["cr_small"], &[1, 8, 32]);
    let (xi, samples) = p.calibrate_xi("cr_small", 3).unwrap();
    assert_eq!(samples.len(), 3);
    // Larger buckets take longer in absolute terms...
    assert!(samples[2].1 > samples[0].1, "{samples:?}");
    // ...and the fitted model is monotone.
    assert!(xi.xi(32) > xi.xi(1));
    // Batching amortizes the PJRT invocation overhead.
    let per_event_1 = samples[0].1 as f64;
    let per_event_32 = samples[2].1 as f64 / 32.0;
    assert!(
        per_event_32 < per_event_1,
        "batch-32 per-event {per_event_32} vs solo {per_event_1}"
    );
}

#[test]
fn zero_query_disables_score_head() {
    let p = pool(&["va"], &[1]);
    let q = vec![0f32; FEAT_DIM];
    let out = p.execute("va", &identity_image(3, 0, 0.25), &q).unwrap();
    assert!(out.scores[0].abs() < 1e-4, "{}", out.scores[0]);
}

#[test]
fn bad_inputs_are_errors() {
    let p = pool(&["va"], &[1]);
    let q = vec![0f32; FEAT_DIM];
    // Wrong image length.
    assert!(p.execute("va", &vec![0f32; 100], &q).is_err());
    // Wrong query length.
    let img = identity_image(1, 0, 0.25);
    assert!(p.execute("va", &img, &vec![0f32; 3]).is_err());
    // Unknown variant.
    assert!(p.execute("nope", &img, &q).is_err());
    // Empty batch.
    assert!(p.execute("va", &[], &q).is_err());
}
