//! Block-API equivalence and safety properties.
//!
//! The api redesign inverted the engines' dependency on application
//! logic: `AppKind` conditionals are gone and every execution path
//! drives UDF trait objects. These tests pin the contract:
//!
//! 1. **Engine/API equivalence** — for every Table-1 app and several
//!    seeds, a run through the explicit `AppDefinition` trait path is
//!    metric-identical (summary counters, detections, dispatched
//!    events, per-tick active-set sizes) to the config-resolved path,
//!    on both the single-query and multi-query DES engines.
//! 2. **Object safety** — every block trait works as `Box<dyn …>`
//!    behind one indirection, including heterogeneous collections.
//! 3. **User-defined blocks** — a block implemented *in this test
//!    file* (outside the crate's modules) runs through the public API
//!    and visibly changes behaviour.
//! 4. **Totality of the TL library** — `TlKind::Base` is a working
//!    stock block; no input sequence reaches a panic.

use anveshak::apps::{self, AppBuilder, SimDetector, SimReid};
use anveshak::config::{
    AppKind, BatchingKind, ExperimentConfig, TlKind,
};
use anveshak::coordinator::des;
use anveshak::coordinator::{stock_tl, KeepAllActive};
use anveshak::dataflow::{
    ContentionResolver, FilterControl, ModelVariant, QueryFusion,
    QueryId, TlEnv, TrackingLogic, VideoAnalytics,
};
use anveshak::roadnet::{generate, place_cameras};
use anveshak::service::engine as mq_engine;
use anveshak::util::Micros;

fn base_cfg(seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.seed = seed;
    c.num_cameras = 60;
    c.workload.vertices = 60;
    c.workload.edges = 160;
    c.duration_secs = 60.0;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c
}

/// The two public entry points must be the same machine: resolving the
/// app from the config vs. handing the engine the explicit Table-1
/// `AppDefinition` (with the config's TL, as `resolve` documents).
#[test]
fn table_apps_trait_path_is_metric_identical_per_seed() {
    let kinds = [
        AppKind::App1,
        AppKind::App2,
        AppKind::App3,
        AppKind::App4,
    ];
    for kind in kinds {
        for seed in [2019u64, 7, 91] {
            let mut cfg = base_cfg(seed);
            cfg.app = kind;
            apps::table1(kind).apply(&mut cfg, true);

            let via_config = des::run(cfg.clone());
            let explicit =
                apps::table1(kind).with_tl_kind(cfg.tl);
            let via_api = des::run_app(cfg.clone(), &explicit);

            let (a, b) = (&via_config.summary, &via_api.summary);
            assert_eq!(a.generated, b.generated, "{kind:?}/{seed}");
            assert_eq!(a.on_time, b.on_time, "{kind:?}/{seed}");
            assert_eq!(a.delayed, b.delayed, "{kind:?}/{seed}");
            assert_eq!(a.dropped, b.dropped, "{kind:?}/{seed}");
            assert_eq!(
                a.true_positives, b.true_positives,
                "{kind:?}/{seed}"
            );
            assert_eq!(
                via_config.detections, via_api.detections,
                "{kind:?}/{seed}"
            );
            assert_eq!(
                via_config.peak_active, via_api.peak_active,
                "{kind:?}/{seed}"
            );
            assert_eq!(
                via_config.core_events, via_api.core_events,
                "{kind:?}/{seed}: dispatched-event counts must match"
            );
            // Per-tick active-set sizes (the TL trajectory).
            let rows_a: Vec<usize> = via_config
                .timeline
                .rows()
                .iter()
                .map(|r| r.active_cameras)
                .collect();
            let rows_b: Vec<usize> = via_api
                .timeline
                .rows()
                .iter()
                .map(|r| r.active_cameras)
                .collect();
            assert_eq!(rows_a, rows_b, "{kind:?}/{seed}: active sets");
        }
    }
}

/// Same equivalence on the multi-query engine (cross-query batches,
/// per-query ledgers).
#[test]
fn multi_query_trait_path_is_metric_identical() {
    for seed in [2019u64, 13] {
        let mut cfg = base_cfg(seed);
        cfg.multi_query.num_queries = 3;
        cfg.multi_query.mean_interarrival_secs = 5.0;
        cfg.multi_query.lifetime_secs = 40.0;
        let mq = cfg.multi_query.clone();

        let via_config = mq_engine::run(cfg.clone(), mq.clone());
        let explicit = apps::table1(cfg.app).with_tl_kind(cfg.tl);
        let via_api = mq_engine::run_app(cfg.clone(), mq, &explicit);

        assert_eq!(
            via_config.aggregate.generated,
            via_api.aggregate.generated
        );
        assert_eq!(
            via_config.aggregate.on_time,
            via_api.aggregate.on_time
        );
        assert_eq!(
            via_config.aggregate.dropped,
            via_api.aggregate.dropped
        );
        assert_eq!(via_config.core_events, via_api.core_events);
        assert_eq!(
            via_config.peak_concurrent,
            via_api.peak_concurrent
        );
        for (qa, qb) in
            via_config.queries.iter().zip(via_api.queries.iter())
        {
            assert_eq!(qa.detections, qb.detections, "query {}", qa.id);
            assert_eq!(
                qa.peak_active, qb.peak_active,
                "query {}",
                qa.id
            );
        }
    }
}

/// Determinism through the trait path: same seed, same everything.
#[test]
fn trait_path_runs_are_deterministic() {
    let app = apps::app5();
    let mut cfg = base_cfg(2019);
    app.apply(&mut cfg, true);
    let a = des::run_app(cfg.clone(), &app);
    let b = des::run_app(cfg, &app);
    assert_eq!(a.summary.generated, b.summary.generated);
    assert_eq!(a.summary.on_time, b.summary.on_time);
    assert_eq!(a.detections, b.detections);
    assert_eq!(a.core_events, b.core_events);
}

/// App 2's fusion block refines embeddings and (since the feedback
/// edge went live) those refinements flow back into VA/CR — so a
/// fusing run is *deterministic* but no longer contractually identical
/// to a fusion-less one. A QF that never refines (here: a fusing block
/// with an unreachable confidence bar) must still be exactly
/// metric-neutral: the plumbing itself costs nothing.
#[test]
fn query_fusion_refines_and_inert_qf_is_metric_neutral() {
    let mut cfg = base_cfg(2019);
    apps::table1(AppKind::App2).apply(&mut cfg, true);
    let with_qf = des::run_app(
        cfg.clone(),
        &apps::table1(AppKind::App2).with_tl_kind(cfg.tl),
    );
    assert!(with_qf.fusion_updates > 0, "App 2 fuses on detections");
    // Determinism through the live feedback loop.
    let again = des::run_app(
        cfg.clone(),
        &apps::table1(AppKind::App2).with_tl_kind(cfg.tl),
    );
    assert_eq!(with_qf.summary.generated, again.summary.generated);
    assert_eq!(with_qf.detections, again.detections);
    assert_eq!(with_qf.fusion_updates, again.fusion_updates);
    assert_eq!(with_qf.core_events, again.core_events);

    // Identical composition except fusion disabled…
    let no_qf = AppBuilder::new("app2-no-qf")
        .video_analytics(SimDetector::hog())
        .contention_resolver(SimReid::large())
        .tracking_logic(cfg.tl)
        .build();
    let without = des::run_app(cfg.clone(), &no_qf);
    assert_eq!(without.fusion_updates, 0);
    // …and the same again with a QF that *fuses* but can never reach
    // its confidence bar: no refinement is minted, so the feedback
    // plumbing must leave every metric bit-identical.
    let inert = AppBuilder::new("app2-inert-qf")
        .video_analytics(SimDetector::hog())
        .contention_resolver(SimReid::large())
        .query_fusion(anveshak::apps::RnnFusion::new(8, 0.9, 2.0))
        .tracking_logic(cfg.tl)
        .build();
    let inert_run = des::run_app(cfg, &inert);
    assert_eq!(inert_run.fusion_updates, 0);
    assert_eq!(
        inert_run.summary.generated,
        without.summary.generated
    );
    assert_eq!(inert_run.summary.on_time, without.summary.on_time);
    assert_eq!(inert_run.detections, without.detections);
    assert_eq!(inert_run.core_events, without.core_events);
}

/// Heterogeneous boxed blocks — the engines' actual usage pattern.
#[test]
fn blocks_are_object_safe_in_collections() {
    let vas: Vec<Box<dyn VideoAnalytics>> = vec![
        Box::new(SimDetector::hog()),
        Box::new(SimDetector::yolo()),
        Box::new(SimDetector::reid_small()),
    ];
    assert_eq!(
        vas.iter().map(|b| b.variant()).collect::<Vec<_>>(),
        vec![
            ModelVariant::Va,
            ModelVariant::Va,
            ModelVariant::CrSmall
        ]
    );
    let crs: Vec<Box<dyn ContentionResolver>> =
        vec![Box::new(SimReid::small()), Box::new(SimReid::large())];
    assert!(crs[1].cost() > crs[0].cost());

    // TL via the stock factory, exercised through the trait object.
    let g = generate(&Default::default(), 3);
    let cams = place_cameras(&g, 50, 0, 40.0);
    let env = TlEnv {
        peak_speed_mps: 4.0,
        mean_road_m: 84.5,
        fov_m: 40.0,
        cameras: &cams,
    };
    let mut tls: Vec<Box<dyn TrackingLogic>> = vec![
        stock_tl(TlKind::Base, &env),
        stock_tl(TlKind::Bfs, &env),
        stock_tl(TlKind::Wbfs, &env),
        stock_tl(TlKind::WbfsSpeed, &env),
        stock_tl(TlKind::Probabilistic, &env),
    ];
    let mut out = Vec::new();
    for tl in tls.iter_mut() {
        tl.on_detection(3, 1_000_000, true);
        tl.on_detection(3, 2_000_000, false);
        tl.active_set_into(&g, 30_000_000, &mut out);
        assert!(!out.is_empty());
    }
}

/// The old `TlKind::Base => unreachable!()` is structurally gone:
/// `Base` is [`KeepAllActive`], total over any detection sequence.
#[test]
fn base_tl_is_total_not_a_panic_path() {
    let g = generate(&Default::default(), 3);
    let cams = place_cameras(&g, 40, 0, 40.0);
    let mut tl = KeepAllActive::with_cameras(&cams);
    let mut out = Vec::new();
    // Arbitrary (including stale/out-of-order) detection sequences.
    for (cam, t, det) in [
        (5usize, 10i64, true),
        (7, 5, true),
        (5, 20, false),
        (39, 30, true),
        (0, 1, false),
    ] {
        tl.on_detection(cam, t as Micros, det);
        tl.active_set_into(&g, (t + 1) as Micros, &mut out);
        assert_eq!(out.len(), 40, "Base keeps the whole network live");
    }
    assert!(tl.last_seen().is_some());

    // And end to end: a full DES run under Base never panics.
    let mut cfg = base_cfg(2019);
    cfg.tl = TlKind::Base;
    cfg.duration_secs = 20.0;
    let r = des::run(cfg);
    assert!(r.summary.conserved());
}

/// A block defined *here* — outside the crate's modules — composes and
/// runs through the public API, and its policy visibly bites: a
/// half-rate FC admits roughly half the frames of the stock app.
#[test]
fn user_defined_fc_runs_through_public_api() {
    #[derive(Clone)]
    struct HalfRateFc;
    impl FilterControl for HalfRateFc {
        fn admit(
            &mut self,
            _query: QueryId,
            _camera: usize,
            frame_no: u64,
            _now: Micros,
            active: bool,
        ) -> bool {
            active && frame_no % 2 == 0
        }
        fn label(&self) -> &'static str {
            "half-rate"
        }
    }

    let cfg = base_cfg(2019);
    let stock = des::run_app(
        cfg.clone(),
        &apps::table1(AppKind::App1).with_tl_kind(cfg.tl),
    );
    let custom_app = AppBuilder::new("half-rate")
        .filter_control(HalfRateFc)
        .tracking_logic(cfg.tl)
        .build();
    let custom = des::run_app(cfg, &custom_app);

    assert!(custom.summary.conserved());
    assert!(custom.summary.generated > 0);
    assert!(
        custom.summary.generated < stock.summary.generated,
        "half-rate FC must admit fewer frames: {} vs {}",
        custom.summary.generated,
        stock.summary.generated
    );
}

/// A user-defined QF block is invoked at the sink through the trait.
#[test]
fn user_defined_qf_counts_detections() {
    #[derive(Clone, Default)]
    struct CountingQf;
    impl QueryFusion for CountingQf {
        fn on_detection(
            &mut self,
            ev: &anveshak::dataflow::Event,
        ) -> bool {
            matches!(
                ev.payload,
                anveshak::dataflow::Payload::Detection {
                    detected: true,
                    ..
                }
            )
        }
        fn fuses(&self) -> bool {
            true
        }
    }

    let cfg = base_cfg(2019);
    let app = AppBuilder::new("counting-qf")
        .query_fusion(CountingQf)
        .tracking_logic(cfg.tl)
        .build();
    let r = des::run_app(cfg, &app);
    assert!(r.detections > 0);
    assert_eq!(
        r.fusion_updates, r.detections,
        "QF sees every confirmed detection"
    );
}

/// Typed model handles: a typo is a composition-time error naming the
/// valid set, not a runtime artifact miss.
#[test]
fn model_variant_resolution_errors_are_clear() {
    let err = ModelVariant::from_artifact("cr_big").unwrap_err();
    assert!(err.contains("cr_big"));
    for valid in ["va", "cr_small", "cr_large", "qf"] {
        assert!(err.contains(valid), "error lists {valid}: {err}");
        assert!(ModelVariant::from_artifact(valid).is_ok());
    }
}
