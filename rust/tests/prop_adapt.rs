//! Property suite for the adaptation plane (`tuning::adapt`).
//!
//! The contract under test, end to end:
//!
//! * **Identity-ladder bit-identity** — for *any* generated
//!   [`AdaptationConfig`], every inert toggle combination (controller
//!   off, controller on over the identity ladder, controller off over
//!   the generated ladder) leaves both DES engines bit-identical per
//!   seed: `Summary`, detections, fusion updates, dispatch count and
//!   RNG draws all match the pre-adaptation baseline exactly.
//! * **Exactly-once, stale-discard** — a command stream delivered in
//!   *any* arrival order applies each `(camera, seq)` at most once,
//!   lands on the highest-seq command, and discards duplicates and
//!   out-of-order stragglers deterministically — under the *same*
//!   staleness rule as query refinements ([`FeedbackState`]), which
//!   shares the feedback envelope.
//! * **Controller beats frozen** — under generated severe compute
//!   slowdowns (the DeepScale regime), the controller arm completes at
//!   least as many on-time events as the frozen arm at the same seed,
//!   and strictly more whenever it actually engaged; offered load is
//!   identical across the arms and both ledgers conserve.
//! * **K-invariance** — adaptation-enabled runs are bit-identical
//!   across generated shard plans: command minting, routing and
//!   application commute with `shard_plan()`.
//!
//! Failures shrink toward the canonical do-nothing value (the enabled
//! identity ladder, the empty schedule, the unsharded plan) and the
//! `adapt` A/B property persists `seed case` pairs in
//! `rust/tests/regressions/adapt.seeds`.

use std::sync::Arc;

use anveshak::check::domain::{
    adaptation_config, arrival_order, compute_schedule, shard_plan,
    ShardPlan,
};
use anveshak::check::runner::regression_seeds;
use anveshak::check::{check, generate_case, CheckConfig};
use anveshak::config::{
    preset, AdaptationConfig, BatchingKind, ComputeEvent,
    ExperimentConfig, TlKind,
};
use anveshak::coordinator::des;
use anveshak::dataflow::{FeedbackState, ModelVariant};
use anveshak::service::engine as mq_engine;
use anveshak::tuning::adapt::{AdaptationCommand, AdaptationState};

// ---------------------------------------------------------------------------
// Identity-ladder bit-identity across every inert toggle.
// ---------------------------------------------------------------------------

/// Small-but-busy single-query config (the `prop_feedback` workload).
fn base_cfg(seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.seed = seed;
    c.num_cameras = 60;
    c.workload.vertices = 60;
    c.workload.edges = 160;
    c.duration_secs = 30.0;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c.drops_enabled = true;
    c
}

#[test]
fn prop_inert_toggles_are_bit_identical_on_both_engines() {
    // The headline determinism contract: an adaptation-aware build with
    // an inert plane is the pre-adaptation build, per seed, by
    // construction. Three inert arms per generated config: the default
    // (off, identity ladder), the controller switched ON over the
    // identity ladder, and the controller switched OFF over the
    // generated non-trivial ladder.
    check(
        "adapt_identity",
        &CheckConfig::with_cases(2),
        &adaptation_config(),
        |g| {
            let mut identity_on = AdaptationConfig::default();
            identity_on.enabled = true;
            let mut generated_off = g.clone();
            generated_off.enabled = false;
            for ad in [&identity_on, &generated_off] {
                if !ad.is_identity() {
                    return Err(format!("arm not inert: {ad:?}"));
                }
            }

            let run_with = |ad: &AdaptationConfig| {
                let mut c = base_cfg(2019);
                c.adaptation = ad.clone();
                des::run(c)
            };
            let want = run_with(&AdaptationConfig::default());
            for (arm, ad) in
                [("identity_on", &identity_on), ("gen_off", &generated_off)]
            {
                let got = run_with(ad);
                if got.summary != want.summary
                    || got.detections != want.detections
                    || got.fusion_updates != want.fusion_updates
                    || got.core_events != want.core_events
                    || got.rng_draws != want.rng_draws
                {
                    return Err(format!(
                        "DES diverged under inert arm {arm}: {:?} != {:?}",
                        got.summary, want.summary
                    ));
                }
                if got.metrics.adapt_minted != 0
                    || got.metrics.adapt_applied != 0
                {
                    return Err(format!(
                        "inert arm {arm} minted/applied commands"
                    ));
                }
            }

            // Same contract on the multi-query engine, down to the
            // per-query ledger rows.
            let mq_run = |ad: &AdaptationConfig| {
                let mut c = base_cfg(2019);
                c.adaptation = ad.clone();
                c.multi_query.num_queries = 3;
                c.multi_query.mean_interarrival_secs = 5.0;
                c.multi_query.lifetime_secs = 20.0;
                let mq = c.multi_query.clone();
                mq_engine::run(c, mq)
            };
            let mwant = mq_run(&AdaptationConfig::default());
            for (arm, ad) in
                [("identity_on", &identity_on), ("gen_off", &generated_off)]
            {
                let mgot = mq_run(ad);
                if mgot.aggregate != mwant.aggregate
                    || mgot.fusion_updates != mwant.fusion_updates
                    || mgot.core_events != mwant.core_events
                    || mgot.rng_draws != mwant.rng_draws
                {
                    return Err(format!(
                        "mq engine diverged under inert arm {arm}"
                    ));
                }
                for (a, b) in
                    mgot.queries.iter().zip(mwant.queries.iter())
                {
                    if a.summary != b.summary
                        || a.detections != b.detections
                    {
                        return Err(format!(
                            "query {} ledger diverged under inert \
                             arm {arm}",
                            a.id
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Exactly-once, stale-discard — shared staleness rule with refinements.
// ---------------------------------------------------------------------------

#[test]
fn prop_commands_apply_exactly_once_in_any_delivery_order() {
    // A per-camera stream of commands seq = 1..=n delivered in an
    // arbitrary order: only the running-max prefix applies (exactly the
    // left-to-right maxima of the delivery order), the state lands on
    // the highest seq, and a full redelivery is discarded wholesale.
    // The FeedbackState refinement ledger, driven by the same delivery
    // order, must accept/reject the *same* pattern — one staleness rule
    // across both feedback flavors.
    let n = 12usize;
    let strat = (arrival_order(n), adaptation_config());
    check(
        "adapt_once",
        &CheckConfig::with_cases(32),
        &strat,
        |(order, ad)| {
            let rungs = ad.ladder.len();
            let nominal = ModelVariant::CrLarge;
            let cmd = |seq: usize| {
                let level = seq % rungs;
                AdaptationCommand {
                    camera: 0,
                    level,
                    variant: if level == 0 {
                        nominal
                    } else {
                        nominal.downshifted()
                    },
                    seq: seq as u32,
                }
            };
            let mut st = AdaptationState::new(ad, 1);
            let mut fb = FeedbackState::new();
            let mut applied = Vec::new();
            let mut running_max = 0u32;
            for &i in order {
                let c = cmd(i + 1);
                let took = st.apply(&c);
                let fb_took =
                    fb.apply(0, c.seq, Arc::new(vec![c.seq as f32]));
                if took != fb_took {
                    return Err(format!(
                        "staleness rules diverged at seq {}: \
                         adapt {took} vs refinement {fb_took}",
                        c.seq
                    ));
                }
                let fresh = c.seq > running_max;
                if took != fresh {
                    return Err(format!(
                        "seq {} with running max {running_max}: \
                         applied={took}, want {fresh}",
                        c.seq
                    ));
                }
                if fresh {
                    running_max = c.seq;
                    applied.push(c.seq);
                }
            }
            if st.last_seq(0) != n as u32 {
                return Err(format!(
                    "state must land on the highest seq: {} != {n}",
                    st.last_seq(0)
                ));
            }
            let top = cmd(n);
            if st.level_of(0) != top.level {
                return Err(format!(
                    "state must land on the highest-seq level: \
                     {} != {}",
                    st.level_of(0),
                    top.level
                ));
            }
            if st.applied_count() != applied.len() as u64
                || st.stale_count() != (n - applied.len()) as u64
            {
                return Err(format!(
                    "apply/stale ledger wrong: ({}, {}) != ({}, {})",
                    st.applied_count(),
                    st.stale_count(),
                    applied.len(),
                    n - applied.len()
                ));
            }
            // The gauge agrees with the surviving command.
            if st.downshifted() != usize::from(top.level > 0) {
                return Err("downshifted gauge disagrees".into());
            }
            // Full redelivery: every copy is stale, nothing moves.
            let (level, seq) = (st.level_of(0), st.last_seq(0));
            for &i in order {
                if st.apply(&cmd(i + 1)) {
                    return Err(format!(
                        "redelivered seq {} applied twice",
                        i + 1
                    ));
                }
            }
            if st.level_of(0) != level || st.last_seq(0) != seq {
                return Err("redelivery moved the operating point".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Controller beats frozen under generated compute slowdowns.
// ---------------------------------------------------------------------------

/// The `harness adapt --smoke` workload with the preset's compute
/// schedule replaced by a generated one.
fn ab_cfg(name: &str, evs: &[ComputeEvent]) -> ExperimentConfig {
    let mut c = preset(name);
    c.num_cameras = 60;
    c.workload.vertices = 60;
    c.workload.edges = 160;
    c.duration_secs = 60.0;
    c.service.compute_events = evs.to_vec();
    c
}

#[test]
fn prop_controller_beats_frozen_under_generated_slowdowns() {
    // Generated compute schedules, clamped into the DeepScale regime
    // (global, severe, early enough to matter): the controller arm
    // must never complete fewer on-time events than the frozen arm,
    // and must win strictly whenever a command actually applied.
    // `adapt.seeds` persists regression pairs for this property.
    check(
        "adapt",
        &CheckConfig::with_cases(2),
        &compute_schedule(2, 4),
        |sched| {
            let mut evs = sched.clone();
            for e in &mut evs {
                e.node = None; // cluster-wide regime change
                e.factor = e.factor.clamp(4.0, 8.0);
                e.at_sec = e.at_sec.clamp(5.0, 20.0);
            }
            if evs.is_empty() {
                // The shrink floor still exercises the A/B.
                evs.push(ComputeEvent {
                    at_sec: 10.0,
                    node: None,
                    factor: 4.0,
                });
            }
            let on = des::run(ab_cfg("adapt_on", &evs));
            let off = des::run(ab_cfg("adapt_off", &evs));
            for (arm, r) in [("on", &on), ("off", &off)] {
                if !r.summary.conserved() {
                    return Err(format!(
                        "conservation violated ({arm}): {:?}",
                        r.summary
                    ));
                }
            }
            if on.summary.generated != off.summary.generated {
                return Err(format!(
                    "offered load differs: on {} vs off {}",
                    on.summary.generated, off.summary.generated
                ));
            }
            if off.metrics.adapt_minted != 0 {
                return Err("frozen arm minted a command".into());
            }
            if on.metrics.adapt_minted == 0 {
                return Err(
                    "controller never engaged under a >=4x global \
                     slowdown"
                        .into(),
                );
            }
            if on.summary.on_time < off.summary.on_time {
                return Err(format!(
                    "controller made things worse: on-time {} < {}",
                    on.summary.on_time, off.summary.on_time
                ));
            }
            if on.metrics.adapt_applied > 0
                && on.summary.on_time <= off.summary.on_time
            {
                return Err(format!(
                    "controller engaged ({} applied) but did not \
                     strictly win: on-time {} <= {}",
                    on.metrics.adapt_applied,
                    on.summary.on_time,
                    off.summary.on_time
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// K-invariance of adaptation runs.
// ---------------------------------------------------------------------------

/// Shard-plan config carrying a generated (active) adaptation plane.
fn plan_cfg(plan: &ShardPlan, ad: &AdaptationConfig) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.name = format!("prop_adapt_k{}", plan.shards);
    c.seed = 1302;
    c.num_cameras = plan.cameras;
    c.workload.vertices = plan.cameras;
    c.workload.edges = plan.cameras * 3;
    c.duration_secs = 20.0;
    c.tl = TlKind::Base;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c.drops_enabled = true;
    c.adaptation = ad.clone();
    c.sharding.shards = plan.shards;
    c.sharding.threads = plan.threads;
    c
}

#[test]
fn prop_adaptation_runs_are_k_invariant() {
    // Command minting, feedback routing and the single application
    // point all commute with sharding: an adaptation-enabled run is
    // bit-identical across generated shard plans.
    let strat = (shard_plan(), adaptation_config());
    check(
        "adapt_shard",
        &CheckConfig::with_cases(2),
        &strat,
        |(plan, ad)| {
            let sharded = des::run(plan_cfg(plan, ad));
            let baseline = des::run(plan_cfg(
                &ShardPlan {
                    shards: 1,
                    threads: 0,
                    cameras: plan.cameras,
                },
                ad,
            ));
            if sharded.summary != baseline.summary {
                return Err(format!(
                    "summary diverged under {plan:?}: {:?} != {:?}",
                    sharded.summary, baseline.summary
                ));
            }
            if sharded.detections != baseline.detections
                || sharded.fusion_updates != baseline.fusion_updates
                || sharded.core_events != baseline.core_events
                || sharded.rng_draws != baseline.rng_draws
            {
                return Err(format!(
                    "per-seed outputs diverged under {plan:?}"
                ));
            }
            if sharded.metrics.adapt_minted
                != baseline.metrics.adapt_minted
                || sharded.metrics.adapt_applied
                    != baseline.metrics.adapt_applied
                || sharded.metrics.adapt_stale
                    != baseline.metrics.adapt_stale
            {
                return Err(format!(
                    "adaptation registry diverged under {plan:?}: \
                     ({}, {}, {}) != ({}, {}, {})",
                    sharded.metrics.adapt_minted,
                    sharded.metrics.adapt_applied,
                    sharded.metrics.adapt_stale,
                    baseline.metrics.adapt_minted,
                    baseline.metrics.adapt_applied,
                    baseline.metrics.adapt_stale,
                ));
            }
            if !sharded.summary.conserved() {
                return Err(format!(
                    "conservation violated: {:?}",
                    sharded.summary
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Persisted regressions.
// ---------------------------------------------------------------------------

#[test]
fn adapt_seed_file_replays_deterministically() {
    // The committed pairs replay first on every `check("adapt", ...)`
    // run; pin the file's presence and the generator's determinism so
    // the replay path cannot silently rot.
    let seeds = regression_seeds("adapt");
    assert!(
        !seeds.is_empty(),
        "rust/tests/regressions/adapt.seeds is missing or empty"
    );
    let strat = compute_schedule(2, 4);
    for (seed, case) in seeds {
        let a = generate_case(&strat, seed, case);
        assert_eq!(a, generate_case(&strat, seed, case));
        assert!(a.len() <= 2, "{a:?}");
    }
}
