//! Property tests over the fault-injection and recovery subsystem
//! (hand-rolled generator loops; see `prop_tuning.rs` for the house
//! style).
//!
//! The contract under test:
//!
//! * An EMPTY fault schedule leaves both DES engines bit-identical
//!   per seed, with recovery enabled or disabled — the fault machinery
//!   must cost zero determinism when unused.
//! * Fault schedules are data, not randomness: the same schedule under
//!   the same seed reruns bit-identically, on both engines, for every
//!   fault class (crash, outage, partition, message loss).
//! * Conservation survives every fault class: generated = on-time +
//!   delayed + dropped + lost_to_fault + in-flight, and the metrics
//!   registry agrees with the ledger on the fault losses.
//! * Recovery never hurts: same seed, same mid-run node crash —
//!   recovery-on completes at least as many events on time as
//!   recovery-off, on exactly the same offered load.
//! * The §4.3.3 exemption (avoid-drop/probe) is still honored while
//!   faults fire: no event that earned an exemption is ever dropped.

use anveshak::config::{
    BatchingKind, ExperimentConfig, FaultEvent, FaultKind, TlKind,
};
use anveshak::coordinator::des;
use anveshak::metrics::Summary;
use anveshak::obs::{validate_trace, JsonlSink};
use anveshak::util::{rng, Json, Rng};

fn cases(seed: u64, n: usize) -> impl Iterator<Item = Rng> {
    (0..n).map(move |i| rng(seed, i as u64))
}

/// Small-but-busy config: Base TL keeps the whole network generating,
/// so injected faults always have in-flight work to hit.
fn small_cfg(seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.name = format!("prop_faults_{seed}");
    c.seed = seed;
    c.num_cameras = 50;
    c.workload.vertices = 50;
    c.workload.edges = 140;
    c.duration_secs = 40.0;
    c.tl = TlKind::Base;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c
}

fn with_mq(mut c: ExperimentConfig) -> ExperimentConfig {
    c.multi_query.num_queries = 3;
    c.multi_query.mean_interarrival_secs = 5.0;
    c.multi_query.lifetime_secs = 25.0;
    c.multi_query.max_active = 8;
    c.multi_query.max_active_cameras = 10_000;
    c
}

/// Bit-identity over every summary field (floats included — the claim
/// is identity, not tolerance).
fn assert_summaries_eq(a: &Summary, b: &Summary, ctx: &str) {
    assert_eq!(a.generated, b.generated, "{ctx}: generated");
    assert_eq!(a.on_time, b.on_time, "{ctx}: on_time");
    assert_eq!(a.delayed, b.delayed, "{ctx}: delayed");
    assert_eq!(a.dropped, b.dropped, "{ctx}: dropped");
    assert_eq!(
        a.lost_to_fault, b.lost_to_fault,
        "{ctx}: lost_to_fault"
    );
    assert_eq!(a.in_flight, b.in_flight, "{ctx}: in_flight");
    assert_eq!(a.latency.median, b.latency.median, "{ctx}: median");
    assert_eq!(a.latency.p99, b.latency.p99, "{ctx}: p99");
    assert_eq!(a.latency.max, b.latency.max, "{ctx}: max");
}

/// One random fault event drawn from all four fault classes.
fn random_fault(r: &mut Rng, cams: usize) -> FaultEvent {
    let at_sec = r.range_f64(5.0, 30.0);
    let window = |r: &mut Rng| {
        if r.bool(0.5) {
            Some(r.range_f64(2.0, 10.0))
        } else {
            None
        }
    };
    let kind = match r.range_u(0, 4) {
        0 => FaultKind::NodeCrash {
            node: r.range_u(0, 10),
            down_secs: window(r),
        },
        1 => FaultKind::CameraOutage {
            camera: r.range_u(0, cams),
            down_secs: window(r),
        },
        2 => FaultKind::LinkPartition {
            a: r.range_u(0, 10),
            b: r.range_u(0, 10),
            down_secs: window(r),
        },
        _ => FaultKind::MessageLoss {
            prob: r.range_f64(0.05, 0.4),
            dur_secs: window(r),
        },
    };
    FaultEvent { at_sec, kind }
}

// ---------------------------------------------------------------------------
// (a) Empty schedule => the fault machinery is invisible.
// ---------------------------------------------------------------------------

#[test]
fn prop_empty_schedule_bit_identical_across_recovery_toggle() {
    for seed in [3u64, 17, 41] {
        let mk = |enabled: bool| {
            let mut c = small_cfg(seed);
            c.drops_enabled = seed % 3 == 0;
            assert!(c.service.fault_events.is_empty());
            c.service.recovery.enabled = enabled;
            c
        };
        let a = des::run(mk(true));
        let b = des::run(mk(false));
        let ctx = format!("seed {seed} recovery toggle");
        assert_summaries_eq(&a.summary, &b.summary, &ctx);
        assert_eq!(a.summary.lost_to_fault, 0, "{ctx}");
        assert_eq!(a.detections, b.detections, "{ctx}");
        assert_eq!(a.core_events, b.core_events, "{ctx}");
        assert_eq!(a.rng_draws, b.rng_draws, "{ctx}");
        assert_eq!(a.metrics.faults_injected, 0, "{ctx}");

        let ma = des::run_multi(with_mq(mk(true)));
        let mb = des::run_multi(with_mq(mk(false)));
        let ctx = format!("seed {seed} mq recovery toggle");
        assert_summaries_eq(&ma.aggregate, &mb.aggregate, &ctx);
        assert_eq!(ma.core_events, mb.core_events, "{ctx}");
        assert_eq!(ma.rng_draws, mb.rng_draws, "{ctx}");
        assert_eq!(ma.metrics.faults_injected, 0, "{ctx}");
    }
}

// ---------------------------------------------------------------------------
// (b) Fault schedules are deterministic data + conservation holds.
// ---------------------------------------------------------------------------

#[test]
fn prop_fault_schedules_rerun_bit_identical_and_conserve() {
    for (i, mut r) in cases(51, 6).enumerate() {
        let mut cfg = small_cfg(500 + i as u64);
        cfg.drops_enabled = r.bool(0.5);
        let n = r.range_u(1, 4);
        cfg.service.fault_events =
            (0..n).map(|_| random_fault(&mut r, 50)).collect();
        cfg.service.recovery.enabled = r.bool(0.5);
        let ctx = format!(
            "case {i} schedule {:?}",
            cfg.service.fault_events
        );

        let a = des::run(cfg.clone());
        let b = des::run(cfg.clone());
        assert!(a.summary.conserved(), "{ctx}: {:?}", a.summary);
        assert_summaries_eq(&a.summary, &b.summary, &ctx);
        assert_eq!(a.detections, b.detections, "{ctx}");
        assert_eq!(a.rng_draws, b.rng_draws, "{ctx}");
        assert_eq!(
            a.metrics.lost_to_fault, a.summary.lost_to_fault,
            "{ctx}: registry and ledger disagree on fault losses"
        );

        let ma = des::run_multi(with_mq(cfg.clone()));
        let mb = des::run_multi(with_mq(cfg));
        assert!(ma.aggregate.conserved(), "{ctx}: {:?}", ma.aggregate);
        assert_summaries_eq(&ma.aggregate, &mb.aggregate, &ctx);
        assert_eq!(ma.rng_draws, mb.rng_draws, "{ctx}");
        assert_eq!(
            ma.metrics.lost_to_fault, ma.aggregate.lost_to_fault,
            "{ctx}: mq registry and ledgers disagree on fault losses"
        );
    }
}

// ---------------------------------------------------------------------------
// (c) Recovery never hurts at the same seed.
// ---------------------------------------------------------------------------

#[test]
fn prop_recovery_never_completes_fewer_on_time() {
    for seed in [9u64, 27] {
        let mk = |enabled: bool| {
            let mut c = small_cfg(seed);
            c.service.fault_events = vec![FaultEvent {
                at_sec: 15.0,
                kind: FaultKind::NodeCrash {
                    node: 1,
                    down_secs: None,
                },
            }];
            c.service.recovery.enabled = enabled;
            c
        };
        let on = des::run(mk(true));
        let off = des::run(mk(false));
        assert!(on.summary.conserved(), "{:?}", on.summary);
        assert!(off.summary.conserved(), "{:?}", off.summary);
        assert_eq!(
            on.summary.generated, off.summary.generated,
            "seed {seed}: fault handling changed the offered load"
        );
        assert!(
            on.summary.on_time >= off.summary.on_time,
            "seed {seed}: recovery on {} < off {}",
            on.summary.on_time,
            off.summary.on_time
        );
        // The permanent crash orphans real work when recovery is off.
        assert!(
            off.summary.lost_to_fault > 0,
            "seed {seed}: {:?}",
            off.summary
        );
    }
}

// ---------------------------------------------------------------------------
// (d) The §4.3.3 exemption survives fault injection.
// ---------------------------------------------------------------------------

#[test]
fn prop_exempt_events_never_dropped_under_faults() {
    for seed in [12u64, 34] {
        let mut cfg = small_cfg(seed);
        cfg.drops_enabled = true;
        cfg.service.fault_events = vec![
            FaultEvent {
                at_sec: 10.0,
                kind: FaultKind::NodeCrash {
                    node: 1,
                    down_secs: Some(10.0),
                },
            },
            FaultEvent {
                at_sec: 20.0,
                kind: FaultKind::MessageLoss {
                    prob: 0.2,
                    dur_secs: Some(10.0),
                },
            },
        ];
        let sink = JsonlSink::in_memory();
        let r = des::run_with_sink(cfg, sink.clone());
        let text = sink.contents().unwrap();
        let check = validate_trace(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(
            check.violations().is_empty(),
            "seed {seed}: {:?}",
            check.violations()
        );
        assert_eq!(
            check.lost_to_fault, r.summary.lost_to_fault,
            "seed {seed}"
        );
        // An event that earned an exemption (avoid_drop from a CR
        // detection, or a probe) must never be dropped AFTERWARDS.
        // Order matters: probes recycle the id of the drop that
        // spawned them, so drop-then-exempted is legitimate — only
        // exempted-then-drop violates §4.3.3. Trace lines are in time
        // order, so one forward scan decides it.
        let mut exempted = std::collections::BTreeSet::new();
        let mut violations = Vec::new();
        for line in text.lines().skip(1) {
            let j = Json::parse(line).unwrap();
            match j.at("ev").as_str() {
                Some("exempted") => {
                    exempted.insert(j.at("event").as_usize().unwrap());
                }
                Some("drop") => {
                    let id = j.at("event").as_usize().unwrap();
                    if exempted.contains(&id) {
                        violations.push(id);
                    }
                }
                _ => {}
            }
        }
        assert!(
            violations.is_empty(),
            "seed {seed}: exempt events dropped under faults: \
             {violations:?}"
        );
    }
}
