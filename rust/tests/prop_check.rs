//! Property tests on the `check` harness itself, plus the prop-suite
//! invariants migrated onto it.
//!
//! Three layers:
//!
//! * **Harness self-tests**: planted bugs whose minimal counterexample
//!   is known in advance — the shrinker must converge to it (a broken
//!   budget-ring model shrinks to a 2-event schedule, a two-fault
//!   interaction shrinks to 2 canonical events) and the printed
//!   `seed`/`case` pair must replay the original failure bit-for-bit.
//!   Plus the persisted-regression-seed replay path.
//! * **Migrated invariants** from the hand-rolled `prop_tuning.rs` /
//!   `prop_faults.rs` loops, now running over generated inputs with
//!   shrinking: drop-gate exemption, budget-ring residue hygiene,
//!   feedback exactly-once under arbitrary arrival orders, DRR
//!   proportionality, and DES bit-identity + conservation under
//!   generated fault/compute/bandwidth schedules and `ServiceConfig`
//!   mutations. With `--features strict-invariants` the runtime
//!   checkers inside the engines arm as well.
//! * **Repo invariants**: the `harness lint` pass must run clean on
//!   the repo itself, and the live front must surface supervisor
//!   health as typed state.

use std::sync::Arc;

use anveshak::check::domain::{
    arrival_order, bandwidth_schedule, compute_schedule, drr_weights,
    fault_schedule, service_config_mutations,
};
use anveshak::check::runner::regression_seeds;
use anveshak::check::{
    check, find_failure, generate_case, lint_repo, range_i64, range_u,
    vec_of, CheckConfig,
};
use anveshak::config::{
    BatchingKind, ExperimentConfig, FaultKind, TlKind,
};
use anveshak::coordinator::des;
use anveshak::dataflow::{FeedbackState, Stage};
use anveshak::metrics::Summary;
use anveshak::service::{
    AdmissionPolicy, QuerySpec, SimBackend, SupervisorHealth,
    TrackingService,
};
use anveshak::tuning::budget::BudgetManager;
use anveshak::tuning::{
    drop_at_exec, drop_at_queue, drop_at_transmit, drop_before_exec,
    drop_before_queue, drop_before_transmit, EventRecord, FairShare,
};

// ---------------------------------------------------------------------------
// (a) Harness self-tests: planted bugs with known minimal
// counterexamples.
// ---------------------------------------------------------------------------

/// A deliberately broken budget-ring model: records land in slot
/// `id % CAP` like the real [`BudgetManager`] ring, but recording also
/// clears the *neighbouring* slot — the planted foreign-key eviction
/// the `strict-invariants` assert in the real ring guards against.
const CAP: usize = 4;

struct BrokenRing {
    slots: Vec<Option<u64>>,
}

impl BrokenRing {
    fn new() -> Self {
        Self {
            slots: vec![None; CAP],
        }
    }

    fn record(&mut self, id: u64) {
        self.slots[id as usize % CAP] = Some(id);
        // The planted bug: an off-by-one also evicts slot (id+1) % CAP,
        // which belongs to a different residue class.
        self.slots[(id as usize + 1) % CAP] = None;
    }

    fn get(&self, id: u64) -> Option<u64> {
        self.slots[id as usize % CAP].filter(|&x| x == id)
    }
}

/// Property: after replaying a schedule of record calls, every id whose
/// slot was never legitimately re-recorded (no later id in the same
/// residue class) is still retrievable. One event can never fail it
/// (a record only clears a *different* class), so the unique minimal
/// counterexample is a 2-event schedule — exactly what the shrinker
/// must converge to.
fn ring_keeps_unevicted_ids(ids: &[usize]) -> Result<(), String> {
    let mut ring = BrokenRing::new();
    for &id in ids {
        ring.record(id as u64);
    }
    for (i, &id) in ids.iter().enumerate() {
        let superseded =
            ids[i + 1..].iter().any(|&x| x % CAP == id % CAP);
        if !superseded && ring.get(id as u64).is_none() {
            return Err(format!("id {id} vanished from its slot"));
        }
    }
    Ok(())
}

#[test]
fn planted_ring_bug_shrinks_to_a_two_event_schedule() {
    let strat = vec_of(range_u(0, 16), 0, 8);
    let cfg = CheckConfig::default();
    let f = find_failure(&cfg, &strat, |v| ring_keeps_unevicted_ids(v))
        .expect("the planted eviction bug must surface within 64 cases");
    // ≤ 3 elements is the acceptance bar; the construction above makes
    // exactly 2 the true minimum (1 record never clears its own slot).
    assert_eq!(
        f.minimal.len(),
        2,
        "minimal counterexample {:?} (from {:?})",
        f.minimal,
        f.original
    );
    // The clearing record's neighbour slot is the victim's slot.
    let (victim, clearer) = (f.minimal[0], f.minimal[1]);
    assert_eq!((clearer + 1) % CAP, victim % CAP);
    assert_ne!(clearer % CAP, victim % CAP);

    // Deterministic replay: the printed (seed, case) regenerates the
    // original failing input bit-for-bit, and the whole search is
    // reproducible end to end.
    assert_eq!(generate_case(&strat, f.seed, f.case), f.original);
    let f2 = find_failure(&cfg, &strat, |v| ring_keeps_unevicted_ids(v))
        .expect("replayed search");
    assert_eq!(f2.case, f.case);
    assert_eq!(f2.minimal, f.minimal);
    assert_eq!(f2.shrink_steps, f.shrink_steps);
}

#[test]
fn planted_fault_interaction_shrinks_to_two_canonical_events() {
    // Planted "bug": schedules mixing a node crash with message loss
    // are rejected. The minimal counterexample is one of each, with
    // every field canonicalised (earliest time, node 0, permanent
    // window, lowest loss probability).
    let strat = fault_schedule(6, 50, 10);
    let prop = |sched: &Vec<anveshak::config::FaultEvent>| {
        let crash = sched
            .iter()
            .any(|e| matches!(e.kind, FaultKind::NodeCrash { .. }));
        let loss = sched
            .iter()
            .any(|e| matches!(e.kind, FaultKind::MessageLoss { .. }));
        if crash && loss {
            Err("crash and loss in one schedule".into())
        } else {
            Ok(())
        }
    };
    let f = find_failure(&CheckConfig::default(), &strat, prop)
        .expect("a crash+loss schedule appears within 64 cases");
    assert_eq!(f.minimal.len(), 2, "minimal: {:?}", f.minimal);
    for ev in &f.minimal {
        assert_eq!(ev.at_sec, 5.0, "time canonicalised: {ev:?}");
        match ev.kind {
            FaultKind::NodeCrash { node, down_secs } => {
                assert_eq!(node, 0);
                assert_eq!(down_secs, None);
            }
            FaultKind::MessageLoss { prob, dur_secs } => {
                assert_eq!(prob, 0.05);
                assert_eq!(dur_secs, None);
            }
            other => panic!("unexpected kind survived: {other:?}"),
        }
    }
    assert_eq!(generate_case(&strat, f.seed, f.case), f.original);
}

#[test]
fn regression_seed_file_replays_before_fresh_cases() {
    // The committed demo file pins one (seed, case) pair.
    let seeds = regression_seeds("prop_check_demo");
    assert_eq!(seeds, vec![(42, 7)]);
    // Replay is deterministic for the persisted pair…
    let strat = vec_of(range_u(0, 16), 0, 8);
    let a = generate_case(&strat, 42, 7);
    assert_eq!(a, generate_case(&strat, 42, 7));
    // …and `check` walks the persisted pair plus fresh cases without
    // incident for a passing property.
    check(
        "prop_check_demo",
        &CheckConfig::with_cases(8),
        &strat,
        |_| Ok(()),
    );
}

// ---------------------------------------------------------------------------
// (b) Migrated invariants, now over generated + shrinking inputs.
// ---------------------------------------------------------------------------

#[test]
fn prop_drop_gates_honor_exemption() {
    // Migrated from prop_tuning.rs: over arbitrary (u, q, xi, budget)
    // timings — including degenerate budgets that doom every event —
    // an exempt event is never dropped at any of the three points, and
    // a non-exempt verdict always matches the raw predicate.
    let strat = (
        range_i64(0, 120_000_000),
        range_i64(0, 60_000_000),
        range_i64(1, 5_000_000),
        range_i64(0, 2_000_000),
    );
    check(
        "drop_gates_exemption",
        &CheckConfig::with_cases(256),
        &strat,
        |&(u, q, x, budget)| {
            if drop_at_queue(true, u, x, budget)
                || drop_at_exec(true, u, q, x, budget)
                || drop_at_transmit(true, u, q + x, budget)
            {
                return Err(format!(
                    "exempt event dropped at (u={u}, q={q}, x={x}, \
                     budget={budget})"
                ));
            }
            let consistent = drop_at_queue(false, u, x, budget)
                == drop_before_queue(u, x, budget)
                && drop_at_exec(false, u, q, x, budget)
                    == drop_before_exec(u, q, x, budget)
                && drop_at_transmit(false, u, q + x, budget)
                    == drop_before_transmit(u, q + x, budget);
            if consistent {
                Ok(())
            } else {
                Err("gate disagrees with raw predicate".into())
            }
        },
    );
}

#[test]
fn prop_budget_ring_keeps_latest_record_per_residue_class() {
    // Migrated from the budget.rs unit suite's hand-picked collisions:
    // for arbitrary id schedules, the ring holds exactly the last
    // record of each residue class — an overwrite never corrupts a
    // foreign class (the strict-invariants assert inside `record`
    // arms on the same walk).
    let ring_cap = 17u64; // prime, per the BudgetManager docs
    let strat = vec_of(range_u(0, 4096), 0, 64);
    check(
        "budget_ring_residue",
        &CheckConfig::with_cases(128),
        &strat,
        |ids| {
            let mut b = BudgetManager::new(1, 25, ring_cap as usize);
            for &id in ids {
                b.record(
                    id as u64,
                    EventRecord {
                        departure: 1_000_000,
                        queue: 1_000,
                        batch: 1,
                        sent_to: 0,
                    },
                );
            }
            for class in 0..ring_cap {
                let in_class: Vec<u64> = ids
                    .iter()
                    .map(|&x| x as u64)
                    .filter(|x| x % ring_cap == class)
                    .collect();
                let Some(&last) = in_class.last() else {
                    continue;
                };
                if b.get_record(last).is_none() {
                    return Err(format!(
                        "latest id {last} of class {class} missing"
                    ));
                }
                for &id in &in_class {
                    if id != last && b.get_record(id).is_some() {
                        return Err(format!(
                            "stale id {id} still resolvable after \
                             {last} took class {class}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_feedback_applies_each_refinement_exactly_once() {
    // Migrated from the feedback.rs unit suite's hand-picked orders:
    // under an arbitrary arrival order of refinements 1..=n (then a
    // full duplicate redelivery), an update applies iff it is a
    // left-to-right maximum, and the final state is the freshest seq.
    let n = 12usize;
    check(
        "feedback_exactly_once",
        &CheckConfig::with_cases(128),
        &arrival_order(n),
        |order| {
            let mut st = FeedbackState::new();
            let mut max_seen = 0u32;
            for &i in order {
                let seq = (i + 1) as u32;
                let did = st.apply(7, seq, Arc::new(vec![seq as f32]));
                if did != (seq > max_seen) {
                    return Err(format!(
                        "seq {seq} applied={did} with max {max_seen}"
                    ));
                }
                max_seen = max_seen.max(seq);
            }
            for &i in order {
                if st.apply(7, (i + 1) as u32, Arc::new(vec![-1.0])) {
                    return Err(format!(
                        "duplicate redelivery of seq {} applied",
                        i + 1
                    ));
                }
            }
            if st.last_seq(7) != n as u32 {
                return Err(format!(
                    "final seq {} != {n}",
                    st.last_seq(7)
                ));
            }
            if st.refined(7) != Some(&[n as f32][..]) {
                return Err("final embedding is not the freshest".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_drr_weight_sets_serve_proportionally() {
    // Migrated from the share.rs unit suite's fixed weight tables: for
    // arbitrary weight sets, a fully backlogged FairShare serves each
    // query exactly `weight × cycles` slots per `Σweight × cycles`
    // picks.
    let cycles = 6u32;
    check(
        "drr_proportional",
        &CheckConfig::with_cases(64),
        &drr_weights(2, 5, 4),
        |weights| {
            let mut fs = FairShare::new();
            for (q, &w) in weights.iter().enumerate() {
                fs.ensure(q as u32, w);
            }
            let total: u32 = weights.iter().sum();
            let mut counts = vec![0u32; weights.len()];
            for _ in 0..total * cycles {
                let k = fs
                    .pick(|_| true)
                    .ok_or_else(|| "pick starved".to_string())?;
                fs.charge(k, 1);
                counts[k as usize] += 1;
            }
            for (q, &w) in weights.iter().enumerate() {
                if counts[q] != w * cycles {
                    return Err(format!(
                        "query {q} (weight {w}) served {} of {} \
                         expected: {counts:?}",
                        counts[q],
                        w * cycles
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Small-but-busy DES config in the `prop_faults.rs` mould.
fn dyn_cfg(seed: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.name = format!("prop_check_{seed}");
    c.seed = seed;
    c.num_cameras = 40;
    c.workload.vertices = 40;
    c.workload.edges = 110;
    c.duration_secs = 30.0;
    c.tl = TlKind::Base;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c
}

fn summaries_eq(a: &Summary, b: &Summary) -> Result<(), String> {
    let pairs = [
        ("generated", a.generated, b.generated),
        ("on_time", a.on_time, b.on_time),
        ("delayed", a.delayed, b.delayed),
        ("dropped", a.dropped, b.dropped),
        ("lost_to_fault", a.lost_to_fault, b.lost_to_fault),
        ("in_flight", a.in_flight, b.in_flight),
    ];
    for (field, x, y) in pairs {
        if x != y {
            return Err(format!("{field}: {x} != {y}"));
        }
    }
    if a.latency.median != b.latency.median
        || a.latency.p99 != b.latency.p99
    {
        return Err("latency stats diverged".into());
    }
    Ok(())
}

#[test]
fn prop_generated_dynamism_schedules_rerun_bit_identical() {
    // Migrated from prop_faults.rs / prop_roadnet.rs: fault, compute
    // and bandwidth schedules are data, not randomness — any generated
    // combination reruns bit-identically and conserves every event.
    // (Runs the DES twice per case, so the case count stays small; a
    // failure shrinks toward the empty/identity schedules, isolating
    // the one event that breaks determinism.)
    let strat = (
        fault_schedule(3, 40, 10),
        compute_schedule(2, 10),
        bandwidth_schedule(2),
    );
    check(
        "dynamism_schedules_deterministic",
        &CheckConfig::with_cases(2),
        &strat,
        |(faults, computes, bandwidths)| {
            let mut cfg = dyn_cfg(911);
            cfg.drops_enabled = true;
            cfg.service.fault_events = faults.clone();
            cfg.service.compute_events = computes.clone();
            cfg.network.events = bandwidths.clone();
            let a = des::run(cfg.clone());
            let b = des::run(cfg);
            if !a.summary.conserved() {
                return Err(format!(
                    "conservation violated: {:?}",
                    a.summary
                ));
            }
            summaries_eq(&a.summary, &b.summary)?;
            if a.rng_draws != b.rng_draws {
                return Err(format!(
                    "rng draws {} != {}",
                    a.rng_draws, b.rng_draws
                ));
            }
            if a.detections != b.detections {
                return Err("detections diverged".into());
            }
            if a.metrics.lost_to_fault != a.summary.lost_to_fault {
                return Err(
                    "registry and ledger disagree on fault losses"
                        .into(),
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_service_config_mutations_keep_des_deterministic() {
    // ξ-model timing knobs and jitter are inputs, not nondeterminism:
    // any mutated ServiceConfig reruns bit-identically and conserves.
    // A failure shrinks by resetting fields to the base one at a time,
    // naming the single knob that broke determinism.
    let base = ExperimentConfig::default().service.clone();
    check(
        "service_config_deterministic",
        &CheckConfig::with_cases(2),
        &service_config_mutations(base),
        |sc| {
            let mut cfg = dyn_cfg(117);
            cfg.duration_secs = 20.0;
            cfg.service = sc.clone();
            let a = des::run(cfg.clone());
            let b = des::run(cfg);
            if !a.summary.conserved() {
                return Err(format!(
                    "conservation violated: {:?}",
                    a.summary
                ));
            }
            summaries_eq(&a.summary, &b.summary)?;
            if a.rng_draws != b.rng_draws {
                return Err("rng draws diverged".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// (c) Repo invariants: the lint pass on the repo itself, and typed
// supervisor health on the live front.
// ---------------------------------------------------------------------------

#[test]
fn repo_passes_harness_lint() {
    let report = lint_repo();
    assert!(
        report.files_scanned > 20,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
    assert!(
        report.is_clean(),
        "`harness lint` found violations:\n{:#?}",
        report.violations
    );
}

#[test]
fn service_surfaces_supervisor_health_as_typed_state() {
    let mut cfg = ExperimentConfig::default();
    cfg.num_cameras = 8;
    cfg.workload.vertices = 40;
    cfg.workload.edges = 100;
    cfg.fps = 10.0;
    cfg.gamma_ms = 2_000.0;
    cfg.cluster.va_instances = 2;
    cfg.cluster.cr_instances = 2;
    let svc = TrackingService::start(
        cfg,
        AdmissionPolicy {
            max_active: 4,
            max_active_cameras: 10_000,
            queue_capacity: 2,
        },
        Arc::new(SimBackend::default()),
    )
    .unwrap();
    // Healthy service: typed state says so, and submission works.
    let health = svc.supervisor_health();
    assert_eq!(health, SupervisorHealth::AllWorkersLive);
    assert!(!health.is_degraded());
    assert_eq!(health.lost_at(Stage::Va), 0);
    assert_eq!(health.lost_at(Stage::Cr), 0);
    let spec = QuerySpec {
        lifetime_secs: 0.5,
        ..QuerySpec::new("probe", 0)
    };
    svc.submit(spec).expect("healthy service accepts queries");
    // The final report embeds the same typed state.
    let report = svc.stop();
    assert_eq!(report.supervisor, SupervisorHealth::AllWorkersLive);
    assert!(!report.supervisor.is_degraded());
}
