//! Integration: the stock applications (Table 1 plus App 5) compose
//! and run end to end on the DES engine through the public
//! `AppDefinition` API, and their distinguishing characteristics show
//! up in the outcomes.

use anveshak::apps::{all, table1};
use anveshak::config::{AppKind, BatchingKind, ExperimentConfig, TlKind};
use anveshak::coordinator::des;

fn base_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.num_cameras = 120;
    c.workload.vertices = 120;
    c.workload.edges = 330;
    c.duration_secs = 120.0;
    c.batching = BatchingKind::Dynamic { max: 25 };
    c
}

#[test]
fn all_apps_run_and_track() {
    // All five stock apps (including App 5, which has no AppKind and
    // exists only as a block composition) run through the same trait
    // path: `run_app` with their own blocks.
    for app in all() {
        let mut cfg = base_cfg();
        app.apply(&mut cfg, true);
        let r = des::run_app(cfg, &app);
        assert!(r.summary.conserved(), "{}: {:?}", app.name, r.summary);
        assert!(
            r.detections > 0,
            "{} never detected the entity: {:?}",
            app.name,
            r.summary
        );
        assert!(
            r.summary.on_time > 0,
            "{}: nothing on time",
            app.name
        );
    }
}

#[test]
fn app2_cr_is_heavier_than_app1() {
    // Same workload; App 2's CR is ~63% slower per frame, so its CR
    // batches take longer and the median latency rises.
    let mut c1 = base_cfg();
    table1(AppKind::App1).apply(&mut c1, false); // keep TL identical (Bfs)
    let mut c2 = base_cfg();
    table1(AppKind::App2).apply(&mut c2, false);
    let r1 = des::run(c1);
    let r2 = des::run(c2);
    let x1 = r1.summary.latency.median;
    let x2 = r2.summary.latency.median;
    assert!(
        x2 > x1,
        "App2 median {x2:.2}s should exceed App1 {x1:.2}s"
    );
}

#[test]
fn app3_tracks_fast_vehicles() {
    let mut cfg = base_cfg();
    table1(AppKind::App3).apply(&mut cfg, true);
    assert!(cfg.workload.entity_speed_mps >= 8.0);
    assert_eq!(cfg.tl, TlKind::WbfsSpeed);
    let r = des::run(cfg);
    assert!(r.summary.conserved());
    // A vehicle crosses FOVs fast: fewer positive frames, but the
    // speed-aware spotlight must still reacquire it.
    assert!(r.detections > 0, "{:?}", r.summary);
}

#[test]
fn app4_probabilistic_tl_bounds_active_set() {
    let mut cfg = base_cfg();
    table1(AppKind::App4).apply(&mut cfg, true);
    let r = des::run(cfg);
    assert!(r.detections > 0);
    // The 90%-mass likelihood spotlight never needs the whole network.
    assert!(
        r.peak_active < cfg_peak_bound(),
        "peak {} too large",
        r.peak_active
    );
}

fn cfg_peak_bound() -> usize {
    120 // the full (small) network
}

#[test]
fn tl_knob_orders_work_done() {
    // Base >> BFS >= WBFS in frames processed (the scalability knob).
    let mk = |tl| {
        let mut c = base_cfg();
        c.tl = tl;
        des::run(c).summary.generated
    };
    let base = mk(TlKind::Base);
    let bfs = mk(TlKind::Bfs);
    let wbfs = mk(TlKind::Wbfs);
    assert!(base > 3 * bfs, "base {base} vs bfs {bfs}");
    assert!(wbfs <= bfs, "wbfs {wbfs} vs bfs {bfs}");
}
