//! Hot-path microbenchmarks (hand-rolled harness; the offline build has
//! no criterion). Run via `cargo bench --bench hotpath`.
//!
//! Covers every L3 request-path primitive plus the PJRT model execution
//! per batch bucket (the measured ξ(b) of §4.2), and the DES engine's
//! virtual-event throughput that bounds harness turnaround.

use std::time::Instant;

use anveshak::config::{BatchingKind, ExperimentConfig, WorkloadConfig};
use anveshak::coordinator::des;
use anveshak::dataflow::Partitioner;
use anveshak::roadnet::{bfs_spotlight, generate, wbfs_spotlight};
use anveshak::runtime::{default_dir, ModelPool};
use anveshak::sim::identity_image;
use anveshak::tuning::{
    drop_before_exec, Batcher, BatcherPoll, BudgetManager, EventRecord,
    QueuedEvent, Signal, XiModel,
};
use anveshak::util::{Json, MS, SEC};

/// Time `f` over `iters` iterations; returns ns/op.
fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> f64 {
    // Warm-up.
    for _ in 0..iters.min(100) {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = start.elapsed().as_nanos() as f64 / iters as f64;
    let (val, unit) = if ns > 1e6 {
        (ns / 1e6, "ms")
    } else if ns > 1e3 {
        (ns / 1e3, "us")
    } else {
        (ns, "ns")
    };
    println!("{name:<44} {val:>10.2} {unit}/op   ({iters} iters)");
    ns
}

fn main() {
    println!("== L3 request-path primitives ==");

    let part = Partitioner::new(10);
    let mut k = 0usize;
    bench("partitioner.route", 5_000_000, || {
        k = k.wrapping_add(1);
        std::hint::black_box(part.route(k));
    });

    let xi = XiModel::affine_ms(52.5, 67.5);
    bench("xi.estimate", 5_000_000, || {
        std::hint::black_box(xi.xi(std::hint::black_box(17)));
    });

    bench("drop_point_2.check", 5_000_000, || {
        std::hint::black_box(drop_before_exec(
            std::hint::black_box(10 * SEC),
            2 * SEC,
            1_740 * MS,
            15 * SEC,
        ));
    });

    // Batcher: steady-state push+poll cycle at batch ~8.
    let mut b: Batcher<u64> = Batcher::dynamic(25);
    let mut now = 0i64;
    let mut id = 0u64;
    bench("batcher.push_poll (dynamic)", 300_000, || {
        now += 125 * MS;
        b.push(QueuedEvent {
            item: id,
            id,
            arrival: now,
            deadline: now + 10 * SEC,
        });
        id += 1;
        if let BatcherPoll::Ready(batch) = b.poll(now, &xi) {
            std::hint::black_box(batch.len());
        }
    });

    // Budget bookkeeping: record + signal application.
    let mut bm = BudgetManager::new(10, 25, 4096);
    let mut e = 0u64;
    bench("budget.record", 1_000_000, || {
        bm.record(
            e,
            EventRecord {
                departure: 5 * SEC,
                queue: SEC,
                batch: 10,
                sent_to: (e % 10) as usize,
            },
        );
        e += 1;
    });
    let mut s = 0u64;
    bench("budget.apply(reject)", 1_000_000, || {
        bm.apply(
            Signal::Reject {
                event: s % e,
                eps: SEC,
                sum_queue: 2 * SEC,
            },
            &xi,
        );
        s += 1;
    });

    println!("\n== Road-network / TL substrate ==");
    let g = generate(&WorkloadConfig::default(), 2019);
    bench("wbfs_spotlight r=500m (1000v graph)", 2_000, || {
        std::hint::black_box(wbfs_spotlight(&g, 0, 500.0).len());
    });
    bench("bfs_spotlight r=500m", 2_000, || {
        std::hint::black_box(bfs_spotlight(&g, 0, 500.0, 84.5).len());
    });

    println!("\n== Infra substrates ==");
    let manifest_text = std::fs::read_to_string(
        default_dir().join("manifest.json"),
    )
    .unwrap_or_else(|_| "{\"a\":[1,2,3]}".into());
    bench("json.parse(manifest)", 2_000, || {
        std::hint::black_box(Json::parse(&manifest_text).unwrap());
    });

    println!("\n== DES engine throughput ==");
    {
        let mut cfg = ExperimentConfig::default();
        cfg.num_cameras = 200;
        cfg.workload.vertices = 200;
        cfg.workload.edges = 560;
        cfg.duration_secs = 120.0;
        cfg.tl = anveshak::config::TlKind::Base; // all active: max load
        cfg.batching = BatchingKind::Dynamic { max: 25 };
        cfg.drops_enabled = true;
        let start = Instant::now();
        let r = des::run(cfg);
        let wall = start.elapsed().as_secs_f64();
        // Each source event crosses ~4 tasks; count hops as DES events.
        let hops = r.summary.generated * 4;
        println!(
            "des.run 200cams x 120s: {:.2}s wall, {} source events, {:.0} task-hops/s",
            wall,
            r.summary.generated,
            hops as f64 / wall
        );
    }

    println!("\n== L1/L2: PJRT model execution (measured xi(b)) ==");
    match ModelPool::load(&default_dir(), &["va", "cr_small"], Some(&[1, 8, 25])) {
        Ok(pool) => {
            for variant in ["va", "cr_small"] {
                let (fit, samples) = pool.calibrate_xi(variant, 5).unwrap();
                for (b, us) in &samples {
                    println!(
                        "pjrt.{variant:<9} b={b:<3} {:>9.2} ms/batch  {:>8.2} ms/event",
                        *us as f64 / 1e3,
                        *us as f64 / 1e3 / *b as f64
                    );
                }
                println!(
                    "pjrt.{variant:<9} fitted xi(b) = {:.2} + {:.3}*b ms",
                    fit.alpha_us() / 1e3,
                    fit.beta_us() / 1e3
                );
            }
            // End-to-end model call including upload of one frame.
            let img = identity_image(1, 0, 0.25);
            let q = vec![0f32; pool.feat_dim()];
            bench("pjrt.va.execute b=1 (incl upload)", 200, || {
                std::hint::black_box(
                    pool.execute("va", &img, &q).unwrap().scores[0],
                );
            });
        }
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }
}
