//! Hot-path microbenchmarks (hand-rolled harness; the offline build has
//! no criterion). Run via `cargo bench --bench hotpath`.
//!
//! # Methodology
//!
//! Each primitive is timed by [`bench`]: up to 100 warm-up iterations,
//! then `iters` timed iterations under `Instant`, reporting mean ns/op
//! (no outlier rejection — these are comparative numbers on one
//! machine, not absolute claims). Engine throughput is measured by
//! running a fixed workload to completion and dividing the
//! [`EventCore`]'s dispatched-event counter by the wall-clock seconds
//! of the `run()` phase alone (engine construction — road generation,
//! ground truth — is timed separately as `setup_s`).
//!
//! # Flags
//!
//! * `--smoke` — shrink iteration counts and DES workloads (~100x) so
//!   CI can verify the bench builds and the JSON emitter works in
//!   seconds. Smoke numbers are *not* comparable to full runs and the
//!   emitted JSON carries `"mode": "smoke"` with no baseline ratios.
//! * `--json` — additionally emit `BENCH_3.json` in the working
//!   directory (the workspace root under `cargo bench`).
//!
//! # JSON schema (`BENCH_3.json`, schema `anveshak-hotpath-bench-v3`)
//!
//! ```json
//! {
//!   "schema": "anveshak-hotpath-bench-v3",
//!   "mode": "full" | "smoke",
//!   "baseline_commit": "...",         // full mode only
//!   "primitives_ns_per_op": {
//!     "<name>": {"current": ns, "baseline": ns?, "speedup": x?}
//!   },
//!   "des_runs": {
//!     "<name>": {"setup_s": s, "wall_s": s, "core_events": n,
//!                 "events_per_sec": r, "generated": n,
//!                 "baseline_wall_s": s?, "speedup": x?}
//!   }
//! }
//! ```
//!
//! The v3 `baseline` values are one recorded run of commit fc1d8fe
//! (the PR 2 hot-path overhaul, *before* the UDF-trait dispatch
//! redesign), compiled into [`BASELINE_NS`] /
//! [`BASELINE_DES_WALL_S`] from its committed `BENCH_2.json`. The DES
//! `speedup` ratios therefore measure exactly what the trait redesign
//! must not regress: a ratio near 1.0 means batch-hoisted dyn dispatch
//! costs nothing measurable; materially below 1.0 means a per-event
//! indirection snuck in. **Caveat:** the baselines are machine-specific
//! (one dev-box run). A speedup computed against them is only
//! meaningful on comparable hardware; to re-establish the comparison
//! locally, check out fc1d8fe, run its bench, update the constants,
//! and re-run `--json` on this tree.

use std::time::Instant;

use anveshak::apps;
use anveshak::config::{
    preset, AppKind, BatchingKind, ComputeEvent, ExperimentConfig,
    FaultEvent, FaultKind, TlKind, WorkloadConfig,
};
use anveshak::coordinator::des::DesEngine;
use anveshak::dataflow::{Event, ModelVariant, Partitioner, Stage};
use anveshak::engine::EventCore;
use anveshak::obs::{NullSink, ObsSink, RingSink};
use anveshak::roadnet::{
    bfs_spotlight, bfs_spotlight_into, generate, probabilistic_spotlight,
    probabilistic_spotlight_into, wbfs_spotlight, wbfs_spotlight_into,
    SpotlightWorkspace,
};
use anveshak::runtime::{default_dir, ModelPool};
use anveshak::service::engine::MultiQueryDes;
use anveshak::service::{ScoreBackend, ScoreCtx, SimBackend};
use anveshak::sim::{
    identity_embedding, identity_image, identity_image_into,
    IdentityGallery,
};
use anveshak::tuning::{
    drop_before_exec, Batcher, BatcherPoll, BudgetManager, EventRecord,
    QueuedEvent, Signal, XiModel,
};
use anveshak::util::{Json, Micros, MS, SEC};

/// fc1d8fe (PR 2) ns/op numbers (full mode, one dev-box run, from its
/// committed BENCH_2.json) for the primitives that carry across.
const BASELINE_NS: &[(&str, f64)] = &[
    ("spotlight.wbfs_r150.repeated", 213.4),
    ("spotlight.wbfs_r500.repeated", 3_742.9),
    ("spotlight.bfs_r500.repeated", 2_216.8),
    ("spotlight.prob_60s.repeated", 24_880.0),
    ("graph.generate_1000v", 5_870_000.0),
    ("graph.generate_10000v", 604_000_000.0),
    ("identity.embedding", 1_842.7),
    ("identity.image", 61_320.4),
    ("simbackend.score_b25.per_event", 60.5),
];

/// fc1d8fe (PR 2) wall seconds of the `run()` phase for the DES
/// workloads — the pre-trait-dispatch throughput the redesigned
/// engines are held to.
const BASELINE_DES_WALL_S: &[(&str, f64)] = &[
    ("des.1000cam.base.1q", 1.52),
    ("mq.1000cam.wbfs.1q", 0.37),
    ("mq.1000cam.wbfs.4q", 1.31),
    ("mq.1000cam.wbfs.8q", 2.66),
];

struct Report {
    mode: &'static str,
    /// (name, current ns/op)
    primitives: Vec<(String, f64)>,
    /// (name, setup_s, wall_s, core_events, generated)
    des: Vec<(String, f64, f64, u64, u64)>,
}

impl Report {
    fn baseline_ns(name: &str) -> Option<f64> {
        BASELINE_NS
            .iter()
            .find(|(n, _)| *n == name)
            .map(|&(_, v)| v)
    }

    fn baseline_wall(name: &str) -> Option<f64> {
        BASELINE_DES_WALL_S
            .iter()
            .find(|(n, _)| *n == name)
            .map(|&(_, v)| v)
    }

    fn to_json(&self) -> String {
        let full = self.mode == "full";
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"anveshak-hotpath-bench-v3\",\n");
        s.push_str(&format!("  \"mode\": \"{}\",\n", self.mode));
        if full {
            s.push_str(
                "  \"baseline_commit\": \"fc1d8fe (PR 2 hot-path \
                 overhaul, pre trait-dispatch redesign)\",\n",
            );
            s.push_str(
                "  \"baseline_note\": \"baselines are one recorded \
                 dev-box run of fc1d8fe (its BENCH_2.json); DES \
                 speedups near 1.0 mean the batch-hoisted trait \
                 dispatch costs nothing measurable. Ratios are only \
                 meaningful when 'current' comes from comparable \
                 hardware — re-record both sides locally before citing \
                 them\",\n",
            );
        }
        s.push_str("  \"primitives_ns_per_op\": {\n");
        for (i, (name, ns)) in self.primitives.iter().enumerate() {
            let comma = if i + 1 < self.primitives.len() { "," } else { "" };
            match Self::baseline_ns(name).filter(|_| full) {
                Some(base) => s.push_str(&format!(
                    "    \"{name}\": {{\"current\": {ns:.1}, \
                     \"baseline\": {base:.1}, \"speedup\": {:.2}}}{comma}\n",
                    base / ns
                )),
                None => s.push_str(&format!(
                    "    \"{name}\": {{\"current\": {ns:.1}}}{comma}\n"
                )),
            }
        }
        s.push_str("  },\n");
        s.push_str("  \"des_runs\": {\n");
        for (i, (name, setup, wall, events, generated)) in
            self.des.iter().enumerate()
        {
            let comma = if i + 1 < self.des.len() { "," } else { "" };
            let eps = *events as f64 / wall.max(1e-9);
            match Self::baseline_wall(name).filter(|_| full) {
                Some(bw) => {
                    // Same workload, same event count: the throughput
                    // ratio is the wall-clock ratio.
                    s.push_str(&format!(
                        "    \"{name}\": {{\"setup_s\": {setup:.2}, \
                         \"wall_s\": {wall:.2}, \"core_events\": {events}, \
                         \"events_per_sec\": {eps:.0}, \
                         \"generated\": {generated}, \
                         \"baseline_wall_s\": {bw:.2}, \
                         \"speedup\": {:.2}}}{comma}\n",
                        bw / *wall
                    ))
                }
                None => s.push_str(&format!(
                    "    \"{name}\": {{\"setup_s\": {setup:.2}, \
                     \"wall_s\": {wall:.2}, \"core_events\": {events}, \
                     \"events_per_sec\": {eps:.0}, \
                     \"generated\": {generated}}}{comma}\n"
                )),
            }
        }
        s.push_str("  }\n");
        s.push_str("}\n");
        s
    }
}

/// Time `f` over `iters` iterations; returns ns/op.
fn bench<F: FnMut()>(
    report: &mut Report,
    name: &str,
    iters: u64,
    mut f: F,
) -> f64 {
    // Warm-up.
    for _ in 0..iters.min(100) {
        f();
    }
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    let ns = start.elapsed().as_nanos() as f64 / iters as f64;
    let (val, unit) = if ns > 1e6 {
        (ns / 1e6, "ms")
    } else if ns > 1e3 {
        (ns / 1e3, "us")
    } else {
        (ns, "ns")
    };
    println!("{name:<44} {val:>10.2} {unit}/op   ({iters} iters)");
    report.primitives.push((name.to_string(), ns));
    ns
}

/// Run a single-query DES workload; records setup/run wall + counters.
fn run_des(report: &mut Report, name: &str, cfg: ExperimentConfig) {
    let setup = Instant::now();
    let engine = DesEngine::new(cfg);
    let setup_s = setup.elapsed().as_secs_f64();
    let start = Instant::now();
    let r = engine.run();
    let wall = start.elapsed().as_secs_f64();
    println!(
        "{name:<34} setup {setup_s:>5.2}s  run {wall:>6.2}s  \
         {:>9} core events  {:>9.0} ev/s  ({} frames)",
        r.core_events,
        r.core_events as f64 / wall.max(1e-9),
        r.summary.generated,
    );
    report.des.push((
        name.to_string(),
        setup_s,
        wall,
        r.core_events,
        r.summary.generated,
    ));
}

/// Run a single-query DES workload with an explicit trace sink: the
/// observability-overhead A/B rows (NullSink must cost nothing over
/// the plain build; the RingSink delta prices the always-on flight
/// recorder).
fn run_des_sink<S: ObsSink>(
    report: &mut Report,
    name: &str,
    cfg: ExperimentConfig,
    sink: S,
) {
    let setup = Instant::now();
    let app = apps::resolve(&cfg);
    let engine = DesEngine::with_app_sink(cfg, &app, sink);
    let setup_s = setup.elapsed().as_secs_f64();
    let start = Instant::now();
    let r = engine.run();
    let wall = start.elapsed().as_secs_f64();
    println!(
        "{name:<34} setup {setup_s:>5.2}s  run {wall:>6.2}s  \
         {:>9} core events  {:>9.0} ev/s  ({} frames)",
        r.core_events,
        r.core_events as f64 / wall.max(1e-9),
        r.summary.generated,
    );
    report.des.push((
        name.to_string(),
        setup_s,
        wall,
        r.core_events,
        r.summary.generated,
    ));
}

/// Run a single-query DES workload through an explicit
/// [`apps::AppDefinition`]; reports the fusion-update count alongside
/// throughput (the fusion-on/off section holds everything but the QF
/// block fixed).
fn run_des_app(
    report: &mut Report,
    name: &str,
    cfg: ExperimentConfig,
    app: &apps::AppDefinition,
) {
    let setup = Instant::now();
    let engine = DesEngine::with_app(cfg, app);
    let setup_s = setup.elapsed().as_secs_f64();
    let start = Instant::now();
    let r = engine.run();
    let wall = start.elapsed().as_secs_f64();
    println!(
        "{name:<34} setup {setup_s:>5.2}s  run {wall:>6.2}s  \
         {:>9} core events  {:>9.0} ev/s  ({} frames, {} detections, \
         {} refinements)",
        r.core_events,
        r.core_events as f64 / wall.max(1e-9),
        r.summary.generated,
        r.detections,
        r.fusion_updates,
    );
    report.des.push((
        name.to_string(),
        setup_s,
        wall,
        r.core_events,
        r.summary.generated,
    ));
}

/// Run a multi-query DES workload (N queries over the shared workers).
fn run_mq(report: &mut Report, name: &str, cfg: ExperimentConfig) {
    let mq = cfg.multi_query.clone();
    let setup = Instant::now();
    let engine = MultiQueryDes::new(cfg, mq);
    let setup_s = setup.elapsed().as_secs_f64();
    let start = Instant::now();
    let r = engine.run();
    let wall = start.elapsed().as_secs_f64();
    println!(
        "{name:<34} setup {setup_s:>5.2}s  run {wall:>6.2}s  \
         {:>9} core events  {:>9.0} ev/s  ({} frames)",
        r.core_events,
        r.core_events as f64 / wall.max(1e-9),
        r.aggregate.generated,
    );
    report.des.push((
        name.to_string(),
        setup_s,
        wall,
        r.core_events,
        r.aggregate.generated,
    ));
}

fn des_cfg(smoke: bool) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    if smoke {
        c.num_cameras = 60;
        c.workload.vertices = 60;
        c.workload.edges = 160;
        c.duration_secs = 10.0;
    } else {
        c.num_cameras = 1000;
        c.duration_secs = 60.0;
    }
    c.batching = BatchingKind::Dynamic { max: 25 };
    c.drops_enabled = true;
    c
}

fn mq_cfg(smoke: bool, queries: usize) -> ExperimentConfig {
    let mut c = des_cfg(smoke);
    c.tl = TlKind::Wbfs;
    c.multi_query.num_queries = queries;
    c.multi_query.mean_interarrival_secs = 5.0;
    c.multi_query.lifetime_secs = if smoke { 10.0 } else { 60.0 };
    c.multi_query.max_active = 16;
    c.multi_query.max_active_cameras = 100_000;
    c
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let emit_json = args.iter().any(|a| a == "--json");
    let mut report = Report {
        mode: if smoke { "smoke" } else { "full" },
        primitives: Vec::new(),
        des: Vec::new(),
    };
    let rp = &mut report;
    // Iteration scaler for smoke mode.
    let it = |n: u64| if smoke { (n / 100).max(10) } else { n };

    println!("== Shared event core ==");
    {
        let mut core: EventCore<u64> = EventCore::new();
        let mut t: Micros = 0;
        // Two schedules + two pops per iteration: steady state, so the
        // slab/heap stay at their (tiny) high-water capacity.
        bench(rp, "event_core.schedule_pop_x2", it(2_500_000), || {
            t += 100;
            core.schedule(t, t as u64);
            core.schedule(t + 50, t as u64 + 1);
            while core.pop_until(t + 50).is_some() {}
        });
    }

    println!("\n== L3 request-path primitives ==");

    let part = Partitioner::new(10);
    let mut k = 0usize;
    bench(rp, "partitioner.route", it(5_000_000), || {
        k = k.wrapping_add(1);
        std::hint::black_box(part.route(k));
    });

    let xi = XiModel::affine_ms(52.5, 67.5);
    bench(rp, "xi.estimate", it(5_000_000), || {
        std::hint::black_box(xi.xi(std::hint::black_box(17)));
    });

    bench(rp, "drop_point_2.check", it(5_000_000), || {
        std::hint::black_box(drop_before_exec(
            std::hint::black_box(10 * SEC),
            2 * SEC,
            1_740 * MS,
            15 * SEC,
        ));
    });

    // Batcher: steady-state push+poll cycle at batch ~8.
    let mut b: Batcher<u64> = Batcher::dynamic(25);
    let mut now = 0i64;
    let mut id = 0u64;
    bench(rp, "batcher.push_poll (dynamic)", it(300_000), || {
        now += 125 * MS;
        b.push(QueuedEvent {
            item: id,
            id,
            arrival: now,
            deadline: now + 10 * SEC,
        });
        id += 1;
        if let BatcherPoll::Ready(batch) = b.poll(now, &xi) {
            std::hint::black_box(batch.len());
        }
    });

    // Budget bookkeeping: record + signal application.
    let mut bm = BudgetManager::new(10, 25, 4096);
    let mut e = 0u64;
    bench(rp, "budget.record", it(1_000_000), || {
        bm.record(
            e,
            EventRecord {
                departure: 5 * SEC,
                queue: SEC,
                batch: 10,
                sent_to: (e % 10) as usize,
            },
        );
        e += 1;
    });
    let mut s = 0u64;
    bench(rp, "budget.apply(reject)", it(1_000_000), || {
        bm.apply(
            Signal::Reject {
                event: s % e,
                eps: SEC,
                sum_queue: 2 * SEC,
            },
            &xi,
        );
        s += 1;
    });

    println!("\n== Road-network generation (CSR + dedup-set builder) ==");
    bench(rp, "graph.generate_1000v", it(300), || {
        std::hint::black_box(
            generate(&WorkloadConfig::default(), 2019).num_edges(),
        );
    });
    if !smoke {
        let w10k = WorkloadConfig {
            vertices: 10_000,
            edges: 28_170,
            ..Default::default()
        };
        bench(rp, "graph.generate_10000v", 3, || {
            std::hint::black_box(generate(&w10k, 2019).num_edges());
        });
    }

    println!("\n== TL spotlight expansion (fresh vs reused workspace) ==");
    let g = generate(&WorkloadConfig::default(), 2019);
    let mut ws = SpotlightWorkspace::new();
    let mut out = Vec::new();
    // r=150 m is the typical early blind-spot radius (es=4 m/s, a few
    // seconds blind, + FOV): the contracted-spotlight common case the
    // TL re-expands every tick.
    bench(rp, "spotlight.wbfs_r150.fresh", it(200_000), || {
        std::hint::black_box(wbfs_spotlight(&g, 0, 150.0).len());
    });
    bench(rp, "spotlight.wbfs_r150.repeated", it(200_000), || {
        wbfs_spotlight_into(&g, 0, 150.0, &mut ws, &mut out);
        std::hint::black_box(out.len());
    });
    bench(rp, "spotlight.wbfs_r500.fresh", it(50_000), || {
        std::hint::black_box(wbfs_spotlight(&g, 0, 500.0).len());
    });
    bench(rp, "spotlight.wbfs_r500.repeated", it(50_000), || {
        wbfs_spotlight_into(&g, 0, 500.0, &mut ws, &mut out);
        std::hint::black_box(out.len());
    });
    bench(rp, "spotlight.bfs_r500.fresh", it(50_000), || {
        std::hint::black_box(bfs_spotlight(&g, 0, 500.0, 84.5).len());
    });
    bench(rp, "spotlight.bfs_r500.repeated", it(50_000), || {
        bfs_spotlight_into(&g, 0, 500.0, 84.5, &mut ws, &mut out);
        std::hint::black_box(out.len());
    });
    bench(rp, "spotlight.prob_60s.fresh", it(20_000), || {
        std::hint::black_box(
            probabilistic_spotlight(&g, 0, 4.0, 60.0, 0.9).len(),
        );
    });
    bench(rp, "spotlight.prob_60s.repeated", it(20_000), || {
        probabilistic_spotlight_into(
            &g, 0, 4.0, 60.0, 0.9, &mut ws, &mut out,
        );
        std::hint::black_box(out.len());
    });

    println!("\n== Identity images / batch scoring ==");
    let mut ident = 0u64;
    bench(rp, "identity.embedding", it(100_000), || {
        ident = (ident + 1) % 16;
        std::hint::black_box(identity_embedding(ident).len());
    });
    let mut gallery = IdentityGallery::new();
    bench(rp, "identity.embedding.cached", it(1_000_000), || {
        ident = (ident + 1) % 16;
        std::hint::black_box(gallery.embedding(ident).len());
    });
    let mut frame = 0u64;
    bench(rp, "identity.image", it(5_000), || {
        frame += 1;
        std::hint::black_box(identity_image(1, frame, 0.25).len());
    });
    let mut img_buf = Vec::new();
    bench(rp, "identity.image.into_buffer", it(5_000), || {
        frame += 1;
        identity_image_into(1, frame, 0.25, &mut img_buf);
        std::hint::black_box(img_buf.len());
    });

    // SimBackend columnar batch scoring, 25 events per batch.
    {
        let backend = SimBackend::default();
        let events: Vec<Event> = (0..25)
            .map(|i| Event::frame(i, i as usize % 8, i, 0, i % 3 == 0))
            .collect();
        let mut scores: Vec<f32> = Vec::new();
        let ctx = ScoreCtx {
            stage: Stage::Va,
            variant: ModelVariant::Va,
            query: 0,
            refined: None,
        };
        let per_batch = bench(
            rp,
            "simbackend.score_b25.batch",
            it(200_000),
            || {
                scores.clear();
                backend.score_into(&ctx, &events, &mut scores);
                std::hint::black_box(scores.len());
            },
        );
        let per_event = per_batch / events.len() as f64;
        println!(
            "simbackend.score_b25.per_event               {per_event:>10.2} ns/op"
        );
        rp.primitives
            .push(("simbackend.score_b25.per_event".into(), per_event));
    }

    println!("\n== Infra substrates ==");
    let manifest_text = std::fs::read_to_string(
        default_dir().join("manifest.json"),
    )
    .unwrap_or_else(|_| "{\"a\":[1,2,3]}".into());
    bench(rp, "json.parse(manifest)", it(2_000), || {
        std::hint::black_box(Json::parse(&manifest_text).unwrap());
    });

    println!("\n== DES engine throughput (events/sec, shared core) ==");
    {
        // Single query, Base TL (all cameras active): the max-load
        // configuration that stresses the batcher/budget/drop path.
        let mut c = des_cfg(smoke);
        c.tl = TlKind::Base;
        run_des(rp, "des.1000cam.base.1q", c);
    }
    {
        // Observability A/B on the same max-load workload: NullSink is
        // the default build (the two wall clocks should be
        // indistinguishable — the property tests prove the *results*
        // identical, this row prices the residual branch); the ring
        // row is the always-on flight recorder.
        let mut c = des_cfg(smoke);
        c.tl = TlKind::Base;
        run_des_sink(rp, "des.1000cam.obs.null", c.clone(), NullSink);
        run_des_sink(rp, "des.1000cam.obs.ring", c, RingSink::new(4093));
    }
    for queries in [1usize, 4, 8] {
        let c = mq_cfg(smoke, queries);
        run_mq(rp, &format!("mq.1000cam.wbfs.{queries}q"), c);
    }

    println!(
        "\n== Query-fusion feedback loop (DES, App 2, fusion on/off) =="
    );
    {
        // Same composition (large CR, BFS spotlight) with the QF block
        // as the only difference: `fusion_on` routes RnnFusion
        // refinements back to VA/CR (refined queries score with
        // sharpened error rates), `fusion_off` swaps in NoFusion. The
        // delta is the recall-vs-throughput price of closing the
        // feedback loop.
        let mut c = des_cfg(smoke);
        c.tl = TlKind::Bfs;
        c.app = AppKind::App2;
        let on = apps::table1(AppKind::App2).with_tl_kind(c.tl);
        let off = apps::AppBuilder::new("app2-fusion-off")
            .filter_control(apps::ActiveFlagFc)
            .video_analytics(apps::SimDetector::hog())
            .contention_resolver(apps::SimReid::large())
            .tracking_logic(c.tl)
            .build();
        run_des_app(rp, "des.1000cam.app2.fusion_on", c.clone(), &on);
        run_des_app(rp, "des.1000cam.app2.fusion_off", c, &off);
    }

    println!(
        "\n== Compute dynamism (4x mid-run node slowdown, frozen vs online xi) =="
    );
    {
        // Identical workload and seed; the only difference is whether
        // executors feed observed durations back into their ξ models.
        // The frozen run prices batches/drops against a model 4x too
        // optimistic after the step — the events/sec *and* the
        // on-time/dropped mix move; online ξ re-tunes within seconds.
        let mk = |online: bool| {
            let mut c = des_cfg(smoke);
            c.tl = TlKind::Base;
            c.service.online_xi = online;
            c.service.compute_events.push(ComputeEvent {
                // Mid-run: des_cfg is 60 s full / 10 s smoke.
                at_sec: if smoke { 5.0 } else { 30.0 },
                node: None,
                factor: 4.0,
            });
            c
        };
        run_des(
            rp,
            "des.1000cam.varying_compute.frozen_xi",
            mk(false),
        );
        run_des(
            rp,
            "des.1000cam.varying_compute.online_xi",
            mk(true),
        );
    }

    println!(
        "\n== Fault injection (mid-run node crash, recovery on/off) =="
    );
    {
        // Same max-load workload and seed. The `none` row is the
        // zero-fault control — it prices the fault-model plumbing
        // itself and should be indistinguishable from
        // des.1000cam.base.1q; the crash rows differ only in the
        // recovery switch (retry/backoff + orphan re-dispatch vs
        // write-off as lost_to_fault).
        let mk = |crash: bool, recovery: bool| {
            let mut c = des_cfg(smoke);
            c.tl = TlKind::Base;
            if crash {
                c.service.fault_events.push(FaultEvent {
                    // Mid-run: des_cfg is 60 s full / 10 s smoke.
                    at_sec: if smoke { 5.0 } else { 30.0 },
                    kind: FaultKind::NodeCrash {
                        node: 1,
                        down_secs: None,
                    },
                });
            }
            c.service.recovery.enabled = recovery;
            c
        };
        run_des(rp, "des.1000cam.faults.none", mk(false, true));
        run_des(rp, "des.1000cam.faults.recovery_on", mk(true, true));
        run_des(rp, "des.1000cam.faults.recovery_off", mk(true, false));
    }

    println!(
        "\n== Sharded execution (K=1 vs K=4, sequential vs threaded) =="
    );
    {
        // Same workload and seed; the arms differ only in the shard
        // layout and merge backend. The property suite proves the
        // *results* bit-identical, so these rows price purely the
        // merge machinery: k1 vs the single-core baseline is the
        // router + merge-loop overhead, k4 adds real cross-shard
        // envelope traffic, and k4_threaded prices the channel
        // round-trips of the worker backend against the inline merge.
        let mk = |shards: usize, threads: usize| {
            let mut c = des_cfg(smoke);
            c.tl = TlKind::Base;
            c.sharding.shards = shards;
            c.sharding.threads = threads;
            c
        };
        run_des(rp, "des.1000cam.shards.k1", mk(1, 0));
        run_des(rp, "des.1000cam.shards.k4", mk(4, 0));
        run_des(rp, "des.1000cam.shards.k4_threaded", mk(4, 4));
    }

    println!(
        "\n== Adaptation plane (4x mid-run slowdown, controller on/off) =="
    );
    {
        // Same max-load workload, seed, ladder and compute step as the
        // rest of the DES section; the arms differ only in the
        // controller switch. The `off` row carries the full adaptation
        // config with the controller frozen — it prices the inert
        // plane's plumbing (the bit-identity property says the results
        // match a pre-adaptation build; this row says the wall clock
        // does too). The `on` row adds command minting, feedback
        // routing and per-camera effective-batch pricing under load.
        let mk = |on: bool| {
            let mut c = des_cfg(smoke);
            c.tl = TlKind::Base;
            c.adaptation = preset("adapt_on").adaptation;
            c.adaptation.enabled = on;
            c.service.compute_events.push(ComputeEvent {
                // Mid-run: des_cfg is 60 s full / 10 s smoke.
                at_sec: if smoke { 5.0 } else { 30.0 },
                node: None,
                factor: 4.0,
            });
            c
        };
        run_des(rp, "des.1000cam.adapt.on", mk(true));
        run_des(rp, "des.1000cam.adapt.off", mk(false));
    }

    println!("\n== L1/L2: PJRT model execution (measured xi(b)) ==");
    match ModelPool::load(&default_dir(), &["va", "cr_small"], Some(&[1, 8, 25])) {
        Ok(pool) => {
            for variant in ["va", "cr_small"] {
                let (fit, samples) = pool.calibrate_xi(variant, 5).unwrap();
                for (b, us) in &samples {
                    println!(
                        "pjrt.{variant:<9} b={b:<3} {:>9.2} ms/batch  {:>8.2} ms/event",
                        *us as f64 / 1e3,
                        *us as f64 / 1e3 / *b as f64
                    );
                }
                println!(
                    "pjrt.{variant:<9} fitted xi(b) = {:.2} + {:.3}*b ms",
                    fit.alpha_us() / 1e3,
                    fit.beta_us() / 1e3
                );
            }
            // End-to-end model call including upload of one frame.
            let img = identity_image(1, 0, 0.25);
            let q = vec![0f32; pool.feat_dim()];
            bench(rp, "pjrt.va.execute b=1 (incl upload)", 200, || {
                std::hint::black_box(
                    pool.execute("va", &img, &q).unwrap().scores[0],
                );
            });
        }
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }

    if emit_json {
        let json = report.to_json();
        std::fs::write("BENCH_3.json", &json)
            .expect("write BENCH_3.json");
        println!("\nwrote BENCH_3.json ({} bytes)", json.len());
    }
}
