//! One bench per paper table/figure: runs each §5 experiment preset end
//! to end on the DES engine and prints the paper-comparable headline
//! row plus the wall-clock cost of regenerating it.
//!
//! Run via `cargo bench --bench fig_tables`. (Hand-rolled harness; the
//! offline build has no criterion.) The full-resolution CSV series come
//! from `cargo run --release --bin harness -- all`.

use std::time::Instant;

use anveshak::config::preset;
use anveshak::coordinator::des;

struct Row {
    fig: &'static str,
    label: &'static str,
    preset: &'static str,
    paper: &'static str,
}

fn main() {
    let rows = [
        Row { fig: "Fig5/7a", label: "SB-1 (stream)", preset: "fig7a",
              paper: "median ~0.2s, occasional >gamma at peak cams" },
        Row { fig: "Fig5/7b", label: "SB-20", preset: "fig7b",
              paper: "median 3.65s, ~6% (703) delayed" },
        Row { fig: "Fig5/7c", label: "NOB-25", preset: "fig7c",
              paper: "median 0.4s, 90 delayed" },
        Row { fig: "Fig5/7d", label: "DB-25", preset: "fig7d",
              paper: "median 7.66s, 0 delayed" },
        Row { fig: "Fig6b", label: "SB-1 es=6", preset: "fig6b_sb1",
              paper: "57% delayed" },
        Row { fig: "Fig6b", label: "SB-20 es=6", preset: "fig6b_sb20",
              paper: "0 delayed (this run), knob-dependent" },
        Row { fig: "Fig6b", label: "DB-25 es=6", preset: "fig6b_db25",
              paper: "0 delayed" },
        Row { fig: "Fig9", label: "DB-25 +bw-drop", preset: "fig9_anv",
              paper: "stable, no delays after 30Mbps drop" },
        Row { fig: "Fig9", label: "NOB +bw-drop", preset: "fig9_nob",
              paper: "unstable after 500s" },
        Row { fig: "Fig10", label: "WBFS SB-1", preset: "fig10_wbfs_sb1",
              paper: "stable; peak 67 cams (vs BFS 111)" },
        Row { fig: "Fig10", label: "Base 100c", preset: "fig10_base_100",
              paper: "stable, ~60k frames" },
        Row { fig: "Fig10", label: "Base 200c", preset: "fig10_base_200",
              paper: "unstable, >55% of ~120k delayed" },
        Row { fig: "Fig11", label: "DB-25 es=7", preset: "fig11_nodrops",
              paper: "unstable, 85% delayed" },
        Row { fig: "Fig11", label: "+drops es=7", preset: "fig11_drops",
              paper: "stable, 17% dropped, 0 delayed" },
        Row { fig: "Fig12", label: "App2 SB-20", preset: "fig12_sb20",
              paper: "median 4.33s, ~5% delayed" },
        Row { fig: "Fig12", label: "App2 DB-25", preset: "fig12_db25",
              paper: "median 5.39s, 0 delayed" },
        Row { fig: "Fig12", label: "App2 es6 drops", preset: "fig12_es6_drops",
              paper: "median 5.36s, ~12% dropped" },
    ];

    println!(
        "{:<8} {:<16} {:>8} {:>8} {:>7} {:>7} {:>8} {:>6} {:>9}  paper-expectation",
        "figure", "config", "events", "on-time", "delay%", "drop%",
        "median-s", "peak", "bench-s"
    );
    let mut total = 0.0;
    for row in &rows {
        let cfg = preset(row.preset);
        let start = Instant::now();
        let r = des::run(cfg);
        let wall = start.elapsed().as_secs_f64();
        total += wall;
        let s = &r.summary;
        println!(
            "{:<8} {:<16} {:>8} {:>8} {:>6.1}% {:>6.1}% {:>8.2} {:>6} {:>9.2}  {}",
            row.fig,
            row.label,
            s.generated,
            s.on_time,
            100.0 * s.delay_rate(),
            100.0 * s.drop_rate(),
            s.latency.median,
            r.peak_active,
            wall,
            row.paper
        );
    }
    println!("\ntotal bench wall time: {total:.1}s for {} experiments", rows.len());
}
