//! Multi-query service bench: engine throughput and per-query latency
//! vs. the number of concurrent queries sharing the deployment.
//!
//! Runs the multi-query DES mode with 1, 4 and 8 concurrent queries on
//! the same camera network and reports aggregate event throughput
//! (simulated events per wall-clock second of engine time), on-time
//! rate and latency percentiles — the scaling story the service layer
//! exists to tell. Run via `cargo bench --bench multi_query`.
//! (Hand-rolled harness; the offline build has no criterion.)

use std::time::Instant;

use anveshak::config::ExperimentConfig;
use anveshak::coordinator::des::run_multi;

fn cfg_for(concurrent: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("mq-bench-{concurrent}");
    // A mid-size network keeps the bench quick while still exercising
    // cross-query batching on shared workers.
    cfg.num_cameras = 300;
    cfg.workload.vertices = 300;
    cfg.workload.edges = 840;
    // All queries arrive (nearly) together and live the whole window,
    // so `concurrent` is the steady-state multiprogramming level.
    cfg.multi_query.num_queries = concurrent;
    cfg.multi_query.mean_interarrival_secs = 0.5;
    cfg.multi_query.lifetime_secs = 120.0;
    cfg.multi_query.max_active = concurrent.max(1);
    cfg.multi_query.max_active_cameras = 10_000;
    cfg.multi_query.queue_capacity = concurrent;
    cfg
}

fn main() {
    println!(
        "{:<12} {:>8} {:>12} {:>10} {:>9} {:>9} {:>9} {:>7}",
        "concurrent",
        "events",
        "events/s(w)",
        "on-time%",
        "median-s",
        "p99-s",
        "drop%",
        "wall-s"
    );
    for concurrent in [1usize, 4, 8] {
        let cfg = cfg_for(concurrent);
        let start = Instant::now();
        let r = run_multi(cfg);
        let wall = start.elapsed().as_secs_f64();
        let s = &r.aggregate;
        let done = s.on_time + s.delayed;
        let throughput = if wall > 0.0 {
            done as f64 / wall
        } else {
            0.0
        };
        let on_time_pct = if s.generated > 0 {
            100.0 * s.on_time as f64 / s.generated as f64
        } else {
            0.0
        };
        println!(
            "{:<12} {:>8} {:>12.0} {:>9.1}% {:>9.2} {:>9.2} {:>8.1}% {:>7.2}",
            concurrent,
            s.generated,
            throughput,
            on_time_pct,
            s.latency.median,
            s.latency.p99,
            100.0 * s.drop_rate(),
            wall
        );
        assert_eq!(
            r.peak_concurrent, concurrent,
            "bench config should reach the target concurrency"
        );
    }
}
