//! Ablation benches for the design choices DESIGN.md calls out — what
//! the paper leaves implicit, measured:
//!
//! 1. probe signals (§4.5.2): without them, collapsed budgets never
//!    recover and the drop rate stays pinned high;
//! 2. the early-arrival threshold ε_max: too small → budgets grow on
//!    noise (latency creeps toward γ); too large → batches stay small;
//! 3. b_max for dynamic batching: the throughput/latency frontier;
//! 4. the per-transit re-id miss rate: robustness of the tuning-triangle
//!    conclusions to the workload's blind-spell length.
//!
//! Run via `cargo bench --bench ablations`.

use anveshak::config::{preset, BatchingKind};
use anveshak::coordinator::des;

fn main() {
    println!("== Ablation 1: probe signals (es=7, drops on) ==");
    println!(
        "{:<18} {:>8} {:>8} {:>8} {:>9}",
        "probe_every", "events", "delay%", "drop%", "median-s"
    );
    for probe in [0u64, 10, 50, 200] {
        let mut cfg = preset("fig11_drops");
        cfg.probe_every = probe;
        let r = des::run(cfg);
        let s = &r.summary;
        println!(
            "{:<18} {:>8} {:>7.1}% {:>7.1}% {:>9.2}",
            if probe == 0 {
                "disabled".to_string()
            } else {
                format!("every {probe}th")
            },
            s.generated,
            100.0 * s.delay_rate(),
            100.0 * s.drop_rate(),
            s.latency.median
        );
    }

    println!("\n== Ablation 2: eps_max (budget-growth threshold, DB-25) ==");
    println!(
        "{:<12} {:>8} {:>8} {:>9} {:>9}",
        "eps_max", "events", "delay%", "median-s", "p99-s"
    );
    for eps_ms in [250.0, 1_000.0, 2_000.0, 8_000.0] {
        let mut cfg = preset("fig7d");
        cfg.eps_max_ms = eps_ms;
        let r = des::run(cfg);
        let s = &r.summary;
        println!(
            "{:<12} {:>8} {:>7.1}% {:>9.2} {:>9.2}",
            format!("{:.2}s", eps_ms / 1e3),
            s.generated,
            100.0 * s.delay_rate(),
            s.latency.median,
            s.latency.p99
        );
    }

    println!("\n== Ablation 3: dynamic-batching b_max frontier ==");
    println!(
        "{:<8} {:>8} {:>8} {:>9} {:>9} {:>6}",
        "b_max", "events", "delay%", "median-s", "p99-s", "peak"
    );
    for bmax in [2, 5, 10, 25, 40] {
        let mut cfg = preset("fig7d");
        cfg.batching = BatchingKind::Dynamic { max: bmax };
        let r = des::run(cfg);
        let s = &r.summary;
        println!(
            "{:<8} {:>8} {:>7.1}% {:>9.2} {:>9.2} {:>6}",
            bmax,
            s.generated,
            100.0 * s.delay_rate(),
            s.latency.median,
            s.latency.p99,
            r.peak_active
        );
    }

    println!("\n== Ablation 4: workload sensitivity (transit miss rate) ==");
    println!(
        "{:<8} {:>10} {:>8} {:>8} {:>6}   (DB-25 stays 0-delayed until the",
        "miss", "events", "delay%", "drop%", "peak"
    );
    println!("{:<54}spotlight exceeds cluster capacity)", "");
    for miss in [0.0, 0.03, 0.05, 0.10] {
        let mut cfg = preset("fig7d");
        cfg.semantics.transit_miss = miss;
        let r = des::run(cfg);
        let s = &r.summary;
        println!(
            "{:<8} {:>10} {:>7.1}% {:>7.1}% {:>6}",
            format!("{:.0}%", miss * 100.0),
            s.generated,
            100.0 * s.delay_rate(),
            100.0 * s.drop_rate(),
            r.peak_active
        );
    }
}
