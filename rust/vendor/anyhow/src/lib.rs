//! Minimal offline shim of the `anyhow` crate — see README.md.
//!
//! String-backed errors with context chaining; enough for the subset of
//! the real API this repository uses (`anyhow!`, `ensure!`, `Context`,
//! `Result`, `?`-conversions from `std::error::Error` types).

use std::fmt;

/// A string-backed error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` lowers to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            msg: m.to_string(),
        }
    }

    /// Prefix the message with a context line (newest first, like the
    /// real crate's report rendering).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Self {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`, exactly
// like the real crate — that is what makes this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($rest:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($rest)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($rest:tt)+) => {
        return Err($crate::anyhow!($($rest)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_and_context() {
        let e = anyhow!("bad thing {}", 7);
        assert_eq!(format!("{e}"), "bad thing 7");
        let r: Result<()> = Err(anyhow!("inner"));
        let r = r.with_context(|| "outer");
        assert_eq!(format!("{}", r.unwrap_err()), "outer: inner");
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
    }

    #[test]
    fn ensure_returns_error() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
    }
}
