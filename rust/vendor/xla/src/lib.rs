//! Offline stub of the `xla`/PJRT bindings — see README.md.
//!
//! Every entry point that would touch PJRT returns [`Error`]; the types
//! exist purely so the `pjrt`-gated runtime code type-checks on
//! machines without the real bindings.

use std::borrow::Borrow;
use std::path::Path;

/// Stub error: always "PJRT backend unavailable".
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla stub: PJRT backend not linked (replace vendor/xla with the \
         real bindings to execute models)"
            .to_string(),
    ))
}

/// Stub of the PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable()
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
