//! Metrics: the per-event ledger, the 1-second timeline aggregation
//! that back every figure in the paper's evaluation, and the per-query
//! ledger set used by the multi-query service layer.

mod ledger;
mod multi;
mod timeline;

pub use ledger::{Ledger, Outcome, Summary};
pub use multi::QueryLedgers;
pub use timeline::{Timeline, TimelineRow};
