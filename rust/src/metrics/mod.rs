//! Metrics: the per-event ledger and the 1-second timeline aggregation
//! that back every figure in the paper's evaluation.

mod ledger;
mod timeline;

pub use ledger::{Ledger, Outcome, Summary};
pub use timeline::{Timeline, TimelineRow};
