//! Per-second timeline aggregation — the data behind the paper's
//! timeline plots (Figs 7, 8, 9, 10, 11): active camera count, mean
//! end-to-end event latency per second, and per-stage batch sizes.

use crate::dataflow::Stage;
use crate::util::FastMap;
use crate::util::{Micros, SEC};

/// One second of aggregated run state.
#[derive(Debug, Clone, Default)]
pub struct TimelineRow {
    /// Active camera count sampled during this second.
    pub active_cameras: usize,
    /// Mean end-to-end latency (s) of events completing this second.
    pub mean_latency_s: f64,
    /// Number of events completing this second.
    pub completed: usize,
    /// Events dropped this second.
    pub dropped: usize,
    /// Mean batch size executed per stage this second.
    pub mean_batch: FastMap<Stage, f64>,
}

#[derive(Debug, Default)]
struct Acc {
    active_cameras: usize,
    lat_sum: f64,
    completed: usize,
    dropped: usize,
    batch_sum: FastMap<Stage, (f64, usize)>,
    /// (latency_s, batch_size) samples per stage — Fig 8's scatter.
    scatter: Vec<(Stage, f64, usize)>,
}

/// Collects per-second aggregates for a run.
#[derive(Debug, Default)]
pub struct Timeline {
    rows: FastMap<i64, Acc>,
    horizon: i64,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    fn acc(&mut self, t: Micros) -> &mut Acc {
        let s = t / SEC;
        self.horizon = self.horizon.max(s);
        self.rows.entry(s).or_default()
    }

    /// Sample the current active camera count (call once per second).
    pub fn sample_active(&mut self, t: Micros, active: usize) {
        self.acc(t).active_cameras = active;
    }

    /// An event completed at `t` with end-to-end `latency`.
    pub fn completed(&mut self, t: Micros, latency: Micros) {
        let a = self.acc(t);
        a.lat_sum += latency as f64 / 1e6;
        a.completed += 1;
    }

    /// An event was dropped at `t`.
    pub fn dropped(&mut self, t: Micros) {
        self.acc(t).dropped += 1;
    }

    /// A batch of size `b` executed at `stage`, with per-event task
    /// latency `task_lat` (queue + exec) — feeds Fig 8's scatter too.
    pub fn batch_executed(
        &mut self,
        t: Micros,
        stage: Stage,
        b: usize,
        task_lat: Micros,
    ) {
        let a = self.acc(t);
        let e = a.batch_sum.entry(stage).or_insert((0.0, 0));
        e.0 += b as f64;
        e.1 += 1;
        a.scatter.push((stage, task_lat as f64 / 1e6, b));
    }

    /// Materialize dense per-second rows `0..=horizon`.
    pub fn rows(&self) -> Vec<TimelineRow> {
        let mut out = Vec::with_capacity(self.horizon as usize + 1);
        let mut last_active = 0;
        for s in 0..=self.horizon {
            let mut row = TimelineRow::default();
            if let Some(a) = self.rows.get(&s) {
                // Hold the last sampled camera count through gaps.
                if a.active_cameras > 0 {
                    last_active = a.active_cameras;
                }
                row.active_cameras = last_active;
                row.completed = a.completed;
                row.dropped = a.dropped;
                row.mean_latency_s = if a.completed > 0 {
                    a.lat_sum / a.completed as f64
                } else {
                    0.0
                };
                for (stage, (sum, n)) in &a.batch_sum {
                    row.mean_batch.insert(*stage, sum / *n as f64);
                }
            } else {
                row.active_cameras = last_active;
            }
            out.push(row);
        }
        out
    }

    /// All (stage, task latency s, batch size) samples — Fig 8c/8d.
    pub fn scatter(&self, stage: Stage) -> Vec<(f64, usize)> {
        let mut out = Vec::new();
        let mut keys: Vec<_> = self.rows.keys().copied().collect();
        keys.sort();
        for k in keys {
            for (s, lat, b) in &self.rows[&k].scatter {
                if *s == stage {
                    out.push((*lat, *b));
                }
            }
        }
        out
    }

    /// Peak active camera count over the run.
    pub fn peak_active(&self) -> usize {
        self.rows
            .values()
            .map(|a| a.active_cameras)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::secs;

    #[test]
    fn per_second_bucketing() {
        let mut t = Timeline::new();
        t.completed(secs(1.2), secs(0.5));
        t.completed(secs(1.8), secs(1.5));
        t.completed(secs(3.0), secs(2.0));
        t.dropped(secs(1.5));
        let rows = t.rows();
        assert_eq!(rows[1].completed, 2);
        assert!((rows[1].mean_latency_s - 1.0).abs() < 1e-9);
        assert_eq!(rows[1].dropped, 1);
        assert_eq!(rows[2].completed, 0);
        assert_eq!(rows[3].completed, 1);
    }

    #[test]
    fn active_count_held_through_gaps() {
        let mut t = Timeline::new();
        t.sample_active(secs(0.0), 42);
        t.completed(secs(5.0), secs(1.0));
        let rows = t.rows();
        assert_eq!(rows[0].active_cameras, 42);
        assert_eq!(rows[3].active_cameras, 42);
        assert_eq!(rows[5].active_cameras, 42);
        assert_eq!(t.peak_active(), 42);
    }

    #[test]
    fn batch_means_and_scatter() {
        let mut t = Timeline::new();
        t.batch_executed(secs(2.0), Stage::Va, 10, secs(1.0));
        t.batch_executed(secs(2.5), Stage::Va, 20, secs(2.0));
        t.batch_executed(secs(2.5), Stage::Cr, 5, secs(3.0));
        let rows = t.rows();
        assert!((rows[2].mean_batch[&Stage::Va] - 15.0).abs() < 1e-9);
        assert!((rows[2].mean_batch[&Stage::Cr] - 5.0).abs() < 1e-9);
        let sc = t.scatter(Stage::Va);
        assert_eq!(sc.len(), 2);
        assert_eq!(sc[1], (2.0, 20));
    }
}
