//! Per-query ledgers for the multi-query service layer.
//!
//! Each admitted tracking query gets its own [`Ledger`], so conservation
//! and recall/latency statistics hold *per query* even though all
//! queries share the same VA/CR workers. A global mirror ledger backs
//! aggregate (whole-service) summaries without merging latency samples.

use crate::dataflow::{QueryId, Stage};
use crate::metrics::{Ledger, Summary};
use crate::util::{FastMap, Micros};

/// One [`Ledger`] per query plus a global aggregate mirror.
#[derive(Debug, Default)]
pub struct QueryLedgers {
    per: FastMap<QueryId, Ledger>,
    /// First-seen registration order, for stable reporting.
    order: Vec<QueryId>,
    global: Ledger,
}

impl QueryLedgers {
    pub fn new() -> Self {
        Self::default()
    }

    fn ledger_mut(&mut self, q: QueryId) -> &mut Ledger {
        if !self.per.contains_key(&q) {
            self.per.insert(q, Ledger::new());
            self.order.push(q);
        }
        self.per.get_mut(&q).expect("just inserted")
    }

    /// A source event for query `q` entered the dataflow.
    pub fn generated(&mut self, q: QueryId, id: u64, entity_present: bool) {
        self.ledger_mut(q).generated(id, entity_present);
        self.global.generated(id, entity_present);
    }

    /// Query `q`'s event reached the sink.
    pub fn completed(
        &mut self,
        q: QueryId,
        id: u64,
        latency: Micros,
        gamma: Micros,
        detected: bool,
    ) {
        self.ledger_mut(q).completed(id, latency, gamma, detected);
        self.global.completed(id, latency, gamma, detected);
    }

    /// Query `q`'s event was dropped at `stage`.
    pub fn dropped(&mut self, q: QueryId, id: u64, stage: Stage) {
        self.ledger_mut(q).dropped(id, stage);
        self.global.dropped(id, stage);
    }

    /// Query `q`'s event was lost to an injected fault at `stage`.
    pub fn lost_to_fault(&mut self, q: QueryId, id: u64, stage: Stage) {
        self.ledger_mut(q).lost_to_fault(id, stage);
        self.global.lost_to_fault(id, stage);
    }

    /// Summary for one query (None if the query never generated events).
    pub fn summary(&self, q: QueryId) -> Option<Summary> {
        self.per.get(&q).map(Ledger::summary)
    }

    /// Per-query summaries in first-seen order.
    pub fn summaries(&self) -> Vec<(QueryId, Summary)> {
        self.order
            .iter()
            .map(|&q| (q, self.per[&q].summary()))
            .collect()
    }

    /// Whole-service aggregate summary.
    pub fn aggregate(&self) -> Summary {
        self.global.summary()
    }

    /// Number of queries that generated at least one event.
    pub fn num_queries(&self) -> usize {
        self.order.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SEC;

    #[test]
    fn per_query_isolation_and_aggregate() {
        let mut ql = QueryLedgers::new();
        // Interleaved ids across two queries (ids are globally dense).
        ql.generated(1, 0, true);
        ql.generated(2, 1, false);
        ql.generated(1, 2, true);
        ql.completed(1, 0, SEC, 15 * SEC, true);
        ql.dropped(1, 2, Stage::Cr);
        ql.completed(2, 1, 20 * SEC, 15 * SEC, false);

        let s1 = ql.summary(1).unwrap();
        assert_eq!(s1.generated, 2);
        assert_eq!(s1.on_time, 1);
        assert_eq!(s1.dropped, 1);
        assert_eq!(s1.true_positives, 1);
        assert!(s1.conserved());

        let s2 = ql.summary(2).unwrap();
        assert_eq!(s2.generated, 1);
        assert_eq!(s2.delayed, 1);
        assert!(s2.conserved());

        let agg = ql.aggregate();
        assert_eq!(agg.generated, 3);
        assert_eq!(agg.on_time + agg.delayed + agg.dropped, 3);
        assert!(agg.conserved());
        assert_eq!(ql.num_queries(), 2);
    }

    #[test]
    fn summaries_in_first_seen_order() {
        let mut ql = QueryLedgers::new();
        ql.generated(7, 0, false);
        ql.generated(3, 1, false);
        ql.generated(7, 2, false);
        let ids: Vec<QueryId> =
            ql.summaries().iter().map(|&(q, _)| q).collect();
        assert_eq!(ids, vec![7, 3]);
    }

    #[test]
    fn unknown_query_has_no_summary() {
        let ql = QueryLedgers::new();
        assert!(ql.summary(9).is_none());
    }
}
