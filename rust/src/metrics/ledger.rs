//! Per-event outcome ledger.
//!
//! Every source event (camera frame entering the dataflow) is accounted
//! for exactly once: processed within γ, processed but delayed, dropped
//! at some stage, lost to an injected fault, or still in flight at
//! shutdown — the categories of Fig 6 plus the failure-model class.
//! Conservation (`generated = on_time + delayed + dropped +
//! lost_to_fault + in_flight`) is asserted by the property suite.

use crate::dataflow::Stage;
use crate::util::{Micros, Stats};

/// Final outcome of one source event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    InFlight,
    OnTime { latency: Micros },
    Delayed { latency: Micros },
    Dropped { stage: Stage },
    /// Consumed by an injected fault (node crash, partition, message
    /// loss) rather than a budget verdict — the recovery machinery's
    /// accounting class, distinct from gate drops so the A/B harness
    /// can tell "the gate said no" from "the fault ate it".
    LostToFault { stage: Stage },
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    outcome: Outcome,
    entity_present: bool,
    detected: bool,
}

/// Event accounting for one experiment run.
///
/// Source event ids are dense (a global counter), so entries live in a
/// flat `Vec` indexed by id — the ledger is touched twice per event on
/// the hot path and hashing dominated the old map-based version
/// (see EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct Ledger {
    entries: Vec<Option<Entry>>,
    generated: u64,
    /// Count of `InFlight` → terminal transitions (completed, dropped,
    /// lost-to-fault). Maintained on every path so the strict build can
    /// cross-check the per-entry scan in [`Ledger::summary`] against
    /// the running count — the trace↔ledger conservation tripwire.
    terminated: u64,
}

/// Aggregate counts + latency stats for a run.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub generated: u64,
    pub on_time: u64,
    pub delayed: u64,
    pub dropped: u64,
    /// Events consumed by injected faults (crash/partition/loss) —
    /// never charged to a drop gate.
    pub lost_to_fault: u64,
    pub in_flight: u64,
    /// Latency stats (seconds) over completed (on-time + delayed) events.
    pub latency: Stats,
    /// Ground-truth-positive frames that completed with a detection.
    pub true_positives: u64,
    /// Ground-truth-positive frames dropped before detection.
    pub positives_dropped: u64,
    /// Ground-truth-positive frames generated.
    pub positives_generated: u64,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// A source event entered the dataflow.
    pub fn generated(&mut self, id: u64, entity_present: bool) {
        self.generated += 1;
        let idx = id as usize;
        if idx >= self.entries.len() {
            self.entries.resize(idx + 1, None);
        }
        // Invariant: source ids come from a global counter, so a
        // generated id must never overwrite a live (in-flight) entry —
        // that would double-count `generated` for one slot.
        crate::strict_assert!(
            !matches!(
                self.entries.get(idx),
                Some(Some(e)) if matches!(e.outcome, Outcome::InFlight)
            ),
            "event id {id} re-generated while still in flight"
        );
        self.entries[idx] = Some(Entry {
            outcome: Outcome::InFlight,
            entity_present,
            detected: false,
        });
    }

    /// The event reached the sink with the given end-to-end latency.
    pub fn completed(
        &mut self,
        id: u64,
        latency: Micros,
        gamma: Micros,
        detected: bool,
    ) {
        // Invariant: a sink arrival must reference a generated event —
        // an unknown id here means the trace and the ledger diverged.
        crate::strict_assert!(
            matches!(self.entries.get(id as usize), Some(Some(_))),
            "sink arrival for unledgered event id {id}"
        );
        if let Some(Some(e)) = self.entries.get_mut(id as usize) {
            if matches!(e.outcome, Outcome::InFlight) {
                self.terminated += 1;
            }
            e.detected = detected;
            e.outcome = if latency <= gamma {
                Outcome::OnTime { latency }
            } else {
                Outcome::Delayed { latency }
            };
        }
        crate::strict_assert!(
            self.terminated <= self.generated,
            "more terminal outcomes than generated events"
        );
    }

    /// The event was dropped at `stage`.
    pub fn dropped(&mut self, id: u64, stage: Stage) {
        if let Some(Some(e)) = self.entries.get_mut(id as usize) {
            // First drop wins; an event cannot be dropped twice (1:1
            // selectivity) but defensive against double accounting.
            if matches!(e.outcome, Outcome::InFlight) {
                self.terminated += 1;
                e.outcome = Outcome::Dropped { stage };
            }
        }
    }

    /// The event was lost to an injected fault at `stage`.
    pub fn lost_to_fault(&mut self, id: u64, stage: Stage) {
        if let Some(Some(e)) = self.entries.get_mut(id as usize) {
            if matches!(e.outcome, Outcome::InFlight) {
                self.terminated += 1;
                e.outcome = Outcome::LostToFault { stage };
            }
        }
    }

    pub fn outcome(&self, id: u64) -> Option<Outcome> {
        self.entries
            .get(id as usize)
            .and_then(|e| e.as_ref())
            .map(|e| e.outcome)
    }

    pub fn generated_count(&self) -> u64 {
        self.generated
    }

    pub fn summary(&self) -> Summary {
        let mut s = Summary {
            generated: self.generated,
            on_time: 0,
            delayed: 0,
            dropped: 0,
            lost_to_fault: 0,
            in_flight: 0,
            latency: Stats::default(),
            true_positives: 0,
            positives_dropped: 0,
            positives_generated: 0,
        };
        let mut lats = Vec::new();
        for e in self.entries.iter().flatten() {
            if e.entity_present {
                s.positives_generated += 1;
            }
            match e.outcome {
                Outcome::InFlight => s.in_flight += 1,
                Outcome::OnTime { latency } => {
                    s.on_time += 1;
                    lats.push(latency as f64 / 1e6);
                    if e.entity_present && e.detected {
                        s.true_positives += 1;
                    }
                }
                Outcome::Delayed { latency } => {
                    s.delayed += 1;
                    lats.push(latency as f64 / 1e6);
                    if e.entity_present && e.detected {
                        s.true_positives += 1;
                    }
                }
                Outcome::Dropped { .. } => {
                    s.dropped += 1;
                    if e.entity_present {
                        s.positives_dropped += 1;
                    }
                }
                Outcome::LostToFault { .. } => {
                    s.lost_to_fault += 1;
                    if e.entity_present {
                        s.positives_dropped += 1;
                    }
                }
            }
        }
        s.latency = Stats::from(lats);
        // Conservation cross-check: the per-entry scan must agree with
        // the running transition counter maintained by the mutators.
        crate::strict_assert!(
            s.on_time + s.delayed + s.dropped + s.lost_to_fault == self.terminated,
            "ledger scan disagrees with the terminal-transition counter"
        );
        s
    }

    /// `InFlight` → terminal transitions so far (completed + dropped +
    /// lost-to-fault). Always maintained; the strict build additionally
    /// cross-checks it in [`Ledger::summary`].
    pub fn terminated_count(&self) -> u64 {
        self.terminated
    }
}

impl Summary {
    /// Conservation law over the run: generated = delivered +
    /// dropped-at-gate + lost-to-fault + in-flight.
    pub fn conserved(&self) -> bool {
        self.generated
            == self.on_time
                + self.delayed
                + self.dropped
                + self.lost_to_fault
                + self.in_flight
    }

    pub fn drop_rate(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.dropped as f64 / self.generated as f64
        }
    }

    pub fn delay_rate(&self) -> f64 {
        if self.generated == 0 {
            0.0
        } else {
            self.delayed as f64 / self.generated as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SEC;

    #[test]
    fn outcomes_accounted_once() {
        let mut l = Ledger::new();
        for id in 0..10u64 {
            l.generated(id, id % 2 == 0);
        }
        l.completed(0, 2 * SEC, 15 * SEC, true);
        l.completed(1, 20 * SEC, 15 * SEC, false);
        l.dropped(2, Stage::Cr);
        l.dropped(2, Stage::Va); // double-drop ignored
        let s = l.summary();
        assert_eq!(s.generated, 10);
        assert_eq!(s.on_time, 1);
        assert_eq!(s.delayed, 1);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.in_flight, 7);
        assert!(s.conserved());
        assert_eq!(l.outcome(2), Some(Outcome::Dropped { stage: Stage::Cr }));
    }

    #[test]
    fn lost_to_fault_is_a_distinct_terminal() {
        let mut l = Ledger::new();
        for id in 0..4u64 {
            l.generated(id, id == 0);
        }
        l.lost_to_fault(0, Stage::Va);
        l.lost_to_fault(0, Stage::Cr); // double-loss ignored
        l.dropped(1, Stage::Cr);
        l.lost_to_fault(1, Stage::Va); // first outcome wins
        l.completed(2, SEC, 15 * SEC, false);
        let s = l.summary();
        assert_eq!(s.lost_to_fault, 1);
        assert_eq!(s.dropped, 1);
        assert_eq!(s.in_flight, 1);
        assert!(s.conserved());
        assert_eq!(s.positives_dropped, 1, "lost positive counted");
        assert_eq!(
            l.outcome(0),
            Some(Outcome::LostToFault { stage: Stage::Va })
        );
    }

    #[test]
    fn latency_classification_boundary() {
        let mut l = Ledger::new();
        l.generated(1, false);
        l.completed(1, 15 * SEC, 15 * SEC, false);
        assert!(matches!(l.outcome(1), Some(Outcome::OnTime { .. })));
    }

    #[test]
    fn detection_accounting() {
        let mut l = Ledger::new();
        l.generated(1, true);
        l.generated(2, true);
        l.generated(3, true);
        l.completed(1, SEC, 15 * SEC, true);
        l.dropped(2, Stage::Va);
        l.completed(3, SEC, 15 * SEC, false); // missed detection
        let s = l.summary();
        assert_eq!(s.positives_generated, 3);
        assert_eq!(s.true_positives, 1);
        assert_eq!(s.positives_dropped, 1);
    }

    #[test]
    fn rates() {
        let mut l = Ledger::new();
        for id in 0..100u64 {
            l.generated(id, false);
            if id < 17 {
                l.dropped(id, Stage::Cr);
            } else {
                l.completed(id, SEC, 15 * SEC, false);
            }
        }
        let s = l.summary();
        assert!((s.drop_rate() - 0.17).abs() < 1e-12);
        assert_eq!(s.delay_rate(), 0.0);
    }
}
