//! Spotlight search algorithms for the Tracking Logic module.
//!
//! The TL expands a search region around the entity's last-seen location
//! while it is in a blind-spot, and contracts it on a positive detection
//! (Fig 1 of the paper). Three substrate algorithms:
//!
//! * [`bfs_spotlight`] — hop-count BFS assuming a *fixed* road length for
//!   every edge (the paper's TL-BFS).
//! * [`wbfs_spotlight`] — weighted BFS (a Dijkstra ball) using exact road
//!   lengths (TL-WBFS).
//! * [`probabilistic_spotlight`] — Naive-Bayes style path-likelihood
//!   activation (App 4's TL).
//!
//! Each has an `_into` variant taking a reusable [`SpotlightWorkspace`]:
//! the TL re-expands on **every** blind-spot tick, and the legacy
//! implementations paid a `vec![usize::MAX; n]` (or `vec![f64::INFINITY;
//! n]`) allocation-and-initialisation per expansion. The workspace keeps
//! epoch-stamped distance arrays — bumping a `u32` epoch invalidates the
//! whole previous expansion in O(1) — plus the queue/heap/scratch
//! buffers, so a steady-state expansion allocates nothing and touches
//! only the vertices it actually reaches. The allocating free functions
//! remain as thin wrappers (and as the reference the property suite
//! compares against).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use super::graph::{Graph, VertexId};

/// Reusable scratch state for spotlight expansions.
///
/// One workspace serves any number of sequential expansions over graphs
/// of any size (arrays grow to the largest graph seen). Stamps make
/// reuse safe: a vertex's `hops`/`dist` entry is only meaningful when
/// its stamp equals the current epoch, so no state leaks between
/// expansions — property-tested in `tests/prop_roadnet.rs`.
pub struct SpotlightWorkspace {
    epoch: u32,
    stamp: Vec<u32>,
    /// Hop distance (BFS), valid where `stamp == epoch`.
    hops: Vec<u32>,
    /// Road distance (Dijkstra), valid where `stamp == epoch`.
    dist: Vec<f64>,
    /// Vertices stamped this epoch, in first-stamp order.
    touched: Vec<VertexId>,
    queue: VecDeque<VertexId>,
    heap: BinaryHeap<HeapItem>,
    /// `(likelihood, vertex)` scratch for the probabilistic TL.
    lik: Vec<(f64, VertexId)>,
}

impl Default for SpotlightWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl SpotlightWorkspace {
    pub fn new() -> Self {
        Self {
            epoch: 0,
            stamp: Vec::new(),
            hops: Vec::new(),
            dist: Vec::new(),
            touched: Vec::new(),
            queue: VecDeque::new(),
            heap: BinaryHeap::new(),
            lik: Vec::new(),
        }
    }

    /// Start a new expansion over a graph of `n` vertices.
    fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, 0);
            self.hops.resize(n, 0);
            self.dist.resize(n, 0.0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // u32 wrap after ~4e9 expansions: stale stamps could alias
            // the fresh epoch, so reset them once.
            self.stamp.fill(0);
            self.epoch = 1;
        }
        self.touched.clear();
        self.queue.clear();
        self.heap.clear();
        self.lik.clear();
    }

    /// Stamp `v` for this epoch; returns whether it was fresh.
    #[inline]
    fn visit(&mut self, v: VertexId) -> bool {
        if self.stamp[v] == self.epoch {
            false
        } else {
            self.stamp[v] = self.epoch;
            self.touched.push(v);
            true
        }
    }
}

/// Hop-limited BFS into `out` (see [`bfs_spotlight`]), reusing `ws`.
pub fn bfs_spotlight_into(
    g: &Graph,
    src: VertexId,
    radius_m: f64,
    fixed_len_m: f64,
    ws: &mut SpotlightWorkspace,
    out: &mut Vec<VertexId>,
) {
    let max_hops = if fixed_len_m <= 0.0 {
        0
    } else {
        (radius_m / fixed_len_m).floor() as u32
    };
    ws.begin(g.num_vertices());
    out.clear();
    ws.visit(src);
    ws.hops[src] = 0;
    ws.queue.push_back(src);
    out.push(src);
    while let Some(v) = ws.queue.pop_front() {
        if ws.hops[v] >= max_hops {
            continue;
        }
        let next_hops = ws.hops[v] + 1;
        for &(u, _) in g.neighbors(v) {
            if ws.visit(u) {
                ws.hops[u] = next_hops;
                out.push(u);
                ws.queue.push_back(u);
            }
        }
    }
}

/// Vertices reachable within `radius_m` of `src`, assuming every edge is
/// `fixed_len_m` long (hop distance x fixed length <= radius).
pub fn bfs_spotlight(
    g: &Graph,
    src: VertexId,
    radius_m: f64,
    fixed_len_m: f64,
) -> Vec<VertexId> {
    let mut ws = SpotlightWorkspace::new();
    let mut out = Vec::new();
    bfs_spotlight_into(g, src, radius_m, fixed_len_m, &mut ws, &mut out);
    out
}

#[derive(PartialEq)]
struct HeapItem(f64, VertexId);

impl Eq for HeapItem {}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on distance.
        other.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
    }
}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bounded Dijkstra into the workspace: after the call, `ws.touched`
/// holds every vertex within `max_m` road distance of `src` (in
/// first-reach order) and `ws.dist[v]` its exact distance.
fn dijkstra_ball(
    g: &Graph,
    src: VertexId,
    max_m: f64,
    ws: &mut SpotlightWorkspace,
) {
    ws.begin(g.num_vertices());
    ws.visit(src);
    ws.dist[src] = 0.0;
    ws.heap.push(HeapItem(0.0, src));
    while let Some(HeapItem(d, v)) = ws.heap.pop() {
        if d > ws.dist[v] || d > max_m {
            continue;
        }
        for &(u, len) in g.neighbors(v) {
            let nd = d + len;
            if nd > max_m {
                continue;
            }
            if ws.stamp[u] != ws.epoch || nd < ws.dist[u] {
                if ws.stamp[u] != ws.epoch {
                    ws.stamp[u] = ws.epoch;
                    ws.touched.push(u);
                }
                ws.dist[u] = nd;
                ws.heap.push(HeapItem(nd, u));
            }
        }
    }
}

/// Shortest-path (road-length) distances from `src`, bounded by
/// `max_m` (pass `f64::INFINITY` for the full graph). Allocates a full
/// distance vector; the engines' hot path uses the workspace variants.
pub fn dijkstra_distances(g: &Graph, src: VertexId, max_m: f64) -> Vec<f64> {
    let mut ws = SpotlightWorkspace::new();
    dijkstra_ball(g, src, max_m, &mut ws);
    let mut dist = vec![f64::INFINITY; g.num_vertices()];
    for &v in &ws.touched {
        dist[v] = ws.dist[v];
    }
    dist
}

/// Dijkstra ball into `out` (see [`wbfs_spotlight`]), reusing `ws`.
pub fn wbfs_spotlight_into(
    g: &Graph,
    src: VertexId,
    radius_m: f64,
    ws: &mut SpotlightWorkspace,
    out: &mut Vec<VertexId>,
) {
    dijkstra_ball(g, src, radius_m, ws);
    out.clear();
    out.extend_from_slice(&ws.touched);
}

/// Vertices whose exact road distance from `src` is within `radius_m`
/// (the paper's weighted BFS — a Dijkstra ball). Order is unspecified
/// (first-reach); callers needing determinism sort.
pub fn wbfs_spotlight(g: &Graph, src: VertexId, radius_m: f64) -> Vec<VertexId> {
    let mut ws = SpotlightWorkspace::new();
    let mut out = Vec::new();
    wbfs_spotlight_into(g, src, radius_m, &mut ws, &mut out);
    out
}

/// Probabilistic spotlight into `out` (see
/// [`probabilistic_spotlight`]), reusing `ws`.
pub fn probabilistic_spotlight_into(
    g: &Graph,
    src: VertexId,
    es_mps: f64,
    elapsed_s: f64,
    mass: f64,
    ws: &mut SpotlightWorkspace,
    out: &mut Vec<VertexId>,
) {
    let mu = es_mps * elapsed_s;
    // The walker cannot be farther than mu (peak speed); sigma widens
    // with time to reflect route uncertainty.
    let sigma = (0.35 * mu).max(30.0);
    dijkstra_ball(g, src, mu + 4.0 * sigma, ws);
    ws.lik.clear();
    for &v in &ws.touched {
        let d = ws.dist[v];
        // Walkers dawdle: anywhere in [0, mu] is plausible, with the
        // frontier decaying as a half-Gaussian beyond mu.
        let l = if d <= mu {
            1.0
        } else {
            (-((d - mu) / sigma).powi(2) / 2.0).exp()
        };
        ws.lik.push((l, v));
    }
    // Total order (likelihood desc, id asc): output is independent of
    // the touched-set order.
    ws.lik
        .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let total: f64 = ws.lik.iter().map(|&(l, _)| l).sum();
    out.clear();
    let mut acc = 0.0;
    for &(l, v) in &ws.lik {
        out.push(v);
        acc += l;
        if acc >= mass * total {
            break;
        }
    }
}

/// Naive-Bayes path-likelihood spotlight (App 4's TL).
///
/// A random walker of expected speed `es` departing `elapsed_s` ago is
/// most likely at road distance `mu = es * elapsed_s`; the likelihood of
/// each vertex is a Gaussian over `|d(v) - mu|`. Returns the smallest set
/// of vertices capturing `mass` of the total likelihood (vertices sorted
/// by likelihood, greedy).
pub fn probabilistic_spotlight(
    g: &Graph,
    src: VertexId,
    es_mps: f64,
    elapsed_s: f64,
    mass: f64,
) -> Vec<VertexId> {
    let mut ws = SpotlightWorkspace::new();
    let mut out = Vec::new();
    probabilistic_spotlight_into(
        g, src, es_mps, elapsed_s, mass, &mut ws, &mut out,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::roadnet::{generate, GraphBuilder};

    fn line_graph() -> Graph {
        // 0 -100m- 1 -100m- 2 -50m- 3
        let mut b = GraphBuilder::new(vec![
            (0.0, 0.0),
            (100.0, 0.0),
            (200.0, 0.0),
            (250.0, 0.0),
        ]);
        b.add_edge(0, 1, 100.0);
        b.add_edge(1, 2, 100.0);
        b.add_edge(2, 3, 50.0);
        b.finalize()
    }

    #[test]
    fn bfs_uses_hop_counts() {
        let g = line_graph();
        // radius 150 m at fixed length 84.5 => 1 hop
        let s = bfs_spotlight(&g, 1, 150.0, 84.5);
        let mut s = s;
        s.sort();
        assert_eq!(s, vec![0, 1, 2]);
        // radius below one fixed length => only the source
        assert_eq!(bfs_spotlight(&g, 1, 50.0, 84.5), vec![1]);
    }

    #[test]
    fn wbfs_uses_road_lengths() {
        let g = line_graph();
        let mut s = wbfs_spotlight(&g, 2, 60.0);
        s.sort();
        assert_eq!(s, vec![2, 3]); // 3 is 50 m away, 1 is 100 m
        let mut s = wbfs_spotlight(&g, 2, 100.0);
        s.sort();
        assert_eq!(s, vec![1, 2, 3]);
    }

    #[test]
    fn dijkstra_distances_exact() {
        let g = line_graph();
        let d = dijkstra_distances(&g, 0, f64::INFINITY);
        assert_eq!(d, vec![0.0, 100.0, 200.0, 250.0]);
    }

    #[test]
    fn wbfs_is_subset_of_generous_bfs() {
        // With fixed length = min edge length, BFS hop-balls dominate
        // the Dijkstra ball of the same radius.
        let g = generate(&WorkloadConfig::default(), 3);
        let min_len = g.min_edge_len();
        let w = wbfs_spotlight(&g, 0, 400.0);
        let b = bfs_spotlight(&g, 0, 400.0, min_len);
        for v in &w {
            assert!(b.contains(v), "vertex {v} in WBFS but not BFS");
        }
    }

    #[test]
    fn spotlight_grows_with_radius() {
        let g = generate(&WorkloadConfig::default(), 3);
        let a = wbfs_spotlight(&g, 10, 100.0).len();
        let b = wbfs_spotlight(&g, 10, 300.0).len();
        let c = wbfs_spotlight(&g, 10, 900.0).len();
        assert!(a < b && b < c, "{a} {b} {c}");
    }

    #[test]
    fn workspace_reuse_matches_fresh_expansions() {
        let g = generate(&WorkloadConfig::default(), 3);
        let mut ws = SpotlightWorkspace::new();
        let mut out = Vec::new();
        for (src, radius) in
            [(0, 100.0), (10, 900.0), (0, 100.0), (500, 300.0)]
        {
            wbfs_spotlight_into(&g, src, radius, &mut ws, &mut out);
            let mut got = out.clone();
            got.sort_unstable();
            let mut want = wbfs_spotlight(&g, src, radius);
            want.sort_unstable();
            assert_eq!(got, want, "src {src} radius {radius}");
        }
    }

    #[test]
    fn workspace_shrinks_to_smaller_graphs() {
        // Stale stamps from a big graph must not leak into expansions
        // over a smaller one.
        let big = generate(&WorkloadConfig::default(), 3);
        let small = line_graph();
        let mut ws = SpotlightWorkspace::new();
        let mut out = Vec::new();
        wbfs_spotlight_into(&big, 0, 900.0, &mut ws, &mut out);
        wbfs_spotlight_into(&small, 2, 60.0, &mut ws, &mut out);
        out.sort_unstable();
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn probabilistic_concentrates_near_expected_distance() {
        let g = generate(&WorkloadConfig::default(), 3);
        let spot = probabilistic_spotlight(&g, 0, 4.0, 30.0, 0.9);
        // Expected distance 120 m; spotlight should contain everything
        // within 120 m of the source.
        let d = dijkstra_distances(&g, 0, f64::INFINITY);
        for (v, &dv) in d.iter().enumerate() {
            if dv <= 120.0 {
                assert!(spot.contains(&v), "missing vertex {v} at {dv} m");
            }
        }
        // ...but not the whole graph.
        assert!(spot.len() < g.num_vertices() / 2);
    }

    #[test]
    fn probabilistic_mass_monotone() {
        let g = generate(&WorkloadConfig::default(), 3);
        let small = probabilistic_spotlight(&g, 0, 4.0, 60.0, 0.5).len();
        let large = probabilistic_spotlight(&g, 0, 4.0, 60.0, 0.95).len();
        assert!(small <= large);
    }
}
