//! Spotlight search algorithms for the Tracking Logic module.
//!
//! The TL expands a search region around the entity's last-seen location
//! while it is in a blind-spot, and contracts it on a positive detection
//! (Fig 1 of the paper). Three substrate algorithms:
//!
//! * [`bfs_spotlight`] — hop-count BFS assuming a *fixed* road length for
//!   every edge (the paper's TL-BFS).
//! * [`wbfs_spotlight`] — weighted BFS (a Dijkstra ball) using exact road
//!   lengths (TL-WBFS).
//! * [`probabilistic_spotlight`] — Naive-Bayes style path-likelihood
//!   activation (App 4's TL).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::graph::{Graph, VertexId};

/// Vertices reachable within `radius_m` of `src`, assuming every edge is
/// `fixed_len_m` long (hop distance x fixed length <= radius).
pub fn bfs_spotlight(
    g: &Graph,
    src: VertexId,
    radius_m: f64,
    fixed_len_m: f64,
) -> Vec<VertexId> {
    let max_hops = if fixed_len_m <= 0.0 {
        0
    } else {
        (radius_m / fixed_len_m).floor() as usize
    };
    let mut dist = vec![usize::MAX; g.num_vertices()];
    let mut queue = std::collections::VecDeque::new();
    dist[src] = 0;
    queue.push_back(src);
    let mut out = vec![src];
    while let Some(v) = queue.pop_front() {
        if dist[v] >= max_hops {
            continue;
        }
        for &(u, _) in &g.adj[v] {
            if dist[u] == usize::MAX {
                dist[u] = dist[v] + 1;
                out.push(u);
                queue.push_back(u);
            }
        }
    }
    out
}

#[derive(PartialEq)]
struct HeapItem(f64, VertexId);

impl Eq for HeapItem {}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on distance.
        other.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
    }
}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Shortest-path (road-length) distances from `src`, bounded by
/// `max_m` (pass `f64::INFINITY` for the full graph).
pub fn dijkstra_distances(g: &Graph, src: VertexId, max_m: f64) -> Vec<f64> {
    let mut dist = vec![f64::INFINITY; g.num_vertices()];
    let mut heap = BinaryHeap::new();
    dist[src] = 0.0;
    heap.push(HeapItem(0.0, src));
    while let Some(HeapItem(d, v)) = heap.pop() {
        if d > dist[v] || d > max_m {
            continue;
        }
        for &(u, len) in &g.adj[v] {
            let nd = d + len;
            if nd < dist[u] && nd <= max_m {
                dist[u] = nd;
                heap.push(HeapItem(nd, u));
            }
        }
    }
    dist
}

/// Vertices whose exact road distance from `src` is within `radius_m`
/// (the paper's weighted BFS — a Dijkstra ball).
pub fn wbfs_spotlight(g: &Graph, src: VertexId, radius_m: f64) -> Vec<VertexId> {
    dijkstra_distances(g, src, radius_m)
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d.is_finite())
        .map(|(v, _)| v)
        .collect()
}

/// Naive-Bayes path-likelihood spotlight (App 4's TL).
///
/// A random walker of expected speed `es` departing `elapsed_s` ago is
/// most likely at road distance `mu = es * elapsed_s`; the likelihood of
/// each vertex is a Gaussian over `|d(v) - mu|`. Returns the smallest set
/// of vertices capturing `mass` of the total likelihood (vertices sorted
/// by likelihood, greedy).
pub fn probabilistic_spotlight(
    g: &Graph,
    src: VertexId,
    es_mps: f64,
    elapsed_s: f64,
    mass: f64,
) -> Vec<VertexId> {
    let mu = es_mps * elapsed_s;
    // The walker cannot be farther than mu (peak speed); sigma widens
    // with time to reflect route uncertainty.
    let sigma = (0.35 * mu).max(30.0);
    let dist = dijkstra_distances(g, src, mu + 4.0 * sigma);
    let mut lik: Vec<(f64, VertexId)> = dist
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d.is_finite())
        .map(|(v, &d)| {
            // Walkers dawdle: anywhere in [0, mu] is plausible, with the
            // frontier decaying as a half-Gaussian beyond mu.
            let l = if d <= mu {
                1.0
            } else {
                (-((d - mu) / sigma).powi(2) / 2.0).exp()
            };
            (l, v)
        })
        .collect();
    lik.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let total: f64 = lik.iter().map(|&(l, _)| l).sum();
    let mut acc = 0.0;
    let mut out = Vec::new();
    for (l, v) in lik {
        out.push(v);
        acc += l;
        if acc >= mass * total {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::roadnet::generate;

    fn line_graph() -> Graph {
        // 0 -100m- 1 -100m- 2 -50m- 3
        let mut g = Graph::new(vec![
            (0.0, 0.0),
            (100.0, 0.0),
            (200.0, 0.0),
            (250.0, 0.0),
        ]);
        g.add_edge(0, 1, 100.0);
        g.add_edge(1, 2, 100.0);
        g.add_edge(2, 3, 50.0);
        g
    }

    #[test]
    fn bfs_uses_hop_counts() {
        let g = line_graph();
        // radius 150 m at fixed length 84.5 => 1 hop
        let s = bfs_spotlight(&g, 1, 150.0, 84.5);
        let mut s = s;
        s.sort();
        assert_eq!(s, vec![0, 1, 2]);
        // radius below one fixed length => only the source
        assert_eq!(bfs_spotlight(&g, 1, 50.0, 84.5), vec![1]);
    }

    #[test]
    fn wbfs_uses_road_lengths() {
        let g = line_graph();
        let mut s = wbfs_spotlight(&g, 2, 60.0);
        s.sort();
        assert_eq!(s, vec![2, 3]); // 3 is 50 m away, 1 is 100 m
        let mut s = wbfs_spotlight(&g, 2, 100.0);
        s.sort();
        assert_eq!(s, vec![1, 2, 3]);
    }

    #[test]
    fn dijkstra_distances_exact() {
        let g = line_graph();
        let d = dijkstra_distances(&g, 0, f64::INFINITY);
        assert_eq!(d, vec![0.0, 100.0, 200.0, 250.0]);
    }

    #[test]
    fn wbfs_is_subset_of_generous_bfs() {
        // With fixed length = min edge length, BFS hop-balls dominate
        // the Dijkstra ball of the same radius.
        let g = generate(&WorkloadConfig::default(), 3);
        let min_len = g
            .adj
            .iter()
            .flatten()
            .map(|&(_, l)| l)
            .fold(f64::INFINITY, f64::min);
        let w = wbfs_spotlight(&g, 0, 400.0);
        let b = bfs_spotlight(&g, 0, 400.0, min_len);
        for v in &w {
            assert!(b.contains(v), "vertex {v} in WBFS but not BFS");
        }
    }

    #[test]
    fn spotlight_grows_with_radius() {
        let g = generate(&WorkloadConfig::default(), 3);
        let a = wbfs_spotlight(&g, 10, 100.0).len();
        let b = wbfs_spotlight(&g, 10, 300.0).len();
        let c = wbfs_spotlight(&g, 10, 900.0).len();
        assert!(a < b && b < c, "{a} {b} {c}");
    }

    #[test]
    fn probabilistic_concentrates_near_expected_distance() {
        let g = generate(&WorkloadConfig::default(), 3);
        let spot = probabilistic_spotlight(&g, 0, 4.0, 30.0, 0.9);
        // Expected distance 120 m; spotlight should contain everything
        // within 120 m of the source.
        let d = dijkstra_distances(&g, 0, f64::INFINITY);
        for (v, &dv) in d.iter().enumerate() {
            if dv <= 120.0 {
                assert!(spot.contains(&v), "missing vertex {v} at {dv} m");
            }
        }
        // ...but not the whole graph.
        assert!(spot.len() < g.num_vertices() / 2);
    }

    #[test]
    fn probabilistic_mass_monotone() {
        let g = generate(&WorkloadConfig::default(), 3);
        let small = probabilistic_spotlight(&g, 0, 4.0, 60.0, 0.5).len();
        let large = probabilistic_spotlight(&g, 0, 4.0, 60.0, 0.95).len();
        assert!(small <= large);
    }
}
