//! Geographic partition of the CSR roadnet into K shards.
//!
//! The sharded DES (`engine/sharded.rs`) assigns every camera — and
//! therefore every per-camera event stream — to the shard of its host
//! vertex. The partition is *geographic*: vertices are ordered by
//! planar position (x, then y, then id — a total order, so the split
//! is deterministic per graph) and cut into K contiguous, balanced
//! slices. Spotlight edges whose endpoints land in different shards
//! are the *boundary edges*: entity handoffs ride exactly these edges
//! as `CrossShardMsg` envelopes, and two shards sharing at least one
//! boundary edge are *adjacent* — the migration targets for orphaned
//! work when a shard's node dies (see the engines' `pick_survivor`).
//!
//! Like everything on the DES path, the partition is plain data
//! computed once at engine construction: no hashing, no wall clock,
//! no randomness beyond the graph itself.

use super::graph::{Graph, VertexId};

/// A K-way geographic split of a road graph: vertex → shard map,
/// boundary-edge set, and the shard-adjacency relation induced by it.
#[derive(Debug, Clone)]
pub struct Partition {
    shards: usize,
    shard_of_vertex: Vec<u32>,
    /// Edges `(a, b)` with `a < b` whose endpoints lie in different
    /// shards, in [`Graph::iter_edges`] order.
    boundary: Vec<(VertexId, VertexId)>,
    /// `adjacency[s]` — ascending shard ids sharing at least one
    /// boundary edge with `s` (never contains `s` itself).
    adjacency: Vec<Vec<u32>>,
}

/// Split `g` into `shards` balanced geographic slices. The shard count
/// is clamped to `[1, |V|]` (a graph cannot host more non-empty shards
/// than vertices; `shards = |V|` is the degenerate one-camera-per-shard
/// split the property suite exercises).
pub fn partition(g: &Graph, shards: usize) -> Partition {
    let n = g.num_vertices();
    let k = shards.clamp(1, n.max(1));

    // Geographic order: x, then y, then id. `total_cmp` gives a total
    // order over the generator's finite coordinates, so the split is a
    // pure function of the graph.
    let mut order: Vec<VertexId> = (0..n).collect();
    order.sort_by(|&a, &b| {
        g.pos[a]
            .0
            .total_cmp(&g.pos[b].0)
            .then(g.pos[a].1.total_cmp(&g.pos[b].1))
            .then(a.cmp(&b))
    });

    // Balanced contiguous slices: the first `n % k` shards take one
    // extra vertex, so sizes differ by at most one.
    let mut shard_of_vertex = vec![0u32; n];
    let (base, extra) = (n / k, n % k);
    let mut idx = 0;
    for s in 0..k {
        let len = base + usize::from(s < extra);
        for _ in 0..len {
            shard_of_vertex[order[idx]] = s as u32;
            idx += 1;
        }
    }

    // Boundary edges + the adjacency relation they induce. A dense
    // k x k matrix keeps the scan allocation-light and — unlike a hash
    // set — iteration-order deterministic (the map-order rule).
    let mut boundary = Vec::new();
    let mut touch = vec![false; k * k];
    for (a, b, _) in g.iter_edges() {
        let (sa, sb) = (
            shard_of_vertex[a] as usize,
            shard_of_vertex[b] as usize,
        );
        if sa != sb {
            boundary.push((a, b));
            touch[sa * k + sb] = true;
            touch[sb * k + sa] = true;
        }
    }
    let adjacency = (0..k)
        .map(|s| {
            (0..k)
                .filter(|&t| touch[s * k + t])
                .map(|t| t as u32)
                .collect()
        })
        .collect();

    Partition {
        shards: k,
        shard_of_vertex,
        boundary,
        adjacency,
    }
}

impl Partition {
    /// Number of shards after clamping (always ≥ 1).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard hosting vertex `v`.
    #[inline]
    pub fn shard_of_vertex(&self, v: VertexId) -> u32 {
        self.shard_of_vertex[v]
    }

    /// Spotlight edges crossing a shard boundary, each once (`a < b`).
    pub fn boundary_edges(&self) -> &[(VertexId, VertexId)] {
        &self.boundary
    }

    /// Shards sharing at least one boundary edge with `s`, ascending.
    pub fn neighbors(&self, s: u32) -> &[u32] {
        &self.adjacency[s as usize]
    }

    /// Do shards `a` and `b` share a boundary edge?
    pub fn adjacent(&self, a: u32, b: u32) -> bool {
        a != b && self.adjacency[a as usize].binary_search(&b).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::roadnet::generate;

    fn small() -> Graph {
        generate(
            &WorkloadConfig {
                vertices: 60,
                edges: 160,
                ..Default::default()
            },
            7,
        )
    }

    #[test]
    fn deterministic_and_balanced() {
        let g = small();
        for k in [1usize, 2, 3, 4, 8] {
            let p = partition(&g, k);
            let q = partition(&g, k);
            assert_eq!(p.shard_of_vertex, q.shard_of_vertex, "k={k}");
            let mut sizes = vec![0usize; k];
            for v in 0..g.num_vertices() {
                sizes[p.shard_of_vertex(v) as usize] += 1;
            }
            let (min, max) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(max - min <= 1, "k={k}: sizes {sizes:?}");
        }
    }

    #[test]
    fn single_shard_has_no_boundary() {
        let g = small();
        let p = partition(&g, 1);
        assert_eq!(p.shards(), 1);
        assert!(p.boundary_edges().is_empty());
        assert!(p.neighbors(0).is_empty());
        assert!(!p.adjacent(0, 0));
    }

    #[test]
    fn boundary_edges_really_cross() {
        let g = small();
        let p = partition(&g, 4);
        assert!(!p.boundary_edges().is_empty());
        for &(a, b) in p.boundary_edges() {
            assert!(a < b);
            assert_ne!(p.shard_of_vertex(a), p.shard_of_vertex(b));
        }
        // Every boundary edge makes its endpoint shards adjacent,
        // symmetrically.
        for &(a, b) in p.boundary_edges() {
            let (sa, sb) = (p.shard_of_vertex(a), p.shard_of_vertex(b));
            assert!(p.adjacent(sa, sb));
            assert!(p.adjacent(sb, sa));
        }
    }

    #[test]
    fn degenerate_one_vertex_shards() {
        let g = small();
        let n = g.num_vertices();
        // Requesting more shards than vertices clamps to |V|.
        let p = partition(&g, n + 100);
        assert_eq!(p.shards(), n);
        // Every vertex is its own shard; every edge is a boundary.
        let mut seen = vec![false; n];
        for v in 0..n {
            let s = p.shard_of_vertex(v) as usize;
            assert!(!seen[s], "shard {s} hosts two vertices");
            seen[s] = true;
        }
        assert_eq!(p.boundary_edges().len(), g.num_edges());
    }

    #[test]
    fn geographic_slices_are_contiguous_in_x() {
        let g = small();
        let p = partition(&g, 3);
        // Sort vertices by the partition's own order; shard ids along
        // that order must be non-decreasing (contiguous slices).
        let mut order: Vec<usize> = (0..g.num_vertices()).collect();
        order.sort_by(|&a, &b| {
            g.pos[a]
                .0
                .total_cmp(&g.pos[b].0)
                .then(g.pos[a].1.total_cmp(&g.pos[b].1))
                .then(a.cmp(&b))
        });
        let shards: Vec<u32> =
            order.iter().map(|&v| p.shard_of_vertex(v)).collect();
        assert!(shards.windows(2).all(|w| w[0] <= w[1]));
    }
}
