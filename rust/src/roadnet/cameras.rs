//! Camera placement on the road network.

use super::graph::{Graph, VertexId};

pub type CameraId = usize;

/// A fixed camera mounted at a road vertex with a circular FOV.
#[derive(Debug, Clone, Copy)]
pub struct Camera {
    pub id: CameraId,
    pub vertex: VertexId,
    /// Field-of-view radius in metres.
    pub fov_m: f64,
}

impl Camera {
    /// Is a point (metres) within this camera's FOV?
    pub fn sees(&self, g: &Graph, p: (f64, f64)) -> bool {
        let (cx, cy) = g.pos[self.vertex];
        let d2 = (p.0 - cx).powi(2) + (p.1 - cy).powi(2);
        d2 <= self.fov_m * self.fov_m
    }
}

/// Place `n` cameras on the vertices nearest the start vertex (the paper
/// "places cameras on vertices surrounding the starting vertex"). With
/// `n == |V|` every vertex hosts a camera.
pub fn place_cameras(
    g: &Graph,
    n: usize,
    start: VertexId,
    fov_m: f64,
) -> Vec<Camera> {
    let mut order: Vec<VertexId> = (0..g.num_vertices()).collect();
    order.sort_by(|&a, &b| {
        g.euclid(start, a)
            .partial_cmp(&g.euclid(start, b))
            .unwrap()
            .then(a.cmp(&b))
    });
    order
        .into_iter()
        .take(n.min(g.num_vertices()))
        .enumerate()
        .map(|(id, vertex)| Camera { id, vertex, fov_m })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::roadnet::generate;

    #[test]
    fn placement_covers_start_first() {
        let g = generate(&WorkloadConfig::default(), 1);
        let cams = place_cameras(&g, 50, 0, 40.0);
        assert_eq!(cams.len(), 50);
        assert_eq!(cams[0].vertex, 0); // nearest to start is start itself
        // ids are dense 0..n
        for (i, c) in cams.iter().enumerate() {
            assert_eq!(c.id, i);
        }
        // no duplicate vertices
        let mut vs: Vec<_> = cams.iter().map(|c| c.vertex).collect();
        vs.sort();
        vs.dedup();
        assert_eq!(vs.len(), 50);
    }

    #[test]
    fn fov_test_is_euclidean() {
        let g = generate(&WorkloadConfig::default(), 1);
        let cam = Camera {
            id: 0,
            vertex: 0,
            fov_m: 40.0,
        };
        let (x, y) = g.pos[0];
        assert!(cam.sees(&g, (x + 10.0, y)));
        assert!(cam.sees(&g, (x, y + 39.9)));
        assert!(!cam.sees(&g, (x + 41.0, y)));
    }

    #[test]
    fn capped_at_vertex_count() {
        let g = generate(
            &WorkloadConfig {
                vertices: 20,
                edges: 40,
                ..Default::default()
            },
            1,
        );
        assert_eq!(place_cameras(&g, 100, 0, 40.0).len(), 20);
    }
}
