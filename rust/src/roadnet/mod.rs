//! Road-network substrate: CSR graph types, the synthetic
//! OSM-substitute generator, camera placement, the geographic shard
//! partitioner used by the sharded DES, and the spotlight search
//! algorithms used by the Tracking Logic module (with reusable
//! workspaces for the per-tick expansion hot path).

mod cameras;
mod gen;
mod graph;
mod partition;
mod spotlight;

pub use cameras::{place_cameras, Camera, CameraId};
pub use gen::generate;
pub use graph::{Graph, GraphBuilder, VertexId};
pub use partition::{partition, Partition};
pub use spotlight::{
    bfs_spotlight, bfs_spotlight_into, dijkstra_distances,
    probabilistic_spotlight, probabilistic_spotlight_into,
    wbfs_spotlight, wbfs_spotlight_into, SpotlightWorkspace,
};
