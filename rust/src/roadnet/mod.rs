//! Road-network substrate: graph types, the synthetic OSM-substitute
//! generator, camera placement, and the spotlight search algorithms used
//! by the Tracking Logic module.

mod cameras;
mod gen;
mod graph;
mod spotlight;

pub use cameras::{place_cameras, Camera, CameraId};
pub use gen::generate;
pub use graph::{Graph, VertexId};
pub use spotlight::{
    bfs_spotlight, dijkstra_distances, probabilistic_spotlight,
    wbfs_spotlight,
};
