//! Synthetic road-network generator — the OSM-extract substitute.
//!
//! The paper's workload uses a circular 7 km² region around IISc
//! Bangalore with 1,000 vertices, 2,817 edges, and an 84.5 m mean road
//! length. We reproduce those *statistics*: vertices are laid on a
//! jittered triangular-ish grid clipped to a disc, connected to their
//! nearest neighbours until the target edge count is reached, with road
//! lengths set to the Euclidean distance times a wiggle factor (roads
//! bend). The result is planar-ish, connected and deterministic per seed.

use super::graph::Graph;
use crate::config::WorkloadConfig;
use crate::util::rng;

/// Generate a road graph matching the workload statistics.
pub fn generate(w: &WorkloadConfig, seed: u64) -> Graph {
    let mut r = rng(seed, 0x0AD);
    let n = w.vertices;
    // Disc area scales with vertex count at constant density: the paper's
    // 7 km² holds 1,000 vertices; Fig 10's Base runs shrink the region
    // "proportionally smaller" with the camera count.
    let pitch = w.mean_road_m * 0.99; // grid pitch ~= target road length
    let area = n as f64 * pitch * pitch;
    let radius = (area / std::f64::consts::PI).sqrt();

    // Jittered grid points clipped to the disc, nearest to centre first so
    // vertex ids are stable and compact.
    let mut pts: Vec<(f64, f64)> = Vec::new();
    let half = (radius / pitch).ceil() as i64 + 2;
    for gy in -half..=half {
        for gx in -half..=half {
            let jitter = 0.22 * pitch;
            let x = gx as f64 * pitch + r.range_f64(-jitter, jitter);
            // Offset alternate rows for a triangular feel.
            let xo = if gy % 2 == 0 { 0.0 } else { pitch / 2.0 };
            let y = gy as f64 * pitch * 0.9 + r.range_f64(-jitter, jitter);
            pts.push((x + xo, y));
        }
    }
    pts.sort_by(|a, b| {
        let da = a.0 * a.0 + a.1 * a.1;
        let db = b.0 * b.0 + b.1 * b.1;
        da.partial_cmp(&db).unwrap()
    });
    pts.truncate(n);

    let mut g = Graph::new(pts);

    // Candidate edges: k-nearest neighbours by Euclidean distance.
    // O(n²) scan is fine at n = 1000 and keeps the generator simple.
    let mut cands: Vec<(f64, usize, usize)> = Vec::new();
    for a in 0..n {
        let mut nbrs: Vec<(f64, usize)> = (0..n)
            .filter(|&b| b != a)
            .map(|b| (g.euclid(a, b), b))
            .collect();
        nbrs.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        for &(d, b) in nbrs.iter().take(8) {
            if a < b {
                cands.push((d, a, b));
            } else {
                cands.push((d, b, a));
            }
        }
    }
    cands.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    cands.dedup_by(|x, y| x.1 == y.1 && x.2 == y.2);

    // Greedy shortest-first insertion up to the target edge count; the
    // road length is Euclidean distance x wiggle in [1.0, 1.15].
    for &(d, a, b) in &cands {
        if g.num_edges() >= w.edges {
            break;
        }
        let wiggle = 1.0 + r.range_f64(0.0, 0.15);
        g.add_edge(a, b, d * wiggle);
    }

    // Ensure connectivity: link any unreachable component to its nearest
    // reached vertex.
    connect_components(&mut g);
    g
}

fn connect_components(g: &mut Graph) {
    loop {
        let n = g.num_vertices();
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for &(u, _) in &g.adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    stack.push(u);
                }
            }
        }
        let Some(orphan) = (0..n).find(|&v| !seen[v]) else {
            return;
        };
        // Nearest seen vertex to the orphan.
        let best = (0..n)
            .filter(|&v| seen[v])
            .min_by(|&a, &b| {
                g.euclid(orphan, a)
                    .partial_cmp(&g.euclid(orphan, b))
                    .unwrap()
            })
            .expect("vertex 0 is always seen");
        let d = g.euclid(orphan, best);
        g.add_edge(orphan, best, d.max(1.0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_graph() -> Graph {
        generate(&WorkloadConfig::default(), 2019)
    }

    #[test]
    fn matches_paper_statistics() {
        let g = paper_graph();
        assert_eq!(g.num_vertices(), 1000);
        let e = g.num_edges() as f64;
        assert!((e - 2817.0).abs() <= 30.0, "edges = {e}");
        let mean = g.mean_edge_len();
        assert!(
            (mean - 84.5).abs() < 12.0,
            "mean road length = {mean:.1} m (paper: 84.5 m)"
        );
    }

    #[test]
    fn connected() {
        assert!(paper_graph().is_connected());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&WorkloadConfig::default(), 7);
        let b = generate(&WorkloadConfig::default(), 7);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.num_edges(), b.num_edges());
        let c = generate(&WorkloadConfig::default(), 8);
        assert_ne!(a.pos, c.pos);
    }

    #[test]
    fn scales_down_for_base_runs() {
        let w = WorkloadConfig {
            vertices: 100,
            edges: 282,
            ..Default::default()
        };
        let g = generate(&w, 2019);
        assert_eq!(g.num_vertices(), 100);
        assert!(g.is_connected());
        assert!((g.num_edges() as i64 - 282).abs() <= 10);
    }

    #[test]
    fn region_is_disc_shaped() {
        let g = paper_graph();
        // ~7 km² disc => radius ~1.49 km; allow generator slack.
        let rmax = g
            .pos
            .iter()
            .map(|&(x, y)| (x * x + y * y).sqrt())
            .fold(0.0f64, f64::max);
        assert!(rmax < 1800.0, "radius {rmax}");
        assert!(rmax > 1000.0, "radius {rmax}");
    }
}
