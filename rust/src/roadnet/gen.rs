//! Synthetic road-network generator — the OSM-extract substitute.
//!
//! The paper's workload uses a circular 7 km² region around IISc
//! Bangalore with 1,000 vertices, 2,817 edges, and an 84.5 m mean road
//! length. We reproduce those *statistics*: vertices are laid on a
//! jittered triangular-ish grid clipped to a disc, connected to their
//! nearest neighbours until the target edge count is reached, with road
//! lengths set to the Euclidean distance times a wiggle factor (roads
//! bend). The result is planar-ish, connected and deterministic per seed.
//!
//! Edges accumulate in a [`GraphBuilder`] whose hash-set dedup makes
//! each insertion O(1) (the old `Graph::add_edge` paid an O(degree)
//! adjacency scan per candidate, which made 10k-vertex generation
//! degree-quadratic), and connectivity repair runs on a union-find
//! instead of a fresh BFS per orphan component. The RNG draw order is
//! identical to the legacy generator, so graphs are bit-identical per
//! seed.

use super::graph::{Graph, GraphBuilder};
use crate::config::WorkloadConfig;
use crate::util::rng;

/// Generate a road graph matching the workload statistics.
pub fn generate(w: &WorkloadConfig, seed: u64) -> Graph {
    let mut r = rng(seed, 0x0AD);
    let n = w.vertices;
    // Disc area scales with vertex count at constant density: the paper's
    // 7 km² holds 1,000 vertices; Fig 10's Base runs shrink the region
    // "proportionally smaller" with the camera count.
    let pitch = w.mean_road_m * 0.99; // grid pitch ~= target road length
    let area = n as f64 * pitch * pitch;
    let radius = (area / std::f64::consts::PI).sqrt();

    // Jittered grid points clipped to the disc, nearest to centre first so
    // vertex ids are stable and compact.
    let mut pts: Vec<(f64, f64)> = Vec::new();
    let half = (radius / pitch).ceil() as i64 + 2;
    for gy in -half..=half {
        for gx in -half..=half {
            let jitter = 0.22 * pitch;
            let x = gx as f64 * pitch + r.range_f64(-jitter, jitter);
            // Offset alternate rows for a triangular feel.
            let xo = if gy % 2 == 0 { 0.0 } else { pitch / 2.0 };
            let y = gy as f64 * pitch * 0.9 + r.range_f64(-jitter, jitter);
            pts.push((x + xo, y));
        }
    }
    pts.sort_by(|a, b| {
        let da = a.0 * a.0 + a.1 * a.1;
        let db = b.0 * b.0 + b.1 * b.1;
        da.partial_cmp(&db).unwrap()
    });
    pts.truncate(n);

    let mut b = GraphBuilder::new(pts);

    // Candidate edges: k-nearest neighbours by Euclidean distance.
    // O(n²) scan is fine at the paper's n = 1000 and keeps the
    // generator simple; at 10k vertices it is the (non-quadratic-
    // in-degree) dominant cost and still completes in seconds.
    let mut cands: Vec<(f64, usize, usize)> = Vec::new();
    let mut nbrs: Vec<(f64, usize)> = Vec::with_capacity(n);
    for a in 0..n {
        nbrs.clear();
        nbrs.extend(
            (0..n).filter(|&bb| bb != a).map(|bb| (b.euclid(a, bb), bb)),
        );
        nbrs.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
        for &(d, bb) in nbrs.iter().take(8) {
            if a < bb {
                cands.push((d, a, bb));
            } else {
                cands.push((d, bb, a));
            }
        }
    }
    cands.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    cands.dedup_by(|x, y| x.1 == y.1 && x.2 == y.2);

    // Greedy shortest-first insertion up to the target edge count; the
    // road length is Euclidean distance x wiggle in [1.0, 1.15]. The
    // wiggle draw happens for every candidate (dup or not) to keep the
    // RNG stream identical to the legacy generator.
    for &(d, a, bb) in &cands {
        if b.num_edges() >= w.edges {
            break;
        }
        let wiggle = 1.0 + r.range_f64(0.0, 0.15);
        b.add_edge(a, bb, d * wiggle);
    }

    // Ensure connectivity: link each unreachable component to the
    // nearest vertex of vertex 0's component.
    connect_components(&mut b);
    b.finalize()
}

/// Disjoint-set forest (path halving + union by attachment to the
/// reached side).
struct Dsu {
    parent: Vec<usize>,
}

impl Dsu {
    fn new(n: usize) -> Self {
        Self {
            parent: (0..n).collect(),
        }
    }

    fn find(&mut self, mut v: usize) -> usize {
        while self.parent[v] != v {
            self.parent[v] = self.parent[self.parent[v]];
            v = self.parent[v];
        }
        v
    }

    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra] = rb;
        }
    }
}

/// Same orphan-linking policy as the legacy BFS loop — lowest-id vertex
/// outside vertex 0's component links to its geometrically nearest
/// reached vertex — but tracked with a union-find instead of re-running
/// BFS per orphan.
fn connect_components(b: &mut GraphBuilder) {
    let n = b.num_vertices();
    if n == 0 {
        return;
    }
    let mut dsu = Dsu::new(n);
    b.for_each_edge(|a, bb| dsu.union(a, bb));
    loop {
        let root0 = dsu.find(0);
        let Some(orphan) = (0..n).find(|&v| dsu.find(v) != root0)
        else {
            return;
        };
        // Nearest reached vertex to the orphan (strict `<` keeps the
        // legacy `min_by` first-of-equal-minima tie-break).
        let mut best = usize::MAX;
        let mut best_d = f64::INFINITY;
        for v in 0..n {
            if dsu.find(v) == root0 {
                let d = b.euclid(orphan, v);
                if d < best_d {
                    best_d = d;
                    best = v;
                }
            }
        }
        debug_assert!(best != usize::MAX, "vertex 0 is always reached");
        b.add_edge(orphan, best, best_d.max(1.0));
        dsu.union(orphan, best);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_graph() -> Graph {
        generate(&WorkloadConfig::default(), 2019)
    }

    #[test]
    fn matches_paper_statistics() {
        let g = paper_graph();
        assert_eq!(g.num_vertices(), 1000);
        let e = g.num_edges() as f64;
        assert!((e - 2817.0).abs() <= 30.0, "edges = {e}");
        let mean = g.mean_edge_len();
        assert!(
            (mean - 84.5).abs() < 12.0,
            "mean road length = {mean:.1} m (paper: 84.5 m)"
        );
    }

    #[test]
    fn connected() {
        assert!(paper_graph().is_connected());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&WorkloadConfig::default(), 7);
        let b = generate(&WorkloadConfig::default(), 7);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.num_edges(), b.num_edges());
        for v in 0..a.num_vertices() {
            assert_eq!(a.neighbors(v), b.neighbors(v), "vertex {v}");
        }
        let c = generate(&WorkloadConfig::default(), 8);
        assert_ne!(a.pos, c.pos);
    }

    #[test]
    fn scales_down_for_base_runs() {
        let w = WorkloadConfig {
            vertices: 100,
            edges: 282,
            ..Default::default()
        };
        let g = generate(&w, 2019);
        assert_eq!(g.num_vertices(), 100);
        assert!(g.is_connected());
        assert!((g.num_edges() as i64 - 282).abs() <= 10);
    }

    #[test]
    fn region_is_disc_shaped() {
        let g = paper_graph();
        // ~7 km² disc => radius ~1.49 km; allow generator slack.
        let rmax = g
            .pos
            .iter()
            .map(|&(x, y)| (x * x + y * y).sqrt())
            .fold(0.0f64, f64::max);
        assert!(rmax < 1800.0, "radius {rmax}");
        assert!(rmax > 1000.0, "radius {rmax}");
    }

    #[test]
    fn ten_k_vertex_generation_is_tractable() {
        // The degree-quadratic `add_edge` fix target: 10k vertices /
        // 28k edges must generate and connect. (Wall-clock is asserted
        // by the bench, not here — CI machines vary.)
        let w = WorkloadConfig {
            vertices: 10_000,
            edges: 28_170,
            ..Default::default()
        };
        let g = generate(&w, 2019);
        assert_eq!(g.num_vertices(), 10_000);
        assert!(g.is_connected());
        assert!((g.num_edges() as i64 - 28_170).abs() <= 100);
    }
}
