//! Undirected road graph with geometric vertices and per-edge lengths.
//!
//! The graph is stored in **CSR form** (offsets + one flat neighbour
//! array): spotlight expansions walk `neighbors(v)` slices that are
//! contiguous in memory instead of chasing one heap allocation per
//! vertex, which is what the TL's blind-spot re-expansion hammers every
//! tick. Construction goes through [`GraphBuilder`], whose `add_edge`
//! deduplicates through a hash set in O(1) — the generator used to pay
//! an O(degree) `has_edge` scan per candidate edge, which made
//! 10k-vertex generation degree-quadratic.
//!
//! The CSR finalize preserves per-vertex neighbour order exactly as the
//! old `Vec<Vec<_>>` adjacency produced it (insertion order of
//! `add_edge` calls), so entity walks — which draw neighbours by index
//! — are bit-identical per seed across the representation change.

use crate::util::FastSet;

pub type VertexId = usize;

/// Incremental graph construction with O(1) edge dedup.
pub struct GraphBuilder {
    pos: Vec<(f64, f64)>,
    /// Undirected edges in insertion order.
    edges: Vec<(VertexId, VertexId, f64)>,
    /// Packed `(min(a,b) << 32) | max(a,b)` keys of existing edges.
    seen: FastSet<u64>,
}

#[inline]
fn edge_key(a: VertexId, b: VertexId) -> u64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    ((lo as u64) << 32) | hi as u64
}

impl GraphBuilder {
    pub fn new(pos: Vec<(f64, f64)>) -> Self {
        Self {
            pos,
            edges: Vec::new(),
            seen: FastSet::default(),
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.pos.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Add an undirected edge; ignores duplicates and self-loops.
    /// O(1) via the dedup set (the old adjacency scan was O(degree)).
    pub fn add_edge(&mut self, a: VertexId, b: VertexId, len_m: f64) -> bool {
        if a == b || !self.seen.insert(edge_key(a, b)) {
            return false;
        }
        self.edges.push((a, b, len_m));
        true
    }

    pub fn has_edge(&self, a: VertexId, b: VertexId) -> bool {
        self.seen.contains(&edge_key(a, b))
    }

    /// Visit every accepted edge `(a, b)` in insertion order.
    pub fn for_each_edge(&self, mut f: impl FnMut(VertexId, VertexId)) {
        for &(a, b, _) in &self.edges {
            f(a, b);
        }
    }

    /// Euclidean distance between two vertices.
    pub fn euclid(&self, a: VertexId, b: VertexId) -> f64 {
        let (ax, ay) = self.pos[a];
        let (bx, by) = self.pos[b];
        ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt()
    }

    /// Flatten into the CSR [`Graph`]. Per-vertex neighbour order is
    /// the `add_edge` insertion order (stable counting sort), matching
    /// the legacy `Vec<Vec<_>>` adjacency exactly.
    pub fn finalize(self) -> Graph {
        let n = self.pos.len();
        let mut degree = vec![0usize; n];
        for &(a, b, _) in &self.edges {
            degree[a] += 1;
            degree[b] += 1;
        }
        let mut offsets = Vec::with_capacity(n + 1);
        let mut acc = 0usize;
        offsets.push(0);
        for d in &degree {
            acc += d;
            offsets.push(acc);
        }
        let mut cursor: Vec<usize> = offsets[..n].to_vec();
        let mut nbrs: Vec<(VertexId, f64)> = vec![(0, 0.0); acc];
        for &(a, b, len) in &self.edges {
            nbrs[cursor[a]] = (b, len);
            cursor[a] += 1;
            nbrs[cursor[b]] = (a, len);
            cursor[b] += 1;
        }
        Graph {
            pos: self.pos,
            offsets,
            nbrs,
            edge_count: self.edges.len(),
        }
    }
}

/// Undirected road network in CSR form. Vertices carry planar
/// coordinates (metres); edges carry road lengths (metres) which may
/// differ from the Euclidean distance (roads bend).
#[derive(Debug, Clone)]
pub struct Graph {
    /// Vertex coordinates in metres.
    pub pos: Vec<(f64, f64)>,
    /// CSR offsets: `nbrs[offsets[v]..offsets[v + 1]]` are `v`'s
    /// neighbours.
    offsets: Vec<usize>,
    /// Flat neighbour array: `(neighbor, road_length_m)`.
    nbrs: Vec<(VertexId, f64)>,
    edge_count: usize,
}

impl Graph {
    pub fn num_vertices(&self) -> usize {
        self.pos.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edge_count
    }

    /// The neighbours of `v` as a contiguous slice.
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[(VertexId, f64)] {
        &self.nbrs[self.offsets[v]..self.offsets[v + 1]]
    }

    pub fn degree(&self, v: VertexId) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    pub fn has_edge(&self, a: VertexId, b: VertexId) -> bool {
        self.neighbors(a).iter().any(|&(v, _)| v == b)
    }

    pub fn edge_len(&self, a: VertexId, b: VertexId) -> Option<f64> {
        self.neighbors(a)
            .iter()
            .find(|&&(v, _)| v == b)
            .map(|&(_, l)| l)
    }

    /// Every undirected edge once, as `(a, b, length)` with `a < b`,
    /// ordered by `a` then adjacency position.
    pub fn iter_edges(
        &self,
    ) -> impl Iterator<Item = (VertexId, VertexId, f64)> + '_ {
        (0..self.num_vertices()).flat_map(move |v| {
            self.neighbors(v)
                .iter()
                .filter(move |&&(u, _)| u > v)
                .map(move |&(u, l)| (v, u, l))
        })
    }

    /// Mean road length over all edges.
    pub fn mean_edge_len(&self) -> f64 {
        let (mut sum, mut n) = (0.0, 0usize);
        for (_, _, l) in self.iter_edges() {
            sum += l;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Shortest edge length in the graph (`INFINITY` when edgeless).
    pub fn min_edge_len(&self) -> f64 {
        self.nbrs
            .iter()
            .map(|&(_, l)| l)
            .fold(f64::INFINITY, f64::min)
    }

    /// Euclidean distance between two vertices.
    pub fn euclid(&self, a: VertexId, b: VertexId) -> f64 {
        let (ax, ay) = self.pos[a];
        let (bx, by) = self.pos[b];
        ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt()
    }

    /// Is the graph connected? (DFS from vertex 0.)
    pub fn is_connected(&self) -> bool {
        if self.pos.is_empty() {
            return true;
        }
        let mut seen = vec![false; self.num_vertices()];
        let mut stack = vec![0];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &(u, _) in self.neighbors(v) {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.num_vertices()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri() -> (GraphBuilder, Graph) {
        let mut b = GraphBuilder::new(vec![
            (0.0, 0.0),
            (3.0, 0.0),
            (0.0, 4.0),
        ]);
        b.add_edge(0, 1, 3.0);
        b.add_edge(1, 2, 5.0);
        b.add_edge(2, 0, 4.0);
        let mut b2 = GraphBuilder::new(vec![
            (0.0, 0.0),
            (3.0, 0.0),
            (0.0, 4.0),
        ]);
        b2.add_edge(0, 1, 3.0);
        b2.add_edge(1, 2, 5.0);
        b2.add_edge(2, 0, 4.0);
        (b, b2.finalize())
    }

    #[test]
    fn edges_are_undirected_and_deduped() {
        let (mut b, g) = tri();
        assert_eq!(g.num_edges(), 3);
        assert!(!b.add_edge(0, 1, 9.0)); // duplicate
        assert!(!b.add_edge(1, 0, 9.0)); // reversed duplicate
        assert!(!b.add_edge(1, 1, 1.0)); // self loop
        assert_eq!(b.num_edges(), 3);
        assert!(b.has_edge(1, 0));
        assert!(!b.has_edge(0, 0));
        assert_eq!(g.edge_len(1, 0), Some(3.0));
        assert!(g.has_edge(2, 1));
        assert_eq!(g.edge_len(0, 2), Some(4.0));
    }

    #[test]
    fn csr_preserves_insertion_order_per_vertex() {
        let (_, g) = tri();
        // Vertex 0's edges were inserted 0-1 then 2-0.
        assert_eq!(g.neighbors(0), &[(1, 3.0), (2, 4.0)]);
        assert_eq!(g.neighbors(1), &[(0, 3.0), (2, 5.0)]);
        assert_eq!(g.neighbors(2), &[(1, 5.0), (0, 4.0)]);
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn iter_edges_each_once() {
        let (_, g) = tri();
        let mut es: Vec<_> = g.iter_edges().collect();
        es.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        assert_eq!(es, vec![(0, 1, 3.0), (0, 2, 4.0), (1, 2, 5.0)]);
        assert!((g.min_edge_len() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn mean_edge_len_counts_each_edge_once() {
        let (_, g) = tri();
        assert!((g.mean_edge_len() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn euclid_matches_geometry() {
        let (_, g) = tri();
        assert!((g.euclid(1, 2) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn connectivity() {
        let mut b = GraphBuilder::new(vec![
            (0.0, 0.0),
            (1.0, 0.0),
            (2.0, 0.0),
        ]);
        b.add_edge(0, 1, 1.0);
        assert!(!b.clone_finalize().is_connected());
        b.add_edge(1, 2, 1.0);
        assert!(b.finalize().is_connected());
    }
}

#[cfg(test)]
impl GraphBuilder {
    /// Test helper: finalize a snapshot without consuming the builder.
    fn clone_finalize(&self) -> Graph {
        let mut b = GraphBuilder::new(self.pos.clone());
        for &(a, bb, l) in &self.edges {
            b.add_edge(a, bb, l);
        }
        b.finalize()
    }
}
