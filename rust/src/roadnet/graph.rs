//! Undirected road graph with geometric vertices and per-edge lengths.

pub type VertexId = usize;

/// Undirected road network. Vertices carry planar coordinates (metres);
/// edges carry road lengths (metres) which may differ from the Euclidean
/// distance (roads bend).
#[derive(Debug, Clone)]
pub struct Graph {
    /// Vertex coordinates in metres.
    pub pos: Vec<(f64, f64)>,
    /// Adjacency: `adj[v] = [(neighbor, road_length_m), ...]`.
    pub adj: Vec<Vec<(VertexId, f64)>>,
    edge_count: usize,
}

impl Graph {
    pub fn new(pos: Vec<(f64, f64)>) -> Self {
        let n = pos.len();
        Self {
            pos,
            adj: vec![Vec::new(); n],
            edge_count: 0,
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.pos.len()
    }

    pub fn num_edges(&self) -> usize {
        self.edge_count
    }

    /// Add an undirected edge; ignores duplicates and self-loops.
    pub fn add_edge(&mut self, a: VertexId, b: VertexId, len_m: f64) -> bool {
        if a == b || self.has_edge(a, b) {
            return false;
        }
        self.adj[a].push((b, len_m));
        self.adj[b].push((a, len_m));
        self.edge_count += 1;
        true
    }

    pub fn has_edge(&self, a: VertexId, b: VertexId) -> bool {
        self.adj[a].iter().any(|&(v, _)| v == b)
    }

    pub fn edge_len(&self, a: VertexId, b: VertexId) -> Option<f64> {
        self.adj[a].iter().find(|&&(v, _)| v == b).map(|&(_, l)| l)
    }

    /// Mean road length over all edges.
    pub fn mean_edge_len(&self) -> f64 {
        let (mut sum, mut n) = (0.0, 0usize);
        for (v, nbrs) in self.adj.iter().enumerate() {
            for &(u, l) in nbrs {
                if u > v {
                    sum += l;
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Euclidean distance between two vertices.
    pub fn euclid(&self, a: VertexId, b: VertexId) -> f64 {
        let (ax, ay) = self.pos[a];
        let (bx, by) = self.pos[b];
        ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt()
    }

    /// Is the graph connected? (BFS from vertex 0.)
    pub fn is_connected(&self) -> bool {
        if self.pos.is_empty() {
            return true;
        }
        let mut seen = vec![false; self.num_vertices()];
        let mut stack = vec![0];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &(u, _) in &self.adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.num_vertices()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri() -> Graph {
        let mut g = Graph::new(vec![(0.0, 0.0), (3.0, 0.0), (0.0, 4.0)]);
        g.add_edge(0, 1, 3.0);
        g.add_edge(1, 2, 5.0);
        g.add_edge(2, 0, 4.0);
        g
    }

    #[test]
    fn edges_are_undirected_and_deduped() {
        let mut g = tri();
        assert_eq!(g.num_edges(), 3);
        assert!(!g.add_edge(0, 1, 9.0)); // duplicate
        assert!(!g.add_edge(1, 1, 1.0)); // self loop
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.edge_len(1, 0), Some(3.0));
    }

    #[test]
    fn mean_edge_len_counts_each_edge_once() {
        assert!((tri().mean_edge_len() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn euclid_matches_geometry() {
        assert!((tri().euclid(1, 2) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn connectivity() {
        let mut g = Graph::new(vec![(0.0, 0.0), (1.0, 0.0), (2.0, 0.0)]);
        g.add_edge(0, 1, 1.0);
        assert!(!g.is_connected());
        g.add_edge(1, 2, 1.0);
        assert!(g.is_connected());
    }
}
