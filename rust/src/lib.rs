//! # Anveshak-RS
//!
//! A from-scratch reproduction of *"A Scalable Platform for Distributed
//! Object Tracking across a Many-camera Network"* (Khochare, Krishnan,
//! Simmhan — 2019; the **Anveshak** platform) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the
//!   domain-specific tracking dataflow (FC → VA → CR → {TL, QF, UV}),
//!   per-task FIFO queues with the paper's three drop points (§4.3),
//!   deadline-driven dynamic batching (§4.4), completion-budget
//!   adaptation via accept/reject/probe signals (§4.5), and the
//!   spotlight Tracking-Logic algorithms.
//! * **Layer 2/1 (build-time Python)** — the VA/CR re-identification
//!   models and their Pallas kernels, AOT-lowered to HLO text in
//!   `artifacts/` and executed from Rust through the PJRT C API
//!   ([`runtime`]). Python never runs on the request path.
//!
//! Two execution engines share the same module and tuning logic:
//!
//! * [`coordinator::des`] — a virtual-time discrete-event engine used by
//!   the experiment harness to regenerate every figure of the paper's
//!   evaluation in seconds instead of 600-second wall-clock runs.
//! * [`coordinator::live`] — a tokio engine with real clocks and real
//!   PJRT model execution, used by the serving examples.

pub mod apps;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod metrics;
pub mod roadnet;
pub mod runtime;
pub mod sim;
pub mod tuning;
pub mod util;

pub use config::ExperimentConfig;
