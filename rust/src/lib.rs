//! # Anveshak-RS
//!
//! A from-scratch reproduction of *"A Scalable Platform for Distributed
//! Object Tracking across a Many-camera Network"* (Khochare, Krishnan,
//! Simmhan — 2019; the **Anveshak** platform) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! * **Layer 3 (this crate)** — the coordination contribution: the
//!   domain-specific tracking dataflow (FC → VA → CR → {TL, QF, UV}),
//!   per-task FIFO queues with the paper's three drop points (§4.3),
//!   deadline-driven dynamic batching (§4.4), completion-budget
//!   adaptation via accept/reject/probe signals (§4.5), and the
//!   spotlight Tracking-Logic algorithms.
//! * **Layer 2/1 (build-time Python)** — the VA/CR re-identification
//!   models and their Pallas kernels, AOT-lowered to HLO text in
//!   `artifacts/` and executed from Rust through the PJRT C API
//!   ([`runtime`]). Python never runs on the request path.
//!
//! Two execution engines share the same module and tuning logic — and
//! one event substrate, [`engine::EventCore`] (slab-indexed storage, a
//! binary heap over 24-byte keys, zero steady-state allocation), so
//! there is a single dispatch loop implementation rather than one per
//! engine:
//!
//! * [`coordinator::des`] — a virtual-time discrete-event engine used by
//!   the experiment harness to regenerate every figure of the paper's
//!   evaluation in seconds instead of 600-second wall-clock runs.
//! * [`coordinator::live`] — a wall-clock engine built on std threads
//!   and mpsc channels (an async/tokio transport is a planned follow-up;
//!   earlier docs called this "a tokio engine" prematurely) with real
//!   PJRT model execution, used by the serving examples. The PJRT
//!   runtime is gated behind the `pjrt` cargo feature; without it a
//!   stub reports a clear error and everything else builds and tests
//!   green.
//!
//! ## The multi-query service layer
//!
//! The seed ran **one** tracking query per process. [`service`] turns
//! the platform into a multi-tenant system, mapping many logical
//! single-query dataflows onto one physical deployment:
//!
//! ```text
//!   submit/cancel ──► QueryRegistry ──► AdmissionController
//!                          │                (admit / queue / reject)
//!                          ▼
//!                  per-query TL spotlights ──union──► camera activation
//!                          │
//!          events tagged with QueryId ([`dataflow::Header`])
//!                          ▼
//!        shared VA/CR workers, FairShareBatcher per executor
//!        (cross-query batches, weighted deficit round robin)
//!                          ▼
//!        per-query budgets/drops ([`tuning`]) + per-query ledgers
//!        ([`metrics::QueryLedgers`]) ──► per-query recall/latency
//! ```
//!
//! Both engines expose a multi-query mode: [`coordinator::des::run_multi`]
//! (Poisson query arrivals over the road network; used by `harness mq`
//! and the `multi_query` bench/example) and [`service::TrackingService`]
//! (runtime submit/cancel over shared wall-clock workers).
//!
//! ## Writing your own tracking app
//!
//! The §2.2 programming model is a set of traits in [`dataflow`]:
//! [`dataflow::FilterControl`], [`dataflow::VideoAnalytics`],
//! [`dataflow::ContentionResolver`], [`dataflow::TrackingLogic`] and
//! [`dataflow::QueryFusion`]. You implement (or pick stock versions
//! of) the blocks, compose them with [`apps::AppBuilder`], and hand
//! the resulting [`apps::AppDefinition`] to any engine — the platform
//! owns batching, dropping, routing, budget adaptation and the QF →
//! VA/CR feedback edge; your code is never on an engine-specific
//! path. App 5 ([`apps::app5`]) is the worked example: a
//! DeepScale-style adaptive frame-rate FC over a vehicle re-id CR,
//! built entirely from the public API (this example *runs* under
//! `cargo test --doc`, on a small network so it finishes in
//! milliseconds):
//!
//! ```
//! use anveshak::apps::{AdaptiveRateFc, AppBuilder, SimDetector, SimReid};
//! use anveshak::config::{ExperimentConfig, TlKind};
//! use anveshak::coordinator::des;
//! use anveshak::dataflow::ModelVariant;
//!
//! // Compose the app: full frame rate while reacquiring the vehicle,
//! // 1-in-4 frames in steady state, cheap small-input detector,
//! // vehicle re-id CR, speed-adaptive spotlight.
//! let app = AppBuilder::new("my-adaptive-vehicle")
//!     .filter_control(AdaptiveRateFc::new(4, 3))
//!     .video_analytics(SimDetector::new(ModelVariant::Va).with_cost(0.6))
//!     .contention_resolver(SimReid::vehicle())
//!     .tracking_logic(TlKind::WbfsSpeed)
//!     .build();
//!
//! // The platform config stays yours: cameras, batching, drops, γ.
//! let mut cfg = ExperimentConfig::default();
//! cfg.num_cameras = 40;
//! cfg.workload.vertices = 40;
//! cfg.workload.edges = 100;
//! cfg.duration_secs = 20.0;
//! app.apply(&mut cfg, true); // cost model + workload tuning + TL
//! let report = des::run_app(cfg, &app);
//! assert!(report.summary.generated > 0);
//! println!("detections: {}", report.detections);
//! ```
//!
//! Custom blocks are ordinary trait impls. A Filter Control that
//! halves every camera's frame rate is a dozen lines, and plugs into
//! the same engines:
//!
//! ```
//! use anveshak::apps::AppBuilder;
//! use anveshak::config::{ExperimentConfig, TlKind};
//! use anveshak::coordinator::des;
//! use anveshak::dataflow::{FilterControl, QueryId};
//! use anveshak::util::Micros;
//!
//! #[derive(Clone)]
//! struct HalfRateFc;
//!
//! impl FilterControl for HalfRateFc {
//!     fn admit(
//!         &mut self,
//!         _query: QueryId,
//!         _camera: usize,
//!         frame_no: u64,
//!         _now: Micros,
//!         active: bool,
//!     ) -> bool {
//!         active && frame_no % 2 == 0
//!     }
//!     fn label(&self) -> &'static str {
//!         "half-rate"
//!     }
//! }
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.num_cameras = 40;
//! cfg.workload.vertices = 40;
//! cfg.workload.edges = 100;
//! cfg.duration_secs = 20.0;
//! let app = AppBuilder::new("half-rate")
//!     .filter_control(HalfRateFc)
//!     .tracking_logic(TlKind::Wbfs)
//!     .build();
//! let report = des::run_app(cfg, &app);
//! assert!(report.summary.conserved());
//! ```
//!
//! `examples/custom_app.rs` goes further (a custom TL as well). Model
//! handles are typed ([`dataflow::ModelVariant`]), so a composition
//! that names a nonexistent AOT artifact fails at build time with a
//! clear error. Since the feedback edge went live, a composition
//! whose QF refines ([`apps::RnnFusion`]) has its fused embedding
//! routed back into VA/CR automatically — see
//! [`dataflow::FeedbackRouter`] / [`dataflow::FeedbackState`] and
//! `docs/ARCHITECTURE.md` for the loop's determinism contract.

// Compiler-backed halves of the `check::lint` repo invariants: the
// no-escape-hatch rule is a hard forbid (the lint pass cross-checks
// binaries and build scripts this header does not cover), and the
// strict-invariants verification build insists on documented items so
// the invariant inventory stays readable.
#![forbid(unsafe_code)]
#![cfg_attr(feature = "strict-invariants", warn(missing_docs))]

pub mod apps;
pub mod check;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod engine;
pub mod metrics;
pub mod obs;
pub mod roadnet;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod tuning;
pub mod util;

pub use config::ExperimentConfig;
