//! Dynamic batch formation (§4.4), plus the Static and NOB strategies.
//!
//! The dynamic batcher considers the event at the head of the queue for
//! the current batch `Bₚ` of size `m`: it is added iff
//! `t + ξ(m+1) ≤ min(Δₚ, δₓ)` — i.e. the grown batch would still finish
//! before both the batch deadline (earliest member deadline) and the new
//! event's own deadline. When the head cannot join, the current batch is
//! submitted. An idle batch auto-submits when the clock reaches
//! `Δₚ − ξ(m)`; the engine drives this through [`BatcherPoll::Timer`].

use std::collections::VecDeque;

use super::budget::BUDGET_INF;
use super::nob::{NobTable, NOB_MAX_RATE, NOB_RATE_STEP};
use super::xi::XiModel;
use crate::util::Micros;

/// An event queued at a task, with the timestamps batching needs.
#[derive(Debug, Clone)]
pub struct QueuedEvent<T> {
    pub item: T,
    /// Source event id `k`.
    pub id: u64,
    /// Observed arrival time at this task (`aᵏᵢ`, local clock).
    pub arrival: Micros,
    /// Event deadline `δ = βᵢ + aᵏ₁` at this task's clock; `BUDGET_INF`
    /// while budgets are uninitialized (bootstrap).
    pub deadline: Micros,
}

/// Result of polling the batcher.
#[derive(Debug)]
pub enum BatcherPoll<T> {
    /// A batch ready for execution now.
    Ready(Vec<QueuedEvent<T>>),
    /// Nothing ready; poll again at this time (auto-submit deadline).
    Timer(Micros),
    /// Nothing pending.
    Idle,
}

enum Kind {
    Static {
        size: usize,
    },
    Dynamic {
        max: usize,
    },
    Nob {
        table: NobTable,
        max: usize,
        rate_ema: f64,
        last_arrival: Option<Micros>,
        /// `(α, β)` the table was last built from — lets
        /// [`Batcher::retune_nob`] rebuild only on material ξ drift.
        /// `None` until the first retune call (frozen-ξ runs never
        /// retune, keeping the §5.1 one-time-benchmark semantics).
        cal: Option<(f64, f64)>,
    },
}

/// Batch-formation state for one task.
pub struct Batcher<T> {
    kind: Kind,
    pending: VecDeque<QueuedEvent<T>>,
    current: Vec<QueuedEvent<T>>,
    /// Δₚ: earliest deadline among `current`.
    cur_deadline: Micros,
}

impl<T> Batcher<T> {
    pub fn fixed(size: usize) -> Self {
        Self::with_kind(Kind::Static { size: size.max(1) })
    }

    pub fn dynamic(max: usize) -> Self {
        Self::with_kind(Kind::Dynamic { max: max.max(1) })
    }

    pub fn nob(table: NobTable, max: usize) -> Self {
        Self::with_kind(Kind::Nob {
            table,
            max: max.max(1),
            rate_ema: 0.0,
            last_arrival: None,
            cal: None,
        })
    }

    fn with_kind(kind: Kind) -> Self {
        Self {
            kind,
            pending: VecDeque::new(),
            current: Vec::new(),
            cur_deadline: BUDGET_INF,
        }
    }

    /// Enqueue an arriving (post-drop-point-1) event.
    pub fn push(&mut self, qe: QueuedEvent<T>) {
        if let Kind::Nob {
            rate_ema,
            last_arrival,
            ..
        } = &mut self.kind
        {
            if let Some(last) = *last_arrival {
                let dt = (qe.arrival - last).max(1) as f64;
                let inst = 1e6 / dt;
                *rate_ema = if *rate_ema == 0.0 {
                    inst
                } else {
                    0.2 * inst + 0.8 * *rate_ema
                };
            }
            *last_arrival = Some(qe.arrival);
        }
        self.pending.push_back(qe);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn current_len(&self) -> usize {
        self.current.len()
    }

    /// Estimated input rate (NOB only).
    pub fn rate_estimate(&self) -> f64 {
        match &self.kind {
            Kind::Nob { rate_ema, .. } => *rate_ema,
            _ => 0.0,
        }
    }

    fn take_current(&mut self) -> Vec<QueuedEvent<T>> {
        self.cur_deadline = BUDGET_INF;
        std::mem::take(&mut self.current)
    }

    /// Hand back an emptied batch vec (from a finished execution) so
    /// its capacity seeds the next batch instead of reallocating. A
    /// non-empty `spare` is cleared; if a batch is already forming, the
    /// spare is simply dropped.
    pub fn recycle(&mut self, mut spare: Vec<QueuedEvent<T>>) {
        if self.current.is_empty() && self.current.capacity() == 0 {
            spare.clear();
            self.current = spare;
        }
    }

    /// Pull every queued event — the forming batch, then the pending
    /// FIFO, preserving arrival order — out of the batcher. The
    /// failure-domain drain: a crashed executor's queue is either
    /// re-dispatched to a surviving peer or written off as lost by the
    /// engines; the batcher itself is left empty and reusable.
    pub fn drain_into(&mut self, out: &mut Vec<QueuedEvent<T>>) {
        out.extend(self.current.drain(..));
        out.extend(self.pending.drain(..));
        self.cur_deadline = BUDGET_INF;
    }

    /// Rebuild the NOB rate → batch-size table from the *current* ξ
    /// estimate — the online-ξ counterpart of the table's one-time
    /// §5.1 benchmark, called by the engines after each
    /// [`XiModel::observe`] when `online_xi` is on. The first call
    /// rebuilds unconditionally (the config-time table may already be
    /// stale under a from-start slowdown); after that, only a material
    /// drift (> 5 % on either coefficient) triggers a rebuild, so the
    /// per-batch call is a cheap comparison in steady state. No-op for
    /// the Static/Dynamic strategies.
    pub fn retune_nob(&mut self, xi: &XiModel) {
        if let Kind::Nob {
            table, max, cal, ..
        } = &mut self.kind
        {
            let (a, b) = (xi.alpha_us(), xi.beta_us());
            let drifted = match *cal {
                None => true,
                Some((ca, cb)) => {
                    let da = (a - ca).abs() / ca.abs().max(1.0);
                    let db = (b - cb).abs() / cb.abs().max(1.0);
                    da.max(db) > 0.05
                }
            };
            if drifted {
                *table =
                    NobTable::build(xi, NOB_MAX_RATE, NOB_RATE_STEP, *max);
                *cal = Some((a, b));
            }
        }
    }

    /// Drive batch formation at time `now`. Call when the executor is
    /// free, after each `push`, and when a previously returned timer
    /// fires.
    pub fn poll(&mut self, now: Micros, xi: &XiModel) -> BatcherPoll<T> {
        match &mut self.kind {
            Kind::Static { size } => {
                let size = *size;
                if self.pending.len() >= size {
                    // Drain into the recycled buffer (`current` is
                    // otherwise unused by the static strategy), so the
                    // steady state circulates one allocation just like
                    // the deadline path.
                    let mut batch = std::mem::take(&mut self.current);
                    batch.extend(self.pending.drain(..size));
                    BatcherPoll::Ready(batch)
                } else {
                    // Static batching never times out — exactly the
                    // unbounded-wait behaviour the paper calls out.
                    BatcherPoll::Idle
                }
            }
            Kind::Nob { table, max, rate_ema, .. } => {
                // §5.1 bootstrap: the rate EMA needs two arrivals
                // before it holds a real estimate ([`Self::push`]
                // seeds it from the first inter-arrival gap). Until
                // then, stream b = 1 — looking up a batch size "for
                // rate 0" would pick the lowest table rate's target
                // and could hold the very first event hostage to a
                // batch that never fills at low input rates.
                let target = if *rate_ema <= 0.0 {
                    1
                } else {
                    table.lookup(*rate_ema).clamp(1, *max)
                };
                if self.pending.len() >= target {
                    let mut batch = std::mem::take(&mut self.current);
                    batch.extend(self.pending.drain(..target));
                    BatcherPoll::Ready(batch)
                } else {
                    BatcherPoll::Idle
                }
            }
            Kind::Dynamic { max } => {
                let max = *max;
                loop {
                    if self.current.len() >= max {
                        return BatcherPoll::Ready(self.take_current());
                    }
                    let Some(head) = self.pending.front() else {
                        // Queue drained: wait for the auto-submit point.
                        if self.current.is_empty() {
                            return BatcherPoll::Idle;
                        }
                        let m = self.current.len();
                        let submit_at =
                            self.cur_deadline.saturating_sub(xi.xi(m));
                        if now >= submit_at {
                            return BatcherPoll::Ready(self.take_current());
                        }
                        return BatcherPoll::Timer(submit_at);
                    };
                    // Bootstrap: no budget yet -> streaming (b = 1).
                    if head.deadline >= BUDGET_INF {
                        if !self.current.is_empty() {
                            return BatcherPoll::Ready(self.take_current());
                        }
                        let head = self.pending.pop_front().unwrap();
                        return BatcherPoll::Ready(vec![head]);
                    }
                    let m = self.current.len();
                    let fits = now + xi.xi(m + 1)
                        <= self.cur_deadline.min(head.deadline);
                    if fits {
                        let head = self.pending.pop_front().unwrap();
                        self.cur_deadline =
                            self.cur_deadline.min(head.deadline);
                        self.current.push(head);
                    } else if !self.current.is_empty() {
                        return BatcherPoll::Ready(self.take_current());
                    } else {
                        // Even alone the head misses its deadline; pass
                        // it through solo — drop point 2 will judge it.
                        let head = self.pending.pop_front().unwrap();
                        return BatcherPoll::Ready(vec![head]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MS, SEC};

    fn xi() -> XiModel {
        XiModel::affine_ms(52.5, 67.5)
    }

    fn qe(id: u64, arrival: Micros, deadline: Micros) -> QueuedEvent<u64> {
        QueuedEvent {
            item: id,
            id,
            arrival,
            deadline,
        }
    }

    fn ready_ids(p: BatcherPoll<u64>) -> Vec<u64> {
        match p {
            BatcherPoll::Ready(b) => b.iter().map(|e| e.id).collect(),
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    #[test]
    fn static_waits_for_full_batch() {
        let mut b = Batcher::fixed(3);
        b.push(qe(1, 0, BUDGET_INF));
        b.push(qe(2, SEC, BUDGET_INF));
        assert!(matches!(b.poll(SEC, &xi()), BatcherPoll::Idle));
        b.push(qe(3, 2 * SEC, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(2 * SEC, &xi())), vec![1, 2, 3]);
    }

    #[test]
    fn dynamic_bootstrap_streams() {
        let mut b = Batcher::dynamic(25);
        b.push(qe(1, 0, BUDGET_INF));
        b.push(qe(2, 0, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(0, &xi())), vec![1]);
        assert_eq!(ready_ids(b.poll(0, &xi())), vec![2]);
    }

    #[test]
    fn dynamic_accumulates_within_deadline() {
        let mut b = Batcher::dynamic(25);
        // Deadlines far out: batch should accumulate, then Timer.
        let dl = 20 * SEC;
        for k in 0..5 {
            b.push(qe(k, 0, dl));
        }
        match b.poll(0, &xi()) {
            BatcherPoll::Timer(at) => {
                // submit at Δ − ξ(5)
                assert_eq!(at, dl - xi().xi(5));
            }
            other => panic!("{other:?}"),
        }
        // At the timer, the batch is released.
        let at = dl - xi().xi(5);
        assert_eq!(ready_ids(b.poll(at, &xi())), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn dynamic_respects_batch_deadline_test() {
        let mut b = Batcher::dynamic(25);
        let x = xi();
        // First event deadline tight: only a small batch fits.
        b.push(qe(0, 0, x.xi(2) + 1)); // fits with one companion
        b.push(qe(1, 0, 20 * SEC));
        b.push(qe(2, 0, 20 * SEC));
        // Adding event 2 would need now + xi(3) <= Δ = xi(2)+1: fails.
        let ids = ready_ids(b.poll(0, &x));
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn dynamic_max_size_caps_batch() {
        let mut b = Batcher::dynamic(4);
        for k in 0..10 {
            b.push(qe(k, 0, 60 * SEC));
        }
        assert_eq!(ready_ids(b.poll(0, &xi())).len(), 4);
    }

    #[test]
    fn dynamic_solo_event_past_deadline_still_released() {
        let mut b = Batcher::dynamic(25);
        b.push(qe(0, 0, 1)); // cannot meet deadline even alone
        assert_eq!(ready_ids(b.poll(10, &xi())), vec![0]);
    }

    #[test]
    fn dynamic_batch_deadline_is_min_of_members() {
        let mut b = Batcher::dynamic(25);
        let x = xi();
        b.push(qe(0, 0, 30 * SEC));
        b.push(qe(1, 0, 10 * SEC)); // tighter
        match b.poll(0, &x) {
            BatcherPoll::Timer(at) => assert_eq!(at, 10 * SEC - x.xi(2)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn static_and_nob_reuse_recycled_capacity() {
        // A recycled spare's allocation must seed the next batch on
        // the Static/NOB paths (previously `drain().collect()`
        // allocated per batch).
        let mut b: Batcher<u64> = Batcher::fixed(2);
        let spare: Vec<QueuedEvent<u64>> = Vec::with_capacity(64);
        b.recycle(spare);
        b.push(qe(0, 0, BUDGET_INF));
        b.push(qe(1, 0, BUDGET_INF));
        match b.poll(0, &xi()) {
            BatcherPoll::Ready(batch) => {
                assert_eq!(batch.len(), 2);
                assert!(
                    batch.capacity() >= 64,
                    "recycled capacity reused: {}",
                    batch.capacity()
                );
            }
            other => panic!("{other:?}"),
        }

        let x = XiModel::affine_ms(100.0, 10.0);
        let table = NobTable::build(&x, 100.0, 10.0, 32);
        let mut b = Batcher::nob(table, 32);
        let spare: Vec<QueuedEvent<u64>> = Vec::with_capacity(64);
        b.recycle(spare);
        let mut t = 0;
        for k in 0..10 {
            b.push(qe(k, t, BUDGET_INF));
            if let BatcherPoll::Ready(batch) = b.poll(t, &x) {
                assert!(
                    batch.capacity() >= 64,
                    "NOB reuses recycled capacity: {}",
                    batch.capacity()
                );
                return;
            }
            t += 50 * MS;
        }
        panic!("NOB never formed a batch");
    }

    #[test]
    fn nob_uses_rate_lookup() {
        let x = XiModel::affine_ms(100.0, 10.0);
        let table = NobTable::build(&x, 100.0, 10.0, 32);
        let mut b = Batcher::nob(table, 32);
        // First arrival: no rate estimate yet — bootstrap streams b=1.
        b.push(qe(0, 0, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(0, &x)), vec![0]);
        // 20 events/s arrivals -> target batch 3 (see nob tests).
        let mut t = 0;
        let mut got = None;
        for k in 1..12 {
            t += 50 * MS; // 20 events/s
            b.push(qe(k, t, BUDGET_INF));
            if let BatcherPoll::Ready(batch) = b.poll(t, &x) {
                got = Some(batch.len());
                break;
            }
        }
        assert_eq!(got, Some(3));
    }

    #[test]
    fn nob_cold_start_streams_until_rate_is_real() {
        // Regression: until the second arrival `rate_ema` is 0.0 and
        // the old poll looked up a batch size "for rate 0" (the
        // nearest table rate), so a lone first event at a low input
        // rate waited indefinitely for companions. The §5.1 bootstrap
        // contract is streaming (b = 1) until the estimate is real.
        let x = XiModel::affine_ms(52.5, 67.5);
        let table = NobTable::build(&x, 1000.0, 10.0, 25);
        assert!(
            table.lookup(0.0) > 1,
            "precondition: the rate-0 lookup would not stream"
        );
        let mut b = Batcher::nob(table, 25);
        b.push(qe(7, 0, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(0, &x)), vec![7]);
        assert_eq!(b.rate_estimate(), 0.0);
        // The EMA seeds from the first inter-arrival gap (10 s -> 0.1/s).
        b.push(qe(8, 10 * SEC, BUDGET_INF));
        assert!((b.rate_estimate() - 0.1).abs() < 1e-9);
        // With a real (tiny) rate the lookup takes over again; the
        // nearest table rate is 10/s, whose target at this ξ is 2.
        assert!(matches!(b.poll(10 * SEC, &x), BatcherPoll::Idle));
        b.push(qe(9, 20 * SEC, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(20 * SEC, &x)), vec![8, 9]);
    }

    #[test]
    fn retune_nob_tracks_drifted_xi() {
        let x = XiModel::affine_ms(100.0, 10.0);
        let table = NobTable::build(&x, 100.0, 10.0, 32);
        let mut b: Batcher<u64> = Batcher::nob(table, 32);
        // Bootstrap stream, then seed a steady 10 events/s EMA.
        b.push(qe(0, 0, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(0, &x)), vec![0]);
        let mut t = 0;
        b.push(qe(1, t + 100 * MS, BUDGET_INF));
        b.push(qe(2, t + 200 * MS, BUDGET_INF));
        t += 200 * MS;
        // At 10/s the config-time table targets b = 2.
        assert_eq!(ready_ids(b.poll(t, &x)).len(), 2);
        // The machine got 4x slower; online ξ observed it. Retuning
        // rebuilds the table: at 10/s the target is now 7
        // (b / (0.4 s + 0.04 s · b) ≥ 10 ⇒ b ≥ 6.67).
        let slow = XiModel::affine_ms(400.0, 40.0);
        b.retune_nob(&slow);
        for k in 3..10 {
            t += 100 * MS;
            b.push(qe(k, t, BUDGET_INF));
            if k < 9 {
                assert!(
                    matches!(b.poll(t, &slow), BatcherPoll::Idle),
                    "target should have grown past {}",
                    k - 2
                );
            }
        }
        assert_eq!(ready_ids(b.poll(t, &slow)).len(), 7);
        // No material drift -> retune is a no-op comparison.
        b.retune_nob(&slow);
    }

    #[test]
    fn retune_nob_is_inert_for_other_strategies() {
        let x = XiModel::affine_ms(52.5, 67.5);
        let mut b: Batcher<u64> = Batcher::dynamic(25);
        b.retune_nob(&XiModel::affine_ms(500.0, 500.0));
        b.push(qe(0, 0, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(0, &x)), vec![0]);
    }

    #[test]
    fn drain_into_empties_current_then_pending() {
        let mut b = Batcher::dynamic(25);
        let x = xi();
        // Two events join the forming batch (far deadlines), two more
        // stay pending behind a Timer poll.
        for k in 0..2 {
            b.push(qe(k, 0, 60 * SEC));
        }
        assert!(matches!(b.poll(0, &x), BatcherPoll::Timer(_)));
        b.push(qe(2, 0, 60 * SEC));
        b.push(qe(3, 0, 60 * SEC));
        let mut out = Vec::new();
        b.drain_into(&mut out);
        assert!(out.len() >= 2, "drained {} events", out.len());
        assert_eq!(b.pending_len(), 0);
        assert_eq!(b.current_len(), 0);
        // Arrival order is preserved across the current/pending seam.
        let ids: Vec<u64> = out.iter().map(|e| e.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        // The batcher stays usable after the drain.
        b.push(qe(9, SEC, BUDGET_INF));
        assert_eq!(ready_ids(b.poll(SEC, &x)), vec![9]);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::dynamic(25);
        let x = xi();
        for k in 0..6 {
            b.push(qe(k, 0, 60 * SEC));
        }
        // All six join the batch; the timer releases them in order.
        let at = match b.poll(0, &x) {
            BatcherPoll::Timer(at) => at,
            other => panic!("{other:?}"),
        };
        assert_eq!(at, 60 * SEC - x.xi(6));
        let ids = ready_ids(b.poll(at, &x));
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }
}
