//! The adaptation plane: a DeepScale-style accuracy–latency controller
//! (PAPERS.md) threaded through every execution path.
//!
//! The §4 tuning triangle's only pressure valve used to be *dropping
//! data*; DeepScale shows that downshifting *content* — frame
//! resolution and model variant — moves the recall-vs-deadline frontier
//! instead of falling off it. This module is the typed core of that
//! loop:
//!
//! * [`AdaptationCommand`] — one decision: `(camera, resolution level,
//!   model variant)`, stamped with a per-camera monotone sequence
//!   number. Minted at the sink (where deadline slack is observable),
//!   routed upstream on the same seq-stamped feedback edge as query
//!   refinements ([`crate::dataflow::FeedbackRouter`]).
//! * [`AdaptationState`] — **the single shared application point.** All
//!   four engines own exactly one and consume commands exclusively
//!   through [`AdaptationState::apply`]; FC admission, VA/CR batch
//!   pricing and live model selection then read the commanded
//!   `(variant, resolution)` through its accessors. Exactly-once,
//!   stale-discard semantics mirror [`crate::dataflow::FeedbackState`]
//!   (duplicate or out-of-order deliveries discard deterministically).
//! * [`AdaptController`] — sink-side policy: an EMA of per-camera
//!   completion latency turns deadline slack into downshift/upshift
//!   decisions. Deterministic and RNG-free: it never touches an engine
//!   RNG stream, so an inert controller leaves runs bit-identical.
//!
//! Determinism contract: under the **identity ladder** (a single
//! all-1.0 level, the default) the controller mints nothing, every
//! multiplier accessor returns exactly `1.0` (an f64 identity under
//! multiplication) and effective batch sizes stay exact whole counts —
//! an adaptation-enabled build is bit-identical to a pre-adaptation
//! build, per seed, by construction. `rust/tests/prop_adapt.rs` holds
//! that line.

use crate::config::{AdaptationConfig, ResolutionLevel};
use crate::dataflow::ModelVariant;
use crate::util::{Micros, SEC};

/// EMA smoothing for the controller's per-camera latency tracker.
/// Deliberately brisk: the controller must react within a few
/// completions of a compute regime change.
pub const ADAPT_LATENCY_EMA: f64 = 0.25;

/// One adaptation decision, minted at the sink and applied upstream.
///
/// `seq` is per-camera, 1-based and strictly increasing (0 on an event
/// header means "not an adaptation"), mirroring the query-refinement
/// sequence numbers — the two kinds of feedback share one envelope and
/// one staleness rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdaptationCommand {
    /// The camera whose quality operating point moves.
    pub camera: usize,
    /// Target rung on the resolution ladder (0 = native quality).
    pub level: usize,
    /// Model variant the analytics stages should run for this camera.
    pub variant: ModelVariant,
    /// Per-camera monotone command sequence number (1-based).
    pub seq: u32,
}

/// The single shared application point for adaptation commands.
///
/// Every engine owns one `AdaptationState`; no engine mutates a
/// camera's operating point any other way. `apply` is exactly-once per
/// command with deterministic stale-discard; the accessors are what FC
/// admission, batch pricing and live model selection consult.
#[derive(Debug, Clone)]
pub struct AdaptationState {
    ladder: Vec<ResolutionLevel>,
    /// Current ladder rung per camera.
    level: Vec<usize>,
    /// Commanded variant override per camera (`None` = app nominal).
    variant: Vec<Option<ModelVariant>>,
    /// Last applied command seq per camera (0 = none).
    last_seq: Vec<u32>,
    /// Cameras currently below native quality (level > 0).
    downshifted: usize,
    applied: u64,
    stale: u64,
}

impl AdaptationState {
    pub fn new(cfg: &AdaptationConfig, cameras: usize) -> Self {
        assert!(
            !cfg.ladder.is_empty(),
            "resolution ladder must have at least the native level"
        );
        Self {
            ladder: cfg.ladder.clone(),
            level: vec![0; cameras],
            variant: vec![None; cameras],
            last_seq: vec![0; cameras],
            downshifted: 0,
            applied: 0,
            stale: 0,
        }
    }

    /// Apply a command iff it is fresher than the last one applied for
    /// its camera. Returns whether it took effect — `false` means the
    /// delivery was stale (or a duplicate) and was discarded, so a
    /// given command moves a camera's operating point exactly once.
    pub fn apply(&mut self, cmd: &AdaptationCommand) -> bool {
        crate::strict_assert!(
            cmd.level < self.ladder.len(),
            "adaptation command level {} outside ladder of {} rungs",
            cmd.level,
            self.ladder.len()
        );
        let last = self.last_seq[cmd.camera];
        if last >= cmd.seq {
            self.stale += 1;
            return false;
        }
        crate::strict_assert!(
            cmd.seq >= 1,
            "adaptation command for camera {} carries reserved seq 0",
            cmd.camera
        );
        crate::strict_assert!(
            cmd.seq > last,
            "adaptation seq {} for camera {} not fresher than {}",
            cmd.seq,
            cmd.camera,
            last
        );
        let level = cmd.level.min(self.ladder.len() - 1);
        let was_down = self.level[cmd.camera] > 0;
        let is_down = level > 0;
        match (was_down, is_down) {
            (false, true) => self.downshifted += 1,
            (true, false) => self.downshifted -= 1,
            _ => {}
        }
        self.level[cmd.camera] = level;
        self.variant[cmd.camera] = if level == 0 {
            None // native rung restores the app's nominal variant
        } else {
            Some(cmd.variant)
        };
        self.last_seq[cmd.camera] = cmd.seq;
        self.applied += 1;
        true
    }

    /// The camera's current rung.
    pub fn level_of(&self, camera: usize) -> usize {
        self.level[camera]
    }

    /// Last applied command seq for `camera` (0 = none).
    pub fn last_seq(&self, camera: usize) -> u32 {
        self.last_seq[camera]
    }

    /// The rung's [`ResolutionLevel`] for `camera`.
    fn rung(&self, camera: usize) -> &ResolutionLevel {
        &self.ladder[self.level[camera]]
    }

    /// The commanded variant, iff it is a genuine downshift of this
    /// stage's `nominal` model. A CR-variant command must never leak
    /// into VA pricing/scoring (and vice versa), so a stage only sees
    /// an override that is `nominal`'s own cheaper sibling.
    fn override_for(
        &self,
        camera: usize,
        nominal: ModelVariant,
    ) -> Option<ModelVariant> {
        match self.variant[camera] {
            Some(v) if v != nominal && nominal.downshifted() == v => {
                Some(v)
            }
            _ => None,
        }
    }

    /// Relative ξ cost an event from `camera` contributes to a batch at
    /// a stage whose app-nominal variant is `nominal`: the ladder
    /// rung's cost multiplier times the commanded-variant ξ ratio. At
    /// the identity ladder this is exactly `1.0`.
    pub fn rel(&self, camera: usize, nominal: ModelVariant) -> f64 {
        let base = self.rung(camera).cost;
        match self.override_for(camera, nominal) {
            Some(v) => base * v.profile().xi / nominal.profile().xi,
            None => base,
        }
    }

    /// Accuracy multiplier on the simulated true-positive rates for
    /// `camera` at a stage with nominal variant `nominal`. Exactly
    /// `1.0` at the identity ladder (so `p * acc` is bit-exact).
    pub fn accuracy(&self, camera: usize, nominal: ModelVariant) -> f64 {
        let base = self.rung(camera).accuracy;
        match self.override_for(camera, nominal) {
            Some(v) => {
                base * v.profile().accuracy / nominal.profile().accuracy
            }
            None => base,
        }
    }

    /// Commanded frame size for `camera`, scaling `bytes` by the
    /// rung's resolution. The native rung is an exact identity.
    pub fn scaled_bytes(&self, bytes: usize, camera: usize) -> usize {
        let s = self.rung(camera).scale;
        if s == 1.0 {
            bytes
        } else {
            ((bytes as f64) * s).round().max(1.0) as usize
        }
    }

    /// Commanded frame stride for `camera` (1 = every frame).
    pub fn stride(&self, camera: usize) -> u64 {
        self.rung(camera).stride.max(1)
    }

    /// The model variant a stage with nominal model `nominal` should
    /// run for `camera` (`nominal` unless a command downshifted this
    /// stage).
    pub fn variant_for(
        &self,
        camera: usize,
        nominal: ModelVariant,
    ) -> ModelVariant {
        self.override_for(camera, nominal).unwrap_or(nominal)
    }

    /// Cameras currently operating below native quality.
    pub fn downshifted(&self) -> usize {
        self.downshifted
    }

    /// Commands applied / discarded as stale so far.
    pub fn applied_count(&self) -> u64 {
        self.applied
    }

    pub fn stale_count(&self) -> u64 {
        self.stale
    }
}

/// Sink-side adaptation policy (deterministic, RNG-free).
///
/// Tracks an EMA of completion latency per camera; when the deadline
/// slack `(γ − ema)/γ` collapses below `slack_down`, the camera
/// downshifts one ladder rung (cheaper resolution, possibly a lighter
/// model variant); when slack recovers above `slack_up`, it climbs
/// back. A per-camera cooldown keeps the loop from thrashing. With a
/// single-rung (identity) ladder — or `enabled = false` — the
/// controller mints nothing, ever.
#[derive(Debug, Clone)]
pub struct AdaptController {
    enabled: bool,
    rungs: usize,
    slack_down: f64,
    slack_up: f64,
    cooldown: Micros,
    gamma: Micros,
    /// Nominal (rung-0) analytics variant, from the app definition.
    nominal: ModelVariant,
    /// Latency EMA per camera (µs); negative = no completion seen.
    ema: Vec<f64>,
    last_cmd_at: Vec<Micros>,
    next_seq: Vec<u32>,
    /// The controller's view of each camera's commanded rung.
    level: Vec<usize>,
    minted: u64,
}

impl AdaptController {
    pub fn new(
        cfg: &AdaptationConfig,
        cameras: usize,
        gamma: Micros,
        nominal: ModelVariant,
    ) -> Self {
        Self {
            enabled: cfg.enabled && cfg.ladder.len() > 1,
            rungs: cfg.ladder.len().max(1),
            slack_down: cfg.slack_down,
            slack_up: cfg.slack_up,
            cooldown: (cfg.cooldown_secs * SEC as f64) as Micros,
            gamma: gamma.max(1),
            nominal,
            ema: vec![-1.0; cameras],
            last_cmd_at: vec![Micros::MIN / 2; cameras],
            next_seq: vec![0; cameras],
            level: vec![0; cameras],
            minted: 0,
        }
    }

    /// Observe a completion at the sink; possibly mint a command. The
    /// fast path (disabled / identity ladder) returns before touching
    /// any per-camera state.
    pub fn on_completion(
        &mut self,
        camera: usize,
        latency: Micros,
        now: Micros,
    ) -> Option<AdaptationCommand> {
        if !self.enabled {
            return None;
        }
        let l = latency.max(0) as f64;
        let e = &mut self.ema[camera];
        *e = if *e < 0.0 {
            l
        } else {
            (1.0 - ADAPT_LATENCY_EMA) * *e + ADAPT_LATENCY_EMA * l
        };
        let slack = (self.gamma as f64 - *e) / self.gamma as f64;
        if now - self.last_cmd_at[camera] < self.cooldown {
            return None;
        }
        let cur = self.level[camera];
        let target = if slack < self.slack_down && cur + 1 < self.rungs {
            cur + 1
        } else if slack > self.slack_up && cur > 0 {
            cur - 1
        } else {
            return None;
        };
        self.level[camera] = target;
        self.last_cmd_at[camera] = now;
        self.next_seq[camera] += 1;
        self.minted += 1;
        Some(AdaptationCommand {
            camera,
            level: target,
            variant: if target == 0 {
                self.nominal
            } else {
                self.nominal.downshifted()
            },
            seq: self.next_seq[camera],
        })
    }

    /// Commands minted so far.
    pub fn minted(&self) -> u64 {
        self.minted
    }

    /// Whether this controller can ever mint a command.
    pub fn active(&self) -> bool {
        self.enabled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AdaptationConfig;
    use crate::util::SEC;

    fn three_rung() -> AdaptationConfig {
        let mut c = AdaptationConfig::default();
        c.enabled = true;
        c.ladder = vec![
            ResolutionLevel::native(),
            ResolutionLevel {
                scale: 0.5,
                cost: 0.55,
                accuracy: 0.97,
                stride: 1,
            },
            ResolutionLevel {
                scale: 0.25,
                cost: 0.35,
                accuracy: 0.92,
                stride: 2,
            },
        ];
        c
    }

    #[test]
    fn identity_state_is_an_exact_identity() {
        let s = AdaptationState::new(&AdaptationConfig::default(), 4);
        for cam in 0..4 {
            assert_eq!(s.rel(cam, ModelVariant::CrLarge), 1.0);
            assert_eq!(s.accuracy(cam, ModelVariant::Va), 1.0);
            assert_eq!(s.scaled_bytes(307_200, cam), 307_200);
            assert_eq!(s.stride(cam), 1);
            assert_eq!(
                s.variant_for(cam, ModelVariant::CrSmall),
                ModelVariant::CrSmall
            );
        }
        assert_eq!(s.downshifted(), 0);
    }

    #[test]
    fn apply_is_exactly_once_with_stale_discard() {
        let mut s = AdaptationState::new(&three_rung(), 3);
        let cmd = AdaptationCommand {
            camera: 1,
            level: 1,
            variant: ModelVariant::CrSmall,
            seq: 1,
        };
        assert!(s.apply(&cmd));
        // Duplicate delivery of the same seq is discarded.
        assert!(!s.apply(&cmd));
        assert_eq!(s.level_of(1), 1);
        assert_eq!(s.downshifted(), 1);
        // A fresher command applies; an out-of-order older one does not.
        assert!(s.apply(&AdaptationCommand {
            camera: 1,
            level: 2,
            variant: ModelVariant::CrSmall,
            seq: 3,
        }));
        assert!(!s.apply(&AdaptationCommand {
            camera: 1,
            level: 0,
            variant: ModelVariant::CrLarge,
            seq: 2,
        }));
        assert_eq!(s.level_of(1), 2);
        assert_eq!((s.applied_count(), s.stale_count()), (2, 2));
        // Returning to the native rung restores the nominal variant.
        assert!(s.apply(&AdaptationCommand {
            camera: 1,
            level: 0,
            variant: ModelVariant::CrLarge,
            seq: 4,
        }));
        assert_eq!(s.downshifted(), 0);
        assert_eq!(
            s.variant_for(1, ModelVariant::CrLarge),
            ModelVariant::CrLarge
        );
    }

    #[test]
    fn downshifted_rung_prices_and_scores_cheaper() {
        let mut s = AdaptationState::new(&three_rung(), 2);
        s.apply(&AdaptationCommand {
            camera: 0,
            level: 2,
            variant: ModelVariant::CrSmall,
            seq: 1,
        });
        // Ladder cost times the CrSmall/CrLarge ξ ratio.
        let rel = s.rel(0, ModelVariant::CrLarge);
        let expect = 0.35 * ModelVariant::CrSmall.profile().xi
            / ModelVariant::CrLarge.profile().xi;
        assert!((rel - expect).abs() < 1e-12, "rel {rel}");
        assert!(s.accuracy(0, ModelVariant::CrLarge) < 1.0);
        assert_eq!(s.scaled_bytes(1000, 0), 250);
        assert_eq!(s.stride(0), 2);
        // The CR-variant override never leaks into VA: the VA stage
        // sees only the ladder cost, and keeps its nominal model.
        assert_eq!(s.rel(0, ModelVariant::Va), 0.35);
        assert_eq!(
            s.variant_for(0, ModelVariant::Va),
            ModelVariant::Va
        );
        assert_eq!(
            s.variant_for(0, ModelVariant::CrLarge),
            ModelVariant::CrSmall
        );
        // The untouched camera stays native.
        assert_eq!(s.rel(1, ModelVariant::CrLarge), 1.0);
    }

    #[test]
    fn controller_downshifts_under_pressure_and_recovers() {
        let gamma = 15 * SEC;
        let mut c =
            AdaptController::new(&three_rung(), 2, gamma, ModelVariant::CrLarge);
        assert!(c.active());
        // Healthy latencies mint nothing.
        assert!(c.on_completion(0, SEC, 0).is_none());
        // Collapsed slack downshifts once the EMA catches up...
        let mut t = 0;
        let mut cmd = None;
        for _ in 0..64 {
            t += SEC;
            if let Some(m) = c.on_completion(0, 14 * SEC, t) {
                cmd = Some(m);
                break;
            }
        }
        let cmd = cmd.expect("controller never downshifted");
        assert_eq!((cmd.camera, cmd.level, cmd.seq), (0, 1, 1));
        assert_eq!(cmd.variant, ModelVariant::CrSmall);
        // ... and the cooldown gates an immediate second command.
        assert!(c.on_completion(0, 14 * SEC, t + 1).is_none());
        // Recovered slack climbs back toward native quality.
        let mut up = None;
        for _ in 0..256 {
            t += 10 * SEC;
            if let Some(m) = c.on_completion(0, SEC / 2, t) {
                up = Some(m);
                break;
            }
        }
        let up = up.expect("controller never upshifted");
        assert_eq!((up.level, up.seq), (0, 2));
        assert_eq!(up.variant, ModelVariant::CrLarge);
        assert_eq!(c.minted(), 2);
    }

    #[test]
    fn identity_ladder_controller_is_inert() {
        let mut id = AdaptationConfig::default();
        id.enabled = true; // enabled but single-rung: still inert
        let mut c =
            AdaptController::new(&id, 1, 15 * SEC, ModelVariant::Va);
        assert!(!c.active());
        for i in 0..1000 {
            assert!(c
                .on_completion(0, 20 * SEC, i as Micros * SEC)
                .is_none());
        }
        assert_eq!(c.minted(), 0);
    }
}
