//! Deficit-round-robin fair sharing across tracking queries.
//!
//! The service layer multiplexes many queries over the shared VA/CR
//! executors; when an executor is backlogged, batch slots are a scarce
//! resource and one misbehaving query (huge spotlight, collapsed
//! budget, probe storm) must not starve the rest. [`FairShare`] is the
//! pure scheduling core: a weighted deficit-round-robin over query ids,
//! with credits refilled in proportion to priority weights. Like the
//! rest of [`crate::tuning`] it has no clocks or channels, so the DES
//! engine, the live service and the property suite share it unchanged.

use crate::dataflow::QueryId;

#[derive(Debug, Clone)]
struct ShareEntry {
    key: QueryId,
    weight: u32,
    credit: i64,
}

/// Weighted deficit-round-robin state over a dynamic set of queries.
#[derive(Debug, Clone, Default)]
pub struct FairShare {
    entries: Vec<ShareEntry>,
    cursor: usize,
}

impl FairShare {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `key` with the given weight (idempotent; re-registering
    /// updates the weight and keeps accrued credit).
    pub fn ensure(&mut self, key: QueryId, weight: u32) {
        let weight = weight.max(1);
        match self.entries.iter_mut().find(|e| e.key == key) {
            Some(e) => e.weight = weight,
            None => self.entries.push(ShareEntry {
                key,
                weight,
                credit: 0,
            }),
        }
    }

    /// Remove a completed/cancelled query from the rotation.
    pub fn remove(&mut self, key: QueryId) {
        if let Some(i) = self.entries.iter().position(|e| e.key == key) {
            self.entries.remove(i);
            if self.cursor > i {
                self.cursor -= 1;
            }
            if !self.entries.is_empty() {
                self.cursor %= self.entries.len();
            } else {
                self.cursor = 0;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Pick the next query to serve among those for which `has_work`
    /// holds, honouring credits; refills credits (weight-proportional)
    /// when every eligible query is out. Returns `None` iff no
    /// registered query has work.
    pub fn pick(
        &mut self,
        mut has_work: impl FnMut(QueryId) -> bool,
    ) -> Option<QueryId> {
        let n = self.entries.len();
        if n == 0 {
            return None;
        }
        // First pass: someone eligible still holds credit.
        for off in 0..n {
            let i = (self.cursor + off) % n;
            let e = &self.entries[i];
            if e.credit > 0 && has_work(e.key) {
                self.cursor = i;
                return Some(e.key);
            }
        }
        // Refill until some eligible entry holds positive credit. A
        // single pass is not enough when a past `charge` exceeded the
        // weight (deficits carry over, standard DRR); each pass adds
        // `weight >= 1` to every eligible entry, so this terminates.
        loop {
            let mut any = false;
            for e in &mut self.entries {
                if has_work(e.key) {
                    e.credit += e.weight as i64;
                    any = true;
                }
            }
            if !any {
                return None;
            }
            for off in 0..n {
                let i = (self.cursor + off) % n;
                let e = &self.entries[i];
                if e.credit > 0 && has_work(e.key) {
                    self.cursor = i;
                    return Some(e.key);
                }
            }
        }
    }

    /// Charge `cost` units (usually 1 per batch slot) to a query.
    pub fn charge(&mut self, key: QueryId, cost: i64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == key) {
            e.credit -= cost;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serve `rounds` single-unit picks with everyone backlogged and
    /// count per-query service.
    fn serve(fs: &mut FairShare, keys: &[QueryId], rounds: usize) -> Vec<usize> {
        let mut counts = vec![0usize; keys.len()];
        for _ in 0..rounds {
            let k = fs.pick(|_| true).expect("work available");
            fs.charge(k, 1);
            counts[keys.iter().position(|&x| x == k).unwrap()] += 1;
        }
        counts
    }

    #[test]
    fn equal_weights_share_equally() {
        let mut fs = FairShare::new();
        for q in [1u32, 2, 3] {
            fs.ensure(q, 1);
        }
        let counts = serve(&mut fs, &[1, 2, 3], 30);
        assert_eq!(counts, vec![10, 10, 10]);
    }

    #[test]
    fn weights_bias_service_proportionally() {
        let mut fs = FairShare::new();
        fs.ensure(1, 2);
        fs.ensure(2, 1);
        fs.ensure(3, 1);
        let counts = serve(&mut fs, &[1, 2, 3], 40);
        assert_eq!(counts, vec![20, 10, 10]);
    }

    #[test]
    fn idle_queries_do_not_accrue_service() {
        let mut fs = FairShare::new();
        fs.ensure(1, 1);
        fs.ensure(2, 1);
        // Query 2 never has work: query 1 gets every slot.
        for _ in 0..10 {
            let k = fs.pick(|q| q == 1).unwrap();
            assert_eq!(k, 1);
            fs.charge(k, 1);
        }
        assert_eq!(fs.pick(|_| false), None);
    }

    #[test]
    fn remove_keeps_rotation_consistent() {
        let mut fs = FairShare::new();
        for q in [1u32, 2, 3] {
            fs.ensure(q, 1);
        }
        let _ = serve(&mut fs, &[1, 2, 3], 4);
        fs.remove(2);
        assert_eq!(fs.len(), 2);
        let counts = serve(&mut fs, &[1, 2, 3], 20);
        assert_eq!(counts[1], 0, "removed query never served");
        assert_eq!(counts[0] + counts[2], 20);
        assert!((counts[0] as i64 - counts[2] as i64).abs() <= 1);
    }

    #[test]
    fn oversized_charge_carries_deficit_without_stalling() {
        // A charge larger than the weight (e.g. a whole batch) leaves
        // a deficit; pick must keep serving (multi-pass refill) and the
        // over-served query repays the deficit before being served
        // again.
        let mut fs = FairShare::new();
        fs.ensure(1, 1);
        fs.ensure(2, 1);
        let first = fs.pick(|_| true).unwrap();
        fs.charge(first, 8); // deficit of 7
        let mut served = Vec::new();
        for _ in 0..8 {
            let k = fs.pick(|_| true).expect("work pending, no stall");
            fs.charge(k, 1);
            served.push(k);
        }
        let other = if first == 1 { 2 } else { 1 };
        assert!(
            served.iter().filter(|&&k| k == other).count() >= 7,
            "deficit repaid before re-serving {first}: {served:?}"
        );
    }

    #[test]
    fn reregister_updates_weight() {
        let mut fs = FairShare::new();
        fs.ensure(1, 1);
        fs.ensure(2, 1);
        fs.ensure(1, 3); // promote
        assert_eq!(fs.len(), 2);
        let counts = serve(&mut fs, &[1, 2], 40);
        assert_eq!(counts, vec![30, 10]);
    }
}
