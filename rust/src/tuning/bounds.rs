//! Formal bounds under fixed conditions (§4.6.1).
//!
//! Under a constant input rate ω, 1:1 selectivity, accurate ξ and static
//! network/compute, the paper bounds the stable batch size and the drop
//! rate. These closed forms are used by tests to cross-validate the
//! dynamic batcher's steady-state behaviour and by the ablation bench.

use super::xi::XiModel;
use crate::util::Micros;

/// Largest batch size `m` at a task with completion-budget slack
/// `slack = βᵢ − u₁ⁱ` fed at `rate` events/s, satisfying:
///
/// 1. `(m−1)/ω + ξ(m) ≤ slack`  (fill + execute within the deadline)
/// 2. `ξ(m) ≤ slack/2`          (stability: execution ≤ next fill)
///
/// `None` if even `m = 1` violates the constraints (the rate is
/// unsustainable — events must be dropped).
pub fn max_stable_batch(
    rate: f64,
    slack: Micros,
    xi: &XiModel,
    m_max: usize,
) -> Option<usize> {
    let mut best = None;
    for m in 1..=m_max {
        let fill = ((m - 1) as f64 * 1e6 / rate).round() as Micros;
        let exec = xi.xi(m);
        if fill + exec <= slack && 2 * exec <= slack {
            best = Some(m);
        }
    }
    best
}

/// Largest sustainable input rate `ω_max` (and its batch size) under the
/// stability constraint: the service throughput `m/ξ(m)` must cover the
/// rate while `ξ(m) ≤ slack/2`. The drop rate for an offered rate ω is
/// then `max(0, ω − ω_max)`.
pub fn max_stable_rate(
    slack: Micros,
    xi: &XiModel,
    m_max: usize,
) -> (f64, usize) {
    let mut best = (0.0f64, 1usize);
    for m in 1..=m_max {
        if 2 * xi.xi(m) > slack {
            break; // xi monotone: larger m only gets worse
        }
        let thr = xi.throughput(m);
        if thr > best.0 {
            best = (thr, m);
        }
    }
    best
}

/// Average added latency per event from batching at size `m` vs
/// streaming: `(m−1)/(2ω) + ξ(m) − ξ(1)` (§4.6.1).
pub fn batching_added_latency(m: usize, rate: f64, xi: &XiModel) -> Micros {
    let queue_avg = ((m - 1) as f64 * 1e6 / (2.0 * rate)).round() as Micros;
    queue_avg + xi.xi(m) - xi.xi(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MS, SEC};

    fn cr() -> XiModel {
        XiModel::affine_ms(52.5, 67.5)
    }

    #[test]
    fn paper_cr_example() {
        // §5.2.1: a CR task with budget ~3.65 s fed 13 events/s cannot
        // run b=25 (queueing ~1.9 s + xi(25) = 1.74 s exceeds it) but a
        // high-teens batch fits — matching the paper's observed b = 19.
        let xi = cr();
        // At 25 events the fill+exec total is within ~60 ms of the
        // 3.65 s budget; at a slightly tighter effective slack (the
        // paper counts the full m/omega fill, 1.92 s) it breaks.
        let m = max_stable_batch(13.0, 3_580 * MS, &xi, 25).unwrap();
        assert!((17..=24).contains(&m), "m = {m}");
        // With generous slack the cap returns to b_max.
        assert_eq!(max_stable_batch(13.0, 10 * SEC, &xi, 25), Some(25));
    }

    #[test]
    fn unsustainable_rate_has_no_batch() {
        // At 49 events/s per CR (paper Fig 11a) nothing is stable:
        // even m=25's throughput is 14.3/s.
        let m = max_stable_batch(49.0, 2 * SEC, &cr(), 25);
        // A batch may satisfy deadline constraints transiently, but the
        // sustainable rate is what matters:
        let (w_max, _) = max_stable_rate(30 * SEC, &cr(), 25);
        assert!(w_max < 49.0, "w_max = {w_max}");
        let _ = m;
    }

    #[test]
    fn max_rate_grows_with_slack() {
        let xi = cr();
        let (lo, _) = max_stable_rate(SEC, &xi, 25);
        let (hi, _) = max_stable_rate(10 * SEC, &xi, 25);
        assert!(hi >= lo);
    }

    #[test]
    fn max_rate_uses_larger_batches_for_throughput() {
        let (rate, m) = max_stable_rate(30 * SEC, &cr(), 25);
        assert_eq!(m, 25);
        assert!((rate - 14.36).abs() < 0.1, "rate = {rate}");
    }

    #[test]
    fn streaming_slack_bound() {
        // slack below 2*xi(1): not even streaming is stable.
        assert_eq!(max_stable_batch(1.0, 200 * MS, &cr(), 25), None);
        assert!(max_stable_batch(1.0, 250 * MS, &cr(), 25).is_some());
    }

    #[test]
    fn added_latency_formula() {
        let xi = cr();
        // m=1: no added latency.
        assert_eq!(batching_added_latency(1, 10.0, &xi), 0);
        // m=19 at 13/s: (18/26) s + xi(19)-xi(1)
        let l = batching_added_latency(19, 13.0, &xi);
        let expect = (18.0 * 1e6 / 26.0) as Micros + xi.xi(19) - xi.xi(1);
        assert!((l - expect).abs() <= 1);
    }

    #[test]
    fn batch_bound_monotone_in_rate() {
        // Faster arrivals fill batches quicker: feasible m can only grow
        // with rate (constraint 1 relaxes).
        let xi = cr();
        let slack = 4 * SEC;
        let m_slow = max_stable_batch(2.0, slack, &xi, 25).unwrap();
        let m_fast = max_stable_batch(20.0, slack, &xi, 25).unwrap();
        assert!(m_fast >= m_slow);
    }
}
