//! Runtime tuning strategies (§4 of the paper) — the Tuning Triangle.
//!
//! Three knobs trade off three properties:
//!
//! * **batching** ([`batcher`]) controls latency/throughput,
//! * **dropping** ([`drops`]) controls accuracy under overload,
//! * **tracking logic** (in [`crate::roadnet`]/[`crate::apps`]) controls
//!   the active camera-set size (scalability).
//!
//! Everything here is *pure timestamp logic* — no clocks, no channels —
//! so the discrete-event engine and the live thread-based engine share
//! it unchanged, and the skew-resilience property (§4.6.2) can be tested
//! by feeding the same scenario through skewed observation functions.
//!
//! The multi-query service layer adds a fourth concern: **fairness**
//! across concurrent queries sharing the same executors ([`share`]),
//! and the adaptation plane a fifth: **content quality** — per-camera
//! resolution/variant downshifts that move the accuracy–latency
//! frontier instead of dropping data ([`adapt`]).

pub mod adapt;
pub mod batcher;
pub mod bounds;
pub mod budget;
pub mod drops;
pub mod nob;
pub mod share;
pub mod xi;

pub use adapt::{
    AdaptController, AdaptationCommand, AdaptationState,
    ADAPT_LATENCY_EMA,
};
pub use batcher::{Batcher, BatcherPoll, QueuedEvent};
pub use bounds::{batching_added_latency, max_stable_batch, max_stable_rate};
pub use budget::{BudgetManager, EventRecord, Signal};
pub use drops::{
    drop_at_exec, drop_at_queue, drop_at_transmit, drop_before_exec,
    drop_before_queue, drop_before_transmit,
};
pub use nob::{NobTable, NOB_MAX_RATE, NOB_RATE_STEP};
pub use share::FairShare;
pub use xi::{XiModel, ONLINE_XI_EMA};
