//! Near-Optimal Baseline (NOB) batching (§5.1 Baseline).
//!
//! Built by *prior benchmarking on a stable system*: for input rates
//! 1–1000 events/s (step 10) find the smallest batch size that sustains
//! the rate (service throughput `b/ξ(b)` ≥ rate). At runtime the
//! platform looks up the batch size for the rate closest to the current
//! input rate. Near-optimal under static conditions — and exactly the
//! strategy that destabilizes under runtime variability (Fig 9b).

use super::xi::XiModel;

/// Highest input rate (events/s) the engines' NOB tables cover — the
/// paper benchmarks 1–1000 events/s.
pub const NOB_MAX_RATE: f64 = 1000.0;

/// Rate step (events/s) between NOB table entries.
pub const NOB_RATE_STEP: f64 = 10.0;

/// Rate → batch-size lookup table.
#[derive(Debug, Clone)]
pub struct NobTable {
    /// (rate events/s, batch size), sorted by rate.
    entries: Vec<(f64, usize)>,
}

impl NobTable {
    /// Benchmark-build the table for rates `step, 2·step, …, max_rate`.
    pub fn build(xi: &XiModel, max_rate: f64, step: f64, b_max: usize) -> Self {
        let mut entries = Vec::new();
        let mut rate = step;
        while rate <= max_rate + 1e-9 {
            let b = (1..=b_max)
                .find(|&b| {
                    // throughput(b) = b / xi(b) >= rate
                    b as f64 * 1e6 >= rate * xi.xi(b) as f64
                })
                .unwrap_or(b_max);
            entries.push((rate, b));
            rate += step;
        }
        Self { entries }
    }

    /// Batch size for the table rate closest to `rate`.
    pub fn lookup(&self, rate: f64) -> usize {
        self.entries
            .iter()
            .min_by(|a, b| {
                (a.0 - rate)
                    .abs()
                    .partial_cmp(&(b.0 - rate).abs())
                    .unwrap()
            })
            .map(|&(_, b)| b)
            .unwrap_or(1)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cr() -> XiModel {
        XiModel::affine_ms(52.5, 67.5) // paper CR: mu(1) = 8.33/s
    }

    #[test]
    fn low_rate_streams() {
        let t = NobTable::build(&cr(), 1000.0, 10.0, 25);
        // 8.33/s capacity at b=1 covers a 1-8/s rate... table starts at 10.
        // At 10/s: b=1 gives 8.3/s (insufficient); need larger b.
        assert!(t.lookup(1.0) >= 1);
        assert!(t.lookup(10.0) > 1);
    }

    #[test]
    fn batch_size_monotone_in_rate() {
        let t = NobTable::build(&cr(), 1000.0, 10.0, 25);
        let mut last = 0;
        for r in [10.0, 50.0, 100.0, 200.0, 400.0] {
            let b = t.lookup(r);
            assert!(b >= last, "rate {r} size {b} < {last}");
            last = b;
        }
    }

    #[test]
    fn saturates_at_b_max() {
        let t = NobTable::build(&cr(), 1000.0, 10.0, 25);
        // throughput(25) = 25/1.74s ~ 14.4/s; unsustainable rates cap out.
        assert_eq!(t.lookup(900.0), 25);
    }

    #[test]
    fn smallest_sufficient_batch() {
        let xi = XiModel::affine_ms(100.0, 10.0);
        let t = NobTable::build(&xi, 100.0, 10.0, 32);
        // at 20/s: need b with b/ (0.1+0.01b) >= 20 -> b >= 2/0.8 = 2.5 -> 3
        assert_eq!(t.lookup(20.0), 3);
    }

    #[test]
    fn lookup_picks_nearest_rate() {
        let xi = XiModel::affine_ms(100.0, 10.0);
        let t = NobTable::build(&xi, 100.0, 10.0, 32);
        assert_eq!(t.lookup(14.9), t.lookup(10.0));
        assert_eq!(t.lookup(15.1), t.lookup(20.0));
    }

    #[test]
    fn table_covers_paper_range() {
        let t = NobTable::build(&cr(), 1000.0, 10.0, 25);
        assert_eq!(t.len(), 100); // 10..=1000 step 10
    }
}
