//! ξ(b) — estimated batch execution duration (§4.2).
//!
//! The paper assumes ξ monotonically increases with batch size. We model
//! it as the affine `ξ(b) = α + β·b` (invocation overhead + marginal
//! per-event cost), which matches both the paper's published CR numbers
//! (ξ(1)=120 ms, ξ(25)=1.74 s ⇒ α=52.5 ms, β=67.5 ms) and what we measure
//! from the PJRT executables at calibration ([`XiModel::from_samples`]).
//! An online EMA keeps the estimate fresh under drift.

use crate::util::{Micros, MS};

/// EMA smoothing factor the engines use for online ξ recalibration
/// (`ServiceConfig::online_xi`); matches the live engine's calibration
/// loop so the DES and wall-clock paths drift-track identically.
pub const ONLINE_XI_EMA: f64 = 0.1;

/// Affine batch execution-time model with optional online refinement.
#[derive(Debug, Clone)]
pub struct XiModel {
    alpha: f64, // us
    beta: f64,  // us
    /// EMA smoothing for online observations (0 disables updates).
    ema: f64,
}

impl XiModel {
    /// From α, β in milliseconds.
    pub fn affine_ms(alpha_ms: f64, beta_ms: f64) -> Self {
        Self {
            alpha: alpha_ms * MS as f64,
            beta: beta_ms * MS as f64,
            ema: 0.0,
        }
    }

    /// Enable online EMA refinement with the given smoothing factor.
    pub fn with_ema(mut self, ema: f64) -> Self {
        self.ema = ema;
        self
    }

    /// Least-squares fit of `(batch_size, duration)` calibration samples,
    /// e.g. from timing the PJRT executable per batch bucket.
    pub fn from_samples(samples: &[(usize, Micros)]) -> Self {
        assert!(!samples.is_empty());
        if samples.len() == 1 {
            // Degenerate: attribute everything to the marginal cost.
            let (b, t) = samples[0];
            return Self {
                alpha: 0.0,
                beta: t as f64 / b as f64,
                ema: 0.0,
            };
        }
        let n = samples.len() as f64;
        let sx: f64 = samples.iter().map(|&(b, _)| b as f64).sum();
        let sy: f64 = samples.iter().map(|&(_, t)| t as f64).sum();
        let sxx: f64 = samples.iter().map(|&(b, _)| (b * b) as f64).sum();
        let sxy: f64 =
            samples.iter().map(|&(b, t)| b as f64 * t as f64).sum();
        let denom = n * sxx - sx * sx;
        let beta = if denom.abs() < 1e-9 {
            sy / sx
        } else {
            (n * sxy - sx * sy) / denom
        };
        let alpha = (sy - beta * sx) / n;
        Self {
            alpha: alpha.max(0.0),
            beta: beta.max(1.0),
            ema: 0.0,
        }
    }

    /// Estimated execution duration for a batch of `b` events.
    pub fn xi(&self, b: usize) -> Micros {
        (self.alpha + self.beta * b as f64).round() as Micros
    }

    /// ξ at a *fractional* effective batch size. The multi-query engine
    /// prices a cross-application batch as `α + β·Σᵢ relᵢ` where each
    /// event contributes its app's relative cost multiplier instead of
    /// 1 — for a homogeneous batch of the calibration app this is
    /// bit-identical to [`Self::xi`] (`Σ 1.0` over `b` events is
    /// exactly `b`).
    pub fn xi_eff(&self, b_eff: f64) -> Micros {
        (self.alpha + self.beta * b_eff).round() as Micros
    }

    /// A snapshot of this calibration with both coefficients multiplied
    /// by `factor` — a per-application cost scaling (affine models
    /// scale linearly: `m·ξ(b) = m·α + m·β·b`). The snapshot never
    /// observes online; drift tracking stays with the base model.
    pub fn scaled(&self, factor: f64) -> Self {
        Self {
            alpha: self.alpha * factor,
            beta: self.beta * factor,
            ema: 0.0,
        }
    }

    /// Record an observed `(batch, actual_duration)`; nudges α and β by
    /// splitting the residual between them (EMA).
    pub fn observe(&mut self, b: usize, actual: Micros) {
        self.observe_eff(b as f64, actual);
    }

    /// [`Self::observe`] at a fractional effective batch size (the
    /// cross-application counterpart, paired with [`Self::xi_eff`]).
    pub fn observe_eff(&mut self, b_eff: f64, actual: Micros) {
        if self.ema <= 0.0 || b_eff <= 0.0 {
            return;
        }
        let est = self.alpha + self.beta * b_eff;
        let resid = actual as f64 - est;
        // Attribute residual half to overhead, half to marginal cost.
        self.alpha = (self.alpha + self.ema * resid * 0.5).max(0.0);
        self.beta =
            (self.beta + self.ema * resid * 0.5 / b_eff).max(1.0);
    }

    /// Per-event service capacity at batch size `b` (events/sec).
    pub fn throughput(&self, b: usize) -> f64 {
        b as f64 / (self.xi(b) as f64 / 1e6)
    }

    pub fn alpha_us(&self) -> f64 {
        self.alpha
    }

    pub fn beta_us(&self) -> f64 {
        self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MS;

    #[test]
    fn paper_cr_calibration() {
        let m = XiModel::affine_ms(52.5, 67.5);
        assert_eq!(m.xi(1), 120 * MS);
        assert!((m.xi(25) - 1740 * MS).abs() < MS);
        // mu = 8.33 events/s at b=1 (paper §5.2.1)
        assert!((m.throughput(1) - 8.33).abs() < 0.01);
    }

    #[test]
    fn monotone_in_batch_size() {
        let m = XiModel::affine_ms(20.0, 12.0);
        for b in 1..64 {
            assert!(m.xi(b) < m.xi(b + 1));
        }
    }

    #[test]
    fn batching_amortizes_overhead() {
        let m = XiModel::affine_ms(52.5, 67.5);
        assert!(m.throughput(25) > 1.5 * m.throughput(1));
    }

    #[test]
    fn fit_recovers_affine_model() {
        let truth = XiModel::affine_ms(50.0, 70.0);
        let samples: Vec<(usize, Micros)> =
            [1, 2, 4, 8, 16, 25, 32].iter().map(|&b| (b, truth.xi(b))).collect();
        let fit = XiModel::from_samples(&samples);
        for b in [1, 5, 20, 32] {
            let err = (fit.xi(b) - truth.xi(b)).abs();
            assert!(err <= 2, "b={b} err={err}us");
        }
    }

    #[test]
    fn single_sample_fit_is_proportional() {
        let fit = XiModel::from_samples(&[(4, 400)]);
        assert_eq!(fit.xi(8), 800);
    }

    #[test]
    fn ema_tracks_drift() {
        let mut m = XiModel::affine_ms(50.0, 70.0).with_ema(0.3);
        // Actual service got 2x slower.
        for _ in 0..200 {
            m.observe(10, 2 * (50 * MS + 70 * MS * 10));
        }
        let est = m.xi(10) as f64;
        let target = 2.0 * (50.0 + 700.0) * MS as f64;
        assert!((est - target).abs() / target < 0.15, "est {est}");
    }

    #[test]
    fn scaled_snapshot_is_linear_and_frozen() {
        let m = XiModel::affine_ms(52.5, 67.5).with_ema(0.3);
        let s = m.scaled(1.63);
        for b in [1, 5, 25] {
            assert_eq!(
                s.xi(b),
                ((m.xi(b) as f64) * 1.63).round() as Micros
            );
        }
        // Factor 1.0 is bit-exact (×1.0 is an f64 identity).
        let id = m.scaled(1.0);
        assert_eq!(id.xi(17), m.xi(17));
        // Snapshots never observe.
        let mut s2 = m.scaled(2.0);
        let before = s2.xi(10);
        s2.observe(10, 10 * before);
        assert_eq!(s2.xi(10), before);
    }

    #[test]
    fn xi_eff_matches_xi_at_whole_sizes() {
        let m = XiModel::affine_ms(52.5, 67.5);
        for b in 1..=32usize {
            // Σ of b copies of 1.0 is exactly b — the homogeneous
            // cross-query batch path must price like the count path.
            let mut relsum = 0.0;
            for _ in 0..b {
                relsum += 1.0;
            }
            assert_eq!(m.xi_eff(relsum), m.xi(b));
        }
        // Fractional sizes interpolate the affine model.
        assert_eq!(
            m.xi_eff(2.5),
            (52.5 * MS as f64 + 2.5 * 67.5 * MS as f64).round()
                as Micros
        );
    }

    #[test]
    fn ema_disabled_by_default() {
        let mut m = XiModel::affine_ms(50.0, 70.0);
        let before = m.xi(10);
        m.observe(10, 10 * before);
        assert_eq!(m.xi(10), before);
    }
}
