//! The three drop points (§4.3).
//!
//! An event is *stale* at task τᵢ when its upstream time plus remaining
//! processing exceeds the task's completion budget βᵢ. The three
//! decisions are taken (1) on arrival before queueing, (2) after batch
//! formation before execution, and (3) after execution before transmit —
//! each uses progressively better information about the event's actual
//! processing time, so drops happen just-in-time while still saving the
//! downstream work.
//!
//! All inputs are *observed* timestamps/durations at the deciding task's
//! device; the skew-cancellation argument of §4.6.2 holds because every
//! comparison has the same `-σᵢ` term on both sides (validated by the
//! `prop_tuning` suite).

use crate::util::Micros;

/// Drop point 1 — on arrival, before the input queue.
///
/// Conservative: assumes the fastest possible execution (`xi(1)`) and no
/// queueing. `u` is the observed upstream time `aᵏᵢ − aᵏ₁`; `budget` is
/// βᵢ (use the max across downstream budgets when the destination is not
/// yet known — an event is only *guaranteed* stale if it would miss every
/// path).
pub fn drop_before_queue(u: Micros, xi_1: Micros, budget: Micros) -> bool {
    u + xi_1 > budget
}

/// Drop point 2 — batch formed, before execution.
///
/// `q` is this event's queueing duration so far and `xi_b` the estimated
/// execution time of the formed batch.
pub fn drop_before_exec(
    u: Micros,
    q: Micros,
    xi_b: Micros,
    budget: Micros,
) -> bool {
    u + q + xi_b > budget
}

/// Drop point 3 — after execution, before transmit.
///
/// `pi` is the realized processing duration `q + ξ_actual(b)`. Also the
/// point where the destination task is finally known (the partitioner has
/// run), so `budget` is the per-downstream budget (§4.3.4).
pub fn drop_before_transmit(u: Micros, pi: Micros, budget: Micros) -> bool {
    u + pi > budget
}

// ---------------------------------------------------------------------------
// Exemption-aware gates (§4.3.3 + §4.5.2).
//
// `avoid-drop` events (positive matches the user logic flags) and probe
// events must never be dropped, at any of the three points. Both
// engines and the service layer route every drop decision through these
// gates so the invariant lives in exactly one place (and is property-
// tested in `tests/prop_tuning.rs`).
// ---------------------------------------------------------------------------

/// Drop point 1 with the exemption rule applied.
pub fn drop_at_queue(
    exempt: bool,
    u: Micros,
    xi_1: Micros,
    budget: Micros,
) -> bool {
    let verdict = !exempt && drop_before_queue(u, xi_1, budget);
    crate::strict_assert!(
        !(exempt && verdict),
        "drop point 1 dropped an exempt event"
    );
    verdict
}

/// Drop point 2 with the exemption rule applied.
pub fn drop_at_exec(
    exempt: bool,
    u: Micros,
    q: Micros,
    xi_b: Micros,
    budget: Micros,
) -> bool {
    let verdict = !exempt && drop_before_exec(u, q, xi_b, budget);
    crate::strict_assert!(
        !(exempt && verdict),
        "drop point 2 dropped an exempt event"
    );
    verdict
}

/// Drop point 3 with the exemption rule applied.
pub fn drop_at_transmit(
    exempt: bool,
    u: Micros,
    pi: Micros,
    budget: Micros,
) -> bool {
    let verdict = !exempt && drop_before_transmit(u, pi, budget);
    crate::strict_assert!(
        !(exempt && verdict),
        "drop point 3 dropped an exempt event"
    );
    verdict
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MS, SEC};

    #[test]
    fn point1_conservative() {
        // 14 s upstream + 120 ms best-case exec < 15 s budget: keep.
        assert!(!drop_before_queue(14 * SEC, 120 * MS, 15 * SEC));
        // 14.9 s upstream + 120 ms > 15 s: drop.
        assert!(drop_before_queue(14_900 * MS, 120 * MS, 15 * SEC));
    }

    #[test]
    fn point2_accounts_for_queue_and_batch() {
        let (u, budget) = (10 * SEC, 15 * SEC);
        // 3 s queued + 1.74 s batch exec: 14.74 s < 15 s: keep.
        assert!(!drop_before_exec(u, 3 * SEC, 1_740 * MS, budget));
        // 4 s queued: 15.74 s > 15 s: drop.
        assert!(drop_before_exec(u, 4 * SEC, 1_740 * MS, budget));
    }

    #[test]
    fn point3_uses_realized_time() {
        assert!(!drop_before_transmit(10 * SEC, 4 * SEC, 15 * SEC));
        assert!(drop_before_transmit(10 * SEC, 6 * SEC, 15 * SEC));
    }

    #[test]
    fn exact_budget_boundary_is_kept() {
        // <= budget is *not* stale (strict > in all three).
        assert!(!drop_before_queue(10, 5, 15));
        assert!(!drop_before_exec(5, 5, 5, 15));
        assert!(!drop_before_transmit(10, 5, 15));
    }

    #[test]
    fn points_tighten_monotonically() {
        // Any event dropped at point 1 would also be dropped at 2 and 3
        // given consistent inputs (q, xi_b >= xi_1 ... pi >= q + xi_b).
        let (u, budget, xi1) = (12 * SEC, 15 * SEC, 120 * MS);
        if drop_before_queue(u, xi1, budget) {
            assert!(drop_before_exec(u, 0, xi1, budget));
            assert!(drop_before_transmit(u, xi1, budget));
        }
        // And surviving point 2 with pi == q + xi_b survives point 3.
        let (q, xib) = (1 * SEC, 1 * SEC);
        if !drop_before_exec(u, q, xib, budget) {
            assert!(!drop_before_transmit(u, q + xib, budget));
        }
    }

    #[test]
    fn skew_cancels_in_all_points() {
        // Adding the same skew to both u (via observed arrival) and the
        // budget (which is defined relative to the same clock) leaves
        // every decision unchanged.
        for skew in [-700 * MS, -1, 0, 1, 300 * MS] {
            for (u, q, x, b) in [
                (10 * SEC, 2 * SEC, 1 * SEC, 15 * SEC),
                (14 * SEC, 2 * SEC, 1 * SEC, 15 * SEC),
                (0, 0, 120 * MS, 100 * MS),
            ] {
                assert_eq!(
                    drop_before_queue(u, x, b),
                    drop_before_queue(u - skew, x, b - skew)
                );
                assert_eq!(
                    drop_before_exec(u, q, x, b),
                    drop_before_exec(u - skew, q, x, b - skew)
                );
                assert_eq!(
                    drop_before_transmit(u, q + x, b),
                    drop_before_transmit(u - skew, q + x, b - skew)
                );
            }
        }
    }
}
