//! Completion budgets and their adaptation (§4.5).
//!
//! Each task τᵢ keeps one completion budget βᵢ *per downstream task*
//! (§4.3.4). Budgets shrink when downstream drops an event (reject
//! signal, §4.5.1) and grow when events reach the sink well before γ
//! (accept signal, §4.5.2). The task stores a 3-tuple ⟨dᵏ, qᵏ, mᵏ⟩ per
//! processed event so late signals can be resolved; `min`/`max` against
//! the previous budget makes updates resilient to out-of-order signals.
//!
//! The 3-tuple store is a fixed ring keyed by event id (ids are
//! engine-assigned and monotonically increasing, so a slot collision
//! evicts the record `capacity` ids older — approximately the oldest).
//! No hashing, no per-record allocation, and re-recording an id
//! overwrites in place without evicting an unrelated record.

use super::xi::XiModel;
use crate::util::Micros;

/// "No budget yet" sentinel — far below `i64::MAX` so `u + xi > budget`
/// comparisons cannot overflow.
pub const BUDGET_INF: Micros = i64::MAX / 4;

/// Per-event bookkeeping stored at a task after processing (§4.5).
#[derive(Debug, Clone, Copy)]
pub struct EventRecord {
    /// Departure time `d = u + π` (observed at this task's clock).
    pub departure: Micros,
    /// Queueing duration `q`.
    pub queue: Micros,
    /// Batch size `m` the event executed in.
    pub batch: usize,
    /// Index of the downstream task the event was routed to.
    pub sent_to: usize,
}

/// Budget-adaptation signals travelling upstream from a dropping task
/// (reject) or the sink (accept).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    /// Event `event` was dropped downstream having exceeded its budget by
    /// `eps`; `sum_queue` is Σq over the tasks upstream of the dropper.
    Reject {
        event: u64,
        eps: Micros,
        sum_queue: Micros,
    },
    /// Event `event` (the slowest of its batch) reached the sink `eps`
    /// early; `sum_exec` is Σξ over tasks before the sink.
    Accept {
        event: u64,
        eps: Micros,
        sum_exec: Micros,
    },
}

/// Budget state for one task.
#[derive(Debug)]
pub struct BudgetManager {
    /// Per-downstream budget; `None` until the first signal arrives
    /// (bootstrap: "no budgets assigned", streaming b=1).
    budgets: Vec<Option<Micros>>,
    /// Fixed ring of ⟨event id, 3-tuple⟩ records, indexed by
    /// `id % capacity`. Allocated lazily on the first record so idle
    /// managers (e.g. per-camera FC budgets of inactive cameras) cost
    /// nothing.
    slots: Vec<Option<(u64, EventRecord)>>,
    capacity: usize,
    m_max: usize,
}

impl BudgetManager {
    /// `capacity` bounds the record ring. Ids land in slot
    /// `id % capacity`, so callers whose event ids arrive with a
    /// regular stride (per-camera/per-query managers see ids strided
    /// by the active-camera count) should pick a capacity coprime to
    /// any plausible stride — in practice a prime — or the ring
    /// collapses to `capacity / gcd(stride, capacity)` usable slots.
    pub fn new(n_downstream: usize, m_max: usize, capacity: usize) -> Self {
        Self {
            budgets: vec![None; n_downstream.max(1)],
            slots: Vec::new(),
            capacity: capacity.max(1),
            m_max,
        }
    }

    /// Budget toward a specific downstream task (drop point 3).
    pub fn budget_for(&self, downstream: usize) -> Micros {
        self.budgets
            .get(downstream)
            .copied()
            .flatten()
            .unwrap_or(BUDGET_INF)
    }

    /// Optimistic budget for drop points 1–2, where the destination is
    /// unknown: an event is only *guaranteed* stale if it would miss
    /// every downstream path, so use the max.
    pub fn budget_max(&self) -> Micros {
        self.budgets
            .iter()
            .map(|b| b.unwrap_or(BUDGET_INF))
            .max()
            .unwrap_or(BUDGET_INF)
    }

    /// Smallest initialized budget (used for reporting).
    pub fn budget_min_initialized(&self) -> Option<Micros> {
        self.budgets.iter().copied().flatten().min()
    }

    /// Has any signal initialized a budget yet?
    pub fn initialized(&self) -> bool {
        self.budgets.iter().any(|b| b.is_some())
    }

    /// Store the 3-tuple for a processed event. Bounded: the ring slot
    /// `event % capacity` is overwritten, which evicts the record
    /// exactly `capacity` ids older (ids increase monotonically), and
    /// nothing else — re-recording a live id replaces it in place.
    pub fn record(&mut self, event: u64, rec: EventRecord) {
        if self.slots.is_empty() {
            self.slots.resize(self.capacity, None);
        }
        let idx = (event % self.capacity as u64) as usize;
        // Invariant: a ring overwrite may only evict a record in the
        // same residue class — never a foreign key. (No monotonicity
        // assert here: probes legitimately recycle the id of the drop
        // that spawned them, so an older id can land on a newer one.)
        crate::strict_assert!(
            match &self.slots[idx] {
                Some((old_id, _)) => old_id % self.capacity as u64 == event % self.capacity as u64,
                None => true,
            },
            "budget ring slot {idx} held a foreign key"
        );
        self.slots[idx] = Some((event, rec));
    }

    pub fn get_record(&self, event: u64) -> Option<&EventRecord> {
        let idx = (event % self.capacity as u64) as usize;
        match self.slots.get(idx) {
            Some(Some((id, rec))) if *id == event => Some(rec),
            _ => None,
        }
    }

    /// Apply an upstream-travelling signal. Returns the new budget for
    /// the affected downstream if the event was known.
    pub fn apply(&mut self, sig: Signal, xi: &XiModel) -> Option<Micros> {
        match sig {
            Signal::Reject {
                event,
                eps,
                sum_queue,
            } => {
                let rec = *self.get_record(event)?;
                // λ̄ = min(ε·qᵏ/Σq, ξ(mᵏ) − ξ(1))   (§4.5.1)
                let ratio = if sum_queue > 0 {
                    rec.queue as f64 / sum_queue as f64
                } else {
                    0.0
                };
                let lam = ((eps as f64 * ratio) as Micros)
                    .min(xi.xi(rec.batch) - xi.xi(1))
                    .max(0);
                let cand = rec.departure - lam;
                let slot = &mut self.budgets[rec.sent_to];
                let new = match *slot {
                    // min against the old budget: resilient to
                    // out-of-order reject signals.
                    Some(old) => cand.min(old),
                    // Bootstrap: first signal sets the budget directly.
                    None => cand,
                };
                *slot = Some(new);
                Some(new)
            }
            Signal::Accept {
                event,
                eps,
                sum_exec,
            } => {
                let rec = *self.get_record(event)?;
                // λ⃗ = min(ε·ξ(mᵏ)/Σξ,
                //          (mᵐᵃˣ−mᵏ)·qᵏ/mᵏ + ξ(mᵐᵃˣ) − ξ(mᵏ))  (§4.5.2)
                let xi_m = xi.xi(rec.batch);
                let ratio = if sum_exec > 0 {
                    xi_m as f64 / sum_exec as f64
                } else {
                    1.0
                };
                let headroom = (self.m_max as i64 - rec.batch as i64).max(0)
                    as Micros
                    * (rec.queue / rec.batch.max(1) as Micros)
                    + (xi.xi(self.m_max) - xi_m);
                let lam =
                    ((eps as f64 * ratio) as Micros).min(headroom).max(0);
                let cand = rec.departure + lam;
                let slot = &mut self.budgets[rec.sent_to];
                let new = match *slot {
                    // max against the old budget for out-of-order accepts.
                    Some(old) => cand.max(old),
                    None => cand,
                };
                *slot = Some(new);
                Some(new)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MS, SEC};

    fn xi() -> XiModel {
        XiModel::affine_ms(52.5, 67.5)
    }

    fn rec(departure: Micros, queue: Micros, batch: usize) -> EventRecord {
        EventRecord {
            departure,
            queue,
            batch,
            sent_to: 0,
        }
    }

    #[test]
    fn uninitialized_budget_is_infinite() {
        let b = BudgetManager::new(3, 25, 128);
        assert_eq!(b.budget_max(), BUDGET_INF);
        assert_eq!(b.budget_for(2), BUDGET_INF);
        assert!(!b.initialized());
        // No overflow in a drop check against the sentinel:
        assert!(10 * SEC + 120 * MS < b.budget_max());
    }

    #[test]
    fn reject_shrinks_budget() {
        let mut b = BudgetManager::new(1, 25, 128);
        b.record(7, rec(10 * SEC, 2 * SEC, 10));
        let new = b
            .apply(
                Signal::Reject {
                    event: 7,
                    eps: 1 * SEC,
                    sum_queue: 4 * SEC,
                },
                &xi(),
            )
            .unwrap();
        // λ = min(1s * 2/4, ξ(10)−ξ(1)) = min(500ms, 607.5ms) = 500 ms
        assert_eq!(new, 10 * SEC - 500 * MS);
        assert_eq!(b.budget_for(0), new);
    }

    #[test]
    fn reject_lambda_clamped_by_streaming_floor() {
        let mut b = BudgetManager::new(1, 25, 128);
        b.record(7, rec(10 * SEC, 8 * SEC, 3));
        let new = b
            .apply(
                Signal::Reject {
                    event: 7,
                    eps: 5 * SEC,
                    sum_queue: 8 * SEC,
                },
                &xi(),
            )
            .unwrap();
        // ε·q/Σq = 5 s but ξ(3)−ξ(1) = 135 ms caps the reduction.
        assert_eq!(new, 10 * SEC - 135 * MS);
    }

    #[test]
    fn accept_grows_budget() {
        let mut b = BudgetManager::new(1, 25, 128);
        b.record(9, rec(5 * SEC, 1 * SEC, 5));
        let new = b
            .apply(
                Signal::Accept {
                    event: 9,
                    eps: 4 * SEC,
                    sum_exec: xi().xi(5) * 2,
                },
                &xi(),
            )
            .unwrap();
        // ratio = 1/2 -> 2 s, headroom = 20*(1s/5) + ξ(25)−ξ(5) -> 4 s+
        assert_eq!(new, 5 * SEC + 2 * SEC);
    }

    #[test]
    fn accept_capped_by_max_batch_headroom() {
        let mut b = BudgetManager::new(1, 25, 128);
        b.record(9, rec(5 * SEC, 100 * MS, 25)); // already at m_max
        let new = b
            .apply(
                Signal::Accept {
                    event: 9,
                    eps: 60 * SEC,
                    sum_exec: xi().xi(25),
                },
                &xi(),
            )
            .unwrap();
        // headroom = 0 at m = m_max: budget cannot grow.
        assert_eq!(new, 5 * SEC);
    }

    #[test]
    fn out_of_order_signals_resolve_to_extremes() {
        let mut b = BudgetManager::new(1, 25, 128);
        b.record(1, rec(10 * SEC, SEC, 10));
        b.record(2, rec(12 * SEC, SEC, 10));
        let x = xi();
        // Reject for event 2 (later, larger d) then event 1.
        b.apply(
            Signal::Reject {
                event: 2,
                eps: SEC,
                sum_queue: SEC,
            },
            &x,
        );
        let first = b.budget_for(0);
        b.apply(
            Signal::Reject {
                event: 1,
                eps: SEC,
                sum_queue: SEC,
            },
            &x,
        );
        let second = b.budget_for(0);
        assert!(second <= first, "rejects only shrink");
        // A stale accept cannot shrink it back below.
        b.record(3, rec(2 * SEC, SEC, 1));
        b.apply(
            Signal::Accept {
                event: 3,
                eps: 0,
                sum_exec: x.xi(1),
            },
            &x,
        );
        assert!(b.budget_for(0) >= second);
    }

    #[test]
    fn per_downstream_isolation() {
        let mut b = BudgetManager::new(2, 25, 128);
        b.record(
            1,
            EventRecord {
                departure: 10 * SEC,
                queue: SEC,
                batch: 5,
                sent_to: 1,
            },
        );
        b.apply(
            Signal::Reject {
                event: 1,
                eps: SEC,
                sum_queue: SEC,
            },
            &xi(),
        );
        assert_eq!(b.budget_for(0), BUDGET_INF);
        assert!(b.budget_for(1) < BUDGET_INF);
        assert_eq!(b.budget_max(), BUDGET_INF);
    }

    #[test]
    fn unknown_event_signal_ignored() {
        let mut b = BudgetManager::new(1, 25, 128);
        assert!(b
            .apply(
                Signal::Reject {
                    event: 99,
                    eps: SEC,
                    sum_queue: SEC
                },
                &xi()
            )
            .is_none());
    }

    #[test]
    fn record_capacity_evicts_oldest() {
        let mut b = BudgetManager::new(1, 25, 4);
        for k in 0..6u64 {
            b.record(k, rec(SEC, SEC, 1));
        }
        assert!(b.get_record(0).is_none());
        assert!(b.get_record(1).is_none());
        assert!(b.get_record(5).is_some());
    }

    #[test]
    fn re_recording_an_id_evicts_nothing() {
        // Regression: the old FastMap+VecDeque store at capacity
        // evicted its oldest record even when the inserted id was
        // already present (no growth!), and the replaced id kept a
        // stale slot in the eviction order. The ring overwrites in
        // place.
        let mut b = BudgetManager::new(1, 25, 4);
        for k in 0..4u64 {
            b.record(k, rec(SEC, SEC, 1));
        }
        for _ in 0..10 {
            b.record(2, rec(7 * SEC, 2 * SEC, 5));
        }
        // Every id is still resolvable…
        for k in 0..4u64 {
            assert!(b.get_record(k).is_some(), "id {k} evicted");
        }
        // …and the re-record replaced the live slot.
        let r = b.get_record(2).unwrap();
        assert_eq!(r.departure, 7 * SEC);
        assert_eq!(r.batch, 5);
        // Signals against the refreshed record use the new 3-tuple.
        let new = b
            .apply(
                Signal::Reject {
                    event: 2,
                    eps: SEC,
                    sum_queue: 4 * SEC,
                },
                &xi(),
            )
            .unwrap();
        assert!(new < 7 * SEC);
    }

    #[test]
    fn ring_keyed_lookup_rejects_colliding_ids() {
        // Ids `capacity` apart share a slot: the newer one wins and
        // the older is reported gone (never a wrong record).
        let mut b = BudgetManager::new(1, 25, 4);
        b.record(1, rec(SEC, SEC, 1));
        b.record(5, rec(2 * SEC, SEC, 2)); // 5 % 4 == 1 % 4
        assert!(b.get_record(1).is_none());
        assert_eq!(b.get_record(5).unwrap().departure, 2 * SEC);
    }
}
