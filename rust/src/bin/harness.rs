//! Experiment harness: regenerates every table and figure of the
//! paper's evaluation (§5) from the DES engine.
//!
//! Usage:
//!   harness all                 # every figure, results into ./results
//!   harness fig7 fig9           # selected figures
//!   harness table1              # app compositions
//!   harness mq                  # multi-query service run (beyond the
//!                               # paper: concurrent queries over the
//!                               # shared 1000-camera deployment)
//!   harness compute             # compute dynamism: 4x node slowdown
//!                               # at t=300s, frozen vs online xi on
//!                               # both DES engines (Fig 9's missing
//!                               # half)
//!   harness trace [--smoke]     # flight recorder: run with the JSONL
//!                               # trace sink, schema-validate the
//!                               # trace, reconcile it with the ledger
//!                               # (single- and multi-query engines)
//!                               # and print drop explanations + the
//!                               # hot-path profiling breakdown
//!   harness faults [--smoke]    # fault-injection A/B: node 1 crashes
//!                               # mid-run with recovery on vs off at
//!                               # the same seed; traces of both arms
//!                               # must reconcile (incl. lost_to_fault)
//!                               # and recovery-on must complete
//!                               # strictly more on-time events
//!   harness shard [--smoke]     # sharded-execution A/B: the same
//!                               # seed at K=1, K=4 and K=4 threaded;
//!                               # all three traces must schema-
//!                               # validate and reconcile with their
//!                               # ledgers, and every summary must be
//!                               # bit-identical across the arms
//!   harness adapt [--smoke]     # adaptation-plane A/B: every compute
//!                               # node slows 4x mid-run with the
//!                               # DeepScale-style controller on vs
//!                               # off at the same seed; both traces
//!                               # must reconcile (incl. adaptation
//!                               # commands vs the metrics registry)
//!                               # and controller-on must complete
//!                               # strictly more on-time events
//!   harness lint                # repo-invariant static-analysis pass
//!                               # over rust/src (trace gating,
//!                               # wall-clock bans, map determinism);
//!                               # exits non-zero on any violation
//!   harness --out DIR figN ...  # custom output directory
//!
//! Each figure writes CSV series under the output directory and prints
//! the paper-comparable summary rows to stdout.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anveshak::config::preset;
use anveshak::coordinator::des::{run, RunResult};
use anveshak::dataflow::Stage;
use anveshak::obs::{render_rows, ReportRow};
use anveshak::util::json::{obj, Json};

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let mut out_dir = PathBuf::from("results");
    if let Some(i) = args.iter().position(|a| a == "--out") {
        args.remove(i);
        out_dir = PathBuf::from(args.remove(i));
    }
    let smoke = if let Some(i) =
        args.iter().position(|a| a == "--smoke")
    {
        args.remove(i);
        true
    } else {
        false
    };
    if args.is_empty() || args.iter().any(|a| a == "--help") {
        eprintln!(
            "usage: harness [--out DIR] all|table1|fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|mq|compute|trace|faults|shard|adapt|lint [--smoke] ..."
        );
        std::process::exit(2);
    }
    // `lint` is a standalone pass: no output dir, no run cache, and a
    // process exit code CI can block on.
    if args.iter().any(|a| a == "lint") {
        let report = anveshak::check::lint_repo();
        for v in &report.violations {
            eprintln!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
        }
        if report.is_clean() {
            println!(
                "harness lint: OK ({} files scanned, 0 violations)",
                report.files_scanned
            );
            std::process::exit(0);
        }
        eprintln!(
            "harness lint: {} violation(s) across {} files scanned",
            report.violations.len(),
            report.files_scanned
        );
        std::process::exit(1);
    }
    std::fs::create_dir_all(&out_dir).expect("create output dir");

    let all = args.iter().any(|a| a == "all");
    let want = |name: &str| all || args.iter().any(|a| a == name);

    let mut cache: BTreeMap<String, RunResult> = BTreeMap::new();
    if want("table1") {
        table1();
    }
    if want("fig5") {
        fig5(&out_dir, &mut cache);
    }
    if want("fig6") {
        fig6(&out_dir, &mut cache);
    }
    if want("fig7") {
        fig7(&out_dir, &mut cache);
    }
    if want("fig8") {
        fig8(&out_dir, &mut cache);
    }
    if want("fig9") {
        fig9(&out_dir, &mut cache);
    }
    if want("fig10") {
        fig10(&out_dir, &mut cache);
    }
    if want("fig11") {
        fig11(&out_dir, &mut cache);
    }
    if want("fig12") {
        fig12(&out_dir, &mut cache);
    }
    if want("mq") {
        multi_query(&out_dir);
    }
    if want("compute") {
        compute_dynamism(&out_dir, &mut cache);
    }
    if want("trace") {
        trace(&out_dir, smoke);
    }
    if want("faults") {
        faults(&out_dir, smoke);
    }
    if want("shard") {
        shard(&out_dir, smoke);
    }
    if want("adapt") {
        adapt(&out_dir, smoke);
    }
    println!("\nresults written to {}", out_dir.display());
}

/// Run (and memoize) a preset.
fn get<'a>(
    cache: &'a mut BTreeMap<String, RunResult>,
    name: &str,
) -> &'a RunResult {
    if !cache.contains_key(name) {
        let cfg = preset(name);
        eprintln!("[run] {name} ...");
        let start = std::time::Instant::now();
        let r = run(cfg);
        eprintln!(
            "[run] {name} done in {:.1}s (events: {})",
            start.elapsed().as_secs_f64(),
            r.summary.generated
        );
        cache.insert(name.to_string(), r);
    }
    &cache[name]
}

fn write_timeline(out: &Path, name: &str, r: &RunResult) {
    let mut csv = String::from(
        "sec,active_cameras,mean_latency_s,completed,dropped,va_batch,cr_batch\n",
    );
    for (s, row) in r.timeline.rows().iter().enumerate() {
        let _ = writeln!(
            csv,
            "{s},{},{:.3},{},{},{:.2},{:.2}",
            row.active_cameras,
            row.mean_latency_s,
            row.completed,
            row.dropped,
            row.mean_batch.get(&Stage::Va).copied().unwrap_or(0.0),
            row.mean_batch.get(&Stage::Cr).copied().unwrap_or(0.0),
        );
    }
    std::fs::write(out.join(format!("{name}.csv")), csv).unwrap();
}

fn summary_json(r: &RunResult) -> Json {
    let s = &r.summary;
    obj([
        ("generated", (s.generated as i64).into()),
        ("on_time", (s.on_time as i64).into()),
        ("delayed", (s.delayed as i64).into()),
        ("dropped", (s.dropped as i64).into()),
        ("lost_to_fault", (s.lost_to_fault as i64).into()),
        ("in_flight", (s.in_flight as i64).into()),
        ("median_latency_s", s.latency.median.into()),
        ("p25_latency_s", s.latency.p25.into()),
        ("p75_latency_s", s.latency.p75.into()),
        ("p99_latency_s", s.latency.p99.into()),
        ("max_latency_s", s.latency.max.into()),
        ("detections", (r.detections as i64).into()),
        ("peak_active", r.peak_active.into()),
        ("true_positives", (s.true_positives as i64).into()),
        ("positives_dropped", (s.positives_dropped as i64).into()),
    ])
}

fn print_summary_row(label: &str, r: &RunResult) {
    let s = &r.summary;
    let lost = if s.lost_to_fault > 0 {
        format!("  lost-to-fault {:>6}", s.lost_to_fault)
    } else {
        String::new()
    };
    println!(
        "  {label:<22} gen {:>7}  on-time {:>7}  delayed {:>6} ({:>5.1}%)  dropped {:>6} ({:>5.1}%){lost}  median {:.2}s  p99 {:.2}s  peak-cams {}",
        s.generated,
        s.on_time,
        s.delayed,
        100.0 * s.delay_rate(),
        s.dropped,
        100.0 * s.drop_rate(),
        s.latency.median,
        s.latency.p99,
        r.peak_active
    );
}

// ---------------------------------------------------------------------------

fn table1() {
    println!("== Table 1: module mappings for illustrative tracking apps ==");
    println!("   (App 5 is ours, composed on the public block API)");
    for app in anveshak::apps::all() {
        println!(
            "  {:<22} FC: {:<13} VA: {:<14} ({:<8}) CR: {:<12} ({:<8}) TL: {:<13}{}",
            app.name,
            app.fc_label,
            app.va_label,
            app.va_variant.artifact_name(),
            app.cr_label,
            app.cr_variant.artifact_name(),
            app.tl_label,
            if app.qf_enabled { "  QF: fusion" } else { "" }
        );
    }
}

/// Fig 5: distribution of end-to-end latencies per batching strategy
/// (App 1, TL-BFS es=4; plus TL-WBFS SB-1).
fn fig5(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 5: latency distribution per batching strategy ==");
    let runs = [
        ("SB-1", "fig7a"),
        ("SB-20", "fig7b"),
        ("NOB-25", "fig7c"),
        ("DB-25", "fig7d"),
        ("WBFS SB-1", "fig10_wbfs_sb1"),
    ];
    let mut j = Vec::new();
    for (label, name) in runs {
        let r = get(cache, name);
        let s = &r.summary.latency;
        println!(
            "  {label:<10} median {:.2}s  p25 {:.2}s  p75 {:.2}s  p99 {:.2}s  max {:.2}s",
            s.median, s.p25, s.p75, s.p99, s.max
        );
        j.push(obj([
            ("label", label.into()),
            ("median", s.median.into()),
            ("p25", s.p25.into()),
            ("p75", s.p75.into()),
            ("p99", s.p99.into()),
            ("max", s.max.into()),
        ]));
    }
    std::fs::write(out.join("fig5.json"), Json::Arr(j).to_string())
        .unwrap();
}

/// Fig 6: events <= gamma vs delayed vs dropped, for es = 4/6/7.
fn fig6(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 6a: on-time / delayed / dropped (es = 4 m/s) ==");
    let a = [
        ("SB-1", "fig7a"),
        ("SB-20", "fig7b"),
        ("NOB-25", "fig7c"),
        ("DB-25", "fig7d"),
        ("WBFS SB-1", "fig10_wbfs_sb1"),
        ("Base SB-20 100c", "fig10_base_100"),
        ("Base SB-20 200c", "fig10_base_200"),
    ];
    let mut j = Vec::new();
    for (label, name) in a {
        let r = get(cache, name);
        print_summary_row(label, r);
        j.push(obj([("label", label.into()), ("summary", summary_json(r))]));
    }
    println!("== Fig 6b: es = 6 m/s ==");
    for (label, name) in [
        ("SB-1", "fig6b_sb1"),
        ("SB-20", "fig6b_sb20"),
        ("DB-25", "fig6b_db25"),
    ] {
        let r = get(cache, name);
        print_summary_row(label, r);
        j.push(obj([("label", label.into()), ("summary", summary_json(r))]));
    }
    println!("== Fig 6c: es = 7 m/s ==");
    for (label, name) in [
        ("DB-25", "fig11_nodrops"),
        ("DB-25 Drops", "fig11_drops"),
    ] {
        let r = get(cache, name);
        print_summary_row(label, r);
        j.push(obj([("label", label.into()), ("summary", summary_json(r))]));
    }
    std::fs::write(out.join("fig6.json"), Json::Arr(j).to_string())
        .unwrap();
}

/// Fig 7: application timelines for the four batching strategies.
fn fig7(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 7: timelines (active cams + latency) ==");
    for (panel, name) in [
        ("a-SB1", "fig7a"),
        ("b-SB20", "fig7b"),
        ("c-NOB", "fig7c"),
        ("d-DB25", "fig7d"),
    ] {
        let r = get(cache, name);
        print_summary_row(panel, r);
        write_timeline(out, &format!("fig7{panel}"), r);
    }
}

/// Fig 8: batch-size timelines and latency-vs-batch scatter (DB-25).
fn fig8(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 8: dynamic batch sizes (DB-25) ==");
    let r = get(cache, "fig7d");
    write_timeline(out, "fig8_timeline", r);
    for (stage, label) in [(Stage::Va, "va"), (Stage::Cr, "cr")] {
        let sc = r.timeline.scatter(stage);
        let mut csv = String::from("task_latency_s,batch_size\n");
        let mut max_b = 0;
        for (lat, b) in &sc {
            let _ = writeln!(csv, "{lat:.3},{b}");
            max_b = max_b.max(*b);
        }
        std::fs::write(out.join(format!("fig8_{label}_scatter.csv")), csv)
            .unwrap();
        let mean_b = if sc.is_empty() {
            0.0
        } else {
            sc.iter().map(|&(_, b)| b as f64).sum::<f64>() / sc.len() as f64
        };
        println!(
            "  {label}: {} batches, mean size {:.1}, peak size {}",
            sc.len(),
            mean_b,
            max_b
        );
    }
}

/// Fig 9: 1 Gbps -> 30 Mbps at t = 300 s; Anveshak vs NOB.
fn fig9(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 9: bandwidth drop at t=300s (1Gbps -> 30Mbps) ==");
    for (label, name) in [("Anveshak DB-25", "fig9_anv"), ("NOB-25", "fig9_nob")]
    {
        let r = get(cache, name);
        print_summary_row(label, r);
        // Delays before vs after the bandwidth drop tell the story.
        let rows = r.timeline.rows();
        let (mut pre, mut post) = (0usize, 0usize);
        for (s, row) in rows.iter().enumerate() {
            let late = row.mean_latency_s > 15.0;
            if late {
                if s < 300 {
                    pre += 1
                } else {
                    post += 1
                }
            }
        }
        println!(
            "    seconds with avg latency > gamma: pre-drop {pre}, post-drop {post}"
        );
        write_timeline(out, &format!("fig9_{name}"), r);
    }
}

/// Fig 10: tracking-logic knob (WBFS streaming, Base at 100/200 cams).
fn fig10(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 10: tracking logic effect ==");
    for (label, name) in [
        ("WBFS SB-1", "fig10_wbfs_sb1"),
        ("BFS SB-1", "fig7a"),
        ("Base SB-20 100c", "fig10_base_100"),
        ("Base SB-20 200c", "fig10_base_200"),
    ] {
        let r = get(cache, name);
        print_summary_row(label, r);
        write_timeline(out, &format!("fig10_{name}"), r);
    }
    let wbfs_peak = cache["fig10_wbfs_sb1"].peak_active;
    let bfs_peak = cache["fig7a"].peak_active;
    println!(
        "  peak active cams: WBFS {wbfs_peak} vs BFS {bfs_peak} (paper: 67 vs 111)"
    );
}

/// Fig 11: drops disabled vs enabled at es = 7 m/s.
fn fig11(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 11: drop knob at es = 7 m/s ==");
    for (label, name) in [
        ("drops disabled", "fig11_nodrops"),
        ("drops enabled", "fig11_drops"),
    ] {
        let r = get(cache, name);
        print_summary_row(label, r);
        write_timeline(out, &format!("fig11_{name}"), r);
    }
    let nod = &cache["fig11_nodrops"].summary;
    let wd = &cache["fig11_drops"].summary;
    println!(
        "  delayed: {:.0}% -> {:.0}% | dropped: {:.0}% -> {:.0}% (paper: 85% delayed -> 0%, 17% dropped)",
        100.0 * nod.delay_rate(),
        100.0 * wd.delay_rate(),
        100.0 * nod.drop_rate(),
        100.0 * wd.drop_rate()
    );
}

/// Multi-query service run (beyond the paper): 12 queries arrive as a
/// Poisson process over the 1000-camera roadnet and are multiplexed
/// over the shared VA/CR deployment with admission control and
/// fair-share batching; ≥8 run concurrently at peak. Prints per-query
/// recall/latency rows from the per-query ledgers.
fn multi_query(out: &Path) {
    use anveshak::config::ExperimentConfig;
    use anveshak::coordinator::des::run_multi;

    println!("\n== Multi-query service: 1000-camera roadnet ==");
    let mut cfg = ExperimentConfig::default();
    cfg.name = "mq".into();
    cfg.multi_query.num_queries = 12;
    cfg.multi_query.mean_interarrival_secs = 20.0;
    cfg.multi_query.lifetime_secs = 300.0;
    cfg.multi_query.max_active = 16;
    cfg.multi_query.max_active_cameras = 8_000;
    cfg.multi_query.queue_capacity = 8;

    eprintln!("[run] mq ...");
    let start = std::time::Instant::now();
    let r = run_multi(cfg);
    eprintln!(
        "[run] mq done in {:.1}s (events: {}, peak concurrent: {})",
        start.elapsed().as_secs_f64(),
        r.aggregate.generated,
        r.peak_concurrent
    );

    // One reporting function for every path: per-query rows from the
    // per-query ledgers, the aggregate row straight from the metrics
    // registry snapshot, all through obs::render_rows.
    let mut j = Vec::new();
    let mut rows = Vec::new();
    for q in &r.queries {
        let (gen, on_time, dropped, median, p99) = match &q.summary {
            Some(s) => (
                s.generated,
                s.on_time,
                s.dropped,
                s.latency.median,
                s.latency.p99,
            ),
            None => (0, 0, 0, 0.0, 0.0),
        };
        let row = match &q.summary {
            Some(s) => ReportRow::from_summary(&q.label, s),
            None => ReportRow::new(&q.label),
        };
        rows.push(row.with_extra(format!(
            "{:?} prio {} {:?} recall {:.1}% cams {} fusion {}",
            q.app,
            q.priority,
            q.status,
            100.0 * q.recall(),
            q.peak_active,
            q.fusion_updates
        )));
        j.push(obj([
            ("label", q.label.as_str().into()),
            ("app", format!("{:?}", q.app).as_str().into()),
            ("priority", (q.priority as i64).into()),
            ("status", format!("{:?}", q.status).as_str().into()),
            ("generated", (gen as i64).into()),
            ("on_time", (on_time as i64).into()),
            ("dropped", (dropped as i64).into()),
            ("recall", q.recall().into()),
            ("median_latency_s", median.into()),
            ("p99_latency_s", p99.into()),
            ("peak_active_cams", q.peak_active.into()),
            ("fusion_updates", (q.fusion_updates as i64).into()),
        ]));
    }
    rows.push(
        ReportRow::from_snapshot("aggregate", &r.metrics).with_extra(
            format!(
                "peak concurrent {} | conserved {}",
                r.peak_concurrent,
                r.aggregate.conserved()
            ),
        ),
    );
    print!("{}", render_rows(&rows));
    let doc = obj([
        ("peak_concurrent", r.peak_concurrent.into()),
        ("rejected", (r.rejected as i64).into()),
        ("queued", (r.queued as i64).into()),
        ("queries", Json::Arr(j)),
    ]);
    std::fs::write(out.join("mq.json"), doc.to_string()).unwrap();
}

/// Compute dynamism (Fig 9's missing half): every compute node slows
/// 4x at t = 300 s. A/B of frozen config-time ξ vs the online-ξ
/// calibration loop, on both DES engines — frozen ξ keeps batching
/// and dropping against a cost model 4x too optimistic, online ξ
/// re-estimates and re-tunes within seconds of the step.
fn compute_dynamism(
    out: &Path,
    cache: &mut BTreeMap<String, RunResult>,
) {
    println!(
        "\n== Compute dynamism: 4x node slowdown at t=300s (frozen vs online xi) =="
    );
    for (label, name) in [
        ("DB-25 frozen-xi", "fig9_compute_frozen"),
        ("DB-25 online-xi", "fig9_compute_online"),
    ] {
        let r = get(cache, name);
        print_summary_row(label, r);
        let rows = r.timeline.rows();
        let (mut pre, mut post) = (0usize, 0usize);
        for (s, row) in rows.iter().enumerate() {
            if row.mean_latency_s > 15.0 {
                if s < 300 {
                    pre += 1
                } else {
                    post += 1
                }
            }
        }
        println!(
            "    seconds with avg latency > gamma: pre-slowdown {pre}, post-slowdown {post}"
        );
        write_timeline(out, &format!("compute_{name}"), r);
    }

    // The multi-query engine under the same schedule: 6 concurrent
    // queries over the shared deployment, frozen vs online ξ.
    use anveshak::coordinator::des::run_multi;
    println!("  -- multi-query engine, same slowdown --");
    let mut j = Vec::new();
    let mut rows = Vec::new();
    for (label, name) in [
        ("mq frozen-xi", "fig9_compute_frozen"),
        ("mq online-xi", "fig9_compute_online"),
    ] {
        let mut cfg = preset(name);
        cfg.multi_query.num_queries = 6;
        cfg.multi_query.mean_interarrival_secs = 30.0;
        cfg.multi_query.lifetime_secs = 240.0;
        cfg.multi_query.max_active = 16;
        cfg.multi_query.max_active_cameras = 8_000;
        eprintln!("[run] {name} (mq) ...");
        let start = std::time::Instant::now();
        let r = run_multi(cfg);
        eprintln!(
            "[run] {name} (mq) done in {:.1}s",
            start.elapsed().as_secs_f64()
        );
        let s = &r.aggregate;
        // Same shared reporting function as `harness mq` and the live
        // service: row built from the run's metrics snapshot.
        rows.push(
            ReportRow::from_snapshot(label, &r.metrics).with_extra(
                format!("conserved {}", s.conserved()),
            ),
        );
        j.push(obj([
            ("label", label.into()),
            ("generated", (s.generated as i64).into()),
            ("on_time", (s.on_time as i64).into()),
            ("delayed", (s.delayed as i64).into()),
            ("dropped", (s.dropped as i64).into()),
        ]));
    }
    print!("{}", render_rows(&rows));
    std::fs::write(
        out.join("compute_mq.json"),
        Json::Arr(j).to_string(),
    )
    .unwrap();
}

/// Flight recorder: run one DES preset with the JSONL trace sink,
/// schema-validate the trace, reconcile its counts *exactly* against
/// the run's ledger, and print the human-readable drop explanations
/// plus the stage-attributed wall-clock profiling breakdown.
/// `--smoke` swaps in a 60-camera/60-second config so CI can do all of
/// the above in seconds.
fn trace(out: &Path, smoke: bool) {
    use anveshak::config::ExperimentConfig;
    use anveshak::coordinator::des::run_with_sink;
    use anveshak::obs::{validate_trace, JsonlSink};

    println!("\n== Flight recorder: schema-versioned JSONL trace ==");
    let cfg = if smoke {
        let mut c = ExperimentConfig::default();
        c.name = "trace_smoke".into();
        c.num_cameras = 60;
        c.workload.vertices = 60;
        c.workload.edges = 160;
        c.duration_secs = 60.0;
        c.drops_enabled = true;
        c
    } else {
        preset("fig11_drops")
    };
    let name = cfg.name.clone();
    let path = out.join("trace.jsonl");
    let sink = JsonlSink::create(&path).expect("create trace file");
    eprintln!("[run] trace ({name}) ...");
    let start = std::time::Instant::now();
    let r = run_with_sink(cfg, sink.clone());
    sink.flush();
    eprintln!(
        "[run] trace ({name}) done in {:.1}s ({} trace lines)",
        start.elapsed().as_secs_f64(),
        sink.lines()
    );

    let text =
        std::fs::read_to_string(&path).expect("read trace back");
    let check = match validate_trace(&text) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("trace FAILED schema validation: {e}");
            std::process::exit(1);
        }
    };
    // Exact reconciliation against the run's ledger: the trace is the
    // flight recorder, so every counter it implies must equal what the
    // authoritative accounting saw.
    let s = &r.summary;
    let mut ok = true;
    {
        let mut expect = |what: &str, got: u64, want: u64| {
            if got != want {
                eprintln!(
                    "  MISMATCH {what}: trace {got} != ledger {want}"
                );
                ok = false;
            }
        };
        expect("generated", check.generated, s.generated);
        expect("completed", check.completed, s.on_time + s.delayed);
        expect("on_time", check.on_time, s.on_time);
        expect("dropped", check.dropped_total(), s.dropped);
        expect("lost_to_fault", check.lost_to_fault, s.lost_to_fault);
        expect("in_flight", check.unterminated(), s.in_flight);
        expect("detections", check.detections, r.detections);
    }
    let viol = check.violations();
    if !viol.is_empty() {
        eprintln!(
            "  MISMATCH conservation: {} violation(s), first {:?}",
            viol.len(),
            viol[0]
        );
        ok = false;
    }
    if !ok {
        eprintln!("trace FAILED ledger reconciliation");
        std::process::exit(1);
    }
    println!(
        "  trace OK: {} lines reconcile with the ledger (gen {}, completed {}, dropped {}, in-flight {})",
        check.lines,
        check.generated,
        check.completed,
        check.dropped_total(),
        check.unterminated()
    );

    // Drop explanations (§4.3): where the gates fired, then the first
    // few verdicts spelled out the way a human would ask about them
    // (slack = xi_us - eps_us is what the gate compared against ξ(b)).
    println!(
        "  drops by gate: drain {} | gate1-queue {} | gate2-exec {} | gate3-transmit {} | exemptions {}",
        check.drops_gate[0],
        check.drops_gate[1],
        check.drops_gate[2],
        check.drops_gate[3],
        check.exempted
    );
    let mut shown = 0usize;
    for line in text.lines().skip(1) {
        if shown >= 5 {
            break;
        }
        let Ok(j) = Json::parse(line) else { continue };
        if j.at("ev").as_str() != Some("drop") {
            continue;
        }
        let gate = j.at("gate").as_usize().unwrap_or(0);
        if gate == 0 {
            continue; // drain drops carry no budget arithmetic
        }
        let ev = j.at("event").as_usize().unwrap_or(0);
        let b = j.at("batch").as_usize().unwrap_or(1);
        let eps = j.at("eps_us").as_f64().unwrap_or(0.0);
        let xi = j.at("xi_us").as_f64().unwrap_or(0.0);
        let stage = j.at("stage").as_str().unwrap_or("?");
        println!(
            "    event {ev} dropped at gate {gate} ({stage}): slack {:.1}ms < xi(b={b})={:.1}ms, not exempt",
            (xi - eps) / 1e3,
            xi / 1e3
        );
        shown += 1;
    }
    if check.dropped_total() == 0 {
        println!("    (no drops this run)");
    }

    // Delivery table from the metrics registry — the same rows the
    // multi-query and live paths report through.
    println!("  delivery (metrics registry):");
    print!(
        "{}",
        render_rows(&[ReportRow::from_snapshot(name, &r.metrics)
            .with_extra(format!(
                "xi-observations {}",
                r.metrics.xi_observations
            ))])
    );

    // Stage-attributed wall-clock breakdown from the profiling spans.
    let spans = sink.spans().render();
    if !spans.is_empty() {
        println!("  hot-path wall-clock breakdown:");
        print!("{spans}");
    }

    // The multi-query engine under the same flight recorder: trace a
    // service run and reconcile it against the per-query ledgers'
    // aggregate, exactly as `tests/prop_obs.rs` does.
    println!("  -- multi-query engine, same recorder --");
    let mut mcfg = if smoke {
        let mut c = ExperimentConfig::default();
        c.name = "trace_mq_smoke".into();
        c.num_cameras = 60;
        c.workload.vertices = 60;
        c.workload.edges = 160;
        c.duration_secs = 60.0;
        c.drops_enabled = true;
        c.multi_query.num_queries = 3;
        c.multi_query.mean_interarrival_secs = 5.0;
        c.multi_query.lifetime_secs = 20.0;
        c
    } else {
        let mut c = ExperimentConfig::default();
        c.name = "trace_mq".into();
        c.drops_enabled = true;
        c.multi_query.num_queries = 6;
        c.multi_query.mean_interarrival_secs = 20.0;
        c.multi_query.lifetime_secs = 180.0;
        c.multi_query.max_active_cameras = 8_000;
        c
    };
    mcfg.multi_query.max_active = 8;
    let mname = mcfg.name.clone();
    let mpath = out.join("trace_mq.jsonl");
    let msink = JsonlSink::create(&mpath).expect("create trace file");
    eprintln!("[run] trace ({mname}) ...");
    let start = std::time::Instant::now();
    let mr = anveshak::service::engine::run_with_sink(
        mcfg.clone(),
        mcfg.multi_query.clone(),
        msink.clone(),
    );
    msink.flush();
    eprintln!(
        "[run] trace ({mname}) done in {:.1}s ({} trace lines)",
        start.elapsed().as_secs_f64(),
        msink.lines()
    );
    let mtext =
        std::fs::read_to_string(&mpath).expect("read trace back");
    let mcheck = match validate_trace(&mtext) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("mq trace FAILED schema validation: {e}");
            std::process::exit(1);
        }
    };
    let a = &mr.aggregate;
    let mut ok = true;
    {
        let mut expect = |what: &str, got: u64, want: u64| {
            if got != want {
                eprintln!(
                    "  MISMATCH mq {what}: trace {got} != ledgers {want}"
                );
                ok = false;
            }
        };
        expect("generated", mcheck.generated, a.generated);
        expect("completed", mcheck.completed, a.on_time + a.delayed);
        expect("on_time", mcheck.on_time, a.on_time);
        expect("dropped", mcheck.dropped_total(), a.dropped);
        expect("lost_to_fault", mcheck.lost_to_fault, a.lost_to_fault);
        expect("in_flight", mcheck.unterminated(), a.in_flight);
    }
    let mviol = mcheck.violations();
    if !mviol.is_empty() {
        eprintln!(
            "  MISMATCH mq conservation: {} violation(s), first {:?}",
            mviol.len(),
            mviol[0]
        );
        ok = false;
    }
    if !ok {
        eprintln!("mq trace FAILED ledger reconciliation");
        std::process::exit(1);
    }
    println!(
        "  mq trace OK: {} lines reconcile with {} query ledgers (gen {}, completed {}, dropped {}, in-flight {})",
        mcheck.lines,
        mr.queries.len(),
        mcheck.generated,
        mcheck.completed,
        mcheck.dropped_total(),
        mcheck.unterminated()
    );
}

/// Fault-injection A/B (`harness faults`): the `faults_recovery_on` /
/// `faults_recovery_off` presets differ only in the recovery switch —
/// same seed, same workload, same mid-run permanent crash of compute
/// node 1. Both arms run under the JSONL flight recorder teed into a
/// crash-dump ring; each trace must reconcile exactly with its ledger
/// (including the `lost_to_fault` terminal class), the offered load
/// must be identical across the arms, and recovery-on must complete
/// strictly more on-time events than recovery-off, else exit 1.
/// `--smoke` shrinks to 60 cameras / 60 s with the crash at t = 20 s
/// so CI can run the whole A/B in seconds.
fn faults(out: &Path, smoke: bool) {
    use anveshak::coordinator::des::run_with_sink;
    use anveshak::obs::{validate_trace, JsonlSink, RingSink};

    println!(
        "\n== Fault injection A/B: node 1 crashes mid-run, recovery on vs off =="
    );
    // Crash forensics: buffer the newest trace events in a ring and
    // dump them to stderr if the harness itself dies mid-run — the
    // flight recorder earning its name.
    let ring = RingSink::new(4096);
    ring.install_dump_on_panic();

    let mut results: Vec<(&str, RunResult)> = Vec::new();
    for name in ["faults_recovery_on", "faults_recovery_off"] {
        let mut cfg = preset(name);
        if smoke {
            cfg.num_cameras = 60;
            cfg.workload.vertices = 60;
            cfg.workload.edges = 160;
            cfg.duration_secs = 60.0;
            cfg.service.fault_events[0].at_sec = 20.0;
        }
        let arm = name.trim_start_matches("faults_");
        let path = out.join(format!("faults_{arm}.jsonl"));
        let sink = JsonlSink::create(&path).expect("create trace file");
        eprintln!(
            "[run] {name}{} ...",
            if smoke { " (smoke)" } else { "" }
        );
        let start = std::time::Instant::now();
        let r = run_with_sink(cfg, (sink.clone(), ring.clone()));
        sink.flush();
        eprintln!(
            "[run] {name} done in {:.1}s ({} trace lines)",
            start.elapsed().as_secs_f64(),
            sink.lines()
        );

        let text =
            std::fs::read_to_string(&path).expect("read trace back");
        let check = match validate_trace(&text) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{arm}: trace FAILED schema validation: {e}");
                std::process::exit(1);
            }
        };
        let s = &r.summary;
        let mut ok = true;
        {
            let mut expect = |what: &str, got: u64, want: u64| {
                if got != want {
                    eprintln!(
                        "  MISMATCH {arm} {what}: trace {got} != ledger {want}"
                    );
                    ok = false;
                }
            };
            expect("generated", check.generated, s.generated);
            expect("completed", check.completed, s.on_time + s.delayed);
            expect("on_time", check.on_time, s.on_time);
            expect("dropped", check.dropped_total(), s.dropped);
            expect(
                "lost_to_fault",
                check.lost_to_fault,
                s.lost_to_fault,
            );
            expect("in_flight", check.unterminated(), s.in_flight);
            expect("detections", check.detections, r.detections);
        }
        let viol = check.violations();
        if !viol.is_empty() {
            eprintln!(
                "  MISMATCH {arm} conservation: {} violation(s), first {:?}",
                viol.len(),
                viol[0]
            );
            ok = false;
        }
        if !ok {
            eprintln!("{arm}: trace FAILED ledger reconciliation");
            std::process::exit(1);
        }
        print_summary_row(arm, &r);
        let m = &r.metrics;
        println!(
            "    faults {} | retries {} | redispatched {} | node-restarts {} | trace reconciles ({} lines)",
            m.faults_injected,
            m.fault_retries,
            m.redispatched,
            m.node_restarts,
            check.lines
        );
        results.push((arm, r));
    }

    let on = &results[0].1;
    let off = &results[1].1;
    if on.summary.generated != off.summary.generated {
        eprintln!(
            "FAIL: offered load differs across arms: on {} vs off {}",
            on.summary.generated, off.summary.generated
        );
        std::process::exit(1);
    }
    if on.summary.on_time <= off.summary.on_time {
        eprintln!(
            "FAIL: recovery must strictly help: on-time with recovery {} <= without {}",
            on.summary.on_time, off.summary.on_time
        );
        std::process::exit(1);
    }
    println!(
        "  recovery wins: +{} on-time events, {} fewer lost to faults",
        on.summary.on_time - off.summary.on_time,
        off.summary
            .lost_to_fault
            .saturating_sub(on.summary.lost_to_fault)
    );
    let doc = obj([
        ("smoke", smoke.into()),
        ("recovery_on", summary_json(on)),
        ("recovery_off", summary_json(off)),
    ]);
    std::fs::write(out.join("faults.json"), doc.to_string()).unwrap();
}

/// Sharded-execution A/B (`harness shard`): the same seed runs at
/// K=1, K=4 sequential and K=4 threaded. Every arm runs under the
/// JSONL flight recorder; each trace must schema-validate and
/// reconcile exactly with its ledger (including the `cross_shard`
/// count against the metrics registry), and the merge contract is
/// then enforced across the arms: bit-identical summaries, detections,
/// dispatch counts and RNG draws, zero cross-shard traffic at K=1,
/// non-zero at K=4, and identical cross-shard traffic between the
/// sequential and threaded K=4 arms. Any mismatch exits 1. `--smoke`
/// shrinks to 60 cameras / 60 s so CI runs the whole A/B in seconds.
fn shard(out: &Path, smoke: bool) {
    use anveshak::config::{BatchingKind, ExperimentConfig, TlKind};
    use anveshak::coordinator::des::run_with_sink;
    use anveshak::obs::{validate_trace, JsonlSink};

    println!(
        "\n== Sharded execution A/B: same seed at K=1, K=4, K=4 threaded =="
    );
    let mut results: Vec<(&str, RunResult)> = Vec::new();
    for (arm, shards, threads) in
        [("k1", 1usize, 0usize), ("k4", 4, 0), ("k4_threaded", 4, 4)]
    {
        let mut cfg = ExperimentConfig::default();
        cfg.name = format!("shard_{arm}");
        cfg.tl = TlKind::Base;
        cfg.batching = BatchingKind::Dynamic { max: 25 };
        cfg.drops_enabled = true;
        cfg.sharding.shards = shards;
        cfg.sharding.threads = threads;
        if smoke {
            cfg.num_cameras = 60;
            cfg.workload.vertices = 60;
            cfg.workload.edges = 160;
            cfg.duration_secs = 60.0;
        }
        let path = out.join(format!("shard_{arm}.jsonl"));
        let sink = JsonlSink::create(&path).expect("create trace file");
        eprintln!(
            "[run] shard_{arm}{} ...",
            if smoke { " (smoke)" } else { "" }
        );
        let start = std::time::Instant::now();
        let r = run_with_sink(cfg, sink.clone());
        sink.flush();
        eprintln!(
            "[run] shard_{arm} done in {:.1}s ({} trace lines)",
            start.elapsed().as_secs_f64(),
            sink.lines()
        );

        let text =
            std::fs::read_to_string(&path).expect("read trace back");
        let check = match validate_trace(&text) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{arm}: trace FAILED schema validation: {e}");
                std::process::exit(1);
            }
        };
        let s = &r.summary;
        let mut ok = true;
        {
            let mut expect = |what: &str, got: u64, want: u64| {
                if got != want {
                    eprintln!(
                        "  MISMATCH {arm} {what}: trace {got} != ledger {want}"
                    );
                    ok = false;
                }
            };
            expect("generated", check.generated, s.generated);
            expect("completed", check.completed, s.on_time + s.delayed);
            expect("on_time", check.on_time, s.on_time);
            expect("dropped", check.dropped_total(), s.dropped);
            expect("in_flight", check.unterminated(), s.in_flight);
            expect("detections", check.detections, r.detections);
            expect(
                "cross_shard",
                check.cross_shard,
                r.metrics.cross_shard_msgs,
            );
        }
        let viol = check.violations();
        if !viol.is_empty() {
            eprintln!(
                "  MISMATCH {arm} conservation: {} violation(s), first {:?}",
                viol.len(),
                viol[0]
            );
            ok = false;
        }
        if !ok {
            eprintln!("{arm}: trace FAILED ledger reconciliation");
            std::process::exit(1);
        }
        print_summary_row(arm, &r);
        println!(
            "    shards {} | cross-shard msgs {} | trace reconciles ({} lines)",
            r.metrics.shards, r.metrics.cross_shard_msgs, check.lines
        );
        results.push((arm, r));
    }

    let k1 = &results[0].1;
    let mut ok = true;
    for (arm, r) in &results[1..] {
        if r.summary != k1.summary
            || r.detections != k1.detections
            || r.fusion_updates != k1.fusion_updates
            || r.core_events != k1.core_events
            || r.rng_draws != k1.rng_draws
        {
            eprintln!(
                "FAIL: {arm} diverged from k1: {:?} vs {:?}",
                r.summary, k1.summary
            );
            ok = false;
        }
    }
    if k1.metrics.cross_shard_msgs != 0 {
        eprintln!("FAIL: K=1 recorded cross-shard traffic");
        ok = false;
    }
    let k4 = &results[1].1;
    let k4t = &results[2].1;
    if k4.metrics.cross_shard_msgs == 0 {
        eprintln!("FAIL: K=4 recorded no cross-shard traffic");
        ok = false;
    }
    if k4.metrics.cross_shard_msgs != k4t.metrics.cross_shard_msgs {
        eprintln!(
            "FAIL: threaded K=4 cross-shard traffic {} != sequential {}",
            k4t.metrics.cross_shard_msgs, k4.metrics.cross_shard_msgs
        );
        ok = false;
    }
    if !ok {
        std::process::exit(1);
    }
    println!(
        "  merge contract holds: K=4 bit-identical to K=1 ({} cross-shard msgs, threaded agrees)",
        k4.metrics.cross_shard_msgs
    );
    let doc = obj([
        ("smoke", smoke.into()),
        ("k1", summary_json(k1)),
        ("k4", summary_json(k4)),
        ("k4_threaded", summary_json(k4t)),
        (
            "cross_shard_msgs",
            (k4.metrics.cross_shard_msgs as i64).into(),
        ),
    ]);
    std::fs::write(out.join("shard.json"), doc.to_string()).unwrap();
}

/// Adaptation-plane A/B (`harness adapt`): the `adapt_on` /
/// `adapt_off` presets differ only in the controller switch — same
/// seed, same workload, same mid-run 4x slowdown of every compute
/// node, same resolution ladder. Both arms run under the JSONL flight
/// recorder; each trace must schema-validate and reconcile exactly
/// with its ledger, `adaptation` trace lines must match the metrics
/// registry's applied count (and be absent from the frozen arm), the
/// offered load must be identical across the arms, and controller-on
/// must complete strictly more on-time events than controller-off,
/// else exit 1. `--smoke` shrinks to 60 cameras / 60 s with the
/// slowdown at t = 20 s so CI runs the whole A/B in seconds.
fn adapt(out: &Path, smoke: bool) {
    use anveshak::coordinator::des::run_with_sink;
    use anveshak::obs::{validate_trace, JsonlSink, RingSink};

    println!(
        "\n== Adaptation A/B: 4x compute slowdown mid-run, controller on vs off =="
    );
    let ring = RingSink::new(4096);
    ring.install_dump_on_panic();

    let mut results: Vec<(&str, RunResult)> = Vec::new();
    for name in ["adapt_on", "adapt_off"] {
        let mut cfg = preset(name);
        if smoke {
            cfg.num_cameras = 60;
            cfg.workload.vertices = 60;
            cfg.workload.edges = 160;
            cfg.duration_secs = 60.0;
            cfg.service.compute_events[0].at_sec = 20.0;
        }
        let arm = name.trim_start_matches("adapt_");
        let path = out.join(format!("adapt_{arm}.jsonl"));
        let sink = JsonlSink::create(&path).expect("create trace file");
        eprintln!(
            "[run] {name}{} ...",
            if smoke { " (smoke)" } else { "" }
        );
        let start = std::time::Instant::now();
        let r = run_with_sink(cfg, (sink.clone(), ring.clone()));
        sink.flush();
        eprintln!(
            "[run] {name} done in {:.1}s ({} trace lines)",
            start.elapsed().as_secs_f64(),
            sink.lines()
        );

        let text =
            std::fs::read_to_string(&path).expect("read trace back");
        let check = match validate_trace(&text) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{arm}: trace FAILED schema validation: {e}");
                std::process::exit(1);
            }
        };
        let s = &r.summary;
        let m = &r.metrics;
        let mut ok = true;
        {
            let mut expect = |what: &str, got: u64, want: u64| {
                if got != want {
                    eprintln!(
                        "  MISMATCH {arm} {what}: trace {got} != ledger {want}"
                    );
                    ok = false;
                }
            };
            expect("generated", check.generated, s.generated);
            expect("completed", check.completed, s.on_time + s.delayed);
            expect("on_time", check.on_time, s.on_time);
            expect("dropped", check.dropped_total(), s.dropped);
            expect("in_flight", check.unterminated(), s.in_flight);
            expect("detections", check.detections, r.detections);
            // Every applied command leaves exactly one `adaptation`
            // trace line; the frozen arm must leave none.
            expect("adaptations", check.adaptations, m.adapt_applied);
            if name == "adapt_off" {
                expect("adaptations (frozen)", check.adaptations, 0);
                expect("adapt_minted (frozen)", m.adapt_minted, 0);
            }
        }
        let viol = check.violations();
        if !viol.is_empty() {
            eprintln!(
                "  MISMATCH {arm} conservation: {} violation(s), first {:?}",
                viol.len(),
                viol[0]
            );
            ok = false;
        }
        if !ok {
            eprintln!("{arm}: trace FAILED ledger reconciliation");
            std::process::exit(1);
        }
        print_summary_row(arm, &r);
        println!(
            "    adapt minted {} | applied {} | stale {} | cams downshifted {} | trace reconciles ({} lines)",
            m.adapt_minted,
            m.adapt_applied,
            m.adapt_stale,
            m.cameras_downshifted,
            check.lines
        );
        results.push((arm, r));
    }

    let on = &results[0].1;
    let off = &results[1].1;
    if on.summary.generated != off.summary.generated {
        eprintln!(
            "FAIL: offered load differs across arms: on {} vs off {}",
            on.summary.generated, off.summary.generated
        );
        std::process::exit(1);
    }
    if on.metrics.adapt_minted == 0 {
        eprintln!(
            "FAIL: controller arm never minted a command under the 4x slowdown"
        );
        std::process::exit(1);
    }
    if on.summary.on_time <= off.summary.on_time {
        eprintln!(
            "FAIL: adaptation must strictly help: on-time with controller {} <= without {}",
            on.summary.on_time, off.summary.on_time
        );
        std::process::exit(1);
    }
    println!(
        "  adaptation wins: +{} on-time events ({} commands applied, {} stale discards)",
        on.summary.on_time - off.summary.on_time,
        on.metrics.adapt_applied,
        on.metrics.adapt_stale
    );
    let doc = obj([
        ("smoke", smoke.into()),
        ("adapt_on", summary_json(on)),
        ("adapt_off", summary_json(off)),
        (
            "commands_applied",
            (on.metrics.adapt_applied as i64).into(),
        ),
    ]);
    std::fs::write(out.join("adapt.json"), doc.to_string()).unwrap();
}

/// Fig 12: App 2 (CR ~63% slower) latency distribution, delays, cams.
fn fig12(out: &Path, cache: &mut BTreeMap<String, RunResult>) {
    println!("\n== Fig 12: App 2 (large CR) ==");
    for (label, name) in [
        ("BFS SB-20", "fig12_sb20"),
        ("BFS DB-25", "fig12_db25"),
        ("WBFS SB-20", "fig12_wbfs_sb20"),
        ("BFS DB-25 es6", "fig12_es6_db25"),
        ("BFS DB-25 es6 Drops", "fig12_es6_drops"),
    ] {
        let r = get(cache, name);
        print_summary_row(label, r);
        write_timeline(out, &format!("fig12_{name}"), r);
    }
    // Camera-count comparison App1 vs App2 (both SB-20, BFS).
    let _ = get(cache, "fig7b");
    let a1 = cache["fig7b"].peak_active;
    let a2 = cache["fig12_sb20"].peak_active;
    println!("  peak active cams SB-20: App1 {a1} vs App2 {a2}");
    let mut j = Vec::new();
    for name in [
        "fig12_sb20",
        "fig12_db25",
        "fig12_wbfs_sb20",
        "fig12_es6_db25",
        "fig12_es6_drops",
    ] {
        j.push(obj([
            ("name", name.into()),
            ("summary", summary_json(&cache[name])),
        ]));
    }
    std::fs::write(out.join("fig12.json"), Json::Arr(j).to_string())
        .unwrap();
}
