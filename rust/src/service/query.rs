//! Query registry: the submit / queue / activate / cancel / complete
//! lifecycle of tracking queries.
//!
//! The registry is pure bookkeeping (no clocks, no threads): both the
//! DES multi-query engine and the live service front drive it, and the
//! lifecycle invariants are unit-tested directly.

use std::collections::VecDeque;

use crate::config::AppKind;
use crate::dataflow::QueryId;
use crate::metrics::Summary;
use crate::util::{to_secs, Micros};

/// Scheduling priority of a query; higher is more important. Used both
/// as the fair-share weight (batch slots ∝ priority) and to order the
/// admission wait queue.
pub type Priority = u8;

/// What a user submits: which application to run, where the entity was
/// last seen, and how the service should treat the query.
#[derive(Debug, Clone)]
pub struct QuerySpec {
    /// Application composition (Table 1) the query runs.
    pub app: AppKind,
    /// Human-readable tag for reports.
    pub label: String,
    /// Camera of the last known sighting; `None` bootstraps all-active
    /// (expensive — admission accounts for it).
    pub start_camera: Option<usize>,
    pub priority: Priority,
    /// Tracking window once activated (seconds).
    pub lifetime_secs: f64,
}

impl QuerySpec {
    pub fn new(label: impl Into<String>, start_camera: usize) -> Self {
        Self {
            app: AppKind::App1,
            label: label.into(),
            start_camera: Some(start_camera),
            priority: 1,
            lifetime_secs: 120.0,
        }
    }

    /// Fair-share weight (≥ 1).
    pub fn weight(&self) -> u32 {
        self.priority.max(1) as u32
    }

    /// Cameras this query is expected to activate at admission time: a
    /// seeded query contracts to the sighting neighbourhood; an unseeded
    /// one bootstraps the whole network (§2.3).
    pub fn initial_camera_estimate(&self, total_cameras: usize) -> usize {
        if self.start_camera.is_some() {
            4.min(total_cameras)
        } else {
            total_cameras
        }
    }
}

/// Lifecycle state of a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryStatus {
    /// Submitted, admission decision pending.
    Submitted,
    /// Wait-listed by admission control.
    Queued,
    /// Running over the shared workers.
    Active,
    /// Tracking window elapsed (or explicitly finished).
    Completed,
    /// Cancelled by the user before completion.
    Cancelled,
    /// Refused by admission control.
    Rejected,
}

/// Registry entry for one query.
#[derive(Debug, Clone)]
pub struct QueryRecord {
    pub id: QueryId,
    pub spec: QuerySpec,
    pub status: QueryStatus,
    pub submitted: Micros,
    pub activated: Option<Micros>,
    pub finished: Option<Micros>,
}

/// Submit / cancel / complete bookkeeping for all queries of a service.
#[derive(Debug, Default)]
pub struct QueryRegistry {
    records: Vec<QueryRecord>,
    /// Wait-listed ids, highest priority first (FIFO within a level).
    pending: VecDeque<QueryId>,
    active: Vec<QueryId>,
}

impl QueryRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new query (status [`QueryStatus::Submitted`]); the
    /// caller applies the admission decision next.
    pub fn submit(&mut self, spec: QuerySpec, now: Micros) -> QueryId {
        let id = self.records.len() as QueryId;
        self.records.push(QueryRecord {
            id,
            spec,
            status: QueryStatus::Submitted,
            submitted: now,
            activated: None,
            finished: None,
        });
        id
    }

    fn rec_mut(&mut self, id: QueryId) -> &mut QueryRecord {
        &mut self.records[id as usize]
    }

    pub fn record(&self, id: QueryId) -> Option<&QueryRecord> {
        self.records.get(id as usize)
    }

    pub fn status(&self, id: QueryId) -> Option<QueryStatus> {
        self.record(id).map(|r| r.status)
    }

    pub fn records(&self) -> &[QueryRecord] {
        &self.records
    }

    pub fn active_ids(&self) -> &[QueryId] {
        &self.active
    }

    pub fn num_active(&self) -> usize {
        self.active.len()
    }

    pub fn num_queued(&self) -> usize {
        self.pending.len()
    }

    /// Head of the wait queue (highest priority, earliest submission).
    pub fn next_pending(&self) -> Option<QueryId> {
        self.pending.front().copied()
    }

    /// Transition to Active (from Submitted or Queued).
    pub fn activate(
        &mut self,
        id: QueryId,
        now: Micros,
    ) -> Result<(), &'static str> {
        match self.status(id) {
            Some(QueryStatus::Submitted) | Some(QueryStatus::Queued) => {}
            _ => return Err("only submitted/queued queries can activate"),
        }
        self.pending.retain(|&q| q != id);
        let r = self.rec_mut(id);
        r.status = QueryStatus::Active;
        r.activated = Some(now);
        self.active.push(id);
        Ok(())
    }

    /// Wait-list a submitted query, ordered by (priority desc,
    /// submission order).
    pub fn enqueue(&mut self, id: QueryId) -> Result<(), &'static str> {
        if self.status(id) != Some(QueryStatus::Submitted) {
            return Err("only submitted queries can be wait-listed");
        }
        self.rec_mut(id).status = QueryStatus::Queued;
        let prio = self.records[id as usize].spec.priority;
        let pos = self
            .pending
            .iter()
            .position(|&q| self.records[q as usize].spec.priority < prio)
            .unwrap_or(self.pending.len());
        self.pending.insert(pos, id);
        Ok(())
    }

    /// Admission refused the query outright.
    pub fn reject(
        &mut self,
        id: QueryId,
        now: Micros,
    ) -> Result<(), &'static str> {
        if self.status(id) != Some(QueryStatus::Submitted) {
            return Err("only submitted queries can be rejected");
        }
        let r = self.rec_mut(id);
        r.status = QueryStatus::Rejected;
        r.finished = Some(now);
        Ok(())
    }

    /// An active query's tracking window elapsed.
    pub fn complete(
        &mut self,
        id: QueryId,
        now: Micros,
    ) -> Result<(), &'static str> {
        if self.status(id) != Some(QueryStatus::Active) {
            return Err("only active queries can complete");
        }
        self.active.retain(|&q| q != id);
        let r = self.rec_mut(id);
        r.status = QueryStatus::Completed;
        r.finished = Some(now);
        Ok(())
    }

    /// User-initiated cancellation (allowed while submitted, queued or
    /// active).
    pub fn cancel(
        &mut self,
        id: QueryId,
        now: Micros,
    ) -> Result<(), &'static str> {
        match self.status(id) {
            Some(QueryStatus::Submitted)
            | Some(QueryStatus::Queued)
            | Some(QueryStatus::Active) => {}
            _ => return Err("query is not cancellable"),
        }
        self.pending.retain(|&q| q != id);
        self.active.retain(|&q| q != id);
        let r = self.rec_mut(id);
        r.status = QueryStatus::Cancelled;
        r.finished = Some(now);
        Ok(())
    }
}

/// Per-query outcome of a multi-query run, built from the per-query
/// ledger plus registry state.
#[derive(Debug, Clone)]
pub struct QueryReport {
    pub id: QueryId,
    pub label: String,
    /// Application composition this query ran (its own blocks were
    /// minted from it — concurrent queries may run different apps).
    pub app: AppKind,
    pub priority: Priority,
    pub status: QueryStatus,
    pub submitted_s: f64,
    pub activated_s: Option<f64>,
    pub finished_s: Option<f64>,
    /// Event-level summary from this query's own ledger (None if the
    /// query never generated events — e.g. rejected).
    pub summary: Option<Summary>,
    /// Confirmed detections delivered to this query's UV.
    pub detections: u64,
    /// Peak spotlight size of this query.
    pub peak_active: usize,
    /// Query-embedding refinements performed by this query's own QF
    /// block (0 for non-fusing compositions).
    pub fusion_updates: u64,
}

impl QueryReport {
    pub fn from_record(rec: &QueryRecord) -> Self {
        Self {
            id: rec.id,
            label: rec.spec.label.clone(),
            app: rec.spec.app,
            priority: rec.spec.priority,
            status: rec.status,
            submitted_s: to_secs(rec.submitted),
            activated_s: rec.activated.map(to_secs),
            finished_s: rec.finished.map(to_secs),
            summary: None,
            detections: 0,
            peak_active: 0,
            fusion_updates: 0,
        }
    }

    /// Fraction of ground-truth-positive frames this query completed
    /// with a detection (the per-query recall the acceptance criteria
    /// ask for).
    pub fn recall(&self) -> f64 {
        match &self.summary {
            Some(s) if s.positives_generated > 0 => {
                s.true_positives as f64 / s.positives_generated as f64
            }
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SEC;

    fn spec(prio: Priority) -> QuerySpec {
        QuerySpec {
            priority: prio,
            ..QuerySpec::new("t", 0)
        }
    }

    #[test]
    fn lifecycle_happy_path() {
        let mut r = QueryRegistry::new();
        let id = r.submit(spec(1), 0);
        assert_eq!(r.status(id), Some(QueryStatus::Submitted));
        r.activate(id, SEC).unwrap();
        assert_eq!(r.status(id), Some(QueryStatus::Active));
        assert_eq!(r.num_active(), 1);
        r.complete(id, 10 * SEC).unwrap();
        assert_eq!(r.status(id), Some(QueryStatus::Completed));
        assert_eq!(r.num_active(), 0);
        let rec = r.record(id).unwrap();
        assert_eq!(rec.activated, Some(SEC));
        assert_eq!(rec.finished, Some(10 * SEC));
    }

    #[test]
    fn queued_then_promoted() {
        let mut r = QueryRegistry::new();
        let a = r.submit(spec(1), 0);
        let b = r.submit(spec(1), SEC);
        r.activate(a, 0).unwrap();
        r.enqueue(b).unwrap();
        assert_eq!(r.num_queued(), 1);
        assert_eq!(r.next_pending(), Some(b));
        r.complete(a, 5 * SEC).unwrap();
        r.activate(b, 5 * SEC).unwrap();
        assert_eq!(r.num_queued(), 0);
        assert_eq!(r.active_ids(), &[b]);
    }

    #[test]
    fn pending_ordered_by_priority_then_fifo() {
        let mut r = QueryRegistry::new();
        let lo1 = r.submit(spec(1), 0);
        let hi = r.submit(spec(3), 1);
        let lo2 = r.submit(spec(1), 2);
        for id in [lo1, hi, lo2] {
            r.enqueue(id).unwrap();
        }
        assert_eq!(r.next_pending(), Some(hi));
        r.activate(hi, 0).unwrap();
        assert_eq!(r.next_pending(), Some(lo1));
        r.activate(lo1, 0).unwrap();
        assert_eq!(r.next_pending(), Some(lo2));
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut r = QueryRegistry::new();
        let id = r.submit(spec(1), 0);
        assert!(r.complete(id, 0).is_err(), "complete before activate");
        r.reject(id, 0).unwrap();
        assert!(r.activate(id, 0).is_err(), "activate after reject");
        assert!(r.cancel(id, 0).is_err(), "cancel after reject");
        assert!(r.enqueue(id).is_err(), "queue after reject");

        let id2 = r.submit(spec(1), 0);
        r.activate(id2, 0).unwrap();
        assert!(r.reject(id2, 0).is_err(), "reject after activate");
        r.cancel(id2, SEC).unwrap();
        assert_eq!(r.status(id2), Some(QueryStatus::Cancelled));
        assert!(r.complete(id2, SEC).is_err(), "complete after cancel");
        assert_eq!(r.num_active(), 0);
    }

    #[test]
    fn cancel_removes_from_wait_queue() {
        let mut r = QueryRegistry::new();
        let a = r.submit(spec(1), 0);
        r.enqueue(a).unwrap();
        r.cancel(a, SEC).unwrap();
        assert_eq!(r.num_queued(), 0);
        assert_eq!(r.next_pending(), None);
    }

    #[test]
    fn spec_camera_estimates() {
        let seeded = QuerySpec::new("s", 7);
        assert_eq!(seeded.initial_camera_estimate(1000), 4);
        let unseeded = QuerySpec {
            start_camera: None,
            ..QuerySpec::new("u", 0)
        };
        assert_eq!(unseeded.initial_camera_estimate(1000), 1000);
        assert_eq!(QuerySpec { priority: 0, ..seeded }.weight(), 1);
    }
}
