//! Multi-query tracking service: registry → admission → fair-share
//! scheduling over the *shared* VA/CR workers.
//!
//! The paper's platform runs one tracking query per deployment. The
//! service layer turns that into a multi-tenant system:
//!
//! * [`query`] — the **query registry**: submit / queue / activate /
//!   cancel / complete lifecycle with a per-query [`QuerySpec`] (app
//!   kind, start camera, priority, tracking window).
//! * [`admission`] — **admission control**: new queries are admitted,
//!   wait-listed or rejected based on concurrent-query and aggregate
//!   active-camera limits, so a burst of queries cannot melt the
//!   cluster the way an all-active bootstrap would (§2.3).
//! * [`scheduler`] — the **fair-share batcher**: every VA/CR executor
//!   keeps per-query FIFO queues and composes *cross-query batches*
//!   (one model execution serves frames tagged for different queries)
//!   under weighted deficit-round-robin, so one query collapsing its
//!   completion budget or blowing up its spotlight cannot starve the
//!   rest. Budgets, drops and ledgers stay keyed per query
//!   ([`crate::metrics::QueryLedgers`], per-query
//!   [`crate::tuning::BudgetManager`]s).
//! * [`engine`] — the **multi-query DES mode**: N queries arrive as a
//!   Poisson process over the road network (each tracking its own
//!   entity walk with its own spotlight), multiplexed over one shared
//!   deployment; reachable via [`crate::coordinator::des::run_multi`],
//!   the `harness mq` subcommand and the `multi_query` bench/example.
//! * [`front`] — the **live service front-end**: a wall-clock,
//!   thread-based `TrackingService` that accepts queries *at runtime*
//!   (submit/cancel while serving) over shared workers, scoring
//!   through a pluggable [`front::ScoreBackend`].
//!
//! Like the single-query engines, both service execution paths drive
//! application logic exclusively through the [`crate::dataflow`] UDF
//! traits of an [`crate::apps::AppDefinition`] (engine `with_app` /
//! `run_app`, front `TrackingService::start_with_app`); the `start` /
//! `run` conveniences resolve the stock composition the config
//! describes. Each query runs **its own** composition: `QuerySpec.app`
//! resolves through an [`crate::apps::AppCatalog`] and every admitted
//! query gets its own FC/VA/CR/QF/TL block instances — a heterogeneous
//! many-tenant platform, with the QF → VA/CR feedback edge
//! ([`crate::dataflow::FeedbackRouter`]) closed per query.
//!
//! Mapping to the paper: each query still owns the single-query
//! dataflow semantics (FC → VA → CR → {TL, QF, UV}); the service layer
//! multiplexes many such logical dataflows onto one physical deployment
//! by tagging every event with a [`crate::dataflow::QueryId`], keying
//! the tuning triangle per query, and unioning the per-query spotlights
//! into the physical camera activation set.

pub mod admission;
pub mod engine;
pub mod front;
pub mod query;
pub mod scheduler;

pub use admission::{Admission, AdmissionController, AdmissionPolicy};
pub use engine::{MultiQueryDes, MultiQueryResult};
pub use front::{
    LostWorker, ScoreBackend, ScoreCtx, ServiceReport, SimBackend,
    SupervisorHealth, TrackingService,
};
pub use query::{
    Priority, QueryRecord, QueryRegistry, QueryReport, QuerySpec,
    QueryStatus,
};
pub use scheduler::FairShareBatcher;
