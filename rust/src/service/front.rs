//! Live service front-end: accept tracking queries *at runtime* over
//! shared wall-clock workers.
//!
//! [`TrackingService`] is the multi-tenant counterpart of
//! [`crate::coordinator::live::LiveEngine`]: shared VA/CR worker
//! threads (std threads + mpsc channels, like the live engine) serve
//! every admitted query, composing cross-query batches through the same
//! [`FairShareBatcher`] the DES engine uses. Queries are submitted and
//! cancelled while the service runs; admission control applies the same
//! [`AdmissionController`] policy as the DES mode, and wait-listed
//! queries are promoted when capacity frees up (completion or cancel).
//!
//! Each admitted query runs **its own application**: `QuerySpec.app`
//! resolves through an [`AppCatalog`] and the query's blocks (per-
//! worker VA/CR, sink-side QF, control-plane FC + TL) are minted from
//! that composition — concurrent queries may run different apps over
//! one physical deployment. The sink also closes the §2.2 **feedback
//! loop**: QF refinements are seq-stamped and broadcast to every
//! worker as [`Payload::QueryUpdate`] events, and workers score each
//! query's subsequent batches against its refined embedding.
//!
//! Scoring is pluggable through [`ScoreBackend`]: the bundled
//! [`SimBackend`] scores deterministically from ground-truth labels (so
//! the service layer is fully testable without PJRT), while a
//! PJRT-backed deployment implements the trait over
//! [`crate::runtime::ModelPool`] (one `execute` per per-query group of
//! a batch, since each query carries its own embedding).
//!
//! Batching SLA: every event gets the deadline
//! `min(γ, max_batch_delay)` past its source arrival, which drives both
//! dynamic batch formation and (when drops are enabled) the
//! admission-time drop point. Budget *adaptation* (accept/reject
//! signals) is exercised in the engines; the front keeps the static
//! γ-bound deadline.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::apps::{AppCatalog, AppDefinition};
use crate::config::{AppKind, ExperimentConfig, SemanticsConfig};
use crate::dataflow::{
    boosted_rates, AnalyticsBlock, Event, FeedbackEnvelope,
    FeedbackRouter, FeedbackState, FilterControl, Header,
    ModelVariant, Partitioner, Payload, QueryFusion, QueryId,
    ScoreParams, Stage, TlEnv, TrackingLogic,
};
use crate::metrics::{QueryLedgers, Summary};
use crate::obs::{
    span_begin, span_end, Gate, MetricsRegistry, MetricsSnapshot,
    NullSink, ObsSink, QueryPhase, Scope, TraceEvent,
};
use crate::roadnet::{generate, place_cameras, Camera, Graph};
use crate::service::admission::{
    Admission, AdmissionController, AdmissionPolicy,
};
use crate::service::query::{
    QueryRegistry, QueryReport, QuerySpec, QueryStatus,
};
use crate::service::scheduler::FairShareBatcher;
use crate::sim::{EntityWalk, GroundTruth};
use crate::tuning::adapt::{
    AdaptController, AdaptationCommand, AdaptationState,
};
use crate::tuning::budget::BUDGET_INF;
use crate::tuning::{drop_at_queue, BatcherPoll, QueuedEvent, XiModel};
use crate::util::{millis, secs, FastMap, Micros, SEC};

/// What one scoring call is scoring: the pipeline stage, the *block's*
/// typed model variant (chosen per [`AnalyticsBlock::variant`], not per
/// engine — App 4 runs a re-id model inside VA), the query the group
/// belongs to, and the latest QF-refined embedding the worker has
/// applied for that query (the §2.2 feedback edge; `None` until a
/// refinement arrives).
pub struct ScoreCtx<'a> {
    pub stage: Stage,
    pub variant: ModelVariant,
    pub query: QueryId,
    pub refined: Option<&'a [f32]>,
}

/// Pluggable model execution for the service front.
pub trait ScoreBackend: Send + Sync {
    /// Score every event of one query's group within a batch (one score
    /// per event, higher = better match against this query).
    fn score(&self, ctx: &ScoreCtx<'_>, events: &[Event]) -> Vec<f32> {
        let mut out = Vec::with_capacity(events.len());
        self.score_into(ctx, events, &mut out);
        out
    }

    /// Append one score per event to `out` — the workers score whole
    /// batches into one reusable columnar buffer, so backends should
    /// implement this (the hot variant) and inherit `score`. A
    /// PJRT-backed deployment executes `ctx.variant` against
    /// `ctx.refined` (falling back to the query's bootstrap embedding);
    /// the bundled [`SimBackend`] models the refinement as sharpened
    /// error rates.
    fn score_into(
        &self,
        ctx: &ScoreCtx<'_>,
        events: &[Event],
        out: &mut Vec<f32>,
    );

    /// Service-time model for a stage (drives batching deadlines and
    /// the modelled execution duration).
    fn xi(&self, stage: Stage) -> XiModel;
}

/// Deterministic ground-truth-driven backend: frames carry their
/// per-query truth label, scores follow it with a seeded hash coin.
pub struct SimBackend {
    pub seed: u64,
    /// P(score high | entity present).
    pub tp: f64,
    /// P(score high | entity absent).
    pub fp: f64,
    /// Once a query's embedding has been QF-refined, its residual
    /// error rates shrink by this fraction (`tp ← tp + boost·(1−tp)`,
    /// `fp ← fp·(1−boost)`) — a refinement measurably changes
    /// subsequent scores, deterministically. This is the live-front
    /// counterpart of [`SemanticsConfig::fusion_boost`] (same default);
    /// build the backend with [`SimBackend::from_semantics`] when a
    /// config should govern both engines identically — a bare
    /// `SimBackend::default()` does **not** read the config.
    pub fusion_boost: f64,
    /// VA/CR per-batch service models (small, so tests stay fast).
    pub va_xi: XiModel,
    pub cr_xi: XiModel,
}

impl Default for SimBackend {
    fn default() -> Self {
        Self {
            seed: 2019,
            tp: 0.97,
            fp: 0.01,
            fusion_boost: 0.5,
            va_xi: XiModel::affine_ms(1.0, 0.3),
            cr_xi: XiModel::affine_ms(2.0, 0.5),
        }
    }
}

impl SimBackend {
    /// Calibrate the backend from an experiment's simulated-detection
    /// semantics, so a DES run and a live-front run of the same config
    /// share one set of per-stage error rates and one `fusion_boost`
    /// (a bare `Default` keeps its own fixed rates and ignores the
    /// config).
    pub fn from_semantics(sem: &SemanticsConfig) -> Self {
        Self {
            tp: sem.va_tp.min(sem.cr_tp),
            fp: sem.va_fp.max(sem.cr_fp),
            fusion_boost: sem.fusion_boost,
            ..Self::default()
        }
    }

    /// Per-(event, query, stage) coin — the stage salt makes VA and CR
    /// draws independent, so the pipeline's combined error rates are
    /// tp² / fp², not a single shared draw.
    fn coin(&self, ev: &Event, q: QueryId, stage: Stage) -> f64 {
        let stage_salt = match stage {
            Stage::Cr => 0xC12A_5E0F_u64,
            _ => 0x7A11_D00D_u64,
        };
        let mut h = self.seed
            ^ ev.header.id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (q as u64).wrapping_mul(0xC2B2_AE35)
            ^ stage_salt.wrapping_mul(0x9E37_79B9);
        h ^= h >> 33;
        h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        h ^= h >> 33;
        h as f64 / u64::MAX as f64
    }
}

impl ScoreBackend for SimBackend {
    fn score_into(
        &self,
        ctx: &ScoreCtx<'_>,
        events: &[Event],
        out: &mut Vec<f32>,
    ) {
        // The feedback edge: a refined query scores with sharpened
        // error rates (the shared [`boosted_rates`] model). Same
        // per-event coin either way — only the threshold moves — so
        // exactly the coins that fall between the two thresholds flip,
        // deterministically.
        let (tp, fp) = if ctx.refined.is_some() {
            boosted_rates(self.fusion_boost, self.tp, self.fp)
        } else {
            (self.tp, self.fp)
        };
        out.extend(events.iter().map(|ev| {
            let present = ev.payload.entity_present() == Some(true);
            let p = if present { tp } else { fp };
            if self.coin(ev, ctx.query, ctx.stage) < p {
                0.9
            } else {
                0.1
            }
        }));
    }

    fn xi(&self, stage: Stage) -> XiModel {
        match stage {
            Stage::Cr => self.cr_xi.clone(),
            _ => self.va_xi.clone(),
        }
    }
}

/// Worker/sink inbox messages. `Register` carries the per-(query,
/// worker) analytics block minted from *that query's* app —
/// heterogeneous queries run their own compositions over the shared
/// workers. `RegisterQf` is the sink-side counterpart (one QF block
/// per query).
enum Msg {
    Ev(Event),
    /// `(query, weight, app index, ξ cost multiplier, block)` — the
    /// multiplier is the query's app service cost relative to the
    /// engine default at this worker's stage (exactly 1.0 for the
    /// default app), ported from the DES engines' per-app ξ pricing.
    Register(QueryId, u32, usize, f64, AnalyticsBlock),
    RegisterQf(QueryId, Box<dyn QueryFusion>),
    Deregister(QueryId),
    Stop,
}

/// Per-query runtime state owned by the control plane. Ground truth is
/// behind an `Arc` so the feed loop can snapshot it and compute
/// visibility *outside* the state lock. (The query's FC block lives in
/// the feed thread, not here — FC admission and ground-truth scans
/// both run lock-free on the snapshot.)
struct LiveCtx {
    t0: Micros,
    end: Micros,
    gt: Arc<GroundTruth>,
    tl: Box<dyn TrackingLogic>,
    active_cams: Vec<bool>,
    detections: u64,
    peak_active: usize,
}

/// Control-plane state behind one mutex.
struct State {
    registry: QueryRegistry,
    ledgers: QueryLedgers,
    ctx: Vec<(QueryId, LiveCtx)>,
    /// Camera-budget reservations for queries admitted (phase A) whose
    /// context is still being built outside the lock (phase B) —
    /// counted by [`State::active_cameras_total`] so concurrent
    /// admissions cannot overshoot `max_active_cameras` in the window.
    reserved_cameras: Vec<(QueryId, usize)>,
    finished_stats: Vec<(QueryId, (u64, usize))>,
    /// Per-query QF refinement counts (updated by the sink).
    fusion_counts: FastMap<QueryId, u64>,
    next_event_id: u64,
    peak_concurrent: usize,
}

impl State {
    fn ctx_of(&mut self, q: QueryId) -> Option<&mut LiveCtx> {
        self.ctx
            .iter_mut()
            .find(|(id, _)| *id == q)
            .map(|(_, c)| c)
    }

    fn take_ctx(&mut self, q: QueryId) -> Option<LiveCtx> {
        self.ctx
            .iter()
            .position(|(id, _)| *id == q)
            .map(|i| self.ctx.remove(i).1)
    }

    fn active_cameras_total(&self) -> usize {
        let installed: usize = self
            .ctx
            .iter()
            .map(|(_, c)| c.active_cams.iter().filter(|&&a| a).count())
            .sum();
        let reserved: usize =
            self.reserved_cameras.iter().map(|&(_, n)| n).sum();
        installed + reserved
    }

    fn release_reservation(&mut self, q: QueryId) {
        self.reserved_cameras.retain(|&(id, _)| id != q);
    }
}

struct Inner {
    cfg: ExperimentConfig,
    graph: Graph,
    cams: Vec<Camera>,
    admission: AdmissionController,
    /// Resolves each query's `QuerySpec.app` to the composition whose
    /// blocks it runs (per-query FC/VA/CR/QF/TL instances).
    catalog: AppCatalog,
    /// Query-embedding refinements across all queries (sink-side).
    fusion_updates: AtomicU64,
    /// Latest routed refinement per query `(seq, embedding)` — the
    /// sink's replay table. A restarted worker replays these through
    /// its fresh [`FeedbackState`], whose seq-stamping makes the
    /// re-delivery exactly-once: a stale or duplicate entry is
    /// discarded, a missed one is recovered.
    refinements: Mutex<FastMap<QueryId, (u32, Arc<Vec<f32>>)>>,
    state: Mutex<State>,
    /// Workers whose supervisors exhausted their restart budget — the
    /// backing store for [`SupervisorHealth`]. Pushed (at most once
    /// per worker) from the supervisor thread at give-up time.
    lost_workers: Mutex<Vec<LostWorker>>,
    /// Worker counts per stage, kept so the submit path can tell "some
    /// workers lost" (degraded but serving) from "all workers of a
    /// stage lost" (reject new work).
    n_va: usize,
    n_cr: usize,
    start: Instant,
    stopping: AtomicBool,
    /// Shared trace sink (threads hold the service's `Inner`, so one
    /// dyn handle serves the feed loop, every worker and the sink).
    obs: Arc<dyn ObsSink>,
    /// Always-on counters/gauges/histograms, snapshotable mid-run via
    /// [`TrackingService::metrics_snapshot`].
    metrics: MetricsRegistry,
    /// Adaptation plane: the service-global resolution/variant state.
    /// Every `Payload::Adaptation` delivery lands in
    /// [`Inner::apply_adaptation`] and nowhere else.
    adapt: Mutex<AdaptationState>,
    /// Hoisted [`AdaptController::active`] — when false, every
    /// adaptation hook on this path is a single untaken branch and the
    /// pre-adaptation expressions run unchanged.
    adapt_on: bool,
}

impl Inner {
    fn now_us(&self) -> Micros {
        self.start.elapsed().as_micros() as Micros
    }

    fn supervisor_health(&self) -> SupervisorHealth {
        let lost = self.lost_workers.lock().unwrap().clone();
        if lost.is_empty() {
            SupervisorHealth::AllWorkersLive
        } else {
            SupervisorHealth::Degraded { lost }
        }
    }

    /// The service front's single application point for
    /// [`Payload::Adaptation`] commands: every worker's handler lands
    /// here, and the state's seq-stamped stale discard makes the
    /// per-worker broadcast copies apply exactly once.
    fn apply_adaptation(&self, cmd: &AdaptationCommand, now: Micros) {
        let (applied, down) = {
            let mut ad = self.adapt.lock().unwrap();
            let ok = ad.apply(cmd);
            (ok, ad.downshifted())
        };
        if applied {
            self.metrics.adapt_applied();
            self.metrics.set_cameras_downshifted(down);
            if self.obs.enabled() {
                self.obs.emit(
                    now,
                    &TraceEvent::Adaptation {
                        camera: cmd.camera as u32,
                        seq: cmd.seq,
                        level: cmd.level as u32,
                        variant: cmd.variant.profile().artifact,
                    },
                );
            }
        } else {
            self.metrics.adapt_stale();
        }
    }
}

/// The service's worker/sink inboxes, grouped so registration can mint
/// stage-appropriate per-query blocks (VA workers get VA blocks, CR
/// workers CR blocks, the sink gets the QF block).
#[derive(Clone)]
struct Channels {
    va: Vec<Sender<Msg>>,
    cr: Vec<Sender<Msg>>,
    sink: Sender<Msg>,
}

impl Channels {
    /// Announce a freshly admitted query everywhere, minting one block
    /// per worker from the query's own app. Each worker also learns
    /// the query's ξ cost multiplier at its stage (the app's service
    /// cost relative to the catalog default — the same `stage_rel`
    /// scaling the DES engines price per-app ξ with), so the live gate
    /// and batch pricing charge this query's own composition.
    fn register(
        &self,
        catalog: &AppCatalog,
        kind: AppKind,
        id: QueryId,
        weight: u32,
    ) {
        let app = catalog.get(kind);
        let default = catalog.get(catalog.default_kind());
        let rel_va = app.va_cost / default.va_cost.max(1e-9);
        let rel_cr = app.cr_cost / default.cr_cost.max(1e-9);
        for tx in &self.va {
            let _ = tx.send(Msg::Register(
                id,
                weight,
                kind.index(),
                rel_va,
                AnalyticsBlock::Va(app.make_va()),
            ));
        }
        for tx in &self.cr {
            let _ = tx.send(Msg::Register(
                id,
                weight,
                kind.index(),
                rel_cr,
                AnalyticsBlock::Cr(app.make_cr()),
            ));
        }
        let _ = self.sink.send(Msg::RegisterQf(id, app.make_qf()));
    }

    /// Retire a finished/cancelled query everywhere.
    fn deregister(&self, id: QueryId) {
        for tx in self.va.iter().chain(self.cr.iter()) {
            let _ = tx.send(Msg::Deregister(id));
        }
        let _ = self.sink.send(Msg::Deregister(id));
    }
}

/// Phase A of activation — the registry transition plus worker/sink
/// registration (each worker receives its own block minted from the
/// query's app). Caller holds the state lock; the expensive runtime
/// context ([`build_ctx`]) is deliberately **not** built here, so a
/// submit cannot stall the dataflow behind the lock.
fn admit_locked(
    inner: &Inner,
    st: &mut State,
    channels: &Channels,
    id: QueryId,
    now: Micros,
) {
    st.registry
        .activate(id, now)
        .expect("admission checked the transition");
    st.peak_concurrent =
        st.peak_concurrent.max(st.registry.num_active());
    let spec = st.registry.record(id).unwrap().spec.clone();
    // Hold the projected camera budget until the context is installed,
    // so admissions racing with phase B cannot overshoot the limit.
    st.reserved_cameras.push((
        id,
        spec.initial_camera_estimate(inner.cfg.num_cameras),
    ));
    channels.register(&inner.catalog, spec.app, id, spec.weight());
    inner.metrics.set_active_queries(st.registry.num_active());
    if inner.obs.enabled() {
        inner.obs.emit(
            now,
            &TraceEvent::QueryLifecycle {
                query: id,
                phase: QueryPhase::Activated,
            },
        );
    }
}

/// Phase B — build the query's runtime context (entity walk, ground
/// truth, TL). Lock-free: this is the expensive part of activation.
fn build_ctx(
    inner: &Inner,
    spec: &QuerySpec,
    id: QueryId,
    now: Micros,
) -> LiveCtx {
    let lifetime = secs(spec.lifetime_secs);
    let start_cam = spec
        .start_camera
        .unwrap_or(0)
        .min(inner.cams.len().saturating_sub(1));
    let start_vertex = inner.cams[start_cam].vertex;
    let walk = EntityWalk::simulate(
        &inner.graph,
        start_vertex,
        inner.cfg.workload.entity_speed_mps,
        lifetime + 10 * SEC,
        inner.cfg.seed
            ^ (id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let gt = GroundTruth::compute(
        &inner.graph,
        &inner.cams,
        &walk,
        lifetime + 10 * SEC,
        100_000,
    );
    // The query's own app supplies its TL spotlight (its FC gate is
    // minted by the feed thread).
    let app = inner.catalog.get(spec.app);
    let mut tl = app.make_tl(&TlEnv {
        peak_speed_mps: inner.cfg.tl_peak_speed_mps,
        mean_road_m: inner.cfg.workload.mean_road_m,
        fov_m: inner.cfg.workload.fov_m,
        cameras: &inner.cams,
    });
    tl.on_detection(start_cam, now, true);
    let mut active_set = Vec::new();
    tl.active_set_into(&inner.graph, now, &mut active_set);
    let mut active_cams = vec![false; inner.cfg.num_cameras];
    for cam in &active_set {
        active_cams[*cam] = true;
    }
    let peak = active_set.len();
    LiveCtx {
        t0: now,
        end: now + lifetime,
        gt: Arc::new(gt),
        tl,
        active_cams,
        detections: 0,
        peak_active: peak,
    }
}

/// Phase C — install a built context, unless the query was cancelled
/// in the window between phases (then the context is discarded). The
/// phase-A camera reservation is released either way (the installed
/// context's real spotlight takes over the accounting).
fn install_ctx(inner: &Inner, id: QueryId, ctx: LiveCtx) {
    let mut st = inner.state.lock().unwrap();
    st.release_reservation(id);
    if st.registry.status(id) == Some(QueryStatus::Active)
        && !st.ctx.iter().any(|(q, _)| *q == id)
    {
        st.ctx.push((id, ctx));
    }
}

/// Run phases B+C for a batch of freshly admitted queries (specs
/// snapshotted under the lock, contexts built outside it).
fn finish_activation(
    inner: &Inner,
    admitted: Vec<(QueryId, QuerySpec, Micros)>,
) {
    for (id, spec, now) in admitted {
        let ctx = build_ctx(inner, &spec, id, now);
        install_ctx(inner, id, ctx);
    }
}

/// Promote wait-listed queries while they fit (phase A only). Caller
/// holds the lock and must pass the returned list to
/// [`finish_activation`] *after releasing it*.
#[must_use]
fn promote_locked(
    inner: &Inner,
    st: &mut State,
    channels: &Channels,
    now: Micros,
) -> Vec<(QueryId, QuerySpec, Micros)> {
    let mut admitted = Vec::new();
    while let Some(next) = st.registry.next_pending() {
        let spec = st.registry.record(next).unwrap().spec.clone();
        let decision = inner.admission.decide(
            &spec,
            st.registry.num_active(),
            st.registry.num_queued(),
            st.active_cameras_total(),
            inner.cfg.num_cameras,
        );
        if decision == Admission::Admit {
            admit_locked(inner, st, channels, next, now);
            admitted.push((next, spec, now));
        } else {
            break;
        }
    }
    admitted
}

/// A worker whose supervisor gave up restarting it: its restart
/// budget ([`MAX_WORKER_RESTARTS`]) was exhausted by repeated panics,
/// so the thread exited and its partition is no longer processing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LostWorker {
    /// Stage the worker served (VA or CR).
    pub stage: Stage,
    /// Worker index within its stage.
    pub task: u32,
    /// Restarts consumed before the supervisor gave up.
    pub restarts: u32,
}

/// Typed supervisor state — the PR-7 `worker_restarts` gauge promoted
/// to something callers can branch on. Observable mid-run via
/// [`TrackingService::supervisor_health`] and embedded in the final
/// [`ServiceReport`]; the submit path consults it to reject new work
/// once an entire stage has lost every worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SupervisorHealth {
    /// No worker has exhausted its restart budget.
    AllWorkersLive,
    /// One or more workers gave up; the service still runs but their
    /// partitions are dark (events routed there stay in flight).
    Degraded {
        /// The workers whose supervisors gave up, in give-up order.
        lost: Vec<LostWorker>,
    },
}

impl SupervisorHealth {
    /// Whether any worker has been lost.
    pub fn is_degraded(&self) -> bool {
        matches!(self, SupervisorHealth::Degraded { .. })
    }

    /// Lost workers at `stage` (empty when healthy).
    pub fn lost_at(&self, stage: Stage) -> usize {
        match self {
            SupervisorHealth::AllWorkersLive => 0,
            SupervisorHealth::Degraded { lost } => {
                lost.iter().filter(|w| w.stage == stage).count()
            }
        }
    }
}

/// Final report of a service run.
#[derive(Debug)]
pub struct ServiceReport {
    pub queries: Vec<QueryReport>,
    pub aggregate: Summary,
    pub peak_concurrent: usize,
    /// Query-embedding refinements by the app's QF block.
    pub fusion_updates: u64,
    pub wall_secs: f64,
    /// Final metrics-registry snapshot (also observable mid-run via
    /// [`TrackingService::metrics_snapshot`]).
    pub metrics: MetricsSnapshot,
    /// Supervisor state at shutdown: workers that exhausted their
    /// restart budget mid-run and stopped processing.
    pub supervisor: SupervisorHealth,
}

/// The running multi-query service.
pub struct TrackingService {
    inner: Arc<Inner>,
    /// Worker + sink inboxes, grouped by stage so per-query blocks are
    /// minted stage-appropriately at registration.
    channels: Channels,
    feed: Option<JoinHandle<()>>,
    /// VA and CR worker handles, kept separate so shutdown can be
    /// staged upstream-first (VA flushes into live CR workers).
    va_workers: Vec<JoinHandle<()>>,
    cr_workers: Vec<JoinHandle<()>>,
    sink: Option<JoinHandle<()>>,
    max_batch_delay: Micros,
}

impl TrackingService {
    /// Start the service for the stock application the config
    /// describes (`cfg.app` composition, `cfg.tl` spotlight).
    pub fn start(
        cfg: ExperimentConfig,
        policy: AdmissionPolicy,
        backend: Arc<dyn ScoreBackend>,
    ) -> Result<Self> {
        let app = crate::apps::resolve(&cfg);
        Self::start_with_app(cfg, policy, backend, &app)
    }

    /// Start the service with an explicit trace sink — the
    /// flight-recorder entry point for the live path.
    pub fn start_with_sink(
        cfg: ExperimentConfig,
        policy: AdmissionPolicy,
        backend: Arc<dyn ScoreBackend>,
        sink: Arc<dyn ObsSink>,
    ) -> Result<Self> {
        let app = crate::apps::resolve(&cfg);
        Self::start_inner(cfg, policy, backend, &app, sink)
    }

    /// Start the shared workers and the feed loop for an arbitrary
    /// [`AppDefinition`]; returns immediately. `cfg` describes the
    /// camera network and worker counts; queries are then submitted at
    /// runtime. Every admitted query gets its **own** blocks minted
    /// from *its* app (`QuerySpec.app` resolved through an
    /// [`AppCatalog`] whose default is `app`): per-worker VA/CR
    /// blocks, a sink-side QF, and per-query FC + TL in the control
    /// plane — concurrent queries may run different compositions.
    pub fn start_with_app(
        cfg: ExperimentConfig,
        policy: AdmissionPolicy,
        backend: Arc<dyn ScoreBackend>,
        app: &AppDefinition,
    ) -> Result<Self> {
        Self::start_inner(cfg, policy, backend, app, Arc::new(NullSink))
    }

    fn start_inner(
        cfg: ExperimentConfig,
        policy: AdmissionPolicy,
        backend: Arc<dyn ScoreBackend>,
        app: &AppDefinition,
        obs: Arc<dyn ObsSink>,
    ) -> Result<Self> {
        let graph = generate(&cfg.workload, cfg.seed);
        let cams = place_cameras(
            &graph,
            cfg.num_cameras,
            0,
            cfg.workload.fov_m,
        );
        let catalog =
            AppCatalog::new(app.clone(), cfg.app, cfg.tl);
        let n_va = cfg.cluster.va_instances.clamp(1, 4);
        let n_cr = cfg.cluster.cr_instances.clamp(1, 4);
        // Adaptation plane: the sink-side controller mints
        // resolution/variant commands from completion slack; the
        // shared state applies them (exactly once per seq) and prices
        // every gate/batch under the commanded rung.
        let adapt_ctl = AdaptController::new(
            &cfg.adaptation,
            cfg.num_cameras,
            cfg.gamma(),
            app.cr_variant,
        );
        let adapt_on = adapt_ctl.active();
        let adapt = Mutex::new(AdaptationState::new(
            &cfg.adaptation,
            cfg.num_cameras,
        ));
        let inner = Arc::new(Inner {
            admission: AdmissionController::new(policy),
            catalog,
            fusion_updates: AtomicU64::new(0),
            refinements: Mutex::new(FastMap::default()),
            state: Mutex::new(State {
                registry: QueryRegistry::new(),
                ledgers: QueryLedgers::new(),
                ctx: Vec::new(),
                reserved_cameras: Vec::new(),
                finished_stats: Vec::new(),
                fusion_counts: FastMap::default(),
                next_event_id: 0,
                peak_concurrent: 0,
            }),
            lost_workers: Mutex::new(Vec::new()),
            n_va,
            n_cr,
            start: Instant::now(),
            stopping: AtomicBool::new(false),
            graph,
            cams,
            cfg,
            obs,
            metrics: MetricsRegistry::new(),
            adapt,
            adapt_on,
        });
        let cfg = &inner.cfg;
        let max_batch_delay = millis(250.0).min(cfg.gamma());

        let va_part = Partitioner::new(n_va);
        let cr_part = Partitioner::new(n_cr);

        let (sink_tx, sink_rx) = mpsc::channel::<Msg>();

        // CR workers → sink. Each worker's *default* block (late
        // events of already-retired queries) comes from the default
        // app; per-query blocks arrive via Msg::Register.
        let mut cr_tx = Vec::new();
        let mut cr_workers = Vec::new();
        for wi in 0..n_cr {
            let (tx, rx) = mpsc::channel::<Msg>();
            cr_tx.push(tx);
            let out = sink_tx.clone();
            let inner_c = Arc::clone(&inner);
            let backend_c = Arc::clone(&backend);
            let delay = max_batch_delay;
            cr_workers.push(std::thread::spawn(move || {
                supervised_worker(
                    Stage::Cr,
                    wi as u32,
                    rx,
                    inner_c,
                    backend_c,
                    delay,
                    {
                        move |ev| {
                            let _ = out.send(Msg::Ev(ev));
                        }
                    },
                );
            }));
        }

        // VA workers → CR workers.
        let mut va_tx = Vec::new();
        let mut va_workers = Vec::new();
        for wi in 0..n_va {
            let (tx, rx) = mpsc::channel::<Msg>();
            va_tx.push(tx);
            let crs = cr_tx.clone();
            let inner_c = Arc::clone(&inner);
            let backend_c = Arc::clone(&backend);
            let delay = max_batch_delay;
            va_workers.push(std::thread::spawn(move || {
                supervised_worker(
                    Stage::Va,
                    wi as u32,
                    rx,
                    inner_c,
                    backend_c,
                    delay,
                    {
                        move |ev| {
                            let _ = crs[cr_part.route(ev.header.camera)]
                                .send(Msg::Ev(ev));
                        }
                    },
                );
            }));
        }

        let channels = Channels {
            va: va_tx.clone(),
            cr: cr_tx.clone(),
            sink: sink_tx,
        };

        // Sink thread: completion accounting + TL updates + per-query
        // QF, broadcasting refinements back to every worker (the
        // feedback edge).
        let sink = {
            let inner_c = Arc::clone(&inner);
            let workers: Vec<Sender<Msg>> = va_tx
                .iter()
                .chain(cr_tx.iter())
                .cloned()
                .collect();
            std::thread::spawn(move || {
                sink_loop(inner_c, sink_rx, workers, adapt_ctl)
            })
        };

        // Feed thread: per-query FC gating, frame generation, expiry,
        // spotlight refresh, wait-queue promotion.
        let feed = {
            let inner_c = Arc::clone(&inner);
            let vas = va_tx.clone();
            let chans = channels.clone();
            std::thread::spawn(move || {
                feed_loop(inner_c, vas, va_part, chans)
            })
        };

        Ok(Self {
            inner,
            channels,
            feed: Some(feed),
            va_workers,
            cr_workers,
            sink: Some(sink),
            max_batch_delay,
        })
    }

    /// Submit a query; admission control admits, wait-lists or rejects
    /// it. Returns the query id and its initial status.
    pub fn submit(
        &self,
        spec: QuerySpec,
    ) -> Result<(QueryId, QueryStatus)> {
        // A stage whose every worker exhausted its restart budget can
        // no longer process frames at all — reject new work with a
        // typed error instead of admitting queries that would starve.
        {
            let health = self.inner.supervisor_health();
            let lost_va = health.lost_at(Stage::Va);
            let lost_cr = health.lost_at(Stage::Cr);
            if lost_va >= self.inner.n_va || lost_cr >= self.inner.n_cr {
                return Err(anyhow!(
                    "supervisor restart budget exhausted: \
                     {lost_va}/{} VA and {lost_cr}/{} CR workers \
                     lost; service cannot accept new queries",
                    self.inner.n_va,
                    self.inner.n_cr
                ));
            }
        }
        let now = self.inner.now_us();
        let mut st = self.inner.state.lock().unwrap();
        let id = st.registry.submit(spec.clone(), now);
        let decision = self.inner.admission.decide(
            &spec,
            st.registry.num_active(),
            st.registry.num_queued(),
            st.active_cameras_total(),
            self.inner.cfg.num_cameras,
        );
        if self.inner.obs.enabled() {
            self.inner.obs.emit(
                now,
                &TraceEvent::QueryLifecycle {
                    query: id,
                    phase: QueryPhase::Submitted,
                },
            );
        }
        match decision {
            Admission::Admit => {
                admit_locked(
                    &self.inner,
                    &mut st,
                    &self.channels,
                    id,
                    now,
                );
                drop(st);
                // Expensive context construction happens outside the
                // lock so concurrent tenants keep flowing.
                let ctx = build_ctx(&self.inner, &spec, id, now);
                install_ctx(&self.inner, id, ctx);
                Ok((id, QueryStatus::Active))
            }
            Admission::Queue => {
                st.registry.enqueue(id).map_err(|e| anyhow!(e))?;
                if self.inner.obs.enabled() {
                    self.inner.obs.emit(
                        now,
                        &TraceEvent::QueryLifecycle {
                            query: id,
                            phase: QueryPhase::Queued,
                        },
                    );
                }
                Ok((id, QueryStatus::Queued))
            }
            Admission::Reject(_reason) => {
                st.registry.reject(id, now).map_err(|e| anyhow!(e))?;
                if self.inner.obs.enabled() {
                    self.inner.obs.emit(
                        now,
                        &TraceEvent::QueryLifecycle {
                            query: id,
                            phase: QueryPhase::Rejected,
                        },
                    );
                }
                Ok((id, QueryStatus::Rejected))
            }
        }
    }

    /// Point-in-time snapshot of the service's metrics registry —
    /// observable while the service is running (counters are plain
    /// atomics; no lock is taken and no worker is stalled).
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.inner.metrics.snapshot()
    }

    /// Typed supervisor state: which workers (if any) exhausted their
    /// restart budget and stopped processing. Observable mid-run (no
    /// worker is stalled — only the give-up path takes this lock);
    /// the final value is embedded in [`ServiceReport::supervisor`].
    pub fn supervisor_health(&self) -> SupervisorHealth {
        self.inner.supervisor_health()
    }

    /// Cancel a submitted/queued/active query; frees its capacity and
    /// promotes wait-listed queries.
    pub fn cancel(&self, id: QueryId) -> Result<()> {
        let now = self.inner.now_us();
        let mut st = self.inner.state.lock().unwrap();
        st.registry.cancel(id, now).map_err(|e| anyhow!(e))?;
        st.release_reservation(id);
        self.inner
            .metrics
            .set_active_queries(st.registry.num_active());
        if self.inner.obs.enabled() {
            self.inner.obs.emit(
                now,
                &TraceEvent::QueryLifecycle {
                    query: id,
                    phase: QueryPhase::Cancelled,
                },
            );
        }
        if let Some(ctx) = st.take_ctx(id) {
            st.finished_stats
                .push((id, (ctx.detections, ctx.peak_active)));
        }
        self.channels.deregister(id);
        let admitted =
            promote_locked(&self.inner, &mut st, &self.channels, now);
        drop(st);
        finish_activation(&self.inner, admitted);
        Ok(())
    }

    /// Current lifecycle status of a query.
    pub fn status(&self, id: QueryId) -> Option<QueryStatus> {
        self.inner.state.lock().unwrap().registry.status(id)
    }

    /// The service's batching-delay cap (µs).
    pub fn max_batch_delay(&self) -> Micros {
        self.max_batch_delay
    }

    /// Stop the service, join every thread and build the final report.
    ///
    /// Shutdown is staged upstream-first: feed, then VA workers (whose
    /// final flush lands in still-running CR workers), then CR workers
    /// (flushing into the still-running sink), then the sink — so no
    /// in-flight event is silently lost and per-query conservation
    /// holds in the report.
    pub fn stop(mut self) -> ServiceReport {
        self.inner.stopping.store(true, Ordering::SeqCst);
        if let Some(h) = self.feed.take() {
            let _ = h.join();
        }
        for tx in &self.channels.va {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.va_workers.drain(..) {
            let _ = h.join();
        }
        for tx in &self.channels.cr {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.cr_workers.drain(..) {
            let _ = h.join();
        }
        let _ = self.channels.sink.send(Msg::Stop);
        if let Some(h) = self.sink.take() {
            let _ = h.join();
        }
        let wall = self.inner.start.elapsed().as_secs_f64();
        let fusion_updates =
            self.inner.fusion_updates.load(Ordering::Relaxed);
        let st = self.inner.state.lock().unwrap();
        let mut queries = Vec::new();
        for rec in st.registry.records() {
            let mut r = QueryReport::from_record(rec);
            r.summary = st.ledgers.summary(rec.id);
            r.fusion_updates = st
                .fusion_counts
                .get(&rec.id)
                .copied()
                .unwrap_or(0);
            if let Some((_, (d, p))) = st
                .finished_stats
                .iter()
                .find(|(q, _)| *q == rec.id)
            {
                r.detections = *d;
                r.peak_active = *p;
            } else if let Some((_, ctx)) =
                st.ctx.iter().find(|(q, _)| *q == rec.id)
            {
                r.detections = ctx.detections;
                r.peak_active = ctx.peak_active;
            }
            queries.push(r);
        }
        ServiceReport {
            queries,
            aggregate: st.ledgers.aggregate(),
            peak_concurrent: st.peak_concurrent,
            fusion_updates,
            wall_secs: wall,
            metrics: self.inner.metrics.snapshot(),
            supervisor: self.inner.supervisor_health(),
        }
    }
}

/// Frame generation: one event per (active query, active camera) that
/// the query's own FC block admits, at the configured fps; also
/// expires elapsed queries (promoting wait-listed ones) and refreshes
/// per-query spotlights. The per-query FC blocks live *in this
/// thread* (minted from each query's app on first sight, dropped when
/// the query disappears), so both FC admission and the ground-truth
/// visibility scan — the O(queries × cameras) work — run lock-free on
/// a snapshot; the state lock is held only for spotlight refresh and
/// bookkeeping.
fn feed_loop(
    inner: Arc<Inner>,
    va_tx: Vec<Sender<Msg>>,
    va_part: Partitioner,
    channels: Channels,
) {
    let cfg = &inner.cfg;
    let period = Duration::from_micros((1e6 / cfg.fps.max(0.1)) as u64);
    let mut frame_no: u64 = 0;
    let mut active_buf: Vec<usize> = Vec::new();
    // Adaptation plane: per-camera frame strides, snapshotted once per
    // tick so the lock-free FC/visibility pass stays lock-free.
    let mut strides: Vec<u64> = vec![1; cfg.num_cameras];
    // Each query's FC block — feed-thread-owned.
    let mut fcs: FastMap<QueryId, Box<dyn FilterControl>> =
        FastMap::default();
    let mut next_fire = Instant::now();
    while !inner.stopping.load(Ordering::SeqCst) {
        let iter_sp = span_begin(&*inner.obs);
        let now = inner.now_us();
        let mut outgoing: Vec<Event> = Vec::new();
        let mut admitted = Vec::new();
        // Per query: (id, app kind, t0, ground truth, activation
        // flags) — everything the lock-free FC/visibility pass needs.
        let mut snapshots: Vec<(
            QueryId,
            crate::config::AppKind,
            Micros,
            Arc<GroundTruth>,
            Vec<bool>,
        )> = Vec::new();
        {
            let mut st = inner.state.lock().unwrap();
            // Expire elapsed queries.
            let expired: Vec<QueryId> = st
                .ctx
                .iter()
                .filter(|(_, c)| now >= c.end)
                .map(|(q, _)| *q)
                .collect();
            for q in &expired {
                let _ = st.registry.complete(*q, now);
                if let Some(ctx) = st.take_ctx(*q) {
                    st.finished_stats.push((
                        *q,
                        (ctx.detections, ctx.peak_active),
                    ));
                }
                channels.deregister(*q);
                if inner.obs.enabled() {
                    inner.obs.emit(
                        now,
                        &TraceEvent::QueryLifecycle {
                            query: *q,
                            phase: QueryPhase::Completed,
                        },
                    );
                }
            }
            if !expired.is_empty() {
                inner
                    .metrics
                    .set_active_queries(st.registry.num_active());
                admitted =
                    promote_locked(&inner, &mut st, &channels, now);
            }
            // Refresh spotlights and snapshot what the lock-free pass
            // needs.
            let mut cams_total = 0usize;
            for (q, ctx) in st.ctx.iter_mut() {
                let prior = if inner.obs.enabled() {
                    ctx.active_cams.iter().filter(|&&a| a).count()
                } else {
                    usize::MAX
                };
                let sp = span_begin(&*inner.obs);
                ctx.tl.active_set_into(
                    &inner.graph,
                    now,
                    &mut active_buf,
                );
                span_end(&*inner.obs, Scope::SpotlightExpand, sp);
                ctx.peak_active =
                    ctx.peak_active.max(active_buf.len());
                for a in ctx.active_cams.iter_mut() {
                    *a = false;
                }
                for &cam in &active_buf {
                    ctx.active_cams[cam] = true;
                }
                cams_total += active_buf.len();
                if inner.obs.enabled() && active_buf.len() != prior {
                    inner.obs.emit(
                        now,
                        &TraceEvent::Spotlight {
                            query: *q,
                            active: active_buf.len() as u32,
                        },
                    );
                }
            }
            inner.metrics.set_active_cameras(cams_total);
            for (q, ctx) in st.ctx.iter() {
                let kind = st
                    .registry
                    .record(*q)
                    .map(|r| r.spec.app)
                    .unwrap_or(inner.catalog.default_kind());
                snapshots.push((
                    *q,
                    kind,
                    ctx.t0,
                    Arc::clone(&ctx.gt),
                    ctx.active_cams.clone(),
                ));
            }
        }
        // FC admission + visibility lookups, lock-free: each query's
        // own FC block sees every camera with the spotlight's real
        // activation flag — inactive cameras included, so stateful
        // FCs (warm-up windows, duty cycles) observe deactivations.
        let mut frames: Vec<(QueryId, usize, bool)> = Vec::new();
        if inner.adapt_on {
            let ad = inner.adapt.lock().unwrap();
            for (cam, s) in strides.iter_mut().enumerate() {
                *s = ad.stride(cam);
            }
        }
        for (q, kind, t0, gt, active_cams) in &snapshots {
            // First sight of this query: mint its FC from its own app.
            let fc = fcs.entry(*q).or_insert_with(|| {
                inner.catalog.get(*kind).make_fc()
            });
            for (cam, &act) in active_cams.iter().enumerate() {
                // Commanded frame-rate decimation: FC never sees
                // strided-out ticks (mirrors the engines' frame-tick
                // gate).
                if inner.adapt_on
                    && strides[cam] > 1
                    && frame_no % strides[cam] != 0
                {
                    continue;
                }
                if !fc.admit(*q, cam, frame_no, now, act) {
                    continue;
                }
                frames.push((*q, cam, gt.visible(cam, now - t0)));
            }
        }
        // Drop FC blocks of queries that disappeared (completed or
        // cancelled), firing the lifecycle hook first.
        fcs.retain(|id, fc| {
            let live = snapshots.iter().any(|(q, ..)| q == id);
            if !live {
                fc.forget_query(*id);
            }
            live
        });
        // Short second critical section: allocate ids + ledger.
        {
            let mut st = inner.state.lock().unwrap();
            for (q, cam, present) in frames {
                if st.registry.status(q) != Some(QueryStatus::Active) {
                    continue; // cancelled between the two sections
                }
                let id = st.next_event_id;
                st.next_event_id += 1;
                let header = Header::new(id, cam, frame_no, now)
                    .with_query(q);
                st.ledgers.generated(q, id, present);
                inner.metrics.generated();
                inner.metrics.query_generated(q);
                if inner.obs.enabled() {
                    inner.obs.emit(
                        now,
                        &TraceEvent::Generated {
                            event: id,
                            query: q,
                            camera: cam as u32,
                        },
                    );
                }
                outgoing.push(Event {
                    header,
                    payload: Payload::Frame {
                        entity_present: present,
                    },
                });
            }
        }
        for ev in outgoing {
            let _ = va_tx[va_part.route(ev.header.camera)]
                .send(Msg::Ev(ev));
        }
        // Promoted queries' contexts are built outside the lock; their
        // frames start on the next tick.
        finish_activation(&inner, admitted);
        span_end(&*inner.obs, Scope::FeedLoop, iter_sp);
        frame_no += 1;
        next_fire += period;
        let now_i = Instant::now();
        if next_fire > now_i {
            std::thread::sleep(next_fire - now_i);
        } else {
            next_fire = now_i;
        }
    }
}

/// Per-worker runtime state the message handler mutates: the
/// fair-share batcher, the per-query analytics blocks (minted from
/// each query's app and delivered via `Msg::Register`), and the
/// applied QF refinements.
struct WorkerState {
    batcher: FairShareBatcher<Event>,
    /// Each query's block on this worker; removed at deregistration.
    blocks: FastMap<QueryId, AnalyticsBlock>,
    /// Stale-discarding view of routed QF refinements.
    feedback: FeedbackState,
    /// Each query's ξ cost multiplier at this worker's stage (its
    /// app's service cost relative to the default app; 1.0 for
    /// unknown/late queries) — the live port of the DES engines'
    /// per-app ξ pricing. Drives both the admission drop gate and the
    /// effective batch duration.
    rels: FastMap<QueryId, f64>,
}

/// Max automatic restarts per worker before the supervisor gives up —
/// a deterministically-broken backend or block must not spin the
/// thread forever.
const MAX_WORKER_RESTARTS: u32 = 8;

/// Run [`worker_loop`] under a supervisor: user logic (a per-query
/// block or the score backend) panicking kills one *incarnation* of
/// the worker, not its inbox — the `Receiver` is owned out here, so
/// registrations and events sent after the panic are delivered to the
/// restarted loop. Each restart re-mints the worker's per-query state
/// from the control plane ([`reregister_worker`]) and bumps the
/// `worker_restarts` counter. Events queued in the dying incarnation's
/// batcher are lost with it and remain `in_flight` in the ledgers
/// (conservation still holds — they are accounted, just unterminated).
///
/// Pairs with [`crate::obs::RingSink::install_dump_on_panic`]: the
/// panic hook runs *before* the unwind reaches our catch, so the
/// flight-recorder tail is dumped first and then the worker recovers.
///
/// The supervised region never holds the state mutex (batching and
/// scoring run lock-free), so a caught panic cannot poison it.
fn supervised_worker(
    stage: Stage,
    task: u32,
    rx: Receiver<Msg>,
    inner: Arc<Inner>,
    backend: Arc<dyn ScoreBackend>,
    max_batch_delay: Micros,
    mut forward: impl FnMut(Event),
) {
    let mut restarts = 0u32;
    loop {
        let resume = restarts > 0;
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || {
                    worker_loop(
                        stage,
                        task,
                        &rx,
                        &inner,
                        backend.as_ref(),
                        max_batch_delay,
                        &mut forward,
                        resume,
                    )
                },
            ));
        match caught {
            Ok(()) => return,
            Err(_) => {
                inner.metrics.worker_restart();
                eprintln!(
                    "[{stage:?} worker {task}] panicked; \
                     restarting (restart #{})",
                    restarts + 1
                );
                restarts += 1;
                // A panic during the post-Stop final flush must not
                // resurrect the worker (its Stop is already consumed
                // and shutdown would hang on join); same once the
                // restart budget is spent.
                if inner.stopping.load(Ordering::SeqCst) {
                    return;
                }
                if restarts > MAX_WORKER_RESTARTS {
                    // Budget spent mid-run: record the loss so the
                    // submit path and the final report surface it as
                    // typed state, not just a metrics gauge.
                    inner.lost_workers.lock().unwrap().push(LostWorker {
                        stage,
                        task,
                        restarts,
                    });
                    return;
                }
            }
        }
    }
}

/// Rebuild a restarted worker's per-query state from the control
/// plane: re-mint each active query's block from its own app, restore
/// fair-share weights and ξ cost multipliers (the same pricing
/// [`Channels::register`] ships), then replay the sink's latest QF
/// refinements through the fresh seq-stamped [`FeedbackState`] —
/// stale or duplicate deliveries are discarded, so replay composes
/// with in-flight `QueryUpdate`s to exactly-once application.
fn reregister_worker(
    stage: Stage,
    inner: &Inner,
    ws: &mut WorkerState,
    xi: &XiModel,
) {
    {
        let st = inner.state.lock().unwrap();
        let default =
            inner.catalog.get(inner.catalog.default_kind());
        for rec in st.registry.records() {
            if rec.status != QueryStatus::Active {
                continue;
            }
            let app = inner.catalog.get(rec.spec.app);
            let (rel, block) = match stage {
                Stage::Cr => (
                    app.cr_cost / default.cr_cost.max(1e-9),
                    AnalyticsBlock::Cr(app.make_cr()),
                ),
                _ => (
                    app.va_cost / default.va_cost.max(1e-9),
                    AnalyticsBlock::Va(app.make_va()),
                ),
            };
            ws.batcher.register(rec.id, rec.spec.weight());
            ws.blocks.insert(rec.id, block);
            ws.rels.insert(rec.id, rel);
            inner.metrics.set_app_xi(
                rec.spec.app.index(),
                stage,
                ((xi.xi(1) as f64) * rel).round() as Micros,
            );
        }
    }
    for (q, (seq, emb)) in
        inner.refinements.lock().unwrap().iter()
    {
        if ws.blocks.contains_key(q) {
            ws.feedback.apply(*q, *seq, Arc::clone(emb));
        }
    }
}

/// Shared executor loop: fair-share batching + backend scoring, with
/// each query's own VA/CR block owning its payload transformation
/// (`default_block` serves late events of already-retired queries).
/// `resume` marks a post-panic incarnation, whose per-query state is
/// rebuilt from the control plane before any message is processed.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    stage: Stage,
    task: u32,
    rx: &Receiver<Msg>,
    inner: &Arc<Inner>,
    backend: &dyn ScoreBackend,
    max_batch_delay: Micros,
    forward: &mut impl FnMut(Event),
    resume: bool,
) {
    let xi = backend.xi(stage);
    let gamma = inner.cfg.gamma();
    let drops_enabled = inner.cfg.drops_enabled;
    let deadline_window = gamma.min(max_batch_delay);
    // Max batch size follows the configured batching knob, matching
    // what the multi-query DES mode derives from the same config.
    let m_max = match inner.cfg.batching {
        crate::config::BatchingKind::Static { size } => size,
        crate::config::BatchingKind::Dynamic { max }
        | crate::config::BatchingKind::Nob { max } => max,
    };
    let mut default_block = match stage {
        Stage::Cr => AnalyticsBlock::Cr(
            inner.catalog.default_app().make_cr(),
        ),
        _ => AnalyticsBlock::Va(
            inner.catalog.default_app().make_va(),
        ),
    };
    let mut ws = WorkerState {
        batcher: FairShareBatcher::new(m_max.max(1)),
        blocks: FastMap::default(),
        feedback: FeedbackState::new(),
        rels: FastMap::default(),
    };
    if resume {
        reregister_worker(stage, inner, &mut ws, &xi);
    }
    let mut scratch = BatchScratch::default();

    fn handle(
        msg: Msg,
        stage: Stage,
        inner: &Inner,
        ws: &mut WorkerState,
        xi: &XiModel,
        gamma: Micros,
        drops_enabled: bool,
        deadline_window: Micros,
    ) -> bool {
        match msg {
            Msg::Stop => false,
            Msg::Register(q, w, app_idx, rel, block) => {
                ws.batcher.register(q, w);
                ws.blocks.insert(q, block);
                ws.rels.insert(q, rel);
                // Publish the ξ(1) price this stage charges the app —
                // the per-app ξ gauges.
                inner.metrics.set_app_xi(
                    app_idx,
                    stage,
                    ((xi.xi(1) as f64) * rel).round() as Micros,
                );
                true
            }
            Msg::RegisterQf(..) => true, // sink-only
            Msg::Deregister(q) => {
                let left = ws.batcher.deregister(q);
                if !left.is_empty() {
                    let now = inner.now_us();
                    let mut st = inner.state.lock().unwrap();
                    for qe in left {
                        st.ledgers.dropped(q, qe.item.header.id, stage);
                        inner.metrics.dropped(Gate::Drain);
                        inner.metrics.query_dropped(q);
                        if inner.obs.enabled() {
                            inner.obs.emit(
                                now,
                                &TraceEvent::Drop {
                                    gate: Gate::Drain,
                                    stage,
                                    event: qe.item.header.id,
                                    query: q,
                                    batch: 1,
                                    eps_us: 0,
                                    xi_us: 0,
                                },
                            );
                        }
                    }
                }
                ws.blocks.remove(&q);
                ws.feedback.forget(q);
                ws.rels.remove(&q);
                true
            }
            Msg::Ev(ev) => {
                // Feedback edge: a QueryUpdate swaps this worker's
                // scoring target for the query (iff fresher than the
                // last applied update) and is consumed here. Updates
                // for queries this worker no longer serves are dropped
                // — a late delivery racing Deregister must not
                // re-insert forgotten per-query state.
                if let Payload::QueryUpdate(emb) = &ev.payload {
                    let q = ev.header.query;
                    if ws.blocks.contains_key(&q) {
                        ws.feedback.apply(
                            q,
                            ev.header.update_seq,
                            Arc::clone(emb),
                        );
                    }
                    return true;
                }
                // Adaptation commands ride the same feedback edge and
                // are consumed here — never batched, priced or
                // dropped. The state is service-global, so of the
                // per-worker broadcast copies the first arrival
                // applies ([`Inner::apply_adaptation`]) and the rest
                // discard as stale.
                if let Payload::Adaptation(cmd) = &ev.payload {
                    inner.apply_adaptation(cmd, inner.now_us());
                    return true;
                }
                let now = inner.now_us();
                let q = ev.header.query;
                let u = now - ev.header.src_arrival;
                let exempt = ev.header.avoid_drop || ev.header.probe;
                // Gate 1 prices the event under *its* app's ξ — the
                // engine-level stage model scaled by the query's
                // registered cost multiplier (1.0 for the default app
                // and for late events of retired queries).
                let rel = ws.rels.get(&q).copied().unwrap_or(1.0);
                // Under adaptation the gate also charges the commanded
                // (resolution, variant) multiplier for the event's
                // camera; identity rungs multiply by exactly 1.0.
                let xi1 = if inner.adapt_on {
                    let nom = ws
                        .blocks
                        .get(&q)
                        .map(|b| b.variant())
                        .unwrap_or_else(|| {
                            let d = inner.catalog.default_app();
                            match stage {
                                Stage::Cr => d.cr_variant,
                                _ => d.va_variant,
                            }
                        });
                    let arel = inner
                        .adapt
                        .lock()
                        .unwrap()
                        .rel(ev.header.camera, nom);
                    ((xi.xi(1) as f64) * rel * arel).round()
                        as Micros
                } else {
                    ((xi.xi(1) as f64) * rel).round() as Micros
                };
                if drops_enabled
                    && drop_at_queue(exempt, u, xi1, gamma)
                {
                    inner
                        .state
                        .lock()
                        .unwrap()
                        .ledgers
                        .dropped(q, ev.header.id, stage);
                    inner.metrics.dropped(Gate::Queue);
                    inner.metrics.query_dropped(q);
                    if inner.obs.enabled() {
                        inner.obs.emit(
                            now,
                            &TraceEvent::Drop {
                                gate: Gate::Queue,
                                stage,
                                event: ev.header.id,
                                query: q,
                                batch: 1,
                                eps_us: (u + xi1) - gamma,
                                xi_us: xi1,
                            },
                        );
                    }
                    return true;
                }
                if inner.obs.enabled()
                    && exempt
                    && drops_enabled
                    && drop_at_queue(false, u, xi1, gamma)
                {
                    inner.obs.emit(
                        now,
                        &TraceEvent::Exempted {
                            gate: Gate::Queue,
                            stage,
                            event: ev.header.id,
                            query: q,
                        },
                    );
                }
                let deadline = ev.header.src_arrival + deadline_window;
                let id = ev.header.id;
                let rejected = ws.batcher.push(
                    q,
                    QueuedEvent {
                        item: ev,
                        id,
                        arrival: now,
                        deadline,
                    },
                );
                if let Some(qe) = rejected {
                    // Late in-flight event of a completed/cancelled
                    // query: account it so per-query conservation
                    // holds; do not resurrect the query.
                    inner
                        .state
                        .lock()
                        .unwrap()
                        .ledgers
                        .dropped(q, qe.item.header.id, stage);
                    inner.metrics.dropped(Gate::Drain);
                    inner.metrics.query_dropped(q);
                    if inner.obs.enabled() {
                        inner.obs.emit(
                            now,
                            &TraceEvent::Drop {
                                gate: Gate::Drain,
                                stage,
                                event: qe.item.header.id,
                                query: q,
                                batch: 1,
                                eps_us: 0,
                                xi_us: 0,
                            },
                        );
                    }
                }
                true
            }
        }
    }

    'outer: loop {
        let now = inner.now_us();
        let sp = span_begin(&*inner.obs);
        let poll = ws.batcher.poll(now, &xi);
        span_end(&*inner.obs, Scope::BatchPoll, sp);
        match poll {
            BatcherPoll::Ready(batch) => {
                let spare = exec_batch(
                    stage,
                    task,
                    batch,
                    &mut ws.blocks,
                    &mut default_block,
                    &ws.feedback,
                    &ws.rels,
                    backend,
                    &xi,
                    inner,
                    &mut scratch,
                    forward,
                );
                ws.batcher.recycle(spare);
                continue;
            }
            BatcherPoll::Timer(at) => {
                let wait = (at - now).max(0) as u64;
                match rx.recv_timeout(Duration::from_micros(
                    wait.min(100_000),
                )) {
                    Ok(msg) => {
                        if !handle(
                            msg,
                            stage,
                            inner,
                            &mut ws,
                            &xi,
                            gamma,
                            drops_enabled,
                            deadline_window,
                        ) {
                            break 'outer;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            BatcherPoll::Idle => {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(msg) => {
                        if !handle(
                            msg,
                            stage,
                            inner,
                            &mut ws,
                            &xi,
                            gamma,
                            drops_enabled,
                            deadline_window,
                        ) {
                            break 'outer;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        while let Ok(msg) = rx.try_recv() {
            if !handle(
                msg,
                stage,
                inner,
                &mut ws,
                &xi,
                gamma,
                drops_enabled,
                deadline_window,
            ) {
                break 'outer;
            }
        }
    }
    // Final flush: execute whatever is still queued.
    loop {
        match ws.batcher.poll(BUDGET_INF / 2, &xi) {
            BatcherPoll::Ready(batch) => {
                let spare = exec_batch(
                    stage,
                    task,
                    batch,
                    &mut ws.blocks,
                    &mut default_block,
                    &ws.feedback,
                    &ws.rels,
                    backend,
                    &xi,
                    inner,
                    &mut scratch,
                    forward,
                );
                ws.batcher.recycle(spare);
            }
            _ => break,
        }
    }
}

/// Reusable per-worker batch buffers: the batch's events regrouped by
/// query plus one score buffer reused across the per-query groups —
/// the per-group `Vec<Event>`/`Vec<f32>` allocations the old grouping
/// made are gone.
#[derive(Default)]
struct BatchScratch {
    events: Vec<Event>,
    scores: Vec<f32>,
}

/// Execute one cross-query batch: one shared execution sleep for the
/// whole batch, then per-query-group scoring and payload
/// transformation — each group is scored by the backend under *its*
/// block's model variant and its latest applied QF refinement, and
/// transformed by *that query's own* block (heterogeneous apps share
/// one physical batch). Returns the emptied batch vec for the caller
/// to recycle into its batcher.
fn exec_batch(
    stage: Stage,
    task: u32,
    mut batch: Vec<QueuedEvent<Event>>,
    blocks: &mut FastMap<QueryId, AnalyticsBlock>,
    default_block: &mut AnalyticsBlock,
    feedback: &FeedbackState,
    rels: &FastMap<QueryId, f64>,
    backend: &dyn ScoreBackend,
    xi: &XiModel,
    inner: &Inner,
    scratch: &mut BatchScratch,
    forward: &mut impl FnMut(Event),
) -> Vec<QueuedEvent<Event>> {
    if batch.is_empty() {
        return batch;
    }
    let b = batch.len();
    let now = inner.now_us();
    // Effective batch size: Σ of per-app cost multipliers (exactly b
    // for a homogeneous default-app batch) — the same §4.4 pricing the
    // DES engines use. Under adaptation each event also carries its
    // camera's commanded (resolution, variant) multiplier.
    let relsum: f64 = if inner.adapt_on {
        let ad = inner.adapt.lock().unwrap();
        batch
            .iter()
            .map(|qe| {
                let q = qe.item.header.query;
                let rel = rels.get(&q).copied().unwrap_or(1.0);
                let nom = blocks
                    .get(&q)
                    .map(|b| b.variant())
                    .unwrap_or_else(|| default_block.variant());
                rel * ad.rel(qe.item.header.camera, nom)
            })
            .sum()
    } else {
        batch
            .iter()
            .map(|qe| {
                rels.get(&qe.item.header.query)
                    .copied()
                    .unwrap_or(1.0)
            })
            .sum()
    };
    let queue_sum: Micros = batch
        .iter()
        .map(|qe| (now - qe.arrival).max(0))
        .sum();
    if inner.obs.enabled() {
        inner.obs.emit(
            now,
            &TraceEvent::BatchFormed {
                stage,
                task,
                size: b as u32,
            },
        );
    }
    let dur = xi.xi_eff(relsum).clamp(0, 50_000);
    std::thread::sleep(Duration::from_micros(dur as u64));
    inner.metrics.batch_executed(
        stage,
        b,
        queue_sum / (b.max(1) as Micros),
    );

    // Group events by query — a stable sort preserves per-query FIFO
    // order — then score + transform each query group with its own
    // block (scores reuse one columnar scratch buffer per group).
    let sp = span_begin(&*inner.obs);
    let events = &mut scratch.events;
    events.clear();
    events.extend(batch.drain(..).map(|qe| qe.item));
    events.sort_by_key(|ev| ev.header.query);
    let scores = &mut scratch.scores;
    let mut start = 0;
    while start < events.len() {
        let q = events[start].header.query;
        let mut end = start + 1;
        while end < events.len() && events[end].header.query == q {
            end += 1;
        }
        let block = match blocks.get_mut(&q) {
            Some(b) => b,
            None => &mut *default_block,
        };
        scores.clear();
        // Under adaptation the backend executes the commanded
        // (possibly downshifted) variant for this group's camera;
        // nominal otherwise.
        let nominal = block.variant();
        let variant = if inner.adapt_on {
            inner.adapt.lock().unwrap().variant_for(
                events[start].header.camera,
                nominal,
            )
        } else {
            nominal
        };
        let ctx = ScoreCtx {
            stage,
            variant,
            query: q,
            refined: feedback.refined(q),
        };
        let msp = span_begin(&*inner.obs);
        backend.score_into(&ctx, &events[start..end], scores);
        span_end(&*inner.obs, Scope::ModelExec, msp);
        debug_assert_eq!(
            scores.len(),
            end - start,
            "one score per event"
        );
        block.apply_scores(
            &mut events[start..end],
            scores,
            &ScoreParams { threshold: 0.5 },
        );
        start = end;
    }
    span_end(&*inner.obs, Scope::Scoring, sp);
    if inner.obs.enabled() {
        inner.obs.emit(
            now,
            &TraceEvent::BatchExecuted {
                stage,
                task,
                size: b as u32,
                est_us: dur,
                actual_us: dur,
            },
        );
    }
    for ev in events.drain(..) {
        forward(ev);
    }
    batch
}

/// Sink: completion accounting + per-query TL updates + per-query QF.
/// When a query's QF refines its embedding, the refinement is stamped
/// by the [`FeedbackRouter`] and broadcast to every worker as a
/// [`Payload::QueryUpdate`] — closing the feedback loop at runtime.
fn sink_loop(
    inner: Arc<Inner>,
    rx: Receiver<Msg>,
    workers: Vec<Sender<Msg>>,
    mut adapt_ctl: AdaptController,
) {
    let gamma = inner.cfg.gamma();
    // One QF block per query, minted from its app at registration.
    let mut qfs: FastMap<QueryId, Box<dyn QueryFusion>> =
        FastMap::default();
    let mut router = FeedbackRouter::new();
    // Per-query refinement counts stay sink-local on the hot path and
    // fold into the shared state at deregistration / shutdown, so a
    // refinement burst never contends on the state mutex.
    let mut counts: FastMap<QueryId, u64> = FastMap::default();
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(Msg::Ev(ev)) => {
                let now = inner.now_us();
                let q = ev.header.query;
                if ev.header.probe {
                    continue;
                }
                let latency = now - ev.header.src_arrival;
                let detected = matches!(
                    ev.payload,
                    Payload::Detection { detected: true, .. }
                );
                {
                    let mut st = inner.state.lock().unwrap();
                    st.ledgers.completed(
                        q,
                        ev.header.id,
                        latency,
                        gamma,
                        detected,
                    );
                    if let Some(ctx) = st.ctx_of(q) {
                        if detected {
                            ctx.detections += 1;
                        }
                        ctx.tl.on_detection(
                            ev.header.camera,
                            ev.header.captured,
                            detected,
                        );
                    }
                }
                inner.metrics.completed(latency <= gamma);
                inner.metrics.query_completed(q, latency <= gamma);
                if detected {
                    inner.metrics.detection();
                }
                if inner.obs.enabled() {
                    inner.obs.emit(
                        now,
                        &TraceEvent::Completed {
                            event: ev.header.id,
                            query: q,
                            latency_us: latency,
                            on_time: latency <= gamma,
                            detected,
                        },
                    );
                }
                // Adaptation plane: every completion's deadline slack
                // feeds the controller; minted commands broadcast to
                // every worker on the same seq-stamped feedback edge
                // as QF refinements (first arrival applies, the rest
                // discard as stale).
                if inner.adapt_on {
                    if let Some(cmd) = adapt_ctl.on_completion(
                        ev.header.camera,
                        latency,
                        now,
                    ) {
                        inner.metrics.adapt_minted();
                        let upd = FeedbackEnvelope::Adaptation(cmd)
                            .into_event(
                                ev.header.id,
                                ev.header.camera,
                                now,
                            );
                        for tx in &workers {
                            let _ = tx.send(Msg::Ev(upd.clone()));
                        }
                    }
                }
                // QF user-logic, outside the state lock. One lookup
                // serves both the refinement check and the embedding
                // read.
                let mut refinement: Option<Arc<Vec<f32>>> = None;
                let mut refined = false;
                if detected {
                    if let Some(qf) = qfs.get_mut(&q) {
                        if qf.on_detection(&ev) {
                            refined = true;
                            refinement = qf
                                .embedding()
                                .map(|e| Arc::new(e.to_vec()));
                        }
                    }
                }
                if refined {
                    inner
                        .fusion_updates
                        .fetch_add(1, Ordering::Relaxed);
                    *counts.entry(q).or_insert(0) += 1;
                    if let Some(emb) = refinement {
                        let r = router.refine(q, emb);
                        // Record the newest routed refinement so a
                        // restarted worker can replay it into its
                        // fresh FeedbackState.
                        inner.refinements.lock().unwrap().insert(
                            q,
                            (r.seq, Arc::clone(&r.embedding)),
                        );
                        inner.metrics.refinement();
                        if inner.obs.enabled() {
                            inner.obs.emit(
                                now,
                                &TraceEvent::RefinementApplied {
                                    query: q,
                                    seq: r.seq,
                                },
                            );
                        }
                        let upd = r.into_event(
                            ev.header.id,
                            ev.header.camera,
                            now,
                        );
                        for tx in &workers {
                            let _ = tx.send(Msg::Ev(upd.clone()));
                        }
                    }
                }
            }
            Ok(Msg::RegisterQf(q, qf)) => {
                qfs.insert(q, qf);
            }
            Ok(Msg::Deregister(q)) => {
                qfs.remove(&q);
                router.forget(q);
                inner.refinements.lock().unwrap().remove(&q);
                if let Some(n) = counts.remove(&q) {
                    let mut st = inner.state.lock().unwrap();
                    *st.fusion_counts.entry(q).or_insert(0) += n;
                }
            }
            Ok(Msg::Stop) => break,
            Ok(_) => {}
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Shutdown: fold the remaining (still-registered) counts so the
    // final report sees every refinement.
    if !counts.is_empty() {
        let mut st = inner.state.lock().unwrap();
        for (q, n) in counts {
            *st.fusion_counts.entry(q).or_insert(0) += n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn small_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.num_cameras = 8;
        c.workload.vertices = 40;
        c.workload.edges = 100;
        c.fps = 10.0;
        c.gamma_ms = 2_000.0;
        c.cluster.va_instances = 2;
        c.cluster.cr_instances = 2;
        c
    }

    fn policy(max_active: usize, qcap: usize) -> AdmissionPolicy {
        AdmissionPolicy {
            max_active,
            max_active_cameras: 10_000,
            queue_capacity: qcap,
        }
    }

    fn spec(label: &str, cam: usize, secs: f64) -> QuerySpec {
        QuerySpec {
            lifetime_secs: secs,
            ..QuerySpec::new(label, cam)
        }
    }

    #[test]
    fn sim_backend_calibrates_from_semantics() {
        let mut sem = crate::config::SemanticsConfig::default();
        sem.fusion_boost = 0.0;
        sem.cr_tp = 0.9;
        let b = SimBackend::from_semantics(&sem);
        assert_eq!(b.fusion_boost, 0.0, "config governs the boost");
        assert!((b.tp - 0.9).abs() < 1e-12);
        // boost 0: refined scoring is identical to unrefined.
        let events: Vec<Event> =
            (0..16).map(|i| Event::frame(i, 0, i, 0, true)).collect();
        let emb = [0.5f32; 4];
        let plain = b.score(
            &ScoreCtx {
                stage: Stage::Cr,
                variant: crate::dataflow::ModelVariant::CrSmall,
                query: 1,
                refined: None,
            },
            &events,
        );
        let refined = b.score(
            &ScoreCtx {
                stage: Stage::Cr,
                variant: crate::dataflow::ModelVariant::CrSmall,
                query: 1,
                refined: Some(&emb),
            },
            &events,
        );
        assert_eq!(plain, refined, "boost 0 disables the effect");
    }

    #[test]
    fn service_runs_queries_to_completion() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(8, 4),
            Arc::new(SimBackend::default()),
        )
        .unwrap();
        let (a, st_a) = svc.submit(spec("alpha", 0, 0.8)).unwrap();
        let (b, st_b) = svc.submit(spec("beta", 3, 0.8)).unwrap();
        assert_eq!(st_a, QueryStatus::Active);
        assert_eq!(st_b, QueryStatus::Active);
        std::thread::sleep(Duration::from_millis(1_400));
        // Windows elapsed: both completed by the feed loop.
        assert_eq!(svc.status(a), Some(QueryStatus::Completed));
        assert_eq!(svc.status(b), Some(QueryStatus::Completed));
        let report = svc.stop();
        assert_eq!(report.peak_concurrent, 2);
        for q in report.queries.iter() {
            let s = q.summary.as_ref().expect("per-query ledger");
            assert!(s.generated > 0, "query {} idle", q.id);
            assert!(s.conserved(), "query {}: {:?}", q.id, s);
        }
        assert!(report.aggregate.conserved());
    }

    #[test]
    fn admission_queue_and_reject_at_runtime() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(1, 1),
            Arc::new(SimBackend::default()),
        )
        .unwrap();
        let (a, st_a) = svc.submit(spec("a", 0, 5.0)).unwrap();
        let (b, st_b) = svc.submit(spec("b", 1, 5.0)).unwrap();
        let (c, st_c) = svc.submit(spec("c", 2, 5.0)).unwrap();
        assert_eq!(st_a, QueryStatus::Active);
        assert_eq!(st_b, QueryStatus::Queued);
        assert_eq!(st_c, QueryStatus::Rejected);
        assert_eq!(svc.status(c), Some(QueryStatus::Rejected));
        // Cancelling the active query promotes the wait-listed one.
        svc.cancel(a).unwrap();
        assert_eq!(svc.status(b), Some(QueryStatus::Active));
        let report = svc.stop();
        assert_eq!(report.peak_concurrent, 1);
    }

    #[test]
    fn metrics_snapshot_reconciles_with_report() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(8, 4),
            Arc::new(SimBackend::default()),
        )
        .unwrap();
        let (_a, _) = svc.submit(spec("alpha", 0, 0.6)).unwrap();
        std::thread::sleep(Duration::from_millis(900));
        // Mid-run snapshot must be available without stalling workers.
        let mid = svc.metrics_snapshot();
        let report = svc.stop();
        let m = &report.metrics;
        let s = &report.aggregate;
        assert_eq!(m.generated, s.generated);
        assert_eq!(m.on_time, s.on_time);
        assert_eq!(m.delayed, s.delayed);
        assert_eq!(m.dropped_total(), s.dropped);
        assert!(mid.generated <= m.generated);
        // The live front charges the default app rel = 1.0, so its
        // published ξ(1) gauge equals the backend's engine-level price.
        let backend = SimBackend::default();
        assert_eq!(m.xi_app_us[0][0], backend.va_xi.xi(1));
        assert_eq!(m.xi_app_us[0][1], backend.cr_xi.xi(1));
    }

    /// Backend whose first scoring call panics (every later call
    /// delegates) — exercises the worker supervisor end to end.
    struct PanicOnceBackend {
        delegate: SimBackend,
        fired: AtomicBool,
    }

    impl ScoreBackend for PanicOnceBackend {
        fn score_into(
            &self,
            ctx: &ScoreCtx<'_>,
            events: &[Event],
            out: &mut Vec<f32>,
        ) {
            if !self.fired.swap(true, Ordering::SeqCst) {
                panic!("injected scoring fault");
            }
            self.delegate.score_into(ctx, events, out)
        }

        fn xi(&self, stage: Stage) -> XiModel {
            self.delegate.xi(stage)
        }
    }

    #[test]
    fn worker_panic_restarts_and_service_recovers() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(8, 4),
            Arc::new(PanicOnceBackend {
                delegate: SimBackend::default(),
                fired: AtomicBool::new(false),
            }),
        )
        .unwrap();
        let (a, st_a) = svc.submit(spec("alpha", 0, 0.8)).unwrap();
        assert_eq!(st_a, QueryStatus::Active);
        std::thread::sleep(Duration::from_millis(1_400));
        assert_eq!(svc.status(a), Some(QueryStatus::Completed));
        let report = svc.stop();
        assert!(
            report.metrics.worker_restarts >= 1,
            "the panicked worker restarted"
        );
        let s = &report.aggregate;
        assert!(s.generated > 0);
        assert!(s.conserved(), "{s:?}");
        // The pipeline kept completing events after the restart (the
        // lost batch stays in_flight; everything else terminates).
        assert!(s.on_time + s.delayed > 0, "{s:?}");
    }

    #[test]
    fn cancel_mid_run_keeps_ledgers_consistent() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(4, 2),
            Arc::new(SimBackend::default()),
        )
        .unwrap();
        let (a, _) = svc.submit(spec("a", 0, 5.0)).unwrap();
        std::thread::sleep(Duration::from_millis(400));
        svc.cancel(a).unwrap();
        assert_eq!(svc.status(a), Some(QueryStatus::Cancelled));
        std::thread::sleep(Duration::from_millis(200));
        let report = svc.stop();
        let qa = &report.queries[0];
        if let Some(s) = &qa.summary {
            assert!(s.conserved(), "{s:?}");
        }
    }
}
