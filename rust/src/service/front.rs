//! Live service front-end: accept tracking queries *at runtime* over
//! shared wall-clock workers.
//!
//! [`TrackingService`] is the multi-tenant counterpart of
//! [`crate::coordinator::live::LiveEngine`]: shared VA/CR worker
//! threads (std threads + mpsc channels, like the live engine) serve
//! every admitted query, composing cross-query batches through the same
//! [`FairShareBatcher`] the DES engine uses. Queries are submitted and
//! cancelled while the service runs; admission control applies the same
//! [`AdmissionController`] policy as the DES mode, and wait-listed
//! queries are promoted when capacity frees up (completion or cancel).
//!
//! Scoring is pluggable through [`ScoreBackend`]: the bundled
//! [`SimBackend`] scores deterministically from ground-truth labels (so
//! the service layer is fully testable without PJRT), while a
//! PJRT-backed deployment implements the trait over
//! [`crate::runtime::ModelPool`] (one `execute` per per-query group of
//! a batch, since each query carries its own embedding).
//!
//! Batching SLA: every event gets the deadline
//! `min(γ, max_batch_delay)` past its source arrival, which drives both
//! dynamic batch formation and (when drops are enabled) the
//! admission-time drop point. Budget *adaptation* (accept/reject
//! signals) is exercised in the engines; the front keeps the static
//! γ-bound deadline.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::apps::AppDefinition;
use crate::config::ExperimentConfig;
use crate::dataflow::{
    AnalyticsBlock, Event, FilterControl, Header, Partitioner, Payload,
    QueryFusion, QueryId, ScoreParams, Stage, TlEnv, TlFactory,
    TrackingLogic,
};
use crate::metrics::{QueryLedgers, Summary};
use crate::roadnet::{generate, place_cameras, Camera, Graph};
use crate::service::admission::{
    Admission, AdmissionController, AdmissionPolicy,
};
use crate::service::query::{
    QueryRegistry, QueryReport, QuerySpec, QueryStatus,
};
use crate::service::scheduler::FairShareBatcher;
use crate::sim::{EntityWalk, GroundTruth};
use crate::tuning::budget::BUDGET_INF;
use crate::tuning::{drop_at_queue, BatcherPoll, QueuedEvent, XiModel};
use crate::util::{millis, secs, Micros, SEC};

/// Pluggable model execution for the service front.
pub trait ScoreBackend: Send + Sync {
    /// Score every event of one query's group within a batch (one score
    /// per event, higher = better match against this query).
    fn score(
        &self,
        stage: Stage,
        query: QueryId,
        events: &[Event],
    ) -> Vec<f32> {
        let mut out = Vec::with_capacity(events.len());
        self.score_into(stage, query, events, &mut out);
        out
    }

    /// Append one score per event to `out` — the workers score whole
    /// batches into one reusable columnar buffer, so backends should
    /// implement this (the hot variant) and inherit `score`.
    fn score_into(
        &self,
        stage: Stage,
        query: QueryId,
        events: &[Event],
        out: &mut Vec<f32>,
    );

    /// Service-time model for a stage (drives batching deadlines and
    /// the modelled execution duration).
    fn xi(&self, stage: Stage) -> XiModel;
}

/// Deterministic ground-truth-driven backend: frames carry their
/// per-query truth label, scores follow it with a seeded hash coin.
pub struct SimBackend {
    pub seed: u64,
    /// P(score high | entity present).
    pub tp: f64,
    /// P(score high | entity absent).
    pub fp: f64,
    /// VA/CR per-batch service models (small, so tests stay fast).
    pub va_xi: XiModel,
    pub cr_xi: XiModel,
}

impl Default for SimBackend {
    fn default() -> Self {
        Self {
            seed: 2019,
            tp: 0.97,
            fp: 0.01,
            va_xi: XiModel::affine_ms(1.0, 0.3),
            cr_xi: XiModel::affine_ms(2.0, 0.5),
        }
    }
}

impl SimBackend {
    /// Per-(event, query, stage) coin — the stage salt makes VA and CR
    /// draws independent, so the pipeline's combined error rates are
    /// tp² / fp², not a single shared draw.
    fn coin(&self, ev: &Event, q: QueryId, stage: Stage) -> f64 {
        let stage_salt = match stage {
            Stage::Cr => 0xC12A_5E0F_u64,
            _ => 0x7A11_D00D_u64,
        };
        let mut h = self.seed
            ^ ev.header.id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (q as u64).wrapping_mul(0xC2B2_AE35)
            ^ stage_salt.wrapping_mul(0x9E37_79B9);
        h ^= h >> 33;
        h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        h ^= h >> 33;
        h as f64 / u64::MAX as f64
    }
}

impl ScoreBackend for SimBackend {
    fn score_into(
        &self,
        stage: Stage,
        query: QueryId,
        events: &[Event],
        out: &mut Vec<f32>,
    ) {
        out.extend(events.iter().map(|ev| {
            let present = ev.payload.entity_present() == Some(true);
            let p = if present { self.tp } else { self.fp };
            if self.coin(ev, query, stage) < p {
                0.9
            } else {
                0.1
            }
        }));
    }

    fn xi(&self, stage: Stage) -> XiModel {
        match stage {
            Stage::Cr => self.cr_xi.clone(),
            _ => self.va_xi.clone(),
        }
    }
}

/// Worker inbox messages.
enum Msg {
    Ev(Event),
    Register(QueryId, u32),
    Deregister(QueryId),
    Stop,
}

/// Per-query runtime state owned by the control plane. Ground truth is
/// behind an `Arc` so the feed loop can snapshot it and compute
/// visibility *outside* the state lock.
struct LiveCtx {
    t0: Micros,
    end: Micros,
    gt: Arc<GroundTruth>,
    tl: Box<dyn TrackingLogic>,
    active_cams: Vec<bool>,
    detections: u64,
    peak_active: usize,
}

/// Control-plane state behind one mutex.
struct State {
    registry: QueryRegistry,
    ledgers: QueryLedgers,
    ctx: Vec<(QueryId, LiveCtx)>,
    /// Camera-budget reservations for queries admitted (phase A) whose
    /// context is still being built outside the lock (phase B) —
    /// counted by [`State::active_cameras_total`] so concurrent
    /// admissions cannot overshoot `max_active_cameras` in the window.
    reserved_cameras: Vec<(QueryId, usize)>,
    finished_stats: Vec<(QueryId, (u64, usize))>,
    next_event_id: u64,
    peak_concurrent: usize,
}

impl State {
    fn ctx_of(&mut self, q: QueryId) -> Option<&mut LiveCtx> {
        self.ctx
            .iter_mut()
            .find(|(id, _)| *id == q)
            .map(|(_, c)| c)
    }

    fn take_ctx(&mut self, q: QueryId) -> Option<LiveCtx> {
        self.ctx
            .iter()
            .position(|(id, _)| *id == q)
            .map(|i| self.ctx.remove(i).1)
    }

    fn active_cameras_total(&self) -> usize {
        let installed: usize = self
            .ctx
            .iter()
            .map(|(_, c)| c.active_cams.iter().filter(|&&a| a).count())
            .sum();
        let reserved: usize =
            self.reserved_cameras.iter().map(|&(_, n)| n).sum();
        installed + reserved
    }

    fn release_reservation(&mut self, q: QueryId) {
        self.reserved_cameras.retain(|&(id, _)| id != q);
    }
}

struct Inner {
    cfg: ExperimentConfig,
    graph: Graph,
    cams: Vec<Camera>,
    admission: AdmissionController,
    /// Mints one TL block per query (the app's factory).
    tl_factory: TlFactory,
    /// Query-embedding refinements by the app's QF block (sink-side).
    fusion_updates: AtomicU64,
    state: Mutex<State>,
    start: Instant,
    stopping: AtomicBool,
}

impl Inner {
    fn now_us(&self) -> Micros {
        self.start.elapsed().as_micros() as Micros
    }
}

/// Phase A of activation — the registry transition plus worker
/// registration. Caller holds the state lock; the expensive runtime
/// context ([`build_ctx`]) is deliberately **not** built here, so a
/// submit cannot stall the dataflow behind the lock.
fn admit_locked(
    inner: &Inner,
    st: &mut State,
    worker_tx: &[Sender<Msg>],
    id: QueryId,
    now: Micros,
) {
    st.registry
        .activate(id, now)
        .expect("admission checked the transition");
    st.peak_concurrent =
        st.peak_concurrent.max(st.registry.num_active());
    let spec = st.registry.record(id).unwrap().spec.clone();
    // Hold the projected camera budget until the context is installed,
    // so admissions racing with phase B cannot overshoot the limit.
    st.reserved_cameras.push((
        id,
        spec.initial_camera_estimate(inner.cfg.num_cameras),
    ));
    for tx in worker_tx {
        let _ = tx.send(Msg::Register(id, spec.weight()));
    }
}

/// Phase B — build the query's runtime context (entity walk, ground
/// truth, TL). Lock-free: this is the expensive part of activation.
fn build_ctx(
    inner: &Inner,
    spec: &QuerySpec,
    id: QueryId,
    now: Micros,
) -> LiveCtx {
    let lifetime = secs(spec.lifetime_secs);
    let start_cam = spec
        .start_camera
        .unwrap_or(0)
        .min(inner.cams.len().saturating_sub(1));
    let start_vertex = inner.cams[start_cam].vertex;
    let walk = EntityWalk::simulate(
        &inner.graph,
        start_vertex,
        inner.cfg.workload.entity_speed_mps,
        lifetime + 10 * SEC,
        inner.cfg.seed
            ^ (id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let gt = GroundTruth::compute(
        &inner.graph,
        &inner.cams,
        &walk,
        lifetime + 10 * SEC,
        100_000,
    );
    let mut tl = (inner.tl_factory)(&TlEnv {
        peak_speed_mps: inner.cfg.tl_peak_speed_mps,
        mean_road_m: inner.cfg.workload.mean_road_m,
        fov_m: inner.cfg.workload.fov_m,
        cameras: &inner.cams,
    });
    tl.on_detection(start_cam, now, true);
    let mut active_set = Vec::new();
    tl.active_set_into(&inner.graph, now, &mut active_set);
    let mut active_cams = vec![false; inner.cfg.num_cameras];
    for cam in &active_set {
        active_cams[*cam] = true;
    }
    let peak = active_set.len();
    LiveCtx {
        t0: now,
        end: now + lifetime,
        gt: Arc::new(gt),
        tl,
        active_cams,
        detections: 0,
        peak_active: peak,
    }
}

/// Phase C — install a built context, unless the query was cancelled
/// in the window between phases (then the context is discarded). The
/// phase-A camera reservation is released either way (the installed
/// context's real spotlight takes over the accounting).
fn install_ctx(inner: &Inner, id: QueryId, ctx: LiveCtx) {
    let mut st = inner.state.lock().unwrap();
    st.release_reservation(id);
    if st.registry.status(id) == Some(QueryStatus::Active)
        && !st.ctx.iter().any(|(q, _)| *q == id)
    {
        st.ctx.push((id, ctx));
    }
}

/// Run phases B+C for a batch of freshly admitted queries (specs
/// snapshotted under the lock, contexts built outside it).
fn finish_activation(
    inner: &Inner,
    admitted: Vec<(QueryId, QuerySpec, Micros)>,
) {
    for (id, spec, now) in admitted {
        let ctx = build_ctx(inner, &spec, id, now);
        install_ctx(inner, id, ctx);
    }
}

/// Promote wait-listed queries while they fit (phase A only). Caller
/// holds the lock and must pass the returned list to
/// [`finish_activation`] *after releasing it*.
#[must_use]
fn promote_locked(
    inner: &Inner,
    st: &mut State,
    worker_tx: &[Sender<Msg>],
    now: Micros,
) -> Vec<(QueryId, QuerySpec, Micros)> {
    let mut admitted = Vec::new();
    while let Some(next) = st.registry.next_pending() {
        let spec = st.registry.record(next).unwrap().spec.clone();
        let decision = inner.admission.decide(
            &spec,
            st.registry.num_active(),
            st.registry.num_queued(),
            st.active_cameras_total(),
            inner.cfg.num_cameras,
        );
        if decision == Admission::Admit {
            admit_locked(inner, st, worker_tx, next, now);
            admitted.push((next, spec, now));
        } else {
            break;
        }
    }
    admitted
}

/// Final report of a service run.
#[derive(Debug)]
pub struct ServiceReport {
    pub queries: Vec<QueryReport>,
    pub aggregate: Summary,
    pub peak_concurrent: usize,
    /// Query-embedding refinements by the app's QF block.
    pub fusion_updates: u64,
    pub wall_secs: f64,
}

/// The running multi-query service.
pub struct TrackingService {
    inner: Arc<Inner>,
    /// All worker inboxes (VA then CR) for registration broadcasts.
    worker_tx: Vec<Sender<Msg>>,
    va_tx: Vec<Sender<Msg>>,
    cr_tx: Vec<Sender<Msg>>,
    feed: Option<JoinHandle<()>>,
    /// VA and CR worker handles, kept separate so shutdown can be
    /// staged upstream-first (VA flushes into live CR workers).
    va_workers: Vec<JoinHandle<()>>,
    cr_workers: Vec<JoinHandle<()>>,
    sink: Option<JoinHandle<()>>,
    sink_tx: Sender<Msg>,
    max_batch_delay: Micros,
}

impl TrackingService {
    /// Start the service for the stock application the config
    /// describes (`cfg.app` composition, `cfg.tl` spotlight).
    pub fn start(
        cfg: ExperimentConfig,
        policy: AdmissionPolicy,
        backend: Arc<dyn ScoreBackend>,
    ) -> Result<Self> {
        let app = crate::apps::resolve(&cfg);
        Self::start_with_app(cfg, policy, backend, &app)
    }

    /// Start the shared workers and the feed loop for an arbitrary
    /// [`AppDefinition`]; returns immediately. `cfg` describes the
    /// camera network and worker counts; queries are then submitted at
    /// runtime. Each worker thread owns its own minted VA/CR block, the
    /// feed loop owns the FC block, the sink owns QF, and the app's TL
    /// factory builds one spotlight per admitted query.
    pub fn start_with_app(
        cfg: ExperimentConfig,
        policy: AdmissionPolicy,
        backend: Arc<dyn ScoreBackend>,
        app: &AppDefinition,
    ) -> Result<Self> {
        let graph = generate(&cfg.workload, cfg.seed);
        let cams = place_cameras(
            &graph,
            cfg.num_cameras,
            0,
            cfg.workload.fov_m,
        );
        let inner = Arc::new(Inner {
            admission: AdmissionController::new(policy),
            tl_factory: app.tl_factory(),
            fusion_updates: AtomicU64::new(0),
            state: Mutex::new(State {
                registry: QueryRegistry::new(),
                ledgers: QueryLedgers::new(),
                ctx: Vec::new(),
                reserved_cameras: Vec::new(),
                finished_stats: Vec::new(),
                next_event_id: 0,
                peak_concurrent: 0,
            }),
            start: Instant::now(),
            stopping: AtomicBool::new(false),
            graph,
            cams,
            cfg,
        });
        let cfg = &inner.cfg;
        let max_batch_delay = millis(250.0).min(cfg.gamma());

        let n_va = cfg.cluster.va_instances.clamp(1, 4);
        let n_cr = cfg.cluster.cr_instances.clamp(1, 4);
        let va_part = Partitioner::new(n_va);
        let cr_part = Partitioner::new(n_cr);

        let (sink_tx, sink_rx) = mpsc::channel::<Msg>();

        // CR workers → sink.
        let mut cr_tx = Vec::new();
        let mut cr_workers = Vec::new();
        for _ in 0..n_cr {
            let (tx, rx) = mpsc::channel::<Msg>();
            cr_tx.push(tx);
            let out = sink_tx.clone();
            let inner_c = Arc::clone(&inner);
            let backend_c = Arc::clone(&backend);
            let delay = max_batch_delay;
            let block = AnalyticsBlock::Cr(app.make_cr());
            cr_workers.push(std::thread::spawn(move || {
                worker_loop(
                    Stage::Cr,
                    block,
                    rx,
                    inner_c,
                    backend_c,
                    delay,
                    {
                        move |ev| {
                            let _ = out.send(Msg::Ev(ev));
                        }
                    },
                );
            }));
        }

        // VA workers → CR workers.
        let mut va_tx = Vec::new();
        let mut va_workers = Vec::new();
        for _ in 0..n_va {
            let (tx, rx) = mpsc::channel::<Msg>();
            va_tx.push(tx);
            let crs = cr_tx.clone();
            let inner_c = Arc::clone(&inner);
            let backend_c = Arc::clone(&backend);
            let delay = max_batch_delay;
            let block = AnalyticsBlock::Va(app.make_va());
            va_workers.push(std::thread::spawn(move || {
                worker_loop(
                    Stage::Va,
                    block,
                    rx,
                    inner_c,
                    backend_c,
                    delay,
                    {
                        move |ev| {
                            let _ = crs[cr_part.route(ev.header.camera)]
                                .send(Msg::Ev(ev));
                        }
                    },
                );
            }));
        }

        let mut worker_tx: Vec<Sender<Msg>> = Vec::new();
        worker_tx.extend(va_tx.iter().cloned());
        worker_tx.extend(cr_tx.iter().cloned());

        // Sink thread: completion accounting + TL updates + QF.
        let sink = {
            let inner_c = Arc::clone(&inner);
            let qf = app.make_qf();
            std::thread::spawn(move || sink_loop(inner_c, sink_rx, qf))
        };

        // Feed thread: FC gating, frame generation, expiry, spotlight
        // refresh, wait-queue promotion.
        let feed = {
            let inner_c = Arc::clone(&inner);
            let vas = va_tx.clone();
            let all = worker_tx.clone();
            let fc = app.make_fc();
            std::thread::spawn(move || {
                feed_loop(inner_c, fc, vas, va_part, all)
            })
        };

        Ok(Self {
            inner,
            worker_tx,
            va_tx,
            cr_tx,
            feed: Some(feed),
            va_workers,
            cr_workers,
            sink: Some(sink),
            sink_tx,
            max_batch_delay,
        })
    }

    /// Submit a query; admission control admits, wait-lists or rejects
    /// it. Returns the query id and its initial status.
    pub fn submit(
        &self,
        spec: QuerySpec,
    ) -> Result<(QueryId, QueryStatus)> {
        let now = self.inner.now_us();
        let mut st = self.inner.state.lock().unwrap();
        let id = st.registry.submit(spec.clone(), now);
        let decision = self.inner.admission.decide(
            &spec,
            st.registry.num_active(),
            st.registry.num_queued(),
            st.active_cameras_total(),
            self.inner.cfg.num_cameras,
        );
        match decision {
            Admission::Admit => {
                admit_locked(
                    &self.inner,
                    &mut st,
                    &self.worker_tx,
                    id,
                    now,
                );
                drop(st);
                // Expensive context construction happens outside the
                // lock so concurrent tenants keep flowing.
                let ctx = build_ctx(&self.inner, &spec, id, now);
                install_ctx(&self.inner, id, ctx);
                Ok((id, QueryStatus::Active))
            }
            Admission::Queue => {
                st.registry.enqueue(id).map_err(|e| anyhow!(e))?;
                Ok((id, QueryStatus::Queued))
            }
            Admission::Reject(_reason) => {
                st.registry.reject(id, now).map_err(|e| anyhow!(e))?;
                Ok((id, QueryStatus::Rejected))
            }
        }
    }

    /// Cancel a submitted/queued/active query; frees its capacity and
    /// promotes wait-listed queries.
    pub fn cancel(&self, id: QueryId) -> Result<()> {
        let now = self.inner.now_us();
        let mut st = self.inner.state.lock().unwrap();
        st.registry.cancel(id, now).map_err(|e| anyhow!(e))?;
        st.release_reservation(id);
        if let Some(ctx) = st.take_ctx(id) {
            st.finished_stats
                .push((id, (ctx.detections, ctx.peak_active)));
        }
        for tx in &self.worker_tx {
            let _ = tx.send(Msg::Deregister(id));
        }
        let admitted =
            promote_locked(&self.inner, &mut st, &self.worker_tx, now);
        drop(st);
        finish_activation(&self.inner, admitted);
        Ok(())
    }

    /// Current lifecycle status of a query.
    pub fn status(&self, id: QueryId) -> Option<QueryStatus> {
        self.inner.state.lock().unwrap().registry.status(id)
    }

    /// The service's batching-delay cap (µs).
    pub fn max_batch_delay(&self) -> Micros {
        self.max_batch_delay
    }

    /// Stop the service, join every thread and build the final report.
    ///
    /// Shutdown is staged upstream-first: feed, then VA workers (whose
    /// final flush lands in still-running CR workers), then CR workers
    /// (flushing into the still-running sink), then the sink — so no
    /// in-flight event is silently lost and per-query conservation
    /// holds in the report.
    pub fn stop(mut self) -> ServiceReport {
        self.inner.stopping.store(true, Ordering::SeqCst);
        if let Some(h) = self.feed.take() {
            let _ = h.join();
        }
        for tx in &self.va_tx {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.va_workers.drain(..) {
            let _ = h.join();
        }
        for tx in &self.cr_tx {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.cr_workers.drain(..) {
            let _ = h.join();
        }
        let _ = self.sink_tx.send(Msg::Stop);
        if let Some(h) = self.sink.take() {
            let _ = h.join();
        }
        let wall = self.inner.start.elapsed().as_secs_f64();
        let fusion_updates =
            self.inner.fusion_updates.load(Ordering::Relaxed);
        let st = self.inner.state.lock().unwrap();
        let mut queries = Vec::new();
        for rec in st.registry.records() {
            let mut r = QueryReport::from_record(rec);
            r.summary = st.ledgers.summary(rec.id);
            if let Some((_, (d, p))) = st
                .finished_stats
                .iter()
                .find(|(q, _)| *q == rec.id)
            {
                r.detections = *d;
                r.peak_active = *p;
            } else if let Some((_, ctx)) =
                st.ctx.iter().find(|(q, _)| *q == rec.id)
            {
                r.detections = ctx.detections;
                r.peak_active = ctx.peak_active;
            }
            queries.push(r);
        }
        ServiceReport {
            queries,
            aggregate: st.ledgers.aggregate(),
            peak_concurrent: st.peak_concurrent,
            fusion_updates,
            wall_secs: wall,
        }
    }
}

/// Frame generation: one event per (active query, active camera) that
/// the FC block admits, at the configured fps; also expires elapsed
/// queries (promoting wait-listed ones) and refreshes per-query
/// spotlights.
fn feed_loop(
    inner: Arc<Inner>,
    mut fc: Box<dyn FilterControl>,
    va_tx: Vec<Sender<Msg>>,
    va_part: Partitioner,
    all_tx: Vec<Sender<Msg>>,
) {
    let cfg = &inner.cfg;
    let period = Duration::from_micros((1e6 / cfg.fps.max(0.1)) as u64);
    let mut frame_no: u64 = 0;
    let mut active_buf: Vec<usize> = Vec::new();
    let mut next_fire = Instant::now();
    while !inner.stopping.load(Ordering::SeqCst) {
        let now = inner.now_us();
        let mut outgoing: Vec<Event> = Vec::new();
        let mut admitted = Vec::new();
        let mut snapshots: Vec<(
            QueryId,
            Micros,
            Arc<GroundTruth>,
            Vec<bool>,
        )> = Vec::new();
        {
            let mut st = inner.state.lock().unwrap();
            // Expire elapsed queries.
            let expired: Vec<QueryId> = st
                .ctx
                .iter()
                .filter(|(_, c)| now >= c.end)
                .map(|(q, _)| *q)
                .collect();
            for q in &expired {
                let _ = st.registry.complete(*q, now);
                if let Some(ctx) = st.take_ctx(*q) {
                    st.finished_stats.push((
                        *q,
                        (ctx.detections, ctx.peak_active),
                    ));
                }
                // Drop the FC's per-query state with the query.
                fc.forget_query(*q);
                for tx in &all_tx {
                    let _ = tx.send(Msg::Deregister(*q));
                }
            }
            if !expired.is_empty() {
                admitted =
                    promote_locked(&inner, &mut st, &all_tx, now);
            }
            // Refresh spotlights and snapshot what frame generation
            // needs; the O(queries × cameras) ground-truth scan runs
            // *outside* the lock so workers and the sink keep flowing.
            for (_, ctx) in st.ctx.iter_mut() {
                ctx.tl.active_set_into(
                    &inner.graph,
                    now,
                    &mut active_buf,
                );
                ctx.peak_active =
                    ctx.peak_active.max(active_buf.len());
                for a in ctx.active_cams.iter_mut() {
                    *a = false;
                }
                for &cam in &active_buf {
                    ctx.active_cams[cam] = true;
                }
            }
            for (q, ctx) in st.ctx.iter() {
                snapshots.push((
                    *q,
                    ctx.t0,
                    Arc::clone(&ctx.gt),
                    ctx.active_cams.clone(),
                ));
            }
        }
        // FC admission + visibility lookups, lock-free: the FC block
        // sees every (query, camera) pair with the spotlight's real
        // activation flag — inactive cameras included, so stateful FCs
        // (warm-up windows, duty cycles) observe deactivations too.
        let mut frames: Vec<(QueryId, usize, bool)> = Vec::new();
        for (q, t0, gt, active_cams) in &snapshots {
            for (cam, &act) in active_cams.iter().enumerate() {
                if !fc.admit(*q, cam, frame_no, now, act) {
                    continue;
                }
                frames.push((*q, cam, gt.visible(cam, now - t0)));
            }
        }
        // Short second critical section: allocate ids + ledger.
        {
            let mut st = inner.state.lock().unwrap();
            for (q, cam, present) in frames {
                if st.registry.status(q) != Some(QueryStatus::Active) {
                    continue; // cancelled between the two sections
                }
                let id = st.next_event_id;
                st.next_event_id += 1;
                let header = Header::new(id, cam, frame_no, now)
                    .with_query(q);
                st.ledgers.generated(q, id, present);
                outgoing.push(Event {
                    header,
                    payload: Payload::Frame {
                        entity_present: present,
                    },
                });
            }
        }
        for ev in outgoing {
            let _ = va_tx[va_part.route(ev.header.camera)]
                .send(Msg::Ev(ev));
        }
        // Promoted queries' contexts are built outside the lock; their
        // frames start on the next tick.
        finish_activation(&inner, admitted);
        frame_no += 1;
        next_fire += period;
        let now_i = Instant::now();
        if next_fire > now_i {
            std::thread::sleep(next_fire - now_i);
        } else {
            next_fire = now_i;
        }
    }
}

/// Shared executor loop: fair-share batching + backend scoring, with
/// the app's VA/CR block owning the payload transformation.
fn worker_loop(
    stage: Stage,
    mut block: AnalyticsBlock,
    rx: Receiver<Msg>,
    inner: Arc<Inner>,
    backend: Arc<dyn ScoreBackend>,
    max_batch_delay: Micros,
    mut forward: impl FnMut(Event),
) {
    let xi = backend.xi(stage);
    let gamma = inner.cfg.gamma();
    let drops_enabled = inner.cfg.drops_enabled;
    let deadline_window = gamma.min(max_batch_delay);
    // Max batch size follows the configured batching knob, matching
    // what the multi-query DES mode derives from the same config.
    let m_max = match inner.cfg.batching {
        crate::config::BatchingKind::Static { size } => size,
        crate::config::BatchingKind::Dynamic { max }
        | crate::config::BatchingKind::Nob { max } => max,
    };
    let mut batcher: FairShareBatcher<Event> =
        FairShareBatcher::new(m_max.max(1));
    let mut scratch = BatchScratch::default();

    fn handle(
        msg: Msg,
        stage: Stage,
        inner: &Inner,
        batcher: &mut FairShareBatcher<Event>,
        xi: &XiModel,
        gamma: Micros,
        drops_enabled: bool,
        deadline_window: Micros,
    ) -> bool {
        match msg {
            Msg::Stop => false,
            Msg::Register(q, w) => {
                batcher.register(q, w);
                true
            }
            Msg::Deregister(q) => {
                let left = batcher.deregister(q);
                if !left.is_empty() {
                    let mut st = inner.state.lock().unwrap();
                    for qe in left {
                        st.ledgers.dropped(q, qe.item.header.id, stage);
                    }
                }
                true
            }
            Msg::Ev(ev) => {
                let now = inner.now_us();
                let q = ev.header.query;
                let u = now - ev.header.src_arrival;
                let exempt = ev.header.avoid_drop || ev.header.probe;
                if drops_enabled
                    && drop_at_queue(exempt, u, xi.xi(1), gamma)
                {
                    inner
                        .state
                        .lock()
                        .unwrap()
                        .ledgers
                        .dropped(q, ev.header.id, stage);
                    return true;
                }
                let deadline = ev.header.src_arrival + deadline_window;
                let id = ev.header.id;
                let rejected = batcher.push(
                    q,
                    QueuedEvent {
                        item: ev,
                        id,
                        arrival: now,
                        deadline,
                    },
                );
                if let Some(qe) = rejected {
                    // Late in-flight event of a completed/cancelled
                    // query: account it so per-query conservation
                    // holds; do not resurrect the query.
                    inner
                        .state
                        .lock()
                        .unwrap()
                        .ledgers
                        .dropped(q, qe.item.header.id, stage);
                }
                true
            }
        }
    }

    'outer: loop {
        let now = inner.now_us();
        match batcher.poll(now, &xi) {
            BatcherPoll::Ready(batch) => {
                let spare = exec_batch(
                    stage,
                    batch,
                    &mut block,
                    backend.as_ref(),
                    &xi,
                    &mut scratch,
                    &mut forward,
                );
                batcher.recycle(spare);
                continue;
            }
            BatcherPoll::Timer(at) => {
                let wait = (at - now).max(0) as u64;
                match rx.recv_timeout(Duration::from_micros(
                    wait.min(100_000),
                )) {
                    Ok(msg) => {
                        if !handle(
                            msg,
                            stage,
                            &inner,
                            &mut batcher,
                            &xi,
                            gamma,
                            drops_enabled,
                            deadline_window,
                        ) {
                            break 'outer;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            BatcherPoll::Idle => {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(msg) => {
                        if !handle(
                            msg,
                            stage,
                            &inner,
                            &mut batcher,
                            &xi,
                            gamma,
                            drops_enabled,
                            deadline_window,
                        ) {
                            break 'outer;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        while let Ok(msg) = rx.try_recv() {
            if !handle(
                msg,
                stage,
                &inner,
                &mut batcher,
                &xi,
                gamma,
                drops_enabled,
                deadline_window,
            ) {
                break 'outer;
            }
        }
    }
    // Final flush: execute whatever is still queued.
    loop {
        match batcher.poll(BUDGET_INF / 2, &xi) {
            BatcherPoll::Ready(batch) => {
                let spare = exec_batch(
                    stage,
                    batch,
                    &mut block,
                    backend.as_ref(),
                    &xi,
                    &mut scratch,
                    &mut forward,
                );
                batcher.recycle(spare);
            }
            _ => break,
        }
    }
}

/// Reusable per-worker batch buffers: the batch's events regrouped by
/// query plus one columnar score buffer for the whole batch — the
/// per-group `Vec<Event>`/`Vec<f32>` allocations the old grouping made
/// are gone.
#[derive(Default)]
struct BatchScratch {
    events: Vec<Event>,
    scores: Vec<f32>,
}

/// Execute one cross-query batch: one shared execution sleep for the
/// whole batch, then per-query-group scoring (each query carries its
/// own embedding), the app block's score-to-payload transformation,
/// and forwarding. Returns the emptied batch vec for the caller to
/// recycle into its batcher.
fn exec_batch(
    stage: Stage,
    mut batch: Vec<QueuedEvent<Event>>,
    block: &mut AnalyticsBlock,
    backend: &dyn ScoreBackend,
    xi: &XiModel,
    scratch: &mut BatchScratch,
    forward: &mut impl FnMut(Event),
) -> Vec<QueuedEvent<Event>> {
    if batch.is_empty() {
        return batch;
    }
    let b = batch.len();
    let dur = xi.xi(b).clamp(0, 50_000);
    std::thread::sleep(Duration::from_micros(dur as u64));

    // Group events by query — a stable sort preserves per-query FIFO
    // order — then score each query group into one shared columnar
    // buffer (`scores[i]` belongs to `events[i]`).
    let events = &mut scratch.events;
    events.clear();
    events.extend(batch.drain(..).map(|qe| qe.item));
    events.sort_by_key(|ev| ev.header.query);
    let scores = &mut scratch.scores;
    scores.clear();
    let mut start = 0;
    while start < events.len() {
        let q = events[start].header.query;
        let mut end = start + 1;
        while end < events.len() && events[end].header.query == q {
            end += 1;
        }
        backend.score_into(stage, q, &events[start..end], scores);
        debug_assert_eq!(scores.len(), end, "one score per event");
        start = end;
    }
    // One virtual call transforms the whole batch (the block sees the
    // scores in event order); forwarding order is unchanged.
    block.apply_scores(events, scores, &ScoreParams { threshold: 0.5 });
    for ev in events.drain(..) {
        forward(ev);
    }
    batch
}

/// Sink: completion accounting + per-query TL updates + QF.
fn sink_loop(
    inner: Arc<Inner>,
    rx: Receiver<Msg>,
    mut qf: Box<dyn QueryFusion>,
) {
    let gamma = inner.cfg.gamma();
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(Msg::Ev(ev)) => {
                let now = inner.now_us();
                let q = ev.header.query;
                if ev.header.probe {
                    continue;
                }
                let latency = now - ev.header.src_arrival;
                let detected = matches!(
                    ev.payload,
                    Payload::Detection { detected: true, .. }
                );
                {
                    let mut st = inner.state.lock().unwrap();
                    st.ledgers.completed(
                        q,
                        ev.header.id,
                        latency,
                        gamma,
                        detected,
                    );
                    if let Some(ctx) = st.ctx_of(q) {
                        if detected {
                            ctx.detections += 1;
                        }
                        ctx.tl.on_detection(
                            ev.header.camera,
                            ev.header.captured,
                            detected,
                        );
                    }
                }
                // QF user-logic, outside the state lock.
                if detected && qf.on_detection(&ev) {
                    inner
                        .fusion_updates
                        .fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(Msg::Stop) => break,
            Ok(_) => {}
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn small_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.num_cameras = 8;
        c.workload.vertices = 40;
        c.workload.edges = 100;
        c.fps = 10.0;
        c.gamma_ms = 2_000.0;
        c.cluster.va_instances = 2;
        c.cluster.cr_instances = 2;
        c
    }

    fn policy(max_active: usize, qcap: usize) -> AdmissionPolicy {
        AdmissionPolicy {
            max_active,
            max_active_cameras: 10_000,
            queue_capacity: qcap,
        }
    }

    fn spec(label: &str, cam: usize, secs: f64) -> QuerySpec {
        QuerySpec {
            lifetime_secs: secs,
            ..QuerySpec::new(label, cam)
        }
    }

    #[test]
    fn service_runs_queries_to_completion() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(8, 4),
            Arc::new(SimBackend::default()),
        )
        .unwrap();
        let (a, st_a) = svc.submit(spec("alpha", 0, 0.8)).unwrap();
        let (b, st_b) = svc.submit(spec("beta", 3, 0.8)).unwrap();
        assert_eq!(st_a, QueryStatus::Active);
        assert_eq!(st_b, QueryStatus::Active);
        std::thread::sleep(Duration::from_millis(1_400));
        // Windows elapsed: both completed by the feed loop.
        assert_eq!(svc.status(a), Some(QueryStatus::Completed));
        assert_eq!(svc.status(b), Some(QueryStatus::Completed));
        let report = svc.stop();
        assert_eq!(report.peak_concurrent, 2);
        for q in report.queries.iter() {
            let s = q.summary.as_ref().expect("per-query ledger");
            assert!(s.generated > 0, "query {} idle", q.id);
            assert!(s.conserved(), "query {}: {:?}", q.id, s);
        }
        assert!(report.aggregate.conserved());
    }

    #[test]
    fn admission_queue_and_reject_at_runtime() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(1, 1),
            Arc::new(SimBackend::default()),
        )
        .unwrap();
        let (a, st_a) = svc.submit(spec("a", 0, 5.0)).unwrap();
        let (b, st_b) = svc.submit(spec("b", 1, 5.0)).unwrap();
        let (c, st_c) = svc.submit(spec("c", 2, 5.0)).unwrap();
        assert_eq!(st_a, QueryStatus::Active);
        assert_eq!(st_b, QueryStatus::Queued);
        assert_eq!(st_c, QueryStatus::Rejected);
        assert_eq!(svc.status(c), Some(QueryStatus::Rejected));
        // Cancelling the active query promotes the wait-listed one.
        svc.cancel(a).unwrap();
        assert_eq!(svc.status(b), Some(QueryStatus::Active));
        let report = svc.stop();
        assert_eq!(report.peak_concurrent, 1);
    }

    #[test]
    fn cancel_mid_run_keeps_ledgers_consistent() {
        let svc = TrackingService::start(
            small_cfg(),
            policy(4, 2),
            Arc::new(SimBackend::default()),
        )
        .unwrap();
        let (a, _) = svc.submit(spec("a", 0, 5.0)).unwrap();
        std::thread::sleep(Duration::from_millis(400));
        svc.cancel(a).unwrap();
        assert_eq!(svc.status(a), Some(QueryStatus::Cancelled));
        std::thread::sleep(Duration::from_millis(200));
        let report = svc.stop();
        let qa = &report.queries[0];
        if let Some(s) = &qa.summary {
            assert!(s.conserved(), "{s:?}");
        }
    }
}
