//! Multi-query discrete-event engine: N tracking queries multiplexed
//! over one shared deployment, in virtual time.
//!
//! Queries arrive as a Poisson process. Each admitted query tracks its
//! own entity (its own random walk, ground truth and spotlight TL) but
//! shares the physical FC/VA/CR/UV deployment with every other query:
//! a camera produces one logical event per query that has it active,
//! events are tagged with their [`QueryId`], and the shared VA/CR
//! executors form **cross-query batches** under weighted fair sharing
//! ([`FairShareBatcher`]). The tuning triangle is keyed per query —
//! per-(task, query) [`BudgetManager`]s, per-query drop/probe state,
//! per-query ledgers, and per-(stage, app) ξ cost models (each query
//! batches/drops under *its* app's service cost, scaled off the
//! executor's online hardware calibration) — so one query collapsing
//! its completion budget cannot starve or mis-account the rest.
//!
//! Modelling simplifications relative to [`crate::coordinator::des`]
//! (documented, deliberate): device clocks are unskewed (the skew
//! invariance of the tuning logic is property-tested separately) and
//! TL (de)activation commands apply at evaluation time rather than
//! after a control-message latency.

use std::sync::Arc;

use crate::apps::{AppCatalog, AppDefinition};
use crate::config::{
    AppKind, BatchingKind, ExperimentConfig, MultiQueryConfig,
};
use crate::coordinator::topology::Topology;
use crate::dataflow::{
    ContentionResolver, Event, FeedbackEnvelope, FeedbackRouter,
    FeedbackState, FilterControl, ModelVariant, Payload, QueryFusion,
    QueryId, SimCtx, Stage, TlEnv, TrackingLogic, TruthSource,
    VideoAnalytics,
};
use crate::engine::ShardedDes;
use crate::metrics::{QueryLedgers, Summary};
use crate::obs::{
    span_begin, span_end, Gate, MetricsRegistry, MetricsSnapshot,
    NullSink, ObsSink, QueryPhase, Scope, TraceEvent,
};
use crate::roadnet::{
    generate, partition, place_cameras, Camera, Graph, Partition,
};
use crate::service::admission::{
    Admission, AdmissionController, AdmissionPolicy,
};
use crate::service::query::{
    QueryRegistry, QueryReport, QuerySpec, QueryStatus,
};
use crate::service::scheduler::FairShareBatcher;
use crate::sim::{
    backoff_delay, ComputeModel, EntityWalk, FaultModel, GroundTruth,
    NetModel,
};
use crate::tuning::adapt::{
    AdaptController, AdaptationCommand, AdaptationState,
};
use crate::tuning::budget::BUDGET_INF;
use crate::tuning::{
    drop_at_exec, drop_at_queue, drop_at_transmit, BatcherPoll,
    BudgetManager, EventRecord, QueuedEvent, Signal, XiModel,
    ONLINE_XI_EMA,
};
use crate::util::{millis, rng, secs, FastMap, Micros, Rng, SEC};

/// How far ahead the TL spotlight horizon is pushed while any of a
/// query's active cameras is dark (graceful degradation: the entity may
/// travel unobserved, so the plausible region widens).
const FAULT_WIDEN: Micros = 2 * SEC;

/// Simulation events, ordered by time then sequence.
enum Ev {
    /// A camera captures its next frame (one logical event per query
    /// that has the camera active).
    FrameTick { cam: usize },
    /// The `idx`-th query of the arrival schedule is submitted.
    QueryArrive { idx: usize },
    /// An active query's tracking window elapsed.
    QueryEnd { query: QueryId },
    /// A dataflow event arrives at `task` (post-network).
    Arrive {
        task: usize,
        ev: Event,
        batch: Option<(u64, usize)>,
    },
    /// A batcher auto-submit timer.
    BatchTimer { task: usize, seq: u64 },
    /// A cross-query batch finishes executing at `task`.
    ExecDone {
        task: usize,
        batch: Vec<QueuedEvent<Event>>,
        start: Micros,
        xi_est: Micros,
        actual: Micros,
        /// Σ of per-app cost multipliers over `batch` (its effective
        /// size), computed once at formation.
        rel_sum: f64,
    },
    /// A budget signal for one query arrives at `task`.
    SignalAt {
        task: usize,
        query: QueryId,
        sig: Signal,
    },
    /// Periodic per-query TL spotlight evaluation.
    TlTick,
    /// A scheduled fault transition instant (node/camera aliveness may
    /// have flipped).
    FaultTick,
    /// A detection (metadata) reaches a query's TL.
    TlDetection {
        query: QueryId,
        camera: usize,
        captured: Micros,
        detected: bool,
    },
}

/// Shared executor state (VA/CR) — one fair-share batcher, per-query
/// budgets.
struct MqTask {
    stage: Stage,
    node: usize,
    batcher: FairShareBatcher<Event>,
    budgets: FastMap<QueryId, BudgetManager>,
    /// Engine-level stage calibration — the *estimator*, refined
    /// online when `online_xi` is set. Per-application ξ models are
    /// `xi.scaled(rel[kind])` snapshots: hardware drift is shared
    /// across tenants, app cost ratios are static composition facts.
    xi: XiModel,
    /// Frozen nominal cost model — the simulated hardware's ground
    /// truth, from which *actual* durations are generated (× jitter ×
    /// compute slowdown). Never the estimator: observing durations
    /// derived from the model being refined would compound any
    /// slowdown geometrically.
    xi_true: XiModel,
    /// Per-app service-cost multipliers relative to the engine-level
    /// calibration (the default app's slot is exactly 1.0), indexed by
    /// [`AppKind::index`]. Minted from the [`AppCatalog`]'s va/cr cost
    /// metadata at construction.
    rel: [f64; 4],
    busy: bool,
    timer_seq: u64,
    drop_count: u64,
    /// Applied QF refinements, per query (the feedback edge); each
    /// executor receives its own [`Payload::QueryUpdate`] copies and
    /// discards stale deliveries.
    feedback: FeedbackState,
}

impl MqTask {
    /// This task's ξ model for an application: the hardware calibration
    /// scaled by the app's cost multiplier. For the default app this is
    /// a bit-exact copy (rel = 1.0).
    fn app_xi(&self, kind: AppKind) -> XiModel {
        self.xi.scaled(self.rel[kind.index()])
    }
}

/// The UDF blocks one query runs, minted from *its* app's
/// [`AppDefinition`] at activation ([`QuerySpec::app`] →
/// [`AppCatalog`]) — concurrent queries can run different
/// compositions over the shared workers. Blocks are kept for the whole
/// run (late in-flight events of a finished query still step through
/// the same block, preserving the engine RNG stream).
struct QueryBlocks {
    fc: Box<dyn FilterControl>,
    va: Box<dyn VideoAnalytics>,
    cr: Box<dyn ContentionResolver>,
    qf: Box<dyn QueryFusion>,
    /// Refinements this query's QF block performed.
    fusion_updates: u64,
}

impl QueryBlocks {
    /// Mint a fresh per-query block set from an application.
    fn mint(app: &AppDefinition) -> Self {
        Self {
            fc: app.make_fc(),
            va: app.make_va(),
            cr: app.make_cr(),
            qf: app.make_qf(),
            fusion_updates: 0,
        }
    }
}

/// Per-query runtime state while active.
struct QueryCtx {
    /// Activation time (the query's walk/ground-truth run on a clock
    /// starting here).
    t0: Micros,
    gt: GroundTruth,
    tl: Box<dyn TrackingLogic>,
    active_cams: Vec<bool>,
    detections: u64,
    peak_active: usize,
}

/// Per-query ground-truth view for the VA block: each query's walk
/// runs on a clock starting at its activation time.
struct MqTruth<'a> {
    ctx: &'a FastMap<QueryId, QueryCtx>,
}

impl TruthSource for MqTruth<'_> {
    fn interval_index(
        &self,
        query: QueryId,
        camera: usize,
        captured: Micros,
    ) -> Option<usize> {
        let c = self.ctx.get(&query)?;
        c.gt.interval_index(camera, captured - c.t0)
    }
}

/// Result of a multi-query DES run.
#[derive(Debug)]
pub struct MultiQueryResult {
    /// Per-query reports, in submission order.
    pub queries: Vec<QueryReport>,
    /// Whole-service aggregate summary.
    pub aggregate: Summary,
    /// Peak number of concurrently active queries.
    pub peak_concurrent: usize,
    /// Queries rejected by admission control.
    pub rejected: usize,
    /// Queries that were wait-listed at least once.
    pub queued: usize,
    /// Query-embedding refinements performed by the app's QF block
    /// across all queries (0 unless the composition enables fusion).
    pub fusion_updates: u64,
    /// Total simulation events dispatched by the shared
    /// [`ShardedDes`] merge loop — the numerator of the events/sec
    /// throughput metric reported by `benches/hotpath.rs`.
    pub core_events: u64,
    /// End-of-run snapshot of the engine's metrics registry (always
    /// recorded — counters are sink-independent).
    pub metrics: MetricsSnapshot,
    /// Raw `next_u64` draws the engine RNG made — the determinism
    /// probe the obs property tests compare across sinks.
    pub rng_draws: u64,
}

impl MultiQueryResult {
    /// Reports of queries that actually ran (activated at some point).
    pub fn activated(&self) -> impl Iterator<Item = &QueryReport> {
        self.queries.iter().filter(|q| q.activated_s.is_some())
    }
}

/// The multi-query discrete-event engine, generic over the trace sink
/// (the [`NullSink`] default monomorphizes every emission away; the
/// metrics registry stays on either way — atomics never touch the RNG
/// or the event order).
pub struct MultiQueryDes<S: ObsSink = NullSink> {
    cfg: ExperimentConfig,
    topo: Topology,
    graph: Graph,
    cams: Vec<Camera>,
    net: NetModel,
    /// Resolves each query's `QuerySpec.app` to the composition it
    /// runs; per-query FC/VA/CR/QF/TL instances are minted from it at
    /// activation. The engine only talks to blocks through the
    /// dataflow traits.
    catalog: AppCatalog,
    /// Per-query block instances, insertion keyed by [`QueryId`].
    blocks: FastMap<QueryId, QueryBlocks>,
    registry: QueryRegistry,
    admission: AdmissionController,
    /// Active query contexts (insertion-ordered id list for iteration
    /// determinism).
    ctx: FastMap<QueryId, QueryCtx>,
    active: Vec<QueryId>,
    /// (detections, peak_active) of queries that already finished.
    finished_stats: FastMap<QueryId, (u64, usize)>,
    /// Arrival schedule: (arrival time, spec), in submission order.
    schedule: Vec<(Micros, QuerySpec)>,
    service_end: Micros,
    tasks: Vec<MqTask>,
    fc_budget: Vec<FastMap<QueryId, BudgetManager>>,
    fc_xi: XiModel,
    /// Per-node time-varying execution slowdown (compute dynamism).
    compute: ComputeModel,
    /// `cfg.service.online_xi`, hoisted.
    online_xi: bool,
    /// Scheduled fault injection (node crashes, link partitions,
    /// camera dropouts, message loss). Static when
    /// `cfg.service.fault_events` is empty — every hook then
    /// short-circuits and the engine is bit-identical to a build
    /// without the fault machinery.
    faults: FaultModel,
    /// Dedicated RNG stream for message-loss draws; never advanced
    /// unless the schedule has loss windows, so `rng_draws` stays
    /// untouched on loss-free runs.
    fault_rng: Rng,
    /// Per-event re-dispatch attempts after batch voiding (bounded by
    /// `recovery.max_retries`).
    retry_counts: FastMap<u64, u32>,
    /// Where arrivals addressed to each task actually land (identity
    /// until a permanent crash installs a redirect to a survivor).
    task_redirect: Vec<usize>,
    /// Last-observed node aliveness (diffed at each fault tick).
    node_was_up: Vec<bool>,
    /// Last-observed camera aliveness.
    cam_was_up: Vec<bool>,
    /// Geographic shard layout of the roadnet (K=1 unless
    /// `cfg.sharding.shards` says otherwise).
    part: Partition,
    /// Camera index -> owning shard (by the camera's roadnet vertex).
    shard_of_cam: Vec<u32>,
    /// Task index -> owning shard (FC follows its camera; VA/CR are
    /// striped round-robin; TL lives on shard 0).
    shard_of_task: Vec<u32>,
    core: ShardedDes<Ev>,
    next_event_id: u64,
    next_batch_seq: u64,
    frame_counters: Vec<u64>,
    ledgers: QueryLedgers,
    /// batch seq -> (remaining, slowest latency, slowest id, Σξ of
    /// slowest, slowest query, slowest camera).
    sink_batches:
        FastMap<u64, (usize, Micros, u64, Micros, QueryId, usize)>,
    peak_concurrent: usize,
    ever_queued: u64,
    fusion_updates: u64,
    /// Stamps QF refinements with per-query update sequence numbers.
    router: FeedbackRouter,
    /// Commanded per-camera (resolution, variant) state — every
    /// [`Payload::Adaptation`] delivery lands in
    /// `Self::apply_adaptation` and nowhere else. Engine-global:
    /// commands steer cameras, which all queries share.
    adapt: AdaptationState,
    /// Sink-side accuracy–latency controller: mints
    /// [`AdaptationCommand`]s from per-completion deadline slack.
    adapt_ctl: AdaptController,
    /// `adapt_ctl.active()`, hoisted: every pricing/stride/bytes hook
    /// is one branch and bit-identical when the plane is inert.
    adapt_on: bool,
    m_max: usize,
    rng: Rng,
    now: Micros,
    /// Reusable hot-path buffers (drop filtering, staged post-exec
    /// events + their (u, π) meta, outgoing transmissions, per-query
    /// spotlight refresh) — allocations circulate instead of being
    /// re-made per batch/tick.
    kept_scratch: Vec<QueuedEvent<Event>>,
    staged_scratch: Vec<Event>,
    meta_scratch: Vec<(Micros, Micros, usize)>,
    outgoing_scratch: Vec<Event>,
    active_scratch: Vec<usize>,
    obs: S,
    metrics: MetricsRegistry,
}

impl MultiQueryDes {
    /// Build the engine for the stock application the config describes
    /// (`cfg.app` composition, `cfg.tl` spotlight).
    pub fn new(cfg: ExperimentConfig, mq: MultiQueryConfig) -> Self {
        let app = crate::apps::resolve(&cfg);
        Self::with_app(cfg, mq, &app)
    }

    /// Build the engine for an arbitrary [`AppDefinition`].
    pub fn with_app(
        cfg: ExperimentConfig,
        mq: MultiQueryConfig,
        app: &AppDefinition,
    ) -> Self {
        Self::with_app_sink(cfg, mq, app, NullSink)
    }
}

impl<S: ObsSink> MultiQueryDes<S> {
    /// Build the engine for an arbitrary application *and* trace sink
    /// — the flight-recorder entry point.
    pub fn with_app_sink(
        cfg: ExperimentConfig,
        mq: MultiQueryConfig,
        app: &AppDefinition,
        sink: S,
    ) -> Self {
        let graph = generate(&cfg.workload, cfg.seed);
        let cams = place_cameras(
            &graph,
            cfg.num_cameras,
            0,
            cfg.workload.fov_m,
        );
        let topo = Topology::schedule(&cfg);
        let net = NetModel::new(&cfg.network, topo.nodes);

        // Per-query app resolution: the schedule stamps every spec
        // with the kind the *passed* app is registered under (so a
        // custom/explicit `with_app` composition actually runs —
        // `cfg.app` alone would silently resolve to a stock app when
        // the two disagree). `set_app_cycle` overrides this for
        // heterogeneous mixes.
        let catalog = AppCatalog::new(app.clone(), cfg.app, cfg.tl);

        // Online ξ: the engine-level stage *estimators* carry an EMA
        // so observed batch durations refine them (frozen otherwise);
        // the nominal base models — the simulated hardware — stay
        // untouched either way.
        let online_xi = cfg.service.online_xi;
        let mk_xi = |x: &XiModel| {
            if online_xi {
                x.clone().with_ema(ONLINE_XI_EMA)
            } else {
                x.clone()
            }
        };
        let va_base = XiModel::affine_ms(
            cfg.service.va_alpha_ms,
            cfg.service.va_beta_ms,
        );
        let cr_base = XiModel::affine_ms(
            cfg.service.cr_alpha_ms,
            cfg.service.cr_beta_ms,
        );
        let va_xi = mk_xi(&va_base);
        let cr_xi = mk_xi(&cr_base);
        let fc_xi = XiModel::affine_ms(cfg.service.fc_ms, 0.01);

        let m_max = match cfg.batching {
            BatchingKind::Static { size } => size,
            BatchingKind::Dynamic { max } | BatchingKind::Nob { max } => {
                max
            }
        };

        // Per-(stage, app) ξ: each app's service cost *relative to the
        // engine-level calibration* (which is the default app's — so
        // its multiplier is exactly 1.0 and homogeneous runs are
        // bit-identical to an engine without per-app ξ). A query's
        // drop gates, deadlines and budget math then price its own
        // composition instead of one engine-wide cost model.
        let stage_rel = |stage: Stage| -> [f64; 4] {
            let cost = |kind: AppKind| {
                let a = catalog.get(kind);
                match stage {
                    Stage::Va => a.va_cost,
                    Stage::Cr => a.cr_cost,
                    _ => 1.0,
                }
            };
            let base = cost(catalog.default_kind()).max(1e-9);
            let mut rel = [1.0; 4];
            for kind in [
                AppKind::App1,
                AppKind::App2,
                AppKind::App3,
                AppKind::App4,
            ] {
                rel[kind.index()] = cost(kind) / base;
            }
            rel
        };

        let mut tasks = Vec::with_capacity(topo.tasks.len());
        for info in topo.tasks.iter() {
            let (xi, xi_true) = match info.stage {
                Stage::Va => (va_xi.clone(), va_base.clone()),
                Stage::Cr => (cr_xi.clone(), cr_base.clone()),
                _ => (fc_xi.clone(), fc_xi.clone()),
            };
            tasks.push(MqTask {
                stage: info.stage,
                node: info.node,
                batcher: FairShareBatcher::new(m_max.max(1)),
                budgets: FastMap::default(),
                xi,
                xi_true,
                rel: stage_rel(info.stage),
                busy: false,
                timer_seq: 0,
                drop_count: 0,
                feedback: FeedbackState::new(),
            });
        }

        // Poisson arrival schedule with cycling priorities and random
        // start cameras (every query is seeded with a last-seen camera;
        // unseeded bootstraps are an admission-test concern).
        let mut r = rng(cfg.seed, 0x5E81);
        let mut schedule = Vec::with_capacity(mq.num_queries);
        let mut t: Micros = 0;
        let levels = mq.priority_levels.max(1);
        for i in 0..mq.num_queries {
            if i > 0 {
                let u = r.f64().max(1e-12);
                let gap = -u.ln() * mq.mean_interarrival_secs;
                t += secs(gap.min(10.0 * mq.mean_interarrival_secs));
            }
            let start_camera = r.range_u(0, cfg.num_cameras.max(1));
            schedule.push((
                t,
                QuerySpec {
                    app: catalog.default_kind(),
                    label: format!("q{i}"),
                    start_camera: Some(start_camera),
                    priority: (i as u8 % levels) + 1,
                    lifetime_secs: mq.lifetime_secs,
                },
            ));
        }
        let service_end = schedule
            .iter()
            .map(|(at, spec)| *at + secs(spec.lifetime_secs))
            .max()
            .unwrap_or(0);

        let num_cameras = cfg.num_cameras;
        let policy = AdmissionPolicy::from(&mq);
        let seed = cfg.seed;
        let compute =
            ComputeModel::new(&cfg.service.compute_events, topo.nodes);
        let faults = FaultModel::new(
            &cfg.service.fault_events,
            topo.nodes,
            num_cameras,
        );
        let nodes = topo.nodes;
        let task_redirect: Vec<usize> = (0..topo.tasks.len()).collect();

        // Geographic sharding: cameras follow their roadnet vertex,
        // FC tasks follow their camera, shared executors (VA/CR) are
        // striped across shards, and the query/TL/fault machinery is
        // pinned to shard 0. Routing only picks which heap holds an
        // event — the merge serialises dispatch, so any K is
        // bit-identical to K=1.
        let part = partition(&graph, cfg.sharding.shards);
        let shard_of_cam: Vec<u32> = (0..num_cameras)
            .map(|c| {
                cams.get(c)
                    .map_or(0, |cam| part.shard_of_vertex(cam.vertex))
            })
            .collect();
        let shard_of_task: Vec<u32> = topo
            .tasks
            .iter()
            .map(|info| match info.stage {
                Stage::Fc => shard_of_cam[info.instance],
                Stage::Va | Stage::Cr => {
                    (info.instance % part.shards()) as u32
                }
                _ => 0,
            })
            .collect();
        let mut core =
            ShardedDes::with_threads(part.shards(), cfg.sharding.threads);
        if cfg!(feature = "strict-invariants") && part.shards() > 1 {
            core.set_entity_tracking(true);
        }
        // Publish the initial per-(app, stage) ξ(1) prices; refreshed
        // whenever online calibration moves the estimator.
        let metrics = MetricsRegistry::new();
        for t in &tasks {
            if matches!(t.stage, Stage::Va | Stage::Cr) {
                for k in 0..t.rel.len() {
                    metrics.set_app_xi(
                        k,
                        t.stage,
                        t.xi.scaled(t.rel[k]).xi(1),
                    );
                }
            }
        }
        // Adaptation plane: the controller mints commands against the
        // *default* app's CR variant (the downshift-capable stage);
        // per-event pricing re-derives each event's own nominal from
        // the catalog, so heterogeneous mixes stay stage-isolated.
        let adapt = AdaptationState::new(&cfg.adaptation, num_cameras);
        let adapt_ctl = AdaptController::new(
            &cfg.adaptation,
            num_cameras,
            cfg.gamma(),
            app.cr_variant,
        );
        Self {
            cfg,
            topo,
            graph,
            cams,
            net,
            catalog,
            blocks: FastMap::default(),
            registry: QueryRegistry::new(),
            admission: AdmissionController::new(policy),
            ctx: FastMap::default(),
            active: Vec::new(),
            finished_stats: FastMap::default(),
            schedule,
            service_end,
            tasks,
            fc_budget: (0..num_cameras).map(|_| FastMap::default()).collect(),
            fc_xi,
            compute,
            online_xi,
            faults,
            fault_rng: rng(seed, 0x3FA17),
            retry_counts: FastMap::default(),
            task_redirect,
            node_was_up: vec![true; nodes],
            cam_was_up: vec![true; num_cameras],
            part,
            shard_of_cam,
            shard_of_task,
            core,
            next_event_id: 0,
            next_batch_seq: 0,
            frame_counters: vec![0; num_cameras],
            ledgers: QueryLedgers::new(),
            sink_batches: FastMap::default(),
            peak_concurrent: 0,
            ever_queued: 0,
            fusion_updates: 0,
            router: FeedbackRouter::new(),
            adapt_on: adapt_ctl.active(),
            adapt,
            adapt_ctl,
            m_max: m_max.max(1),
            rng: rng(seed, 0x3DE5),
            now: 0,
            kept_scratch: Vec::new(),
            staged_scratch: Vec::new(),
            meta_scratch: Vec::new(),
            outgoing_scratch: Vec::new(),
            active_scratch: Vec::new(),
            obs: sink,
            metrics,
        }
    }

    // ---- event plumbing --------------------------------------------------

    /// Owning shard for an event: camera-addressed events follow the
    /// camera's vertex, task-addressed events follow the task, and the
    /// global machinery (query lifecycle, TL, faults) lives on shard 0.
    fn shard_of(&self, ev: &Ev) -> u32 {
        match ev {
            Ev::FrameTick { cam } => self.shard_of_cam[*cam],
            Ev::Arrive { task, .. }
            | Ev::BatchTimer { task, .. }
            | Ev::ExecDone { task, .. }
            | Ev::SignalAt { task, .. } => self.shard_of_task[*task],
            Ev::QueryArrive { .. }
            | Ev::QueryEnd { .. }
            | Ev::TlTick
            | Ev::FaultTick
            | Ev::TlDetection { .. } => 0,
        }
    }

    fn push(&mut self, t: Micros, ev: Ev) {
        let shard = self.shard_of(&ev);
        // Entity ownership is tracked per source event id; probes reuse
        // a live event's id and QF refinements are broadcast to many
        // tasks, so neither participates in the exactly-one-owner
        // bookkeeping.
        let entity = if self.core.shards() > 1 {
            match &ev {
                Ev::Arrive { ev, .. }
                    if !ev.header.probe
                        && !matches!(
                            ev.payload,
                            Payload::QueryUpdate(_)
                                | Payload::Adaptation(_)
                        ) =>
                {
                    Some(ev.header.id)
                }
                _ => None,
            }
        } else {
            None
        };
        let msg = self.core.schedule(t, shard, ev);
        if let Some(id) = entity {
            match msg {
                Some(m) => self.core.record_handoff(id, m.from, m.to),
                None => self.core.note_arrival(id, shard),
            }
        }
        if let Some(m) = msg {
            self.metrics.cross_shard_msg();
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::CrossShard {
                        from_shard: m.from,
                        to_shard: m.to,
                        seq: m.seq,
                    },
                );
            }
        }
    }

    /// Override which application each scheduled query runs, cycling
    /// through `kinds` in submission order. The Poisson schedule
    /// defaults every query to the engine-level app; this is how an
    /// experiment runs a *heterogeneous* query mix (each admitted
    /// query then gets blocks minted from its own composition). Call
    /// before [`Self::run`].
    pub fn set_app_cycle(&mut self, kinds: &[AppKind]) {
        if kinds.is_empty() {
            return;
        }
        for (i, (_, spec)) in self.schedule.iter_mut().enumerate() {
            spec.app = kinds[i % kinds.len()];
        }
    }

    /// Run to completion: all arrivals, all lifetimes, plus a drain of
    /// two γ for in-flight events.
    pub fn run(mut self) -> MultiQueryResult {
        self.metrics.set_shards(self.core.shards());
        for cam in 0..self.cfg.num_cameras {
            let phase = self
                .rng
                .range_i64(0, (SEC as f64 / self.cfg.fps) as i64);
            self.push(phase, Ev::FrameTick { cam });
        }
        for idx in 0..self.schedule.len() {
            let at = self.schedule[idx].0;
            self.push(at, Ev::QueryArrive { idx });
        }
        self.push(SEC, Ev::TlTick);
        if !self.faults.is_static() {
            // Transition instants are schedule data, known up front;
            // the horizon grows with late promotions, so every tick is
            // scheduled — ones past the final horizon never pop.
            let ticks: Vec<Micros> = self.faults.transitions().to_vec();
            for at in ticks {
                self.push(at, Ev::FaultTick);
            }
        }

        if self.obs.enabled() {
            // The configured dynamism schedule, stamped at its
            // scheduled virtual times (emitted up front: the steps are
            // known before the run starts).
            for e in &self.cfg.service.compute_events {
                self.obs.emit(
                    secs(e.at_sec),
                    &TraceEvent::ComputeFactor {
                        node: e.node.map_or(-1, |n| n as i64),
                        factor: e.factor,
                    },
                );
            }
            for e in &self.cfg.network.events {
                self.obs.emit(
                    secs(e.at_sec),
                    &TraceEvent::Bandwidth { bps: e.bandwidth_bps },
                );
            }
        }

        // Horizon re-evaluated each step: promotions extend
        // `service_end` mid-run.
        loop {
            let horizon = self.service_end + 2 * self.cfg.gamma();
            let Some((t, ev)) = self.core.pop_until(horizon) else {
                break;
            };
            self.now = t;
            let sp = span_begin(&self.obs);
            self.dispatch(ev);
            span_end(&self.obs, Scope::Dispatch, sp);
        }
        self.report()
    }

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::FrameTick { cam } => self.on_frame_tick(cam),
            Ev::QueryArrive { idx } => self.on_query_arrive(idx),
            Ev::QueryEnd { query } => self.on_query_end(query),
            Ev::Arrive { task, ev, batch } => {
                self.on_arrive(task, ev, batch)
            }
            Ev::BatchTimer { task, seq } => {
                if self.tasks[task].timer_seq == seq
                    && !self.tasks[task].busy
                {
                    self.try_form_batch(task);
                }
            }
            Ev::ExecDone {
                task,
                batch,
                start,
                xi_est,
                actual,
                rel_sum,
            } => self
                .on_exec_done(task, batch, start, xi_est, actual, rel_sum),
            Ev::SignalAt { task, query, sig } => {
                // λ̄/λ⃗ caps derive from *this query's* cost model.
                let kind = self.query_app(query);
                let t = &mut self.tasks[task];
                if let Some(bm) = t.budgets.get_mut(&query) {
                    let xi = t.xi.scaled(t.rel[kind.index()]);
                    bm.apply(sig, &xi);
                }
            }
            Ev::TlTick => self.on_tl_tick(),
            Ev::FaultTick => self.on_fault_tick(),
            Ev::TlDetection {
                query,
                camera,
                captured,
                detected,
            } => {
                let Some(ctx) = self.ctx.get_mut(&query) else {
                    return; // query already finished
                };
                ctx.tl.on_detection(camera, captured, detected);
                if detected {
                    self.refresh_active_set(query);
                }
            }
        }
    }

    // ---- query lifecycle -------------------------------------------------

    fn active_cameras_total(&self) -> usize {
        self.active
            .iter()
            .map(|q| {
                self.ctx[q]
                    .active_cams
                    .iter()
                    .filter(|&&a| a)
                    .count()
            })
            .sum()
    }

    fn on_query_arrive(&mut self, idx: usize) {
        // One clone (the registry stores the spec); admission reads
        // the schedule's copy by reference.
        let id = self
            .registry
            .submit(self.schedule[idx].1.clone(), self.now);
        let decision = self.admission.decide(
            &self.schedule[idx].1,
            self.registry.num_active(),
            self.registry.num_queued(),
            self.active_cameras_total(),
            self.cfg.num_cameras,
        );
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::QueryLifecycle {
                    query: id,
                    phase: QueryPhase::Submitted,
                },
            );
        }
        match decision {
            Admission::Admit => self.activate_query(id),
            Admission::Queue => {
                self.ever_queued += 1;
                self.registry
                    .enqueue(id)
                    .expect("submitted query can queue");
                if self.obs.enabled() {
                    self.obs.emit(
                        self.now,
                        &TraceEvent::QueryLifecycle {
                            query: id,
                            phase: QueryPhase::Queued,
                        },
                    );
                }
            }
            Admission::Reject(_) => {
                self.registry
                    .reject(id, self.now)
                    .expect("submitted query can be rejected");
                if self.obs.enabled() {
                    self.obs.emit(
                        self.now,
                        &TraceEvent::QueryLifecycle {
                            query: id,
                            phase: QueryPhase::Rejected,
                        },
                    );
                }
            }
        }
    }

    fn activate_query(&mut self, id: QueryId) {
        self.registry
            .activate(id, self.now)
            .expect("admission checked the transition");
        // Copy the scalar spec fields out instead of cloning the whole
        // spec (the label `String` is the only heap part).
        let (lifetime, start_cam, weight, kind) = {
            let spec = &self.registry.record(id).unwrap().spec;
            (
                secs(spec.lifetime_secs),
                spec.start_camera
                    .unwrap_or(0)
                    .min(self.cams.len().saturating_sub(1)),
                spec.weight(),
                spec.app,
            )
        };
        // Mint this query's own blocks from *its* application — the
        // heterogeneous many-tenant path: concurrent queries may run
        // different compositions over the shared workers. ξ pricing is
        // per-app too: every executor holds per-app cost multipliers
        // (`MqTask::rel`) over its online hardware calibration, so this
        // query batches, drops and budgets under its own cost model.
        let app = Arc::clone(self.catalog.get(kind));
        self.blocks.insert(id, QueryBlocks::mint(&app));
        let start_vertex = self.cams[start_cam].vertex;
        let walk = EntityWalk::simulate(
            &self.graph,
            start_vertex,
            self.cfg.workload.entity_speed_mps,
            lifetime + 60 * SEC,
            self.cfg.seed
                ^ (id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let gt = GroundTruth::compute(
            &self.graph,
            &self.cams,
            &walk,
            lifetime + 60 * SEC,
            200_000,
        );
        let mut tl = app.make_tl(&TlEnv {
            peak_speed_mps: self.cfg.tl_peak_speed_mps,
            mean_road_m: self.cfg.workload.mean_road_m,
            fov_m: self.cfg.workload.fov_m,
            cameras: &self.cams,
        });
        tl.on_detection(start_cam, self.now, true);
        let mut active_set = Vec::new();
        tl.active_set_into(&self.graph, self.now, &mut active_set);
        let mut active_cams = vec![false; self.cfg.num_cameras];
        for cam in &active_set {
            active_cams[*cam] = true;
        }
        let peak = active_set.len();
        self.ctx.insert(
            id,
            QueryCtx {
                t0: self.now,
                gt,
                tl,
                active_cams,
                detections: 0,
                peak_active: peak,
            },
        );
        self.active.push(id);
        self.peak_concurrent =
            self.peak_concurrent.max(self.active.len());
        self.metrics.set_active_queries(self.active.len());
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::QueryLifecycle {
                    query: id,
                    phase: QueryPhase::Activated,
                },
            );
        }
        // Wait-listed queries promoted late run past the static
        // schedule end: extend the service window (frame ticks and the
        // run horizon both follow it dynamically).
        self.service_end = self.service_end.max(self.now + lifetime);
        // Register the query with every executor's fair-share batcher.
        for t in &mut self.tasks {
            if matches!(t.stage, Stage::Va | Stage::Cr) {
                t.batcher.register(id, weight);
            }
        }
        self.push(self.now + lifetime, Ev::QueryEnd { query: id });
    }

    fn on_query_end(&mut self, query: QueryId) {
        if self.registry.status(query) != Some(QueryStatus::Active) {
            return;
        }
        self.registry
            .complete(query, self.now)
            .expect("status checked");
        self.active.retain(|&q| q != query);
        self.metrics.set_active_queries(self.active.len());
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::QueryLifecycle {
                    query,
                    phase: QueryPhase::Completed,
                },
            );
        }
        if let Some(ctx) = self.ctx.remove(&query) {
            self.finished_stats
                .insert(query, (ctx.detections, ctx.peak_active));
        }
        // Drain the query's leftover worker-queue events (ledgered as
        // dropped at the owning stage: they will never complete —
        // traced at the teardown pseudo-gate, [`Gate::Drain`]).
        for ti in 0..self.tasks.len() {
            if !matches!(self.tasks[ti].stage, Stage::Va | Stage::Cr) {
                continue;
            }
            let left = self.tasks[ti].batcher.deregister(query);
            let stage = self.tasks[ti].stage;
            for qe in left {
                self.ledgers.dropped(query, qe.item.header.id, stage);
                self.metrics.dropped(Gate::Drain);
                self.metrics.query_dropped(query);
                if self.obs.enabled() {
                    self.obs.emit(
                        self.now,
                        &TraceEvent::Drop {
                            gate: Gate::Drain,
                            stage,
                            event: qe.item.header.id,
                            query,
                            batch: 1,
                            eps_us: 0,
                            xi_us: 0,
                        },
                    );
                }
            }
            self.tasks[ti].budgets.remove(&query);
            // Applied refinements die with the query.
            self.tasks[ti].feedback.forget(query);
        }
        self.router.forget(query);
        for cam in 0..self.fc_budget.len() {
            self.fc_budget[cam].remove(&query);
        }
        // Fire the FC lifecycle hook (the per-query block instance is
        // kept — late in-flight events still step through it — but any
        // per-query state it holds is dropped now).
        if let Some(qb) = self.blocks.get_mut(&query) {
            qb.fc.forget_query(query);
        }
        // Capacity freed: promote wait-listed queries that now fit.
        while let Some(next) = self.registry.next_pending() {
            let decision = {
                let spec = &self.registry.record(next).unwrap().spec;
                self.admission.decide(
                    spec,
                    self.registry.num_active(),
                    self.registry.num_queued(),
                    self.active_cameras_total(),
                    self.cfg.num_cameras,
                )
            };
            if decision == Admission::Admit {
                self.activate_query(next);
            } else {
                break;
            }
        }
    }

    // ---- feeds + FC ------------------------------------------------------

    fn on_frame_tick(&mut self, cam: usize) {
        let t = self.now;
        if t < self.service_end {
            let period = (SEC as f64 / self.cfg.fps) as Micros;
            self.push(t + period, Ev::FrameTick { cam });
        } else {
            return;
        }
        if self.active.is_empty() {
            return;
        }
        // A dark camera captures nothing: no events are generated (and
        // none ledgered) while its outage window is open.
        if !self.faults.camera_alive(cam, t) {
            return;
        }
        let frame_no = self.frame_counters[cam];
        self.frame_counters[cam] += 1;
        if self.adapt_on {
            // Commanded frame-rate: FC sees a decimated feed. Skipped
            // frames are never generated (and never ledgered), so
            // per-query conservation is untouched.
            let stride = self.adapt.stride(cam);
            if stride > 1 && frame_no % stride != 0 {
                return;
            }
        }
        // One logical event per query that has this camera active.
        // Index iteration instead of cloning the active list per tick:
        // the loop body never mutates `self.active`.
        for qi in 0..self.active.len() {
            let q = self.active[qi];
            // FC user-logic: the query's own FC block decides whether
            // this (query, camera) frame enters the dataflow, given
            // the query's spotlight activation flag.
            let wants = self
                .ctx
                .get(&q)
                .map(|ctx| ctx.active_cams[cam])
                .unwrap_or(false);
            let admitted = self
                .blocks
                .get_mut(&q)
                .map(|b| b.fc.admit(q, cam, frame_no, t, wants))
                .unwrap_or(false);
            if !admitted {
                continue;
            }
            let present = self
                .ctx
                .get(&q)
                .map(|ctx| ctx.gt.visible(cam, t - ctx.t0))
                .unwrap_or(false);
            let id = self.next_event_id;
            self.next_event_id += 1;
            let mut ev = Event::frame(id, cam, frame_no, t, present);
            ev.header = ev.header.with_query(q);
            self.ledgers.generated(q, id, present);
            self.metrics.generated();
            self.metrics.query_generated(q);
            if self.obs.enabled() {
                self.obs.emit(
                    t,
                    &TraceEvent::Generated {
                        event: id,
                        query: q,
                        camera: cam as u32,
                    },
                );
            }

            // FC drop point 1 against this query's FC budget.
            let slot = self
                .topo
                .downstream_slot(self.topo.fc_task(cam), cam);
            let fc_xi1 = self.fc_xi.xi(1);
            if self.cfg.drops_enabled {
                let budget = self.fc_budget[cam]
                    .get(&q)
                    .map(|b| b.budget_max())
                    .unwrap_or(BUDGET_INF);
                if budget < BUDGET_INF
                    && drop_at_queue(false, 0, fc_xi1, budget)
                {
                    self.ledgers.dropped(q, id, Stage::Fc);
                    self.metrics.dropped(Gate::Queue);
                    self.metrics.query_dropped(q);
                    if self.obs.enabled() {
                        self.obs.emit(
                            t,
                            &TraceEvent::Drop {
                                gate: Gate::Queue,
                                stage: Stage::Fc,
                                event: id,
                                query: q,
                                batch: 1,
                                eps_us: fc_xi1 - budget,
                                xi_us: fc_xi1,
                            },
                        );
                    }
                    continue;
                }
            }
            let fc_dur = fc_xi1;
            self.fc_budget[cam]
                .entry(q)
                .or_insert_with(|| {
                    BudgetManager::new(
                        self.topo.va_part.instances(),
                        self.m_max,
                        251, // prime (see task_budget)
                    )
                })
                .record(
                    id,
                    EventRecord {
                        departure: fc_dur,
                        queue: 0,
                        batch: 1,
                        sent_to: slot,
                    },
                );
            ev.header.sum_exec += fc_dur;
            let fc_task = self.topo.fc_task(cam);
            let va = self.topo.va_task(cam);
            let frame_bytes = if self.adapt_on {
                self.adapt.scaled_bytes(self.net.frame_bytes, cam)
            } else {
                self.net.frame_bytes
            };
            self.send_data(
                self.topo.node_of(fc_task),
                va,
                frame_bytes,
                t + fc_dur,
                ev,
                None,
                Stage::Fc,
            );
        }
    }

    // ---- shared executors (VA / CR) --------------------------------------

    /// The application kind a query runs (from its submitted spec;
    /// O(1) — the registry is id-indexed). Falls back to the engine
    /// default for ids the registry has never seen.
    fn query_app(&self, q: QueryId) -> AppKind {
        self.registry
            .record(q)
            .map(|r| r.spec.app)
            .unwrap_or_else(|| self.catalog.default_kind())
    }

    /// Σ of per-app cost multipliers over a batch — the effective
    /// batch size the §4.4 pricing uses at this task (exactly the
    /// member count for a homogeneous default-app batch).
    fn batch_relsum(
        &self,
        task: usize,
        batch: &[QueuedEvent<Event>],
    ) -> f64 {
        let rel = &self.tasks[task].rel;
        if !self.adapt_on {
            return batch
                .iter()
                .map(|qe| {
                    rel[self.query_app(qe.item.header.query).index()]
                })
                .sum();
        }
        // Adaptation multiplies each member's per-app multiplier by
        // its camera's commanded (resolution, variant) rel — the
        // identity ladder is ×1.0 exact, so the sum (and every gate
        // priced from it) is unchanged to the bit.
        batch
            .iter()
            .map(|qe| {
                let kind = self.query_app(qe.item.header.query);
                let nom = self.nominal_of(task, kind);
                rel[kind.index()]
                    * self.adapt.rel(qe.item.header.camera, nom)
            })
            .sum()
    }

    /// The nominal (configured) model variant an app runs at a task's
    /// stage — what an [`AdaptationCommand`] downshifts *from*. Looked
    /// up per event so heterogeneous query mixes stay stage-isolated.
    fn nominal_of(&self, task: usize, kind: AppKind) -> ModelVariant {
        let app = self.catalog.get(kind);
        match self.tasks[task].stage {
            Stage::Cr => app.cr_variant,
            _ => app.va_variant,
        }
    }

    /// Per-(task, query) budget, created on first use. Only call for
    /// queries that are still active (creation for a finished query
    /// would leak state); use [`Self::task_budget_for`] for lookups.
    fn task_budget(
        &mut self,
        task: usize,
        q: QueryId,
    ) -> &mut BudgetManager {
        let n_down = self.topo.downstream_count(task);
        let m_max = self.m_max;
        // Prime record capacity: a (task, query)'s event ids stride by
        // the query's active-camera count, so a power-of-two ring
        // would collapse to capacity/gcd usable slots.
        self.tasks[task]
            .budgets
            .entry(q)
            .or_insert_with(|| BudgetManager::new(n_down, m_max, 4093))
    }

    /// Read-only per-(task, query) budget toward `slot`;
    /// [`BUDGET_INF`] when the query has no budget state at this task.
    fn task_budget_for(
        &self,
        task: usize,
        q: QueryId,
        slot: usize,
    ) -> Micros {
        self.tasks[task]
            .budgets
            .get(&q)
            .map(|bm| bm.budget_for(slot))
            .unwrap_or(BUDGET_INF)
    }

    fn on_arrive(
        &mut self,
        task: usize,
        ev: Event,
        batch: Option<(u64, usize)>,
    ) {
        // Follow any crash redirect: events pushed before the redirect
        // was installed still land at the surviving executor.
        let task = self.route(task);
        match self.tasks[task].stage {
            Stage::Uv => self.on_sink_arrive(ev, batch),
            Stage::Va | Stage::Cr => {
                // Feedback edge: a QueryUpdate swaps this executor's
                // scoring target for the query (iff fresher than the
                // last applied update) and is consumed here — it never
                // touches the fair-share batcher, budgets or drops.
                // Updates for finished queries are dropped: an
                // in-flight delivery arriving after the query's
                // cleanup must not re-insert forgotten state.
                if let Payload::QueryUpdate(emb) = &ev.payload {
                    let q = ev.header.query;
                    if self.ctx.contains_key(&q) {
                        self.tasks[task].feedback.apply(
                            q,
                            ev.header.update_seq,
                            Arc::clone(emb),
                        );
                    }
                    return;
                }
                // Feedback edge, adaptation flavour: engine-global
                // state (not per-query), so the first broadcast copy
                // applies and the rest discard as stale.
                if let Payload::Adaptation(cmd) = &ev.payload {
                    let cmd = *cmd;
                    self.apply_adaptation(cmd);
                    return;
                }
                let now = self.now;
                let q = ev.header.query;
                let u = now - ev.header.src_arrival;
                let exempt = ev.header.avoid_drop || ev.header.probe;
                let slot = self
                    .topo
                    .downstream_slot(task, ev.header.camera);
                // Drop point 1 prices the event under *its* app's ξ,
                // scaled by its camera's commanded rel when the
                // adaptation plane is live (ξ_eff(1.0) ≡ ξ(1) exactly,
                // so the inert path is bit-identical).
                let xi1 = {
                    let kind = self.query_app(q);
                    if self.adapt_on {
                        let nom = self.nominal_of(task, kind);
                        self.tasks[task].app_xi(kind).xi_eff(
                            self.adapt.rel(ev.header.camera, nom),
                        )
                    } else {
                        self.tasks[task].app_xi(kind).xi(1)
                    }
                };
                let budget = self.task_budget_for(task, q, slot);
                if self.cfg.drops_enabled
                    && budget < BUDGET_INF
                    && drop_at_queue(exempt, u, xi1, budget)
                {
                    let eps = (u + xi1) - budget;
                    self.drop_event(
                        task,
                        ev,
                        Gate::Queue,
                        eps,
                        xi1,
                        1,
                    );
                    return;
                }
                if self.obs.enabled()
                    && exempt
                    && self.cfg.drops_enabled
                    && budget < BUDGET_INF
                    && drop_at_queue(false, u, xi1, budget)
                {
                    // The raw predicate fired but the event was
                    // exempt (probe / avoid_drop): record the save.
                    self.obs.emit(
                        now,
                        &TraceEvent::Exempted {
                            gate: Gate::Queue,
                            stage: self.tasks[task].stage,
                            event: ev.header.id,
                            query: q,
                        },
                    );
                }
                let deadline = if budget >= BUDGET_INF {
                    BUDGET_INF
                } else {
                    budget + ev.header.src_arrival
                };
                let id = ev.header.id;
                let rejected = self.tasks[task].batcher.push(
                    q,
                    QueuedEvent {
                        item: ev,
                        id,
                        arrival: now,
                        deadline,
                    },
                );
                if let Some(qe) = rejected {
                    // The query already completed/cancelled (this is a
                    // late in-flight event): it can never be served, so
                    // account it as dropped here — per-query
                    // conservation must still hold, and re-registering
                    // a finished query would leak fair-share state.
                    let stage = self.tasks[task].stage;
                    self.ledgers
                        .dropped(q, qe.item.header.id, stage);
                    self.metrics.dropped(Gate::Drain);
                    self.metrics.query_dropped(q);
                    if self.obs.enabled() {
                        self.obs.emit(
                            now,
                            &TraceEvent::Drop {
                                gate: Gate::Drain,
                                stage,
                                event: qe.item.header.id,
                                query: q,
                                batch: 1,
                                eps_us: 0,
                                xi_us: 0,
                            },
                        );
                    }
                    return;
                }
                if !self.tasks[task].busy {
                    self.try_form_batch(task);
                }
            }
            _ => {}
        }
    }

    fn try_form_batch(&mut self, task: usize) {
        // A dead executor forms no batches; its queue waits in place
        // (revival tick) or is orphaned (permanent crash).
        if !self.faults.node_alive(self.tasks[task].node, self.now) {
            return;
        }
        loop {
            let now = self.now;
            // Batch formation prices each candidate under its own
            // app's cost multiplier (ξ of the Σ of multipliers) — a
            // heterogeneous mix batches under each app's cost model.
            let poll = {
                let sp = span_begin(&self.obs);
                let reg = &self.registry;
                let default_kind = self.catalog.default_kind();
                let rel = self.tasks[task].rel;
                let ts = &mut self.tasks[task];
                let poll = ts.batcher.poll_costed(now, &ts.xi, |q| {
                    let kind = reg
                        .record(q)
                        .map(|r| r.spec.app)
                        .unwrap_or(default_kind);
                    rel[kind.index()]
                });
                span_end(&self.obs, Scope::BatchPoll, sp);
                poll
            };
            match poll {
                BatcherPoll::Idle => return,
                BatcherPoll::Timer(at) => {
                    let ts = &mut self.tasks[task];
                    ts.timer_seq += 1;
                    let seq = ts.timer_seq;
                    self.push(at, Ev::BatchTimer { task, seq });
                    return;
                }
                BatcherPoll::Ready(mut batch) => {
                    // Drop point 2 against each event's own query
                    // budget (per-query isolation). The survivor
                    // buffer is engine-owned scratch, so the filter
                    // allocates nothing in steady state.
                    if self.cfg.drops_enabled {
                        let b0 = batch.len() as u32;
                        let xib = self.tasks[task].xi.xi_eff(
                            self.batch_relsum(task, &batch),
                        );
                        let mut kept =
                            std::mem::take(&mut self.kept_scratch);
                        kept.clear();
                        for qe in batch.drain(..) {
                            let q = qe.item.header.query;
                            let slot = self.topo.downstream_slot(
                                task,
                                qe.item.header.camera,
                            );
                            let budget =
                                self.task_budget_for(task, q, slot);
                            let u =
                                qe.arrival - qe.item.header.src_arrival;
                            let qdur = now - qe.arrival;
                            let exempt = qe.item.header.avoid_drop
                                || qe.item.header.probe;
                            if budget < BUDGET_INF
                                && drop_at_exec(
                                    exempt, u, qdur, xib, budget,
                                )
                            {
                                let eps = (u + qdur + xib) - budget;
                                self.drop_event(
                                    task,
                                    qe.item,
                                    Gate::Exec,
                                    eps,
                                    xib,
                                    b0,
                                );
                            } else {
                                if self.obs.enabled()
                                    && exempt
                                    && budget < BUDGET_INF
                                    && drop_at_exec(
                                        false, u, qdur, xib, budget,
                                    )
                                {
                                    self.obs.emit(
                                        now,
                                        &TraceEvent::Exempted {
                                            gate: Gate::Exec,
                                            stage: self.tasks[task]
                                                .stage,
                                            event: qe.item.header.id,
                                            query: q,
                                        },
                                    );
                                }
                                kept.push(qe);
                            }
                        }
                        std::mem::swap(&mut batch, &mut kept);
                        self.kept_scratch = kept;
                    }
                    if batch.is_empty() {
                        self.tasks[task].batcher.recycle(batch);
                        continue;
                    }
                    let relsum = self.batch_relsum(task, &batch);
                    let (xi_est, xi_true, jitter, node) = {
                        let ts = &self.tasks[task];
                        (
                            ts.xi.xi_eff(relsum),
                            ts.xi_true.xi_eff(relsum),
                            self.cfg.service.jitter,
                            ts.node,
                        )
                    };
                    if self.obs.enabled() {
                        self.obs.emit(
                            now,
                            &TraceEvent::BatchFormed {
                                stage: self.tasks[task].stage,
                                task: task as u32,
                                size: batch.len() as u32,
                            },
                        );
                    }
                    let factor =
                        1.0 + self.rng.range_f64(-jitter, jitter);
                    // Compute dynamism: the *actual* duration is drawn
                    // from the frozen nominal model (the simulated
                    // hardware), scaled by the node's slowdown — never
                    // from the online-refined estimator (that loop
                    // would compound the slowdown geometrically).
                    // Factor 1.0 is a bit-exact identity and the RNG
                    // draw count is unchanged.
                    let slow = self.compute.factor_at(node, now);
                    let actual = ((xi_true as f64) * factor * slow)
                        .round() as Micros;
                    self.tasks[task].busy = true;
                    self.push(
                        now + actual.max(1),
                        Ev::ExecDone {
                            task,
                            batch,
                            start: now,
                            xi_est,
                            actual,
                            rel_sum: relsum,
                        },
                    );
                    return;
                }
            }
        }
    }

    fn on_exec_done(
        &mut self,
        task: usize,
        mut batch: Vec<QueuedEvent<Event>>,
        start: Micros,
        xi_est: Micros,
        actual: Micros,
        rel_sum: f64,
    ) {
        self.tasks[task].busy = false;
        // The executor died mid-execution: nothing the batch computed
        // survives. Members retry (bounded, with backoff) or terminate
        // as lost_to_fault.
        if self
            .faults
            .node_down_during(self.tasks[task].node, start, self.now)
        {
            self.void_batch(task, batch);
            return;
        }
        let b = batch.len();
        let stage = self.tasks[task].stage;
        let batch_seq = self.next_batch_seq;
        self.next_batch_seq += 1;

        // Online ξ recalibration: the observed (slowdown-scaled)
        // duration refines the task's estimator at the batch's
        // effective size (computed once at formation), so every app's
        // scaled snapshot tracks the current machine together.
        if self.online_xi {
            self.tasks[task].xi.observe_eff(rel_sum, actual);
            self.metrics.xi_observed();
            let ts = &self.tasks[task];
            for k in 0..ts.rel.len() {
                self.metrics.set_app_xi(
                    k,
                    stage,
                    ts.xi.scaled(ts.rel[k]).xi(1),
                );
            }
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::XiObserved {
                        stage,
                        task: task as u32,
                        b_eff: rel_sum,
                        actual_us: actual,
                        alpha_us: ts.xi.alpha_us(),
                        beta_us: ts.xi.beta_us(),
                    },
                );
            }
        }

        // First pass: per-event bookkeeping (per-query budget 3-tuples,
        // header accumulators) into engine-owned scratch; the emptied
        // batch vec is recycled into the batcher (no per-batch
        // allocation).
        let mut staged = std::mem::take(&mut self.staged_scratch);
        let mut meta = std::mem::take(&mut self.meta_scratch);
        staged.clear();
        meta.clear();
        let mut queue_sum: Micros = 0;
        for qe in batch.drain(..) {
            let mut ev = qe.item;
            let q = ev.header.query;
            let cam = ev.header.camera;
            let qdur = start - qe.arrival;
            queue_sum += qdur;
            let u = qe.arrival - ev.header.src_arrival;
            let pi = qdur + actual;
            let slot = self.topo.downstream_slot(task, cam);
            // Record only for still-active queries: creating budget
            // state for a finished query would leak it (signals for
            // unknown events are ignored anyway).
            if self.ctx.contains_key(&q) {
                self.task_budget(task, q).record(
                    ev.header.id,
                    EventRecord {
                        departure: u + pi,
                        queue: qdur,
                        batch: b,
                        sent_to: slot,
                    },
                );
            }
            ev.header.sum_exec += xi_est;
            ev.header.sum_queue += qdur;
            staged.push(ev);
            meta.push((u, pi, slot));
        }
        self.tasks[task].batcher.recycle(batch);
        self.metrics.batch_executed(
            stage,
            b,
            queue_sum / (b.max(1) as Micros),
        );
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::BatchExecuted {
                    stage,
                    task: task as u32,
                    size: b as u32,
                    est_us: xi_est,
                    actual_us: actual,
                },
            );
        }

        // Module user-logic: dispatch each maximal run of same-query
        // events to *that query's* block, in arrival order — one
        // virtual call per run, and because every block draws from the
        // shared engine RNG in event order, the RNG stream is identical
        // to whole-batch dispatch when all queries run the same app.
        {
            let sp = span_begin(&self.obs);
            let truth = MqTruth { ctx: &self.ctx };
            let mut sim = SimCtx {
                rng: &mut self.rng,
                truth: &truth,
                sem: &self.cfg.semantics,
                seed: self.cfg.seed,
                feedback: &self.tasks[task].feedback,
                adapt: &self.adapt,
            };
            let mut i = 0;
            while i < staged.len() {
                let q = staged[i].header.query;
                let mut j = i + 1;
                while j < staged.len()
                    && staged[j].header.query == q
                {
                    j += 1;
                }
                // Blocks are minted at activation and kept for the
                // whole run, so any in-flight event finds its block;
                // a missing entry (unreachable in practice) re-mints
                // from the query's own spec — deterministically, and
                // preserving the per-query-app invariant.
                if !self.blocks.contains_key(&q) {
                    debug_assert!(
                        false,
                        "query {q} stepped before activation minted \
                         its blocks"
                    );
                    let kind = self
                        .registry
                        .record(q)
                        .map(|r| r.spec.app)
                        .unwrap_or(self.catalog.default_kind());
                    let app = Arc::clone(self.catalog.get(kind));
                    self.blocks.insert(q, QueryBlocks::mint(&app));
                }
                let qb = self.blocks.get_mut(&q).unwrap();
                match stage {
                    Stage::Va => {
                        qb.va.step_sim(&mut staged[i..j], &mut sim)
                    }
                    Stage::Cr => {
                        qb.cr.step_sim(&mut staged[i..j], &mut sim)
                    }
                    _ => {}
                }
                i = j;
            }
            span_end(&self.obs, Scope::Scoring, sp);
        }

        // Drop point 3 against each event's per-query downstream
        // budget; survivors move to the outgoing scratch.
        let mut outgoing = std::mem::take(&mut self.outgoing_scratch);
        outgoing.clear();
        for (i, ev) in staged.drain(..).enumerate() {
            let (u, pi, slot) = meta[i];
            let exempt = ev.header.avoid_drop || ev.header.probe;
            if self.cfg.drops_enabled {
                let q = ev.header.query;
                let budget = self.task_budget_for(task, q, slot);
                if budget < BUDGET_INF
                    && drop_at_transmit(exempt, u, pi, budget)
                {
                    let eps = (u + pi) - budget;
                    self.drop_event(
                        task,
                        ev,
                        Gate::Transmit,
                        eps,
                        pi,
                        b as u32,
                    );
                    continue;
                }
                if self.obs.enabled()
                    && exempt
                    && budget < BUDGET_INF
                    && drop_at_transmit(false, u, pi, budget)
                {
                    self.obs.emit(
                        self.now,
                        &TraceEvent::Exempted {
                            gate: Gate::Transmit,
                            stage,
                            event: ev.header.id,
                            query: ev.header.query,
                        },
                    );
                }
            }
            outgoing.push(ev);
        }
        self.staged_scratch = staged;
        self.meta_scratch = meta;

        let out_n = outgoing.len();
        let src_node = self.topo.node_of(task);
        for ev in outgoing.drain(..) {
            let cam = ev.header.camera;
            let q = ev.header.query;
            let (next_task, bytes) = match stage {
                Stage::Va => {
                    (self.topo.cr_task(cam), self.net.candidate_bytes)
                }
                Stage::Cr => (self.topo.uv, self.net.meta_bytes),
                _ => unreachable!("only VA/CR execute batches"),
            };
            if stage == Stage::Cr {
                if let Payload::Detection { detected, .. } = ev.payload {
                    // Control-plane fork to the query's TL: best-effort
                    // (no retransmit, no ledger — the data-plane copy
                    // below carries the event's accounting).
                    let tl_node = self.topo.node_of(self.topo.tl);
                    if self.channel_ok(src_node, tl_node, self.now) {
                        let tl_arrive = self.net.transfer(
                            src_node,
                            tl_node,
                            self.net.meta_bytes,
                            self.now,
                        );
                        self.push(
                            tl_arrive,
                            Ev::TlDetection {
                                query: q,
                                camera: cam,
                                captured: ev.header.captured,
                                detected,
                            },
                        );
                    }
                }
            }
            let tag = if stage == Stage::Cr {
                Some((batch_seq, out_n))
            } else {
                None
            };
            self.send_data(
                src_node, next_task, bytes, self.now, ev, tag, stage,
            );
        }
        self.outgoing_scratch = outgoing;

        self.try_form_batch(task);
    }

    // ---- drops + signals -------------------------------------------------

    /// Drop an event at `task`: ledger it per query, send reject
    /// signals upstream (scoped to the same query) and forward every
    /// k-th drop as a probe. Takes the event by value: probes reuse
    /// the dropped event instead of cloning it.
    /// `gate`/`xi_us`/`batch` describe the verdict for the trace: the
    /// gate charged `xi_us` against the budget at batch size `batch`
    /// and came up `eps` short.
    fn drop_event(
        &mut self,
        task: usize,
        ev: Event,
        gate: Gate,
        eps: Micros,
        xi_us: Micros,
        batch: u32,
    ) {
        let stage = self.tasks[task].stage;
        let q = ev.header.query;
        self.ledgers.dropped(q, ev.header.id, stage);
        self.metrics.dropped(gate);
        self.metrics.query_dropped(q);
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::Drop {
                    gate,
                    stage,
                    event: ev.header.id,
                    query: q,
                    batch,
                    eps_us: eps,
                    xi_us,
                },
            );
        }
        self.tasks[task].drop_count += 1;

        let cam = ev.header.camera;
        let sig = Signal::Reject {
            event: ev.header.id,
            eps: eps.max(0),
            sum_queue: ev.header.sum_queue.max(1),
        };
        let path = self.topo.path(cam);
        let my_pos = path
            .iter()
            .position(|&t| t == task)
            .unwrap_or(path.len());
        for &up in path.iter().take(my_pos) {
            if self.topo.stage_of(up) == Stage::Fc {
                if let Some(bm) = self.fc_budget[cam].get_mut(&q) {
                    bm.apply(sig, &self.fc_xi);
                }
            } else {
                let lat = self.net.transfer_estimate(
                    self.net.meta_bytes,
                    self.now,
                );
                self.push(
                    self.now + lat,
                    Ev::SignalAt {
                        task: up,
                        query: q,
                        sig,
                    },
                );
            }
        }

        if self.cfg.probe_every > 0
            && self.tasks[task].drop_count % self.cfg.probe_every == 0
        {
            let mut probe = ev;
            probe.header.probe = true;
            let (next_task, bytes) = match stage {
                Stage::Va => {
                    (self.topo.cr_task(cam), self.net.candidate_bytes)
                }
                Stage::Cr => (self.topo.uv, self.net.meta_bytes),
                _ => return,
            };
            // Probes are control-plane: best-effort through the fault
            // domains, no retransmit (the event is already ledgered as
            // dropped — losing the probe costs signal, not accounting).
            let next_task = self.route(next_task);
            let src = self.tasks[task].node;
            let dst = self.topo.node_of(next_task);
            if self.channel_ok(src, dst, self.now) {
                let arrive =
                    self.net.transfer(src, dst, bytes, self.now);
                self.push(
                    arrive,
                    Ev::Arrive {
                        task: next_task,
                        ev: probe,
                        batch: None,
                    },
                );
            }
        }
    }

    // ---- faults + recovery -----------------------------------------------

    /// Where arrivals addressed to `task` actually land (identity until
    /// a permanent crash installs a redirect).
    #[inline]
    fn route(&self, task: usize) -> usize {
        if self.faults.is_static() {
            task
        } else {
            self.task_redirect[task]
        }
    }

    /// Can a message sent `src → dst` at `t` get through the fault
    /// domains? Consults link partitions and — only when loss windows
    /// exist — draws from the dedicated fault RNG stream, so fault-free
    /// (and loss-free) schedules never touch any RNG.
    fn channel_ok(&mut self, src: usize, dst: usize, t: Micros) -> bool {
        if self.faults.is_static() {
            return true;
        }
        if !self.faults.link_up(src, dst, t) {
            return false;
        }
        if self.faults.has_loss() {
            let p = self.faults.loss_prob(t);
            if p > 0.0 && self.fault_rng.range_f64(0.0, 1.0) < p {
                return false;
            }
        }
        true
    }

    /// Transmit a ledgered data event towards `dst_task`, through the
    /// fault domains. With recovery on, a failed send retransmits with
    /// exponential backoff — the channel is re-evaluated at each
    /// attempt's send time (all draws made now, keeping the schedule
    /// deterministic); once attempts are exhausted, or immediately with
    /// recovery off, the event terminates as `lost_to_fault` *for its
    /// query* at the sending stage. The fault-free fast path is one
    /// branch and bit-identical to the pre-fault engine.
    #[allow(clippy::too_many_arguments)]
    fn send_data(
        &mut self,
        src_node: usize,
        dst_task: usize,
        bytes: usize,
        at: Micros,
        ev: Event,
        batch: Option<(u64, usize)>,
        stage: Stage,
    ) {
        let dst_task = self.route(dst_task);
        let dst_node = self.topo.node_of(dst_task);
        if self.faults.is_static() {
            let arrive =
                self.net.transfer(src_node, dst_node, bytes, at);
            self.push(arrive, Ev::Arrive { task: dst_task, ev, batch });
            return;
        }
        let rec = self.cfg.service.recovery;
        let attempts = if rec.enabled { rec.max_retries + 1 } else { 1 };
        let mut t = at;
        for k in 0..attempts {
            if self.channel_ok(src_node, dst_node, t) {
                if k > 0 {
                    self.metrics.fault_retry();
                    if self.obs.enabled() {
                        self.obs.emit(
                            self.now,
                            &TraceEvent::FaultRetry {
                                event: ev.header.id,
                                query: ev.header.query,
                                attempt: k,
                            },
                        );
                    }
                }
                let arrive =
                    self.net.transfer(src_node, dst_node, bytes, t);
                self.push(
                    arrive,
                    Ev::Arrive { task: dst_task, ev, batch },
                );
                return;
            }
            t += backoff_delay(&rec, k);
        }
        let q = ev.header.query;
        self.lose_event(q, ev.header.id, stage);
    }

    /// Terminal fault accounting for one query's event: a distinct
    /// outcome class from gate drops — per-query conservation becomes
    /// generated = on-time + delayed + dropped + lost-to-fault +
    /// in-flight.
    fn lose_event(&mut self, q: QueryId, id: u64, stage: Stage) {
        self.ledgers.lost_to_fault(q, id, stage);
        self.metrics.lost_to_fault();
        self.metrics.query_lost_to_fault(q);
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::LostToFault {
                    event: id,
                    query: q,
                    stage,
                },
            );
        }
    }

    /// The executor died while this batch was in flight: nothing it
    /// computed survives. With recovery on, members re-arrive at the
    /// (possibly redirected) task after exponential backoff, bounded by
    /// `max_retries` per event; otherwise — or once retries are
    /// exhausted — each terminates as `lost_to_fault` against its own
    /// query.
    fn void_batch(
        &mut self,
        task: usize,
        mut batch: Vec<QueuedEvent<Event>>,
    ) {
        let stage = self.tasks[task].stage;
        let rec = self.cfg.service.recovery;
        for qe in batch.drain(..) {
            let ev = qe.item;
            let id = ev.header.id;
            let q = ev.header.query;
            let attempt =
                self.retry_counts.get(&id).copied().unwrap_or(0);
            if rec.enabled && attempt < rec.max_retries {
                self.retry_counts.insert(id, attempt + 1);
                self.metrics.fault_retry();
                if self.obs.enabled() {
                    self.obs.emit(
                        self.now,
                        &TraceEvent::FaultRetry {
                            event: id,
                            query: q,
                            attempt: attempt + 1,
                        },
                    );
                }
                let to = self.route(task);
                self.push(
                    self.now + backoff_delay(&rec, attempt),
                    Ev::Arrive { task: to, ev, batch: None },
                );
            } else {
                self.lose_event(q, id, stage);
            }
        }
        self.tasks[task].batcher.recycle(batch);
        // If the node already revived mid-execution, whatever queued up
        // during the outage resumes now (the call gates on aliveness).
        self.try_form_batch(task);
    }

    /// A scheduled node/camera transition instant: diff aliveness
    /// against the last tick, emit each flip exactly once, and apply
    /// the consequences (orphan drains and redirects on crash, resumed
    /// batch formation on revival, spotlight refresh over dark
    /// cameras for every active query).
    fn on_fault_tick(&mut self) {
        for node in 0..self.node_was_up.len() {
            let up = self.faults.node_alive(node, self.now);
            if up == self.node_was_up[node] {
                continue;
            }
            self.node_was_up[node] = up;
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::NodeFault { node: node as u32, up },
                );
            }
            if up {
                self.metrics.node_restart();
                // Revival: whatever queued up during the outage
                // resumes batch formation immediately.
                for task in 0..self.tasks.len() {
                    if self.tasks[task].node == node
                        && !self.tasks[task].busy
                    {
                        self.try_form_batch(task);
                    }
                }
            } else {
                self.metrics.fault_injected();
                self.on_node_down(node);
            }
        }
        let down = self.node_was_up.iter().filter(|&&u| !u).count();
        self.metrics.set_nodes_down(down);
        for cam in 0..self.cfg.num_cameras {
            let up = self.faults.camera_alive(cam, self.now);
            if up == self.cam_was_up[cam] {
                continue;
            }
            self.cam_was_up[cam] = up;
            if !up {
                self.metrics.fault_injected();
            }
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::CameraFault {
                        camera: cam as u32,
                        up,
                    },
                );
            }
        }
        // Every query's spotlight reacts at the transition instant,
        // not the next periodic TL tick.
        for qi in 0..self.active.len() {
            let q = self.active[qi];
            self.refresh_active_set(q);
        }
        self.metrics
            .set_active_cameras(self.active_cameras_total());
    }

    /// Crash consequences for every executor on `node`. A task that
    /// will revive keeps its queues in place (formation resumes at the
    /// revival tick); a *permanently* dead task's backlog is orphaned —
    /// re-dispatched to a surviving same-stage peer when recovery is
    /// on (every active query is registered with every executor's
    /// fair-share batcher, so the survivor accepts them), written off
    /// as `lost_to_fault` otherwise. In-flight batches are voided
    /// separately when their completion pops
    /// ([`FaultModel::node_down_during`]).
    fn on_node_down(&mut self, node: usize) {
        let permanent =
            self.faults.node_revives_at(node, self.now).is_none();
        if !permanent {
            return;
        }
        for task in 0..self.tasks.len() {
            if self.tasks[task].node != node
                || !matches!(
                    self.tasks[task].stage,
                    Stage::Va | Stage::Cr
                )
            {
                continue;
            }
            let stage = self.tasks[task].stage;
            let target = self.pick_survivor(task, stage);
            let recover = self.cfg.service.recovery.enabled;
            if recover {
                if let Some(to) = target {
                    self.task_redirect[task] = to;
                    // Repair chains: traffic already redirected at the
                    // dead task follows it to the survivor.
                    for r in self.task_redirect.iter_mut() {
                        if *r == task {
                            *r = to;
                        }
                    }
                }
            }
            let mut orphans = std::mem::take(&mut self.kept_scratch);
            orphans.clear();
            self.tasks[task].batcher.drain_into(&mut orphans);
            match (recover, target) {
                (true, Some(to)) if !orphans.is_empty() => {
                    self.metrics.redispatched(orphans.len() as u64);
                    if self.obs.enabled() {
                        self.obs.emit(
                            self.now,
                            &TraceEvent::Redispatch {
                                stage,
                                from_task: task as u32,
                                to_task: to as u32,
                                events: orphans.len() as u32,
                            },
                        );
                    }
                    // The service re-dispatches from its own copy (the
                    // dead node cannot send): one control-message
                    // latency, arrival order preserved.
                    let lat = self.net.transfer_estimate(
                        self.net.meta_bytes,
                        self.now,
                    );
                    for qe in orphans.drain(..) {
                        self.push(
                            self.now + lat,
                            Ev::Arrive {
                                task: to,
                                ev: qe.item,
                                batch: None,
                            },
                        );
                    }
                }
                _ => {
                    for qe in orphans.drain(..) {
                        let q = qe.item.header.query;
                        self.lose_event(q, qe.id, stage);
                    }
                }
            }
            self.kept_scratch = orphans;
        }
    }

    /// Alive executor of `stage` other than `task`, preferring shard
    /// locality: the dead task's own shard first, then shards adjacent
    /// in the partition graph (orphans migrate over spotlight edges),
    /// then anywhere. At K=1 every candidate is ring 0, so this
    /// degenerates to the first alive executor — bit-identical to the
    /// unsharded policy. The survivor prices re-dispatched work with
    /// its own per-(stage, app) ξ multipliers, so cross-shard recovery
    /// costs the destination's calibration, not the dead shard's.
    fn pick_survivor(&self, task: usize, stage: Stage) -> Option<usize> {
        let home = self.shard_of_task[task];
        (0..self.tasks.len())
            .filter(|&t| {
                t != task
                    && self.tasks[t].stage == stage
                    && self
                        .faults
                        .node_alive(self.tasks[t].node, self.now)
            })
            .min_by_key(|&t| {
                let s = self.shard_of_task[t];
                let ring = if s == home {
                    0u8
                } else if self.part.adjacent(home, s) {
                    1
                } else {
                    2
                };
                (ring, t)
            })
    }

    // ---- sink (UV) -------------------------------------------------------

    fn on_sink_arrive(&mut self, ev: Event, batch: Option<(u64, usize)>) {
        let q = ev.header.query;
        let latency = self.now - ev.header.src_arrival;
        let gamma = self.cfg.gamma();

        if ev.header.probe {
            if latency <= gamma {
                self.send_accepts(
                    q,
                    ev.header.camera,
                    ev.header.id,
                    gamma - latency,
                    ev.header.sum_exec.max(1),
                );
            }
            return;
        }

        let detected = matches!(
            ev.payload,
            Payload::Detection { detected: true, .. }
        );
        if detected {
            self.metrics.detection();
            if let Some(ctx) = self.ctx.get_mut(&q) {
                ctx.detections += 1;
            }
            // This query's own QF block observes the detection; when
            // it refines, close the feedback loop for this query only.
            // Gated on the query still being active — late in-flight
            // detections of a completed query must not keep fusing
            // (the front's sink drops the QF block at deregistration;
            // this is the DES equivalent), and the router's sequence
            // state for the query is already gone.
            let active = self.ctx.contains_key(&q);
            let refined = match self.blocks.get_mut(&q) {
                Some(qb) if active => {
                    if qb.qf.on_detection(&ev) {
                        qb.fusion_updates += 1;
                        self.fusion_updates += 1;
                        qb.qf.embedding().map(|e| Arc::new(e.to_vec()))
                    } else {
                        None
                    }
                }
                _ => None,
            };
            if let Some(emb) = refined {
                self.route_refinement(
                    q,
                    emb,
                    ev.header.id,
                    ev.header.camera,
                );
            }
        }
        self.ledgers
            .completed(q, ev.header.id, latency, gamma, detected);
        self.metrics.completed(latency <= gamma);
        self.metrics.query_completed(q, latency <= gamma);
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::Completed {
                    event: ev.header.id,
                    query: q,
                    latency_us: latency,
                    on_time: latency <= gamma,
                    detected,
                },
            );
        }

        // Accuracy–latency controller: every completion's latency
        // feeds the sink-side slack estimator; minted commands ride
        // the feedback edge upstream.
        if self.adapt_on {
            if let Some(cmd) = self.adapt_ctl.on_completion(
                ev.header.camera,
                latency,
                self.now,
            ) {
                self.metrics.adapt_minted();
                self.route_adaptation(
                    cmd,
                    ev.header.id,
                    ev.header.camera,
                );
            }
        }

        if let Some((seq, size)) = batch {
            let entry = self
                .sink_batches
                .entry(seq)
                .or_insert((size, -1, 0, 0, q, ev.header.camera));
            if latency > entry.1 {
                entry.1 = latency;
                entry.2 = ev.header.id;
                entry.3 = ev.header.sum_exec.max(1);
                entry.4 = q;
                entry.5 = ev.header.camera;
            }
            entry.0 -= 1;
            if entry.0 == 0 {
                let (_, slowest_lat, slowest_id, sum_exec, sq, scam) =
                    self.sink_batches.remove(&seq).unwrap();
                let eps = gamma - slowest_lat;
                if eps > millis(self.cfg.eps_max_ms) {
                    self.send_accepts(sq, scam, slowest_id, eps, sum_exec);
                }
            }
        }
    }

    /// Route a query's fused embedding upstream as a seq-stamped
    /// [`Payload::QueryUpdate`], one copy per VA/CR executor, each
    /// after a control-message network delay (deterministic arrival
    /// order: task index, then event-core sequence).
    fn route_refinement(
        &mut self,
        q: QueryId,
        embedding: Arc<Vec<f32>>,
        trigger: u64,
        camera: usize,
    ) {
        let refinement = self.router.refine(q, embedding);
        self.metrics.refinement();
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::RefinementApplied {
                    query: q,
                    seq: refinement.seq,
                },
            );
        }
        let lat = self
            .net
            .transfer_estimate(self.net.meta_bytes, self.now);
        for task in 0..self.tasks.len() {
            if !matches!(self.tasks[task].stage, Stage::Va | Stage::Cr)
            {
                continue;
            }
            self.push(
                self.now + lat,
                Ev::Arrive {
                    task,
                    ev: refinement.into_event(trigger, camera, self.now),
                    batch: None,
                },
            );
        }
    }

    /// Route a minted [`AdaptationCommand`] upstream on the feedback
    /// edge: one copy per VA/CR executor (same transport, same
    /// seq-stamped envelope as refinements). Consumption is
    /// engine-global, so the first arrival applies and the remaining
    /// copies discard as stale — exercising the stale counter on every
    /// command.
    fn route_adaptation(
        &mut self,
        cmd: AdaptationCommand,
        trigger: u64,
        camera: usize,
    ) {
        let env = FeedbackEnvelope::Adaptation(cmd);
        let lat = self
            .net
            .transfer_estimate(self.net.meta_bytes, self.now);
        for task in 0..self.tasks.len() {
            if !matches!(self.tasks[task].stage, Stage::Va | Stage::Cr)
            {
                continue;
            }
            self.push(
                self.now + lat,
                Ev::Arrive {
                    task,
                    ev: env.into_event(trigger, camera, self.now),
                    batch: None,
                },
            );
        }
    }

    /// The single application point for adaptation commands: every
    /// [`Payload::Adaptation`] delivery, on every path, lands here.
    fn apply_adaptation(&mut self, cmd: AdaptationCommand) {
        if self.adapt.apply(&cmd) {
            self.metrics.adapt_applied();
            self.metrics
                .set_cameras_downshifted(self.adapt.downshifted());
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::Adaptation {
                        camera: cmd.camera as u32,
                        seq: cmd.seq,
                        level: cmd.level as u32,
                        variant: cmd.variant.profile().artifact,
                    },
                );
            }
        } else {
            self.metrics.adapt_stale();
        }
    }

    fn send_accepts(
        &mut self,
        q: QueryId,
        cam: usize,
        event: u64,
        eps: Micros,
        sum_exec: Micros,
    ) {
        let sig = Signal::Accept {
            event,
            eps,
            sum_exec,
        };
        let path = self.topo.path(cam);
        for &up in path.iter().take(3) {
            // FC, VA, CR
            if self.topo.stage_of(up) == Stage::Fc {
                if let Some(bm) = self.fc_budget[cam].get_mut(&q) {
                    bm.apply(sig, &self.fc_xi);
                }
            } else {
                let lat = self
                    .net
                    .transfer_estimate(self.net.meta_bytes, self.now);
                self.push(
                    self.now + lat,
                    Ev::SignalAt {
                        task: up,
                        query: q,
                        sig,
                    },
                );
            }
        }
    }

    // ---- TL --------------------------------------------------------------

    fn on_tl_tick(&mut self) {
        if self.now < self.service_end {
            self.push(self.now + SEC, Ev::TlTick);
        }
        // Index iteration instead of cloning the active list per tick:
        // `refresh_active_set` never mutates `self.active`.
        for qi in 0..self.active.len() {
            let q = self.active[qi];
            self.refresh_active_set(q);
        }
        self.metrics
            .set_active_cameras(self.active_cameras_total());
        if self.cfg.obs.per_second_metrics {
            self.metrics.mark_second(self.now / SEC);
        }
    }

    fn refresh_active_set(&mut self, q: QueryId) {
        let mut active = std::mem::take(&mut self.active_scratch);
        let mut spotlight_changed = None;
        if let Some(ctx) = self.ctx.get_mut(&q) {
            // Count the query's prior activation only when a sink will
            // actually see the Spotlight event.
            let prior = if self.obs.enabled() {
                ctx.active_cams.iter().filter(|&&a| a).count()
            } else {
                usize::MAX
            };
            let sp = span_begin(&self.obs);
            ctx.tl.active_set_into(&self.graph, self.now, &mut active);
            span_end(&self.obs, Scope::SpotlightExpand, sp);
            // Graceful degradation: while any of this query's active
            // cameras is dark, re-expand at a pushed-forward horizon —
            // the entity may travel unobserved, so the plausible region
            // widens over the outage instead of tunnel-visioning on it.
            if !self.faults.is_static()
                && self.cfg.service.recovery.enabled
                && active
                    .iter()
                    .any(|&c| !self.faults.camera_alive(c, self.now))
            {
                ctx.tl.active_set_into(
                    &self.graph,
                    self.now + FAULT_WIDEN,
                    &mut active,
                );
            }
            ctx.peak_active = ctx.peak_active.max(active.len());
            for a in ctx.active_cams.iter_mut() {
                *a = false;
            }
            for &cam in &active {
                ctx.active_cams[cam] = true;
            }
            if self.obs.enabled() && active.len() != prior {
                spotlight_changed = Some(active.len() as u32);
            }
        }
        if let Some(n) = spotlight_changed {
            self.obs.emit(
                self.now,
                &TraceEvent::Spotlight { query: q, active: n },
            );
        }
        self.active_scratch = active;
    }

    // ---- reporting -------------------------------------------------------

    fn report(self) -> MultiQueryResult {
        let mut queries = Vec::new();
        for rec in self.registry.records() {
            let mut r = QueryReport::from_record(rec);
            r.summary = self.ledgers.summary(rec.id);
            r.fusion_updates = self
                .blocks
                .get(&rec.id)
                .map(|b| b.fusion_updates)
                .unwrap_or(0);
            if let Some(&(d, p)) = self.finished_stats.get(&rec.id) {
                r.detections = d;
                r.peak_active = p;
            } else if let Some(ctx) = self.ctx.get(&rec.id) {
                r.detections = ctx.detections;
                r.peak_active = ctx.peak_active;
            }
            queries.push(r);
        }
        let rejected = queries
            .iter()
            .filter(|q| q.status == QueryStatus::Rejected)
            .count();
        MultiQueryResult {
            queries,
            aggregate: self.ledgers.aggregate(),
            peak_concurrent: self.peak_concurrent,
            rejected,
            queued: self.ever_queued as usize,
            fusion_updates: self.fusion_updates,
            core_events: self.core.dispatched(),
            metrics: self.metrics.snapshot(),
            rng_draws: self.rng.draws(),
        }
    }
}

/// Convenience: run a multi-query experiment end to end with the stock
/// application the config describes.
pub fn run(
    cfg: ExperimentConfig,
    mq: MultiQueryConfig,
) -> MultiQueryResult {
    MultiQueryDes::new(cfg, mq).run()
}

/// Run a user-composed application in multi-query mode — the public
/// §2.2 entry point for the service layer.
pub fn run_app(
    cfg: ExperimentConfig,
    mq: MultiQueryConfig,
    app: &AppDefinition,
) -> MultiQueryResult {
    MultiQueryDes::with_app(cfg, mq, app).run()
}

/// Run the stock application with an explicit trace sink — the
/// flight-recorder entry point (`harness trace`, obs property tests).
pub fn run_with_sink<S: ObsSink>(
    cfg: ExperimentConfig,
    mq: MultiQueryConfig,
    sink: S,
) -> MultiQueryResult {
    let app = crate::apps::resolve(&cfg);
    MultiQueryDes::with_app_sink(cfg, mq, &app, sink).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.num_cameras = 60;
        c.workload.vertices = 60;
        c.workload.edges = 160;
        c.batching = BatchingKind::Dynamic { max: 25 };
        c
    }

    fn mq_cfg(n: usize) -> MultiQueryConfig {
        MultiQueryConfig {
            num_queries: n,
            mean_interarrival_secs: 5.0,
            lifetime_secs: 60.0,
            max_active: 16,
            max_active_cameras: 10_000,
            queue_capacity: 8,
            priority_levels: 3,
        }
    }

    #[test]
    fn multi_query_run_conserves_per_query() {
        let r = run(base_cfg(), mq_cfg(4));
        let activated: Vec<_> = r.activated().collect();
        assert_eq!(activated.len(), 4, "all queries admitted");
        for q in &activated {
            let s = q.summary.as_ref().expect("per-query ledger");
            assert!(s.conserved(), "query {}: {:?}", q.id, s);
            assert!(s.generated > 0, "query {} generated no events", q.id);
        }
        assert!(r.aggregate.conserved());
        assert!(r.peak_concurrent >= 2, "{}", r.peak_concurrent);
    }

    #[test]
    fn queries_detect_their_own_entities() {
        let r = run(base_cfg(), mq_cfg(3));
        let with_detections = r
            .activated()
            .filter(|q| q.detections > 0 || q.recall() > 0.0)
            .count();
        assert!(
            with_detections >= 2,
            "most queries should re-acquire their entity: {:?}",
            r.queries
                .iter()
                .map(|q| (q.id, q.detections))
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(base_cfg(), mq_cfg(3));
        let b = run(base_cfg(), mq_cfg(3));
        assert_eq!(a.aggregate.generated, b.aggregate.generated);
        assert_eq!(a.aggregate.on_time, b.aggregate.on_time);
        assert_eq!(a.aggregate.dropped, b.aggregate.dropped);
        assert_eq!(a.peak_concurrent, b.peak_concurrent);
    }

    #[test]
    fn admission_limits_enforced() {
        let mut mq = mq_cfg(5);
        mq.max_active = 1;
        mq.queue_capacity = 1;
        // Arrivals every ~5 s with 60 s lifetimes: the first query is
        // admitted, one waits, the rest are rejected.
        let r = run(base_cfg(), mq);
        assert_eq!(r.peak_concurrent, 1);
        let statuses: Vec<QueryStatus> =
            r.queries.iter().map(|q| q.status).collect();
        assert!(statuses.contains(&QueryStatus::Rejected));
        assert!(r.rejected >= 2, "{statuses:?}");
        assert!(r.queued >= 1, "someone was wait-listed");
        // The wait-listed query is promoted once the first completes.
        let completed = statuses
            .iter()
            .filter(|&&s| s == QueryStatus::Completed)
            .count();
        assert!(completed >= 2, "{statuses:?}");
    }

    #[test]
    fn heterogeneous_mix_prices_per_app_xi() {
        // Apps 1/2/3 differ in VA/CR cost (CR 1.63x for App 2, VA 2.5x
        // for App 3), so this exercises rel ≠ 1.0 on every per-app ξ
        // path: batch pricing (poll_costed), drop gates, budget-signal
        // caps — under drops, online ξ and a mid-run compute slowdown
        // at once. The invariants: per-query conservation and per-seed
        // determinism.
        use crate::config::ComputeEvent;
        let mut cfg = base_cfg();
        cfg.cluster.cr_instances = 3;
        cfg.drops_enabled = true;
        cfg.service.online_xi = true;
        cfg.service.compute_events.push(ComputeEvent {
            at_sec: 30.0,
            node: None,
            factor: 3.0,
        });
        let mq = mq_cfg(4);
        let run_once = || {
            let mut e =
                MultiQueryDes::new(cfg.clone(), mq.clone());
            e.set_app_cycle(&[
                AppKind::App1,
                AppKind::App2,
                AppKind::App3,
            ]);
            e.run()
        };
        let r = run_once();
        assert!(r.aggregate.conserved(), "{:?}", r.aggregate);
        for q in r.activated() {
            let s = q.summary.as_ref().unwrap();
            assert!(s.conserved(), "query {}: {:?}", q.id, s);
        }
        assert_eq!(r.queries[1].app, AppKind::App2);
        assert_eq!(r.queries[2].app, AppKind::App3);
        let r2 = run_once();
        assert_eq!(r.aggregate.generated, r2.aggregate.generated);
        assert_eq!(r.aggregate.on_time, r2.aggregate.on_time);
        assert_eq!(r.aggregate.dropped, r2.aggregate.dropped);
    }

    #[test]
    fn mq_metrics_agree_with_ledgers() {
        let mut cfg = base_cfg();
        cfg.cluster.cr_instances = 2;
        cfg.drops_enabled = true;
        let r = run(cfg, mq_cfg(4));
        let m = &r.metrics;
        assert_eq!(m.generated, r.aggregate.generated);
        assert_eq!(m.on_time, r.aggregate.on_time);
        assert_eq!(m.delayed, r.aggregate.delayed);
        assert_eq!(m.dropped_total(), r.aggregate.dropped);
        assert!(r.rng_draws > 0);
        // Per-query counters reconcile with the per-query ledgers.
        for q in r.activated() {
            let s = q.summary.as_ref().unwrap();
            let (_, c) = m
                .per_query
                .iter()
                .find(|(id, _)| *id == q.id)
                .expect("activated query has metric counters");
            assert_eq!(c.generated, s.generated, "query {}", q.id);
            assert_eq!(c.on_time, s.on_time, "query {}", q.id);
            assert_eq!(c.delayed, s.delayed, "query {}", q.id);
            assert_eq!(c.dropped, s.dropped, "query {}", q.id);
        }
        // Per-second rows are cumulative and cover the service window.
        assert!(m.seconds.len() > 30, "{}", m.seconds.len());
        for w in m.seconds.windows(2) {
            assert!(w[1].generated >= w[0].generated);
        }
    }

    #[test]
    fn ring_sink_run_is_bit_identical_to_null() {
        use crate::obs::RingSink;
        let mut cfg = base_cfg();
        cfg.drops_enabled = true;
        let base = run(cfg.clone(), mq_cfg(3));
        let ring = RingSink::default();
        let traced =
            super::run_with_sink(cfg, mq_cfg(3), ring.clone());
        assert_eq!(base.aggregate.generated, traced.aggregate.generated);
        assert_eq!(base.aggregate.on_time, traced.aggregate.on_time);
        assert_eq!(base.aggregate.delayed, traced.aggregate.delayed);
        assert_eq!(base.aggregate.dropped, traced.aggregate.dropped);
        assert_eq!(base.rng_draws, traced.rng_draws);
        assert_eq!(base.core_events, traced.core_events);
        assert!(ring.total() > 0, "recorder saw the run");
    }

    #[test]
    fn mq_sharding_is_result_neutral() {
        // K-invariance for the multi-query path: the same seed under
        // K=1, K=3 sequential and K=3 threaded must agree on every
        // user-visible output — aggregate ledger, per-query summaries,
        // fusion updates, dispatch count and RNG draws — because the
        // merge serialises dispatch regardless of shard layout.
        let mk = |shards: usize, threads: usize| {
            let mut cfg = base_cfg();
            cfg.drops_enabled = true;
            cfg.sharding.shards = shards;
            cfg.sharding.threads = threads;
            run(cfg, mq_cfg(3))
        };
        let k1 = mk(1, 0);
        let k3 = mk(3, 0);
        let k3t = mk(3, 3);
        for r in [&k3, &k3t] {
            assert_eq!(k1.aggregate, r.aggregate);
            assert_eq!(k1.fusion_updates, r.fusion_updates);
            assert_eq!(k1.core_events, r.core_events);
            assert_eq!(k1.rng_draws, r.rng_draws);
            assert_eq!(k1.peak_concurrent, r.peak_concurrent);
            assert_eq!(k1.queries.len(), r.queries.len());
            for (a, b) in k1.queries.iter().zip(r.queries.iter()) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.status, b.status);
                assert_eq!(a.detections, b.detections);
                assert_eq!(a.summary, b.summary, "query {}", a.id);
            }
        }
        assert_eq!(k1.metrics.cross_shard_msgs, 0);
        assert_eq!(k1.metrics.shards, 1);
        assert_eq!(k3.metrics.shards, 3);
        assert!(
            k3.metrics.cross_shard_msgs > 0,
            "K=3 must hand events across shard boundaries"
        );
        assert_eq!(
            k3.metrics.cross_shard_msgs,
            k3t.metrics.cross_shard_msgs
        );
    }

    #[test]
    fn mq_node_crash_ab_conserves_per_query() {
        use crate::config::{FaultEvent, FaultKind};
        let mk = |enabled: bool| {
            let mut cfg = base_cfg();
            cfg.cluster.cr_instances = 2;
            cfg.service.fault_events.push(FaultEvent {
                at_sec: 20.0,
                kind: FaultKind::NodeCrash {
                    node: 1,
                    down_secs: None,
                },
            });
            cfg.service.recovery.enabled = enabled;
            run(cfg, mq_cfg(3))
        };
        let on = mk(true);
        let off = mk(false);
        for r in [&on, &off] {
            assert!(r.aggregate.conserved(), "{:?}", r.aggregate);
            assert!(r.metrics.faults_injected > 0);
            assert_eq!(
                r.metrics.lost_to_fault,
                r.aggregate.lost_to_fault,
            );
            for q in r.activated() {
                let s = q.summary.as_ref().unwrap();
                assert!(s.conserved(), "query {}: {:?}", q.id, s);
                let (_, c) = r
                    .metrics
                    .per_query
                    .iter()
                    .find(|(id, _)| *id == q.id)
                    .unwrap();
                assert_eq!(
                    c.lost_to_fault, s.lost_to_fault,
                    "query {}",
                    q.id
                );
            }
        }
        // Recovery re-dispatches and retries instead of writing work
        // off: it never loses more than the fail-stop baseline.
        assert!(
            on.aggregate.lost_to_fault <= off.aggregate.lost_to_fault,
            "on={} off={}",
            on.aggregate.lost_to_fault,
            off.aggregate.lost_to_fault,
        );
    }

    #[test]
    fn mq_camera_outage_is_deterministic_and_conserved() {
        use crate::config::{FaultEvent, FaultKind};
        let mk = || {
            let mut cfg = base_cfg();
            cfg.service.fault_events.push(FaultEvent {
                at_sec: 10.0,
                kind: FaultKind::CameraOutage {
                    camera: 3,
                    down_secs: Some(20.0),
                },
            });
            run(cfg, mq_cfg(3))
        };
        let a = mk();
        let b = mk();
        assert!(a.aggregate.conserved(), "{:?}", a.aggregate);
        // An outage alone loses nothing: frames are simply never
        // captured (no loss windows, no crashes).
        assert_eq!(a.aggregate.lost_to_fault, 0);
        assert_eq!(a.aggregate.generated, b.aggregate.generated);
        assert_eq!(a.aggregate.on_time, b.aggregate.on_time);
        assert_eq!(a.rng_draws, b.rng_draws);
        assert_eq!(a.core_events, b.core_events);
    }

    #[test]
    fn mq_empty_fault_schedule_is_bit_identical() {
        // The recovery flag alone (no schedule) must not perturb the
        // run: every fault hook short-circuits on the static model.
        let base = run(base_cfg(), mq_cfg(3));
        let mut cfg = base_cfg();
        cfg.service.recovery.enabled = false;
        let toggled = run(cfg, mq_cfg(3));
        assert_eq!(base.aggregate.generated, toggled.aggregate.generated);
        assert_eq!(base.aggregate.on_time, toggled.aggregate.on_time);
        assert_eq!(base.aggregate.dropped, toggled.aggregate.dropped);
        assert_eq!(base.aggregate.lost_to_fault, 0);
        assert_eq!(base.rng_draws, toggled.rng_draws);
        assert_eq!(base.core_events, toggled.core_events);
    }

    #[test]
    fn per_query_ledgers_survive_overload_with_drops() {
        let mut cfg = base_cfg();
        cfg.cluster.cr_instances = 2;
        cfg.drops_enabled = true;
        let r = run(cfg, mq_cfg(4));
        assert!(r.aggregate.conserved());
        for q in r.activated() {
            let s = q.summary.as_ref().unwrap();
            assert!(s.conserved(), "query {}: {:?}", q.id, s);
        }
    }
}
