//! Fair-share batch formation across concurrent queries.
//!
//! Each shared VA/CR executor owns one [`FairShareBatcher`]: per-query
//! FIFO queues plus the weighted deficit-round-robin core
//! ([`crate::tuning::FairShare`]). Batch composition follows the
//! paper's dynamic-batching rule (§4.4) — an event joins the current
//! batch iff the grown batch still meets both the batch deadline
//! (earliest member) and the event's own deadline — but candidates are
//! drawn across query queues in DRR order, so a backlogged query can
//! take at most its weighted share of batch slots. Batches therefore
//! *mix queries* (one model execution serves frames tagged for
//! different queries) while per-query FIFO order is preserved.

use std::collections::VecDeque;

use crate::dataflow::QueryId;
use crate::tuning::budget::BUDGET_INF;
use crate::tuning::{BatcherPoll, FairShare, QueuedEvent, XiModel};
use crate::util::Micros;

/// Per-executor fair-share batch formation state.
pub struct FairShareBatcher<T> {
    queues: Vec<(QueryId, VecDeque<QueuedEvent<T>>)>,
    share: FairShare,
    current: Vec<QueuedEvent<T>>,
    /// Δₚ: earliest deadline among `current`.
    cur_deadline: Micros,
    /// Effective cost of `current`: Σ per-event cost multipliers (see
    /// [`Self::poll_costed`]); exactly `current.len()` when every
    /// query runs the calibration app.
    cur_relsum: f64,
    max: usize,
}

impl<T> FairShareBatcher<T> {
    pub fn new(max: usize) -> Self {
        Self {
            queues: Vec::new(),
            share: FairShare::new(),
            current: Vec::new(),
            cur_deadline: BUDGET_INF,
            cur_relsum: 0.0,
            max: max.max(1),
        }
    }

    /// Register a query with its fair-share weight (idempotent).
    pub fn register(&mut self, query: QueryId, weight: u32) {
        self.share.ensure(query, weight);
        if !self.queues.iter().any(|(q, _)| *q == query) {
            self.queues.push((query, VecDeque::new()));
        }
    }

    /// Remove a query from the rotation, returning any events still
    /// queued for it (the engine ledgers them; in-flight work of a
    /// cancelled query must not silently vanish).
    pub fn deregister(&mut self, query: QueryId) -> Vec<QueuedEvent<T>> {
        self.share.remove(query);
        let mut out = Vec::new();
        if let Some(i) =
            self.queues.iter().position(|(q, _)| *q == query)
        {
            let (_, dq) = self.queues.remove(i);
            out.extend(dq);
        }
        // The current batch may already hold events of this query;
        // leave them — they execute with the in-progress batch.
        out
    }

    /// Empty the forming batch and every per-query queue into `out`
    /// (forming batch first, then queues in registration order —
    /// per-query FIFO preserved). Registrations and fair-share weights
    /// are kept: this orphans a dead executor's backlog for
    /// re-dispatch, it does not cancel queries.
    pub fn drain_into(&mut self, out: &mut Vec<QueuedEvent<T>>) {
        out.append(&mut self.current);
        self.cur_deadline = BUDGET_INF;
        self.cur_relsum = 0.0;
        for (_, dq) in self.queues.iter_mut() {
            out.extend(dq.drain(..));
        }
    }

    fn queue_mut(
        &mut self,
        query: QueryId,
    ) -> &mut VecDeque<QueuedEvent<T>> {
        let i = self
            .queues
            .iter()
            .position(|(q, _)| *q == query)
            .expect("query registered");
        &mut self.queues[i].1
    }

    /// Enqueue an arriving (post-drop-point-1) event of `query`.
    ///
    /// Returns the event back (`Some`) when the query is not
    /// registered — i.e. it already completed or was cancelled and a
    /// late in-flight event arrived. Callers must account for the
    /// returned event (typically ledger it as dropped); silently
    /// re-registering finished queries here would resurrect their
    /// fair-share and budget state forever.
    #[must_use]
    pub fn push(
        &mut self,
        query: QueryId,
        qe: QueuedEvent<T>,
    ) -> Option<QueuedEvent<T>> {
        if !self.queues.iter().any(|(q, _)| *q == query) {
            return Some(qe);
        }
        self.queue_mut(query).push_back(qe);
        None
    }

    /// Total queued events across queries (excluding the forming batch).
    pub fn pending_len(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    pub fn current_len(&self) -> usize {
        self.current.len()
    }

    fn take_current(&mut self) -> Vec<QueuedEvent<T>> {
        self.cur_deadline = BUDGET_INF;
        self.cur_relsum = 0.0;
        std::mem::take(&mut self.current)
    }

    /// Hand back an emptied batch vec so its capacity seeds the next
    /// batch (same contract as [`crate::tuning::Batcher::recycle`]).
    pub fn recycle(&mut self, mut spare: Vec<QueuedEvent<T>>) {
        if self.current.is_empty() && self.current.capacity() == 0 {
            spare.clear();
            self.current = spare;
        }
    }

    fn head_of(&self, query: QueryId) -> Option<&QueuedEvent<T>> {
        self.queues
            .iter()
            .find(|(q, _)| *q == query)
            .and_then(|(_, dq)| dq.front())
    }

    fn pop_head(&mut self, query: QueryId) -> QueuedEvent<T> {
        self.share.charge(query, 1);
        self.queue_mut(query)
            .pop_front()
            .expect("picked queue non-empty")
    }

    /// Drive batch formation at time `now` — same contract as
    /// [`crate::tuning::Batcher::poll`]. Every event costs 1 (the
    /// homogeneous case); use [`Self::poll_costed`] when queries run
    /// different applications.
    pub fn poll(
        &mut self,
        now: Micros,
        xi: &XiModel,
    ) -> BatcherPoll<T> {
        self.poll_costed(now, xi, |_| 1.0)
    }

    /// [`Self::poll`] with per-query service-cost multipliers: an
    /// event of query `q` contributes `cost(q)` effective batch slots
    /// to the §4.4 deadline test, so the grown-batch estimate is
    /// `ξ(Σ costs)` rather than `ξ(count)` — a heterogeneous mix (say
    /// an App 2 query whose CR is 1.63x App 1's) batches under each
    /// app's cost model. `cost(q) = 1.0` for every query reproduces
    /// [`Self::poll`] bit-exactly (Σ of ones is an exact integer).
    pub fn poll_costed(
        &mut self,
        now: Micros,
        xi: &XiModel,
        cost: impl Fn(QueryId) -> f64,
    ) -> BatcherPoll<T> {
        loop {
            if self.current.len() >= self.max {
                return BatcherPoll::Ready(self.take_current());
            }
            // Next candidate queue under weighted DRR. Borrow the
            // queue table and the DRR state as disjoint fields so the
            // has-work probe needs no snapshot allocation.
            let picked = {
                let queues = &self.queues;
                self.share.pick(|k| {
                    queues
                        .iter()
                        .any(|(q, dq)| *q == k && !dq.is_empty())
                })
            };
            let Some(q) = picked else {
                // No pending work anywhere: submit or arm the timer.
                if self.current.is_empty() {
                    return BatcherPoll::Idle;
                }
                let submit_at = self
                    .cur_deadline
                    .saturating_sub(xi.xi_eff(self.cur_relsum));
                if now >= submit_at {
                    return BatcherPoll::Ready(self.take_current());
                }
                return BatcherPoll::Timer(submit_at);
            };
            let head_deadline =
                self.head_of(q).expect("picked queue non-empty").deadline;
            // Bootstrap (no budget yet): stream solo, like the
            // single-query dynamic batcher.
            if head_deadline >= BUDGET_INF {
                if !self.current.is_empty() {
                    return BatcherPoll::Ready(self.take_current());
                }
                let head = self.pop_head(q);
                return BatcherPoll::Ready(vec![head]);
            }
            let grown = self.cur_relsum + cost(q);
            let fits = now + xi.xi_eff(grown)
                <= self.cur_deadline.min(head_deadline);
            if fits {
                let head = self.pop_head(q);
                self.cur_deadline = self.cur_deadline.min(head.deadline);
                self.cur_relsum = grown;
                self.current.push(head);
            } else if !self.current.is_empty() {
                return BatcherPoll::Ready(self.take_current());
            } else {
                // Even alone the head misses its deadline; release it
                // solo — drop point 2 will judge it.
                let head = self.pop_head(q);
                return BatcherPoll::Ready(vec![head]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SEC;

    fn xi() -> XiModel {
        XiModel::affine_ms(52.5, 67.5)
    }

    fn qe(query: QueryId, id: u64, deadline: Micros) -> QueuedEvent<(QueryId, u64)> {
        QueuedEvent {
            item: (query, id),
            id,
            arrival: 0,
            deadline,
        }
    }

    fn ready(
        p: BatcherPoll<(QueryId, u64)>,
    ) -> Vec<(QueryId, u64)> {
        match p {
            BatcherPoll::Ready(b) => {
                b.into_iter().map(|e| e.item).collect()
            }
            other => panic!("expected Ready, got {other:?}"),
        }
    }

    fn counts(batch: &[(QueryId, u64)], queries: &[QueryId]) -> Vec<usize> {
        queries
            .iter()
            .map(|&q| batch.iter().filter(|(b, _)| *b == q).count())
            .collect()
    }

    /// Push to a registered query, asserting acceptance.
    fn push_ok(
        b: &mut FairShareBatcher<(QueryId, u64)>,
        q: QueryId,
        e: QueuedEvent<(QueryId, u64)>,
    ) {
        assert!(
            b.push(q, e).is_none(),
            "query {q} should be registered"
        );
    }

    #[test]
    fn cross_query_batch_shares_slots_equally() {
        let mut b = FairShareBatcher::new(6);
        for q in [1u32, 2, 3] {
            b.register(q, 1);
            for k in 0..10 {
                push_ok(&mut b, q, qe(q, k, 60 * SEC));
            }
        }
        let batch = ready(b.poll(0, &xi()));
        assert_eq!(batch.len(), 6);
        assert_eq!(counts(&batch, &[1, 2, 3]), vec![2, 2, 2]);
    }

    #[test]
    fn priority_weights_bias_batch_composition() {
        let mut b = FairShareBatcher::new(8);
        b.register(1, 2); // double weight
        b.register(2, 1);
        b.register(3, 1);
        for q in [1u32, 2, 3] {
            for k in 0..20 {
                push_ok(&mut b, q, qe(q, k, 60 * SEC));
            }
        }
        let batch = ready(b.poll(0, &xi()));
        assert_eq!(batch.len(), 8);
        assert_eq!(counts(&batch, &[1, 2, 3]), vec![4, 2, 2]);
    }

    #[test]
    fn fifo_preserved_within_each_query() {
        let mut b = FairShareBatcher::new(25);
        for q in [1u32, 2] {
            b.register(q, 1);
            for k in 0..5 {
                push_ok(&mut b, q, qe(q, k, 60 * SEC));
            }
        }
        // Drain everything via far-future polls.
        let mut seen: Vec<Vec<u64>> = vec![Vec::new(), Vec::new()];
        loop {
            match b.poll(BUDGET_INF / 2, &xi()) {
                BatcherPoll::Ready(batch) => {
                    for e in batch {
                        seen[(e.item.0 - 1) as usize].push(e.item.1);
                    }
                }
                _ => break,
            }
        }
        assert_eq!(seen[0], vec![0, 1, 2, 3, 4]);
        assert_eq!(seen[1], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn bootstrap_streams_solo() {
        let mut b = FairShareBatcher::new(25);
        b.register(7, 1);
        push_ok(&mut b, 7, qe(7, 0, BUDGET_INF));
        push_ok(&mut b, 7, qe(7, 1, BUDGET_INF));
        assert_eq!(ready(b.poll(0, &xi())), vec![(7, 0)]);
        assert_eq!(ready(b.poll(0, &xi())), vec![(7, 1)]);
        assert!(matches!(b.poll(0, &xi()), BatcherPoll::Idle));
    }

    #[test]
    fn timer_is_min_deadline_minus_xi() {
        let mut b = FairShareBatcher::new(25);
        let x = xi();
        b.register(1, 1);
        b.register(2, 1);
        push_ok(&mut b, 1, qe(1, 0, 30 * SEC));
        push_ok(&mut b, 2, qe(2, 0, 10 * SEC)); // tighter
        match b.poll(0, &x) {
            BatcherPoll::Timer(at) => {
                assert_eq!(at, 10 * SEC - x.xi(2));
            }
            other => panic!("{other:?}"),
        }
        let at = 10 * SEC - x.xi(2);
        let batch = ready(b.poll(at, &x));
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn starving_query_is_protected() {
        // Query 1 is hugely backlogged; query 2 trickles. Over repeated
        // max-size batches, query 2's events are always served promptly
        // (each batch takes slots from both while both have work).
        let mut b = FairShareBatcher::new(4);
        b.register(1, 1);
        b.register(2, 1);
        for k in 0..100 {
            push_ok(&mut b, 1, qe(1, k, 60 * SEC));
        }
        push_ok(&mut b, 2, qe(2, 0, 60 * SEC));
        push_ok(&mut b, 2, qe(2, 1, 60 * SEC));
        let batch = ready(b.poll(0, &xi()));
        let c = counts(&batch, &[1, 2]);
        assert_eq!(c[1], 2, "trickle query got its slots: {batch:?}");
        assert_eq!(c[0], 2);
        // Once query 2 drains, query 1 gets full batches.
        let batch = ready(b.poll(0, &xi()));
        assert_eq!(counts(&batch, &[1, 2]), vec![4, 0]);
    }

    #[test]
    fn deregister_returns_leftovers() {
        let mut b = FairShareBatcher::new(8);
        b.register(5, 1);
        for k in 0..3 {
            push_ok(&mut b, 5, qe(5, k, 60 * SEC));
        }
        let left = b.deregister(5);
        assert_eq!(left.len(), 3);
        assert!(matches!(b.poll(0, &xi()), BatcherPoll::Idle));
        assert_eq!(b.pending_len(), 0);
        // Late in-flight events of the finished query bounce back for
        // the caller to account — they must not resurrect the query.
        assert!(b.push(5, qe(5, 9, 60 * SEC)).is_some());
        assert!(matches!(b.poll(0, &xi()), BatcherPoll::Idle));
    }

    #[test]
    fn poll_costed_prices_expensive_queries() {
        let x = xi();
        // The deadline admits an effective batch size of 3, not 4.
        let dl = x.xi(3) + 1;
        // Homogeneous unit cost: three events fit…
        let mut b = FairShareBatcher::new(25);
        b.register(1, 1);
        for k in 0..5 {
            push_ok(&mut b, 1, qe(1, k, dl));
        }
        assert_eq!(ready(b.poll_costed(0, &x, |_| 1.0)).len(), 3);
        // …and unit cost is exactly `poll`.
        let mut b2 = FairShareBatcher::new(25);
        b2.register(1, 1);
        for k in 0..5 {
            push_ok(&mut b2, 1, qe(1, k, dl));
        }
        assert_eq!(ready(b2.poll(0, &x)).len(), 3);
        // A 1.5x-cost app fills the same deadline with two events
        // (Σ costs 3.0); a third would price at ξ(4.5) and miss.
        let mut b3 = FairShareBatcher::new(25);
        b3.register(1, 1);
        for k in 0..5 {
            push_ok(&mut b3, 1, qe(1, k, dl));
        }
        assert_eq!(ready(b3.poll_costed(0, &x, |_| 1.5)).len(), 2);
    }

    #[test]
    fn solo_release_past_deadline() {
        let mut b = FairShareBatcher::new(25);
        b.register(1, 1);
        push_ok(&mut b, 1, qe(1, 0, 1)); // cannot meet deadline even alone
        let batch = ready(b.poll(10, &xi()));
        assert_eq!(batch, vec![(1, 0)]);
    }
}
