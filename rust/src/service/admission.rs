//! Admission control for the multi-query service.
//!
//! New queries are admitted only while the service has headroom on two
//! axes: the number of concurrently active queries (each holds worker
//! queue/budget state) and the *aggregate active-camera set* (the sum
//! of per-query spotlights is what actually drives VA/CR load — an
//! unseeded query bootstraps all-active, §2.3, and admitting two of
//! those on a 1000-camera network is a meltdown). Queries without
//! headroom are wait-listed up to a queue capacity, then rejected.

use crate::config::MultiQueryConfig;
use crate::service::query::QuerySpec;

/// Resource limits the controller enforces.
#[derive(Debug, Clone)]
pub struct AdmissionPolicy {
    /// Maximum concurrently active queries.
    pub max_active: usize,
    /// Maximum aggregate active-camera count across all queries.
    pub max_active_cameras: usize,
    /// Wait-queue capacity before outright rejection.
    pub queue_capacity: usize,
}

impl From<&MultiQueryConfig> for AdmissionPolicy {
    fn from(mq: &MultiQueryConfig) -> Self {
        Self {
            max_active: mq.max_active,
            max_active_cameras: mq.max_active_cameras,
            queue_capacity: mq.queue_capacity,
        }
    }
}

/// Outcome of an admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Activate now.
    Admit,
    /// Wait-list; re-evaluated whenever capacity frees up.
    Queue,
    /// Refuse (wait queue full or query can never fit).
    Reject(&'static str),
}

/// Stateless decision logic over a snapshot of service occupancy.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    pub policy: AdmissionPolicy,
}

impl AdmissionController {
    pub fn new(policy: AdmissionPolicy) -> Self {
        Self { policy }
    }

    /// Decide for `spec` given current occupancy: `active_queries` and
    /// `queued` counts, the current aggregate `active_cameras`, and the
    /// total camera count (to project the query's bootstrap cost).
    pub fn decide(
        &self,
        spec: &QuerySpec,
        active_queries: usize,
        queued: usize,
        active_cameras: usize,
        total_cameras: usize,
    ) -> Admission {
        let projected = spec.initial_camera_estimate(total_cameras);
        // A query that alone exceeds the camera budget can never be
        // admitted — reject instead of wait-listing it forever.
        if projected > self.policy.max_active_cameras {
            return Admission::Reject(
                "query's bootstrap camera set exceeds the service budget",
            );
        }
        let has_query_slot = active_queries < self.policy.max_active;
        let has_camera_room =
            active_cameras + projected <= self.policy.max_active_cameras;
        if has_query_slot && has_camera_room {
            return Admission::Admit;
        }
        if queued < self.policy.queue_capacity {
            return Admission::Queue;
        }
        Admission::Reject("service at capacity and wait queue full")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::query::QuerySpec;

    fn ctl(max_active: usize, max_cams: usize, qcap: usize) -> AdmissionController {
        AdmissionController::new(AdmissionPolicy {
            max_active,
            max_active_cameras: max_cams,
            queue_capacity: qcap,
        })
    }

    #[test]
    fn admits_with_headroom() {
        let c = ctl(4, 100, 2);
        let s = QuerySpec::new("a", 0);
        assert_eq!(c.decide(&s, 0, 0, 0, 1000), Admission::Admit);
        assert_eq!(c.decide(&s, 3, 0, 90, 1000), Admission::Admit);
    }

    #[test]
    fn queues_when_slots_exhausted() {
        let c = ctl(2, 100, 2);
        let s = QuerySpec::new("a", 0);
        assert_eq!(c.decide(&s, 2, 0, 8, 1000), Admission::Queue);
        assert_eq!(c.decide(&s, 2, 1, 8, 1000), Admission::Queue);
    }

    #[test]
    fn rejects_when_queue_full() {
        let c = ctl(2, 100, 2);
        let s = QuerySpec::new("a", 0);
        assert!(matches!(
            c.decide(&s, 2, 2, 8, 1000),
            Admission::Reject(_)
        ));
    }

    #[test]
    fn camera_budget_blocks_unseeded_bootstrap() {
        let c = ctl(8, 500, 2);
        let unseeded = QuerySpec {
            start_camera: None,
            ..QuerySpec::new("u", 0)
        };
        // 1000-camera bootstrap > 500 budget: can never fit.
        assert!(matches!(
            c.decide(&unseeded, 0, 0, 0, 1000),
            Admission::Reject(_)
        ));
        // A seeded query still fits while the aggregate has room.
        let seeded = QuerySpec::new("s", 3);
        assert_eq!(c.decide(&seeded, 0, 0, 497, 1000), Admission::Queue);
        assert_eq!(c.decide(&seeded, 0, 0, 496, 1000), Admission::Admit);
    }

    #[test]
    fn policy_from_config() {
        let mq = crate::config::MultiQueryConfig::default();
        let p = AdmissionPolicy::from(&mq);
        assert_eq!(p.max_active, mq.max_active);
        assert_eq!(p.queue_capacity, mq.queue_capacity);
    }
}
