//! Dataflow stages and their pipeline order.

/// The module types of the tracking dataflow (Fig 2 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Filter Controls — per-camera ingress gate on an edge device.
    Fc,
    /// Video Analytics — per-camera-stream detection (edge/fog/cloud).
    Va,
    /// Contention Resolution — cross-camera re-identification.
    Cr,
    /// Tracking Logic — the distributed-tracking brain (cloud).
    Tl,
    /// Query Fusion — query-embedding refinement.
    Qf,
    /// User Visualization — the sink.
    Uv,
}

impl Stage {
    /// Position in the latency pipeline `[FC, VA, CR, UV]` (§4.2); TL/QF
    /// branch off CR's metadata output and are not latency-accounted.
    pub fn pipeline_index(self) -> Option<usize> {
        match self {
            Stage::Fc => Some(0),
            Stage::Va => Some(1),
            Stage::Cr => Some(2),
            Stage::Uv => Some(3),
            Stage::Tl | Stage::Qf => None,
        }
    }

    /// The next stage in the latency pipeline.
    pub fn next(self) -> Option<Stage> {
        match self {
            Stage::Fc => Some(Stage::Va),
            Stage::Va => Some(Stage::Cr),
            Stage::Cr => Some(Stage::Uv),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Fc => "FC",
            Stage::Va => "VA",
            Stage::Cr => "CR",
            Stage::Tl => "TL",
            Stage::Qf => "QF",
            Stage::Uv => "UV",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_order() {
        assert_eq!(Stage::Fc.next(), Some(Stage::Va));
        assert_eq!(Stage::Va.next(), Some(Stage::Cr));
        assert_eq!(Stage::Cr.next(), Some(Stage::Uv));
        assert_eq!(Stage::Uv.next(), None);
        assert_eq!(Stage::Tl.next(), None);
    }

    #[test]
    fn pipeline_indices_are_sequential() {
        let idx: Vec<_> = [Stage::Fc, Stage::Va, Stage::Cr, Stage::Uv]
            .iter()
            .map(|s| s.pipeline_index().unwrap())
            .collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
        assert_eq!(Stage::Tl.pipeline_index(), None);
    }
}
