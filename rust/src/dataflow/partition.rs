//! Key-based partitioning of events across module instances.
//!
//! Events are grouped by key (camera id) before module execution, like
//! MapReduce's shuffle (§2.2.2); the partitioner maps a key to one of
//! `n` downstream instances, and must be total and stable so a camera's
//! frames always visit the same VA/CR instance (preserving per-camera
//! temporal batches).

/// Stable key → instance mapping.
#[derive(Debug, Clone, Copy)]
pub struct Partitioner {
    n: usize,
}

impl Partitioner {
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "partitioner needs at least one instance");
        Self { n }
    }

    /// Instance index for a key (fibonacci-hash then mod — cheap and
    /// well-spread for dense camera ids).
    pub fn route(&self, key: usize) -> usize {
        let h = (key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) as usize) % self.n
    }

    pub fn instances(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_and_stable() {
        let p = Partitioner::new(10);
        for k in 0..5000 {
            let a = p.route(k);
            assert!(a < 10);
            assert_eq!(a, p.route(k), "stable for key {k}");
        }
    }

    #[test]
    fn spreads_dense_keys() {
        let p = Partitioner::new(10);
        let mut counts = [0usize; 10];
        for k in 0..1000 {
            counts[p.route(k)] += 1;
        }
        // 1000 cameras over 10 instances: every instance gets 60-140.
        for (i, &c) in counts.iter().enumerate() {
            assert!((60..=140).contains(&c), "instance {i} got {c}");
        }
    }

    #[test]
    fn single_instance_routes_everything() {
        let p = Partitioner::new(1);
        for k in 0..100 {
            assert_eq!(p.route(k), 0);
        }
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_instances_panics() {
        Partitioner::new(0);
    }
}
