//! The domain-specific dataflow programming model (§2.2).
//!
//! A tracking application is a fixed dataflow of six module types —
//! Filter Controls (FC), Video Analytics (VA), Contention Resolution
//! (CR), Tracking Logic (TL), Query Fusion (QF) and User Visualization
//! (UV) — for which the **user supplies the functional logic** and the
//! platform owns grouping, batching, dropping and routing (like
//! MapReduce fixes the dataflow and the user fills in Map/Reduce).
//!
//! That contract is expressed as traits in [`blocks`]: an application
//! implements (or picks stock implementations of) [`FilterControl`],
//! [`VideoAnalytics`], [`ContentionResolver`], [`TrackingLogic`] and
//! [`QueryFusion`], composes them with
//! [`crate::apps::AppBuilder`] into an
//! [`crate::apps::AppDefinition`], and every execution engine — the
//! single-query DES ([`crate::coordinator::des`]), the multi-query DES
//! ([`crate::service::engine`]) and the live engines
//! ([`crate::coordinator::live`], [`crate::service::front`]) — drives
//! the blocks exclusively through those traits. No engine branches on
//! *which* application is running.
//!
//! The rest of this module is the data plane the blocks see:
//! [`Event`]s (key-value pairs with the §4 tuning header), the
//! [`Stage`] pipeline, the key [`Partitioner`], and the QF → VA/CR
//! **feedback edge**: sink-side refinements are stamped with per-query
//! update sequence numbers by a [`FeedbackRouter`], routed upstream as
//! [`Payload::QueryUpdate`] events, and applied by each executor's
//! [`FeedbackState`] with deterministic stale-update discard.

mod blocks;
mod event;
mod feedback;
mod partition;
mod stage;

pub use blocks::{
    AnalyticsBlock, ContentionResolver, FilterControl, ModelVariant,
    QueryFusion, ScoreParams, SimCtx, TlEnv, TlFactory, TrackingLogic,
    TruthSource, VariantProfile, VideoAnalytics, VARIANT_TABLE,
};
pub use event::{
    Event, EventId, Header, Payload, QueryId, SINGLE_QUERY,
};
pub use feedback::{
    boosted_rates, boosted_residual, FeedbackEnvelope, FeedbackRouter,
    FeedbackState, QueryRefinement,
};
pub use partition::Partitioner;
pub use stage::Stage;
