//! The domain-specific dataflow programming model (§2.2).
//!
//! A tracking application is a fixed dataflow of six module types —
//! Filter Controls (FC), Video Analytics (VA), Contention Resolution
//! (CR), Tracking Logic (TL), Query Fusion (QF) and User Visualization
//! (UV) — for which the user supplies functional logic; the platform
//! owns grouping, batching, dropping and routing (like MapReduce fixes
//! the dataflow and the user fills in Map/Reduce).

mod event;
mod partition;
mod stage;

pub use event::{
    Event, EventId, Header, Payload, QueryId, SINGLE_QUERY,
};
pub use partition::Partitioner;
pub use stage::Stage;
