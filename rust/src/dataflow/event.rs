//! Events flowing through the dataflow.
//!
//! Events are key-value pairs (camera id, payload) with a header that
//! carries the provenance the tuning strategies need: the source arrival
//! time `a¹` (propagated to all causal downstream events, §4.2), the
//! accumulated execution and queueing durations (Σξ, Σq — the two fields
//! §4.5 adds to every downstream event), the `avoid-drop` flag (§4.3.3)
//! and the probe marker (§4.5.2).

use std::sync::Arc;

use crate::util::Micros;

pub type EventId = u64;

/// Identifier of the tracking query an event belongs to.
///
/// The seed platform ran exactly one query per process; the service
/// layer ([`crate::service`]) multiplexes many concurrent queries over
/// the shared VA/CR workers, so every event is tagged with its query —
/// batches may mix events of different queries (cross-query batching)
/// while budgets, drops and ledgers stay per-query.
pub type QueryId = u32;

/// The query id used by all single-query engines and tests.
pub const SINGLE_QUERY: QueryId = 0;

/// Provenance and tuning metadata carried by every event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    /// Source event id `k`; all causal downstream events share it.
    pub id: EventId,
    /// The tracking query this event serves ([`SINGLE_QUERY`] in
    /// single-query mode).
    pub query: QueryId,
    /// Key: the originating camera.
    pub camera: usize,
    /// Frame number at that camera.
    pub frame_no: u64,
    /// Arrival time `aᵏ₁` at the source task (source device clock κ₁).
    pub src_arrival: Micros,
    /// Capture timestamp at the camera (used by TL for sighting times).
    pub captured: Micros,
    /// Σ ξⱼ(mᵏⱼ) over upstream tasks (§4.5 header field).
    pub sum_exec: Micros,
    /// Σ qᵏⱼ over upstream tasks (§4.5 header field).
    pub sum_queue: Micros,
    /// User-logic hint: never drop this event (e.g. positive matches).
    pub avoid_drop: bool,
    /// Probe events traverse the pipeline without being dropped so the
    /// sink can re-open collapsed budgets (§4.5.2).
    pub probe: bool,
    /// Update sequence number of a [`Payload::QueryUpdate`] refinement
    /// (0 on data events). Stamped per query by the engine's
    /// [`crate::dataflow::FeedbackRouter`]; VA/CR executors apply an
    /// update iff it is fresher than the last one they saw, so
    /// duplicate/out-of-order deliveries are discarded
    /// deterministically.
    pub update_seq: u32,
}

impl Header {
    pub fn new(
        id: EventId,
        camera: usize,
        frame_no: u64,
        src_arrival: Micros,
    ) -> Self {
        Self {
            id,
            query: SINGLE_QUERY,
            camera,
            frame_no,
            src_arrival,
            captured: src_arrival,
            sum_exec: 0,
            sum_queue: 0,
            avoid_drop: false,
            probe: false,
            update_seq: 0,
        }
    }

    /// Tag the header with the query it serves (builder-style).
    pub fn with_query(mut self, query: QueryId) -> Self {
        self.query = query;
        self
    }
}

/// Module-specific payloads. The simulated engines carry ground-truth
/// labels; the live engine carries real pixel data for the PJRT models.
#[derive(Debug, Clone)]
pub enum Payload {
    /// A camera frame (FC → VA). `entity_present` is ground truth.
    Frame { entity_present: bool },
    /// A frame with real pixels (live engine).
    FrameData(Arc<Vec<f32>>),
    /// VA output: candidate detections for CR (bounding boxes in the
    /// paper; here the flag + matching score).
    Candidate { entity_present: bool, score: f32 },
    /// CR output: confirmed detection verdict (CR → UV/TL/QF).
    Detection { detected: bool, confidence: f32 },
    /// QF output: an updated query embedding routed back to VA/CR (the
    /// §2.2 feedback edge). The per-query update sequence number rides
    /// on [`Header::update_seq`]; executors apply the freshest update
    /// through [`crate::dataflow::FeedbackState`] and discard stale
    /// deliveries.
    QueryUpdate(Arc<Vec<f32>>),
    /// Sink-minted adaptation command riding the same feedback edge
    /// (the per-camera command seq on [`Header::update_seq`]). Like
    /// `QueryUpdate`, it is consumed at the executor — never ledgered,
    /// batched or dropped — and applied exactly once per engine via
    /// [`crate::tuning::adapt::AdaptationState::apply`] (duplicate
    /// broadcast copies discard as stale).
    Adaptation(crate::tuning::adapt::AdaptationCommand),
}

impl Payload {
    /// Ground-truth presence, where the payload carries it.
    pub fn entity_present(&self) -> Option<bool> {
        match self {
            Payload::Frame { entity_present }
            | Payload::Candidate { entity_present, .. } => {
                Some(*entity_present)
            }
            Payload::Detection { detected, .. } => Some(*detected),
            _ => None,
        }
    }
}

/// A key-value event: header (key side) plus payload (value side).
#[derive(Debug, Clone)]
pub struct Event {
    pub header: Header,
    pub payload: Payload,
}

impl Event {
    pub fn frame(
        id: EventId,
        camera: usize,
        frame_no: u64,
        src_arrival: Micros,
        entity_present: bool,
    ) -> Self {
        Self {
            header: Header::new(id, camera, frame_no, src_arrival),
            payload: Payload::Frame { entity_present },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_propagates_source_arrival() {
        let e = Event::frame(7, 3, 0, 123456, true);
        assert_eq!(e.header.id, 7);
        assert_eq!(e.header.query, SINGLE_QUERY);
        assert_eq!(
            e.header.with_query(4).query,
            4,
            "query tag is builder-assignable"
        );
        assert_eq!(e.header.src_arrival, 123456);
        assert_eq!(e.header.captured, 123456);
        assert_eq!(e.header.sum_exec, 0);
        assert!(!e.header.avoid_drop);
    }

    #[test]
    fn payload_truth_access() {
        assert_eq!(
            Payload::Frame {
                entity_present: true
            }
            .entity_present(),
            Some(true)
        );
        assert_eq!(
            Payload::Detection {
                detected: false,
                confidence: 0.1
            }
            .entity_present(),
            Some(false)
        );
        assert_eq!(
            Payload::QueryUpdate(Arc::new(vec![])).entity_present(),
            None
        );
    }
}
