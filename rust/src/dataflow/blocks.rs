//! The user-definable block (UDF) traits of the §2.2 programming model.
//!
//! A tracking application is the composition of five user-supplied
//! blocks over the fixed FC → VA → CR → {TL, QF, UV} dataflow. Each
//! block is a trait here; the platform (the engines in
//! [`crate::coordinator`] and [`crate::service`]) owns grouping,
//! batching, dropping, routing and budget adaptation, and calls the
//! blocks only through these traits — like MapReduce fixes the dataflow
//! and the user fills in Map/Reduce.
//!
//! Design constraints, inherited from the hot-path work the engines sit
//! on:
//!
//! * **Object-safe**: engines hold `Box<dyn Block>` so an application
//!   compiled outside this crate plugs in without generics leaking
//!   through the engine types.
//! * **`&mut self` step methods over caller buffers**: blocks write
//!   into the engine's scratch (`&mut [Event]`, `&mut Vec<usize>`),
//!   never allocate per event, and hold their own reusable state.
//! * **Batch-hoisted dispatch**: the VA/CR step methods take a whole
//!   batch slice, so trait-object indirection costs one virtual call
//!   per *batch*, not per event — the zero-allocation dispatch loop of
//!   the engines is untouched by the indirection.
//!
//! The stock implementations (Table 1's building blocks) live in
//! [`crate::apps`]; [`crate::apps::AppBuilder`] composes blocks into an
//! [`crate::apps::AppDefinition`] that every engine accepts.

use std::sync::Arc;

use crate::config::{SemanticsConfig, WorkloadConfig};
use crate::dataflow::{Event, FeedbackState, QueryId};
use crate::roadnet::{Camera, Graph};
use crate::util::{Micros, Rng};

/// Typed handle to an AOT-exported model artifact. Replaces the old
/// stringly `va_variant`/`cr_variant` app fields: a block names its
/// model with a variant that is checked at *build* time instead of a
/// free-form `&str` that only fails (or silently mismatches) when the
/// live engine tries to load the artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelVariant {
    /// The HoG/YOLO-class detector network.
    Va,
    /// The small re-identification network.
    CrSmall,
    /// The large (~1.63x slower) re-identification network.
    CrLarge,
    /// The query-fusion embedding network.
    Qf,
}

/// Everything the platform knows about one model variant, in one row:
/// artifact name, relative ξ cost and relative accuracy. **The single
/// source of truth** — [`ModelVariant::from_artifact`], the stock
/// blocks' default costs and the adaptation plane's variant-swap
/// pricing all read this table, so a variant added here cannot
/// silently miss its ξ multiplier anywhere (and a variant added to
/// the enum without a row is a *panic* at first use, not a default
/// 1.0 — see [`ModelVariant::profile`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VariantProfile {
    pub variant: ModelVariant,
    /// Name of the artifact in `artifacts/manifest.json`.
    pub artifact: &'static str,
    /// ξ multiplier relative to the stage's calibration baseline
    /// (App 1: HoG-class VA, OpenReid-class small CR).
    pub xi: f64,
    /// Relative accuracy (detection-rate multiplier vs the stage's
    /// best variant; ≤ 1.0).
    pub accuracy: f64,
}

/// The typed variant table, in manifest order.
pub const VARIANT_TABLE: &[VariantProfile] = &[
    VariantProfile {
        variant: ModelVariant::Va,
        artifact: "va",
        xi: 1.0,
        accuracy: 1.0,
    },
    VariantProfile {
        variant: ModelVariant::CrSmall,
        artifact: "cr_small",
        xi: 1.0,
        accuracy: 0.95,
    },
    VariantProfile {
        variant: ModelVariant::CrLarge,
        artifact: "cr_large",
        // The deeper CR DNN takes ~63% longer per frame (§5.3) but
        // sets the accuracy reference for the CR stage.
        xi: 1.63,
        accuracy: 1.0,
    },
    VariantProfile {
        variant: ModelVariant::Qf,
        artifact: "qf",
        xi: 1.0,
        accuracy: 1.0,
    },
];

impl ModelVariant {
    /// All known variants, in manifest order.
    pub const ALL: [ModelVariant; 4] = [
        ModelVariant::Va,
        ModelVariant::CrSmall,
        ModelVariant::CrLarge,
        ModelVariant::Qf,
    ];

    /// This variant's [`VariantProfile`] row. Panics — loudly, at
    /// composition time — if a variant was added to the enum without a
    /// table row; a missing ξ multiplier must never decay to 1.0.
    pub fn profile(self) -> &'static VariantProfile {
        VARIANT_TABLE
            .iter()
            .find(|p| p.variant == self)
            .unwrap_or_else(|| {
                panic!(
                    "model variant {self:?} has no VARIANT_TABLE row; \
                     add its artifact/cost/accuracy profile"
                )
            })
    }

    /// Name of the artifact in `artifacts/manifest.json`.
    pub fn artifact_name(self) -> &'static str {
        self.profile().artifact
    }

    /// Resolve an artifact name; errors name the valid set so a typo
    /// fails loudly at composition time rather than as a missing-file
    /// lookup deep inside the PJRT runtime.
    pub fn from_artifact(name: &str) -> Result<Self, String> {
        VARIANT_TABLE
            .iter()
            .find(|p| p.artifact == name)
            .map(|p| p.variant)
            .ok_or_else(|| {
                format!(
                    "unknown model variant {name:?}; known variants: {}",
                    VARIANT_TABLE
                        .iter()
                        .map(|p| p.artifact)
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// The cheaper sibling the adaptation plane downshifts to (the
    /// identity for variants with no lighter alternative).
    pub fn downshifted(self) -> ModelVariant {
        match self {
            ModelVariant::CrLarge => ModelVariant::CrSmall,
            other => other,
        }
    }
}

/// Per-query ground-truth access for the simulated VA path. The DES
/// engines expose their (per-query) [`crate::sim::GroundTruth`] through
/// this so a block never needs to know how queries map to walks.
pub trait TruthSource {
    /// Index of the FOV-transit interval containing `captured` at
    /// `camera` for `query`, or `None` when the entity was not visible
    /// (or the query is unknown/finished).
    fn interval_index(
        &self,
        query: QueryId,
        camera: usize,
        captured: Micros,
    ) -> Option<usize>;
}

/// Platform context handed to VA/CR blocks on the simulated (DES) path:
/// the engine's deterministic RNG, ground-truth access and detection
/// semantics. Blocks draw from `rng` in event order, which keeps runs
/// bit-reproducible per seed.
pub struct SimCtx<'a> {
    pub rng: &'a mut Rng,
    pub truth: &'a dyn TruthSource,
    pub sem: &'a SemanticsConfig,
    /// Experiment seed, for blocks that hash per-(query, camera,
    /// transit) coins (e.g. whole-transit miss modelling).
    pub seed: u64,
    /// This executor's applied QF refinements (the §2.2 feedback
    /// edge). Blocks that model a refined query — e.g. the stock CR
    /// boosting its re-id accuracy once fusion has sharpened the
    /// target — consult [`FeedbackState::refined`] per event. Queries
    /// with no applied refinement (always the case under `NoFusion`)
    /// see `None`, and consulting it never draws from `rng`, so
    /// non-fusing runs stay bit-identical.
    pub feedback: &'a FeedbackState,
    /// The engine's adaptation plane (the single shared application
    /// point for [`crate::tuning::adapt::AdaptationCommand`]s). Blocks
    /// consult [`SimCtx::accuracy`] per event; at the identity ladder
    /// it returns exactly `1.0`, so `p * acc` is bit-exact and
    /// adaptation-unaware runs keep their RNG streams.
    pub adapt: &'a crate::tuning::adapt::AdaptationState,
}

impl SimCtx<'_> {
    /// Accuracy multiplier the adaptation plane commands for `camera`
    /// at a stage whose nominal model is `nominal` (exactly `1.0`
    /// under the identity ladder).
    pub fn accuracy(&self, camera: usize, nominal: ModelVariant) -> f64 {
        self.adapt.accuracy(camera, nominal)
    }
}

/// Platform parameters for the live scoring path.
#[derive(Debug, Clone, Copy)]
pub struct ScoreParams {
    /// Detection threshold the engine is running this block at.
    pub threshold: f32,
}

/// FC — Filter Controls (§2.2.1): the per-camera ingress gate. The
/// platform tells the block whether TL currently wants the camera
/// active; the block decides whether this frame enters the dataflow
/// (frame-rate control, duty-cycling, adaptive sampling).
pub trait FilterControl: Send {
    /// Admit `camera`'s frame `frame_no` (captured at `now`) for
    /// `query`? `active` is the TL spotlight's activation flag.
    fn admit(
        &mut self,
        query: QueryId,
        camera: usize,
        frame_no: u64,
        now: Micros,
        active: bool,
    ) -> bool;

    /// Build-time workload tuning (e.g. a vehicle-tracking FC raises
    /// the entity/expansion speeds). Called by
    /// [`crate::apps::AppDefinition::apply`], never on the hot path.
    fn tune_workload(
        &self,
        _workload: &mut WorkloadConfig,
        _tl_peak_speed_mps: &mut f64,
    ) {
    }

    /// A query finished (completed/cancelled): drop any per-query
    /// state. The multi-query engines call this so stateful FCs (e.g.
    /// per-(query, camera) warm-up windows) cannot leak across a
    /// long-running service's query churn.
    fn forget_query(&mut self, _query: QueryId) {}

    /// Short descriptor for reports (Table-1 style).
    fn label(&self) -> &'static str {
        "fc"
    }
}

/// VA — Video Analytics (§2.2.2): per-frame detection and feature
/// extraction. One trait serves both execution paths:
///
/// * [`VideoAnalytics::step_sim`] — the DES engines call it once per
///   executed batch with the engine's [`SimCtx`]; the block turns
///   `Frame` payloads into `Candidate`s.
/// * [`VideoAnalytics::apply_scores`] — the live engines run the
///   block's [`ModelVariant`] through the model backend and hand the
///   scores back; the block owns the payload transformation.
pub trait VideoAnalytics: Send {
    /// Simulated step over one executed batch (in arrival order).
    fn step_sim(&mut self, events: &mut [Event], ctx: &mut SimCtx<'_>);

    /// Live step: `scores[i]` is the backend's score for `events[i]`.
    fn apply_scores(
        &mut self,
        events: &mut [Event],
        scores: &[f32],
        params: &ScoreParams,
    );

    /// The AOT model this block executes on the live path.
    fn variant(&self) -> ModelVariant {
        ModelVariant::Va
    }

    /// Service-cost multiplier relative to App 1's VA profile; scales
    /// the ξ(b) model at composition time.
    fn cost(&self) -> f64 {
        1.0
    }

    fn label(&self) -> &'static str {
        "va"
    }
}

/// CR — Contention Resolution (§2.2.3): cross-camera re-identification
/// of VA candidates against the query identity. Same two-path shape as
/// [`VideoAnalytics`].
pub trait ContentionResolver: Send {
    fn step_sim(&mut self, events: &mut [Event], ctx: &mut SimCtx<'_>);

    fn apply_scores(
        &mut self,
        events: &mut [Event],
        scores: &[f32],
        params: &ScoreParams,
    );

    fn variant(&self) -> ModelVariant {
        ModelVariant::CrSmall
    }

    /// Service-cost multiplier relative to App 1's CR profile.
    fn cost(&self) -> f64 {
        1.0
    }

    fn label(&self) -> &'static str {
        "cr"
    }
}

/// TL — Tracking Logic (§2.2.4): the spotlight policy. Consumes CR
/// detections (source-timestamped), maintains sighting state, and
/// computes the active camera set over the CSR road network — writing
/// into the engine's reusable buffer so per-tick evaluation allocates
/// nothing in steady state.
///
/// Stock implementations: [`crate::coordinator::tl::SpotlightTracker`]
/// (BFS / WBFS / speed-adaptive / probabilistic expansion) and
/// [`crate::coordinator::tl::KeepAllActive`] (the contemporary
/// everything-on baseline — a total implementation, not a panic path).
pub trait TrackingLogic: Send {
    /// Feed a CR verdict for the frame captured by `camera` at
    /// `captured` (source clock, so late events cannot corrupt the
    /// sighting order).
    fn on_detection(&mut self, camera: usize, captured: Micros, detected: bool);

    /// Camera ids that should be active at `now`, written into `out`
    /// (sorted, deduplicated).
    fn active_set_into(
        &mut self,
        g: &Graph,
        now: Micros,
        out: &mut Vec<usize>,
    );

    /// Last positive sighting (vertex, capture time), if tracked.
    fn last_seen(&self) -> Option<(usize, Micros)> {
        None
    }
}

/// QF — Query Fusion (§2.2.5): refine the query embedding from
/// high-confidence detections. When [`QueryFusion::on_detection`]
/// reports a refinement, the engine reads [`QueryFusion::embedding`],
/// stamps it through its [`crate::dataflow::FeedbackRouter`] and routes
/// it back to every VA/CR executor as a
/// [`crate::dataflow::Payload::QueryUpdate`] event — the §2.2 feedback
/// edge. Fusion therefore *does* influence the dataflow (refined
/// queries score better, which moves detections, the TL spotlight and
/// ultimately which frames are generated); the tuning triangle itself
/// (budgets, drops, batching) still never consults QF state, and a
/// never-refining QF is exactly metric-neutral.
pub trait QueryFusion: Send {
    /// Observe a sink-side detection event; return `true` when the
    /// query embedding was refined by it (the engine then broadcasts
    /// [`QueryFusion::embedding`] upstream, if one is maintained).
    fn on_detection(&mut self, _ev: &Event) -> bool {
        false
    }

    /// The current fused embedding, if this block maintains one.
    fn embedding(&self) -> Option<&[f32]> {
        None
    }

    /// Whether this block refines embeddings at all (Table-1 QF column).
    fn fuses(&self) -> bool {
        false
    }

    fn label(&self) -> &'static str {
        "qf"
    }
}

/// Either analytics block, for engines whose executor workers are
/// stage-generic (the live worker loop is one function serving VA and
/// CR): dispatch stays one virtual call per batch.
pub enum AnalyticsBlock {
    Va(Box<dyn VideoAnalytics>),
    Cr(Box<dyn ContentionResolver>),
}

impl AnalyticsBlock {
    pub fn apply_scores(
        &mut self,
        events: &mut [Event],
        scores: &[f32],
        params: &ScoreParams,
    ) {
        match self {
            AnalyticsBlock::Va(b) => b.apply_scores(events, scores, params),
            AnalyticsBlock::Cr(b) => b.apply_scores(events, scores, params),
        }
    }

    pub fn variant(&self) -> ModelVariant {
        match self {
            AnalyticsBlock::Va(b) => b.variant(),
            AnalyticsBlock::Cr(b) => b.variant(),
        }
    }
}

/// Environment the platform supplies when instantiating a per-query
/// [`TrackingLogic`]: the configured expansion speed and road/FOV
/// geometry plus the camera placement.
pub struct TlEnv<'a> {
    /// Configured peak entity speed `es` (m/s) — the expansion rate.
    pub peak_speed_mps: f64,
    /// Mean road length (the fixed length TL-BFS assumes).
    pub mean_road_m: f64,
    /// Camera FOV radius (spotlight slack).
    pub fov_m: f64,
    pub cameras: &'a [Camera],
}

/// Factory minting a fresh [`TrackingLogic`] per query — every tracking
/// query owns its own spotlight state machine.
pub type TlFactory =
    Arc<dyn Fn(&TlEnv<'_>) -> Box<dyn TrackingLogic> + Send + Sync>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_variant_round_trips() {
        for v in ModelVariant::ALL {
            assert_eq!(
                ModelVariant::from_artifact(v.artifact_name()).unwrap(),
                v
            );
        }
    }

    #[test]
    fn model_variant_typo_is_a_clear_error() {
        let err = ModelVariant::from_artifact("cr_sma11").unwrap_err();
        assert!(err.contains("cr_sma11"), "{err}");
        assert!(err.contains("cr_small"), "lists valid names: {err}");
        assert!(err.contains("cr_large"), "lists valid names: {err}");
    }

    #[test]
    fn variant_table_covers_every_variant_exactly_once() {
        assert_eq!(VARIANT_TABLE.len(), ModelVariant::ALL.len());
        for v in ModelVariant::ALL {
            // `profile` panics rather than defaulting a missing row —
            // this is the "error, not default-1.0" guarantee.
            let p = v.profile();
            assert_eq!(p.variant, v);
            assert!(p.xi > 0.0 && p.xi.is_finite());
            assert!(p.accuracy > 0.0 && p.accuracy <= 1.0);
        }
        // The one non-unit ξ row is the deep CR DNN (§5.3).
        assert!(
            (ModelVariant::CrLarge.profile().xi - 1.63).abs() < 1e-9
        );
    }

    #[test]
    fn downshift_stays_within_the_stage() {
        assert_eq!(
            ModelVariant::CrLarge.downshifted(),
            ModelVariant::CrSmall
        );
        // Variants with no lighter sibling downshift to themselves.
        for v in [ModelVariant::Va, ModelVariant::CrSmall, ModelVariant::Qf]
        {
            assert_eq!(v.downshifted(), v);
        }
        // The downshift target is always cheaper or equal.
        for v in ModelVariant::ALL {
            assert!(v.downshifted().profile().xi <= v.profile().xi);
        }
    }

    #[test]
    fn traits_are_object_safe() {
        // Compile-time proof: every block trait can be boxed.
        fn _fc(_: Box<dyn FilterControl>) {}
        fn _va(_: Box<dyn VideoAnalytics>) {}
        fn _cr(_: Box<dyn ContentionResolver>) {}
        fn _tl(_: Box<dyn TrackingLogic>) {}
        fn _qf(_: Box<dyn QueryFusion>) {}
        fn _truth(_: &dyn TruthSource) {}
    }
}
