//! The QF → VA/CR feedback edge (§2.2, Fig. 2).
//!
//! Query Fusion *refines the query*: when a sink-side QF block folds a
//! high-confidence detection into its embedding, the fused embedding
//! flows **back upstream** so VA/CR score subsequent frames against an
//! improved target — the loop DeepScale exploits for online adaptation
//! (see PAPERS.md). This module is the typed plumbing of that edge:
//!
//! * [`QueryRefinement`] — one refinement: the query it belongs to, a
//!   per-query **update sequence number**, and the fused embedding.
//! * [`FeedbackRouter`] — sink-side: stamps refinements with a
//!   monotonically increasing per-query sequence number. The engines
//!   wrap each refinement in a [`Payload::QueryUpdate`] event (the
//!   sequence number rides on [`Header::update_seq`]) and route one
//!   copy to every VA/CR executor.
//! * [`FeedbackState`] — consumer-side: each executor (task / worker)
//!   keeps one and applies updates **iff fresher** than the last one it
//!   saw for that query. Duplicate or out-of-order deliveries (N tasks
//!   each receive every refinement, at different network delays) are
//!   discarded deterministically, so a refinement changes an executor's
//!   scoring target exactly once.
//!
//! Determinism contract: refinements are ordinary events — in the DES
//! engines they arrive through the same [`crate::engine::EventCore`]
//! ordering as data events, so seeded runs remain bit-reproducible.
//! Apps whose QF never refines (the stock `NoFusion`) mint no
//! refinements at all, leaving every RNG draw and event identical to a
//! build without the feedback edge.
//!
//! [`Payload::QueryUpdate`]: crate::dataflow::Payload::QueryUpdate
//! [`Header::update_seq`]: crate::dataflow::Header::update_seq

use std::sync::Arc;

use crate::dataflow::{Event, EventId, Header, Payload, QueryId};
use crate::tuning::adapt::AdaptationCommand;
use crate::util::{FastMap, Micros};

/// The refinement model shared by every simulated scorer: once a query
/// scores against a fused embedding, its residual error shrinks by
/// `boost` — `tp ← tp + boost·(1 − tp)`, `fp ← fp·(1 − boost)`. One
/// definition so the DES blocks and the live front cannot drift apart
/// (see `SemanticsConfig::fusion_boost` / `SimBackend::fusion_boost`).
pub fn boosted_rates(boost: f64, tp: f64, fp: f64) -> (f64, f64) {
    (tp + boost * (1.0 - tp), boosted_residual(boost, fp))
}

/// A residual error probability under a refined query: shrunk by
/// `boost` (used for `fp` and `transit_miss`).
pub fn boosted_residual(boost: f64, p: f64) -> f64 {
    p * (1.0 - boost)
}

/// One query-embedding refinement emitted by a QF block, stamped with
/// its per-query update sequence number (1-based; 0 on a [`Header`]
/// means "not a refinement").
#[derive(Debug, Clone)]
pub struct QueryRefinement {
    pub query: QueryId,
    /// Update sequence number assigned by the [`FeedbackRouter`];
    /// strictly increasing per query.
    pub seq: u32,
    /// The fused query embedding.
    pub embedding: Arc<Vec<f32>>,
}

impl QueryRefinement {
    /// Wrap this refinement in a routable [`Payload::QueryUpdate`]
    /// event. `id`/`camera` identify the triggering detection (for
    /// traceability only — update events are consumed at the executor,
    /// never ledgered, batched or dropped).
    pub fn into_event(
        &self,
        id: EventId,
        camera: usize,
        now: Micros,
    ) -> Event {
        let mut header =
            Header::new(id, camera, 0, now).with_query(self.query);
        header.update_seq = self.seq;
        Event {
            header,
            payload: Payload::QueryUpdate(Arc::clone(&self.embedding)),
        }
    }
}

/// The refinement-or-adaptation envelope: everything the sink mints
/// onto the upstream feedback edge. Both kinds carry their sequence
/// number on [`Header::update_seq`] (1-based; 0 = "not feedback"),
/// both are broadcast — one copy per executor, each after a
/// control-message network delay — and both are consumed at the
/// receiving executor with the same exactly-once, stale-discard rule:
/// refinements through [`FeedbackState::apply`] (per executor, keyed
/// by query), adaptation commands through the engine's single
/// [`crate::tuning::adapt::AdaptationState::apply`] (keyed by camera,
/// so the first broadcast copy to arrive applies and the rest discard
/// deterministically).
#[derive(Debug, Clone)]
pub enum FeedbackEnvelope {
    /// A fused query embedding (QF → VA/CR).
    Refinement(QueryRefinement),
    /// A quality operating-point command (sink → FC/VA/CR).
    Adaptation(AdaptationCommand),
}

impl FeedbackEnvelope {
    /// The envelope's sequence number (per query for refinements, per
    /// camera for adaptation commands).
    pub fn seq(&self) -> u32 {
        match self {
            FeedbackEnvelope::Refinement(r) => r.seq,
            FeedbackEnvelope::Adaptation(c) => c.seq,
        }
    }

    /// Wrap in a routable event. `trigger`/`camera` identify the
    /// completion that minted this envelope (trace provenance only);
    /// an adaptation command's *target* camera comes from the command
    /// itself.
    pub fn into_event(
        &self,
        trigger: EventId,
        camera: usize,
        now: Micros,
    ) -> Event {
        match self {
            FeedbackEnvelope::Refinement(r) => {
                r.into_event(trigger, camera, now)
            }
            FeedbackEnvelope::Adaptation(cmd) => {
                let mut header =
                    Header::new(trigger, cmd.camera, 0, now);
                header.update_seq = cmd.seq;
                Event {
                    header,
                    payload: Payload::Adaptation(*cmd),
                }
            }
        }
    }
}

/// Sink-side sequencer: one per engine. Stamps each QF refinement with
/// the next per-query sequence number so consumers can discard stale
/// deliveries deterministically.
#[derive(Debug, Default)]
pub struct FeedbackRouter {
    seqs: FastMap<QueryId, u32>,
}

impl FeedbackRouter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mint the next refinement for `query`.
    pub fn refine(
        &mut self,
        query: QueryId,
        embedding: Arc<Vec<f32>>,
    ) -> QueryRefinement {
        let seq = self.seqs.entry(query).or_insert(0);
        *seq += 1;
        QueryRefinement {
            query,
            seq: *seq,
            embedding,
        }
    }

    /// Number of refinements minted for `query` so far.
    pub fn minted(&self, query: QueryId) -> u32 {
        self.seqs.get(&query).copied().unwrap_or(0)
    }

    /// Drop a finished query's sequence state.
    pub fn forget(&mut self, query: QueryId) {
        self.seqs.remove(&query);
    }
}

/// Consumer-side refinement state: the latest applied update per query.
/// Each VA/CR executor owns one; scoring consults [`Self::refined`] to
/// get the current (possibly refined) target.
#[derive(Debug, Default)]
pub struct FeedbackState {
    applied: FastMap<QueryId, (u32, Arc<Vec<f32>>)>,
}

impl FeedbackState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply an update iff it is fresher than the last applied one for
    /// `query`. Returns whether it was applied — `false` means the
    /// delivery was stale (or a duplicate) and was discarded, so a
    /// given refinement changes this executor's scores exactly once.
    pub fn apply(
        &mut self,
        query: QueryId,
        seq: u32,
        embedding: Arc<Vec<f32>>,
    ) -> bool {
        let last = self.applied.get(&query).map(|(s, _)| *s).unwrap_or(0);
        if last >= seq {
            false
        } else {
            // Invariants on the applied path: the router mints 1-based
            // seqs (0 on a header means "not a refinement"), and an
            // applied update is strictly fresher — which is exactly
            // what makes each refinement apply at most once here.
            crate::strict_assert!(
                seq >= 1,
                "refinement for query {query} carries reserved seq 0"
            );
            crate::strict_assert!(
                seq > last,
                "refinement seq {seq} for query {query} not fresher than {last}"
            );
            self.applied.insert(query, (seq, embedding));
            true
        }
    }

    /// The refined embedding for `query`, if any update was applied.
    pub fn refined(&self, query: QueryId) -> Option<&[f32]> {
        self.applied.get(&query).map(|(_, e)| e.as_slice())
    }

    /// Sequence number of the last applied update (0 = none).
    pub fn last_seq(&self, query: QueryId) -> u32 {
        self.applied.get(&query).map(|(s, _)| *s).unwrap_or(0)
    }

    /// Drop a finished query's state.
    pub fn forget(&mut self, query: QueryId) {
        self.applied.remove(&query);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn router_stamps_monotone_per_query_seqs() {
        let mut r = FeedbackRouter::new();
        let a1 = r.refine(1, Arc::new(vec![0.1]));
        let b1 = r.refine(2, Arc::new(vec![0.2]));
        let a2 = r.refine(1, Arc::new(vec![0.3]));
        assert_eq!((a1.query, a1.seq), (1, 1));
        assert_eq!((b1.query, b1.seq), (2, 1));
        assert_eq!((a2.query, a2.seq), (1, 2));
        assert_eq!(r.minted(1), 2);
        r.forget(1);
        assert_eq!(r.minted(1), 0);
        assert_eq!(r.refine(1, Arc::new(vec![])).seq, 1);
    }

    #[test]
    fn state_applies_each_refinement_exactly_once() {
        let mut st = FeedbackState::new();
        assert_eq!(st.refined(7), None);
        let e1 = Arc::new(vec![1.0f32]);
        assert!(st.apply(7, 1, Arc::clone(&e1)));
        // A duplicate delivery of the same seq is discarded.
        assert!(!st.apply(7, 1, Arc::clone(&e1)));
        assert_eq!(st.refined(7), Some(&[1.0f32][..]));
        assert_eq!(st.last_seq(7), 1);
        // A fresher update applies; an out-of-order older one does not.
        assert!(st.apply(7, 3, Arc::new(vec![3.0])));
        assert!(!st.apply(7, 2, Arc::new(vec![2.0])));
        assert_eq!(st.refined(7), Some(&[3.0f32][..]));
        assert_eq!(st.last_seq(7), 3);
        st.forget(7);
        assert_eq!(st.refined(7), None);
        assert_eq!(st.last_seq(7), 0);
    }

    #[test]
    fn adaptation_envelope_rides_the_same_edge() {
        use crate::dataflow::ModelVariant;
        let cmd = AdaptationCommand {
            camera: 9,
            level: 2,
            variant: ModelVariant::CrSmall,
            seq: 5,
        };
        let env = FeedbackEnvelope::Adaptation(cmd);
        assert_eq!(env.seq(), 5);
        // The trigger camera (3) is provenance; the event targets the
        // command's own camera.
        let ev = env.into_event(77, 3, 2_000_000);
        assert_eq!(ev.header.camera, 9);
        assert_eq!(ev.header.update_seq, 5);
        assert_eq!(ev.header.id, 77);
        match ev.payload {
            Payload::Adaptation(c) => assert_eq!(c, cmd),
            other => panic!("{other:?}"),
        }
        // A refinement through the envelope matches the direct path.
        let mut r = FeedbackRouter::new();
        let rf = r.refine(4, Arc::new(vec![0.5]));
        let via_env = FeedbackEnvelope::Refinement(rf.clone())
            .into_event(99, 12, 1_000);
        let direct = rf.into_event(99, 12, 1_000);
        assert_eq!(via_env.header, direct.header);
    }

    #[test]
    fn refinement_event_carries_seq_on_header() {
        let mut r = FeedbackRouter::new();
        let rf = r.refine(4, Arc::new(vec![0.5, 0.6]));
        let ev = rf.into_event(99, 12, 1_000_000);
        assert_eq!(ev.header.query, 4);
        assert_eq!(ev.header.update_seq, 1);
        assert_eq!(ev.header.camera, 12);
        match &ev.payload {
            Payload::QueryUpdate(e) => {
                assert_eq!(e.as_slice(), &[0.5, 0.6])
            }
            other => panic!("{other:?}"),
        }
    }
}
