//! Stock Tracking-Logic blocks — the spotlight state machine (§2.2.4,
//! Alg 1 TL_WBFS) and the everything-on baseline.
//!
//! Both implement the [`TrackingLogic`] UDF trait from
//! [`crate::dataflow`]; the engines only ever hold `Box<dyn
//! TrackingLogic>`, so a user-defined policy slots in the same way.
//!
//! * [`SpotlightTracker`] consumes CR detections, maintains the
//!   last-seen location/time, and computes the set of cameras that
//!   should be active: contracting to the sighting camera on a positive
//!   detection, expanding the spotlight over the road network
//!   ([`SpotlightPolicy`]: BFS / WBFS / speed-adaptive WBFS /
//!   probabilistic) while the entity is in a blind-spot.
//! * [`KeepAllActive`] keeps every camera on all the time — the
//!   contemporary baseline the paper compares against. It is a total
//!   implementation of the trait, **not** a panic path: the old
//!   `TlKind::Base => unreachable!()` arm is structurally gone because
//!   [`SpotlightPolicy`] has no `Base` variant.
//!
//! [`stock_tl`] maps a config-level [`TlKind`] to a boxed stock block;
//! custom applications bypass it entirely via
//! [`crate::apps::AppBuilder::tracking_logic_with`].

use crate::config::TlKind;
use crate::dataflow::{TlEnv, TrackingLogic};
use crate::roadnet::{
    bfs_spotlight_into, probabilistic_spotlight_into, wbfs_spotlight_into,
    Camera, Graph, SpotlightWorkspace, VertexId,
};
use crate::util::{FastMap, Micros, SEC};

/// Spotlight expansion policy of a [`SpotlightTracker`]. Deliberately
/// has no "keep everything on" variant — that is [`KeepAllActive`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpotlightPolicy {
    /// BFS ball with a fixed assumed road length.
    Bfs,
    /// Weighted BFS (Dijkstra ball) with exact road lengths.
    Wbfs,
    /// WBFS that adapts the radius to the entity's observed speed.
    WbfsSpeed,
    /// Naive-Bayes path-likelihood activation (App 4).
    Probabilistic,
}

/// Build the stock [`TrackingLogic`] for a config-level [`TlKind`].
/// Total over the enum: `Base` yields [`KeepAllActive`].
pub fn stock_tl(kind: TlKind, env: &TlEnv<'_>) -> Box<dyn TrackingLogic> {
    let policy = match kind {
        TlKind::Base => {
            // Vertex-aware variant: `last_seen()` reports real road
            // vertices, matching the spotlight trackers.
            return Box::new(KeepAllActive::with_cameras(env.cameras));
        }
        TlKind::Bfs => SpotlightPolicy::Bfs,
        TlKind::Wbfs => SpotlightPolicy::Wbfs,
        TlKind::WbfsSpeed => SpotlightPolicy::WbfsSpeed,
        TlKind::Probabilistic => SpotlightPolicy::Probabilistic,
    };
    Box::new(SpotlightTracker::new(
        policy,
        env.peak_speed_mps,
        env.mean_road_m,
        env.fov_m,
        env.cameras,
    ))
}

/// The contemporary baseline: every camera active all the time. Still
/// tracks sightings so reports can show the last-seen location.
pub struct KeepAllActive {
    num_cameras: usize,
    last_seen: Option<(usize, Micros)>,
    cam_vertex: Vec<usize>,
}

impl KeepAllActive {
    pub fn new(num_cameras: usize) -> Self {
        Self {
            num_cameras,
            last_seen: None,
            cam_vertex: Vec::new(),
        }
    }

    /// Variant that records sighting vertices (for `last_seen`).
    pub fn with_cameras(cameras: &[Camera]) -> Self {
        Self {
            num_cameras: cameras.len(),
            last_seen: None,
            cam_vertex: cameras.iter().map(|c| c.vertex).collect(),
        }
    }
}

impl TrackingLogic for KeepAllActive {
    fn on_detection(
        &mut self,
        camera: usize,
        captured: Micros,
        detected: bool,
    ) {
        if detected {
            let vertex =
                self.cam_vertex.get(camera).copied().unwrap_or(camera);
            match self.last_seen {
                Some((_, t)) if captured < t => {}
                _ => self.last_seen = Some((vertex, captured)),
            }
        }
    }

    fn active_set_into(
        &mut self,
        _g: &Graph,
        _now: Micros,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        out.extend(0..self.num_cameras);
    }

    fn last_seen(&self) -> Option<(usize, Micros)> {
        self.last_seen
    }
}

/// Spotlight tracking state.
pub struct SpotlightTracker {
    policy: SpotlightPolicy,
    /// Configured peak entity speed `es` (m/s) — the expansion rate.
    es_mps: f64,
    /// Fixed road length assumed by TL-BFS (the paper uses the network
    /// mean, 84.5 m).
    fixed_len_m: f64,
    /// Extra slack added to the spotlight radius (covers FOV).
    fov_m: f64,
    /// vertex -> cameras mounted there (hit once per spotlight vertex).
    cam_at: FastMap<usize, Vec<usize>>,
    cameras: Vec<Camera>,
    /// Last positive sighting: (vertex, capture time).
    last_seen: Option<(usize, Micros)>,
    /// Previous sighting (for speed estimation in WbfsSpeed).
    prev_seen: Option<(usize, Micros)>,
    /// Whether the entity was visible at the last evaluation.
    visible: bool,
    /// Reusable expansion state: the TL re-expands on every blind-spot
    /// tick, so the workspace and vertex buffer live for the TL's
    /// lifetime instead of being allocated per expansion.
    ws: SpotlightWorkspace,
    verts: Vec<VertexId>,
}

impl SpotlightTracker {
    pub fn new(
        policy: SpotlightPolicy,
        es_mps: f64,
        fixed_len_m: f64,
        fov_m: f64,
        cameras: &[Camera],
    ) -> Self {
        let mut cam_at: FastMap<usize, Vec<usize>> = FastMap::default();
        for c in cameras {
            cam_at.entry(c.vertex).or_default().push(c.id);
        }
        Self {
            policy,
            es_mps,
            fixed_len_m,
            fov_m,
            cam_at,
            cameras: cameras.to_vec(),
            last_seen: None,
            prev_seen: None,
            visible: false,
            ws: SpotlightWorkspace::new(),
            verts: Vec::new(),
        }
    }

    /// Whether the entity was visible at the last evaluation.
    pub fn visible(&self) -> bool {
        self.visible
    }

    /// Estimated entity speed from the last two sightings (m/s).
    fn observed_speed(&self, g: &Graph) -> Option<f64> {
        let (v1, t1) = self.last_seen?;
        let (v0, t0) = self.prev_seen?;
        if t1 <= t0 {
            return None;
        }
        let d = g.euclid(v0, v1);
        Some(d / ((t1 - t0) as f64 / SEC as f64))
    }

    /// Convenience wrapper over the trait's `active_set_into`.
    pub fn active_set(&mut self, g: &Graph, now: Micros) -> Vec<usize> {
        let mut out = Vec::new();
        self.active_set_into(g, now, &mut out);
        out
    }
}

impl TrackingLogic for SpotlightTracker {
    /// Feed a CR detection for the frame captured by `camera` at
    /// `captured` (source timestamps, so late events can't corrupt the
    /// sighting order).
    fn on_detection(
        &mut self,
        camera: usize,
        captured: Micros,
        detected: bool,
    ) {
        if detected {
            let vertex = self.cameras[camera].vertex;
            match self.last_seen {
                Some((v, t)) if captured >= t => {
                    if v != vertex {
                        self.prev_seen = Some((v, t));
                    }
                    self.last_seen = Some((vertex, captured));
                    self.visible = true;
                }
                None => {
                    self.last_seen = Some((vertex, captured));
                    self.visible = true;
                }
                _ => {} // stale event, ignore
            }
        } else if let Some((_, t)) = self.last_seen {
            // A negative frame *newer* than the last sighting from the
            // last-seen camera means the entity left the FOV.
            if captured > t {
                self.visible = false;
            }
        }
    }

    fn last_seen(&self) -> Option<(usize, Micros)> {
        self.last_seen
    }

    /// Compute the active camera ids at time `now` into `out` (sorted,
    /// deduplicated), reusing the tracker's spotlight workspace — the
    /// engines call this every blind-spot tick, so the expansion
    /// allocates nothing in steady state.
    ///
    /// Expansion (§ Fig 1): while in a blind-spot the spotlight radius
    /// grows as `es * time-since-last-seen + fov`; on a sighting it
    /// contracts to the camera(s) at the sighting vertex.
    fn active_set_into(
        &mut self,
        g: &Graph,
        now: Micros,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        let Some((vertex, seen_at)) = self.last_seen else {
            // Entity never seen: keep the whole network live so the
            // first sighting can happen (paper bootstraps all-active).
            out.extend(0..self.cameras.len());
            return;
        };
        if self.visible {
            // Contracted spotlight: the sighting vertex only.
            if let Some(cams) = self.cam_at.get(&vertex) {
                out.extend_from_slice(cams);
            }
            return;
        }
        let blind_s = ((now - seen_at).max(0)) as f64 / SEC as f64;
        let radius = match self.policy {
            SpotlightPolicy::WbfsSpeed => {
                // Speed-aware: expand with the *observed* speed (capped
                // by the configured peak) instead of always the peak.
                let sp = self
                    .observed_speed(g)
                    .map(|s| (1.5 * s).clamp(0.5, self.es_mps))
                    .unwrap_or(self.es_mps);
                sp * blind_s + self.fov_m
            }
            _ => self.es_mps * blind_s + self.fov_m,
        };
        let mut verts = std::mem::take(&mut self.verts);
        match self.policy {
            SpotlightPolicy::Bfs => bfs_spotlight_into(
                g,
                vertex,
                radius,
                self.fixed_len_m,
                &mut self.ws,
                &mut verts,
            ),
            SpotlightPolicy::Wbfs | SpotlightPolicy::WbfsSpeed => {
                wbfs_spotlight_into(
                    g,
                    vertex,
                    radius,
                    &mut self.ws,
                    &mut verts,
                )
            }
            SpotlightPolicy::Probabilistic => probabilistic_spotlight_into(
                g,
                vertex,
                self.es_mps,
                blind_s.max(1.0),
                0.90,
                &mut self.ws,
                &mut verts,
            ),
        }
        for v in &verts {
            if let Some(cams) = self.cam_at.get(v) {
                out.extend_from_slice(cams);
            }
        }
        self.verts = verts;
        out.sort_unstable();
        out.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::roadnet::{generate, place_cameras};
    use crate::util::secs;

    fn setup(kind: TlKind) -> (Graph, Box<dyn TrackingLogic>) {
        let g = generate(&WorkloadConfig::default(), 5);
        let cams = place_cameras(&g, 1000, 0, 40.0);
        let tl = stock_tl(
            kind,
            &TlEnv {
                peak_speed_mps: 4.0,
                mean_road_m: 84.5,
                fov_m: 40.0,
                cameras: &cams,
            },
        );
        (g, tl)
    }

    fn active(
        tl: &mut Box<dyn TrackingLogic>,
        g: &Graph,
        t: Micros,
    ) -> Vec<usize> {
        let mut out = Vec::new();
        tl.active_set_into(g, t, &mut out);
        out
    }

    #[test]
    fn bootstrap_all_active() {
        let (g, mut tl) = setup(TlKind::Bfs);
        assert_eq!(active(&mut tl, &g, 0).len(), 1000);
    }

    #[test]
    fn positive_detection_contracts_to_camera() {
        let (g, mut tl) = setup(TlKind::Bfs);
        tl.on_detection(5, secs(10.0), true);
        let act = active(&mut tl, &g, secs(10.5));
        assert!(act.contains(&5));
        assert!(act.len() <= 3, "contracted set: {act:?}");
    }

    #[test]
    fn blindspot_expands_with_time() {
        let (g, mut tl) = setup(TlKind::Bfs);
        tl.on_detection(5, secs(10.0), true);
        tl.on_detection(5, secs(11.0), false); // left FOV
        let a = active(&mut tl, &g, secs(15.0)).len();
        let b = active(&mut tl, &g, secs(40.0)).len();
        let c = active(&mut tl, &g, secs(90.0)).len();
        assert!(a < b && b < c, "sawtooth growth: {a} {b} {c}");
    }

    #[test]
    fn reacquisition_contracts_again() {
        let (g, mut tl) = setup(TlKind::Wbfs);
        tl.on_detection(5, secs(10.0), true);
        tl.on_detection(5, secs(11.0), false);
        assert!(active(&mut tl, &g, secs(60.0)).len() > 5);
        tl.on_detection(9, secs(61.0), true);
        let act = active(&mut tl, &g, secs(61.5));
        assert!(act.contains(&9));
        assert!(act.len() <= 3);
    }

    #[test]
    fn stale_detections_ignored() {
        let (_, mut tl) = setup(TlKind::Bfs);
        tl.on_detection(5, secs(20.0), true);
        tl.on_detection(7, secs(10.0), true); // older capture
        assert_eq!(tl.last_seen().unwrap().1, secs(20.0));
    }

    #[test]
    fn stale_negative_cannot_flip_visibility() {
        let g = generate(&WorkloadConfig::default(), 5);
        let cams = place_cameras(&g, 1000, 0, 40.0);
        let mut tl = SpotlightTracker::new(
            SpotlightPolicy::Bfs,
            4.0,
            84.5,
            40.0,
            &cams,
        );
        tl.on_detection(5, secs(20.0), true);
        tl.on_detection(5, secs(15.0), false);
        assert!(tl.visible());
    }

    #[test]
    fn wbfs_spotlight_no_larger_than_bfs() {
        // The paper: WBFS grows more gradually because it knows exact
        // road lengths; BFS with the mean fixed length overshoots once
        // hops overshoot real distances.
        let (g, mut tl_b) = setup(TlKind::Bfs);
        let (_, mut tl_w) = setup(TlKind::Wbfs);
        for tl in [&mut tl_b, &mut tl_w] {
            tl.on_detection(0, secs(10.0), true);
            tl.on_detection(0, secs(11.0), false);
        }
        // Average over several blind-spot durations.
        let (mut nb, mut nw) = (0usize, 0usize);
        for s in [30.0, 60.0, 90.0, 120.0] {
            nb += active(&mut tl_b, &g, secs(s)).len();
            nw += active(&mut tl_w, &g, secs(s)).len();
        }
        assert!(
            nw <= nb,
            "WBFS total {nw} should not exceed BFS total {nb}"
        );
    }

    #[test]
    fn base_keeps_everything_active_without_panicking() {
        // TlKind::Base is a total stock block now: detections feed it
        // and every evaluation returns the full network — there is no
        // unreachable arm left to hit.
        let (g, mut tl) = setup(TlKind::Base);
        tl.on_detection(5, secs(10.0), true);
        tl.on_detection(5, secs(11.0), false);
        assert_eq!(active(&mut tl, &g, secs(20.0)).len(), 1000);
        assert!(tl.last_seen().is_some());
    }

    #[test]
    fn probabilistic_activates_likely_region() {
        let (g, mut tl) = setup(TlKind::Probabilistic);
        tl.on_detection(0, secs(10.0), true);
        tl.on_detection(0, secs(11.0), false);
        let act = active(&mut tl, &g, secs(41.0));
        assert!(!act.is_empty());
        assert!(act.len() < 1000);
    }
}
