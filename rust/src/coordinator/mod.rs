//! The Anveshak coordinator: deployment topology (Master/Scheduler),
//! the stock tracking-logic blocks, and two execution engines sharing
//! the same module and tuning logic — both driving the application's
//! UDF blocks exclusively through the [`crate::dataflow`] traits:
//!
//! * [`des`] — virtual-time discrete-event engine (experiment harness),
//!   with a multi-query mode ([`des::run_multi`]) multiplexing many
//!   queries over the shared deployment;
//! * [`live`] — wall-clock, thread-based engine with real PJRT model
//!   execution (serving examples). Its multi-query counterpart, the
//!   runtime-submission service front, lives in
//!   [`crate::service::TrackingService`].

pub mod des;
pub mod live;
pub mod tl;
pub mod topology;

pub use des::{DesEngine, RunResult};
pub use live::{LiveEngine, LiveReport, ModelService, ENTITY_IDENTITY};
pub use tl::{stock_tl, KeepAllActive, SpotlightPolicy, SpotlightTracker};
pub use topology::{TaskInfo, Topology};
