//! The Anveshak coordinator: deployment topology (Master/Scheduler),
//! the tracking-logic state machine, and two execution engines sharing
//! the same module and tuning logic:
//!
//! * [`des`] — virtual-time discrete-event engine (experiment harness),
//! * [`live`] — wall-clock, thread-based engine with real PJRT model
//!   execution (serving examples).

pub mod des;
pub mod live;
pub mod tl;
pub mod topology;

pub use des::{DesEngine, RunResult};
pub use live::{LiveEngine, LiveReport, ModelService, ENTITY_IDENTITY};
pub use tl::TrackingLogic;
pub use topology::{TaskInfo, Topology};
