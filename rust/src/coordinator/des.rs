//! Virtual-time discrete-event engine.
//!
//! Runs the full Anveshak dataflow — feeds, FC gating, VA/CR executors
//! with FIFO queues, batchers, the three drop points, budget signals,
//! TL spotlight control and the UV sink — against the simulated network
//! and service-time models, in virtual time. The paper's 600-second,
//! 1000-camera experiments replay in seconds of wall-clock, exercising
//! exactly the same tuning code the live engine uses.
//!
//! All application logic enters through the [`crate::dataflow`] UDF
//! traits of an [`AppDefinition`]: the engine never branches on which
//! app is running. Block dispatch is hoisted out of the per-event loop
//! — VA/CR blocks step once per executed *batch* (`step_sim` over the
//! engine's scratch slice), so the trait-object indirection costs one
//! virtual call per batch and the zero-allocation hot path from the
//! performance work is preserved.

use std::sync::Arc;

use crate::util::FastMap;

use crate::apps::AppDefinition;
use crate::config::{BatchingKind, ExperimentConfig};
use crate::coordinator::topology::Topology;
use crate::dataflow::{
    ContentionResolver, Event, FeedbackEnvelope, FeedbackRouter,
    FeedbackState, FilterControl, ModelVariant, Payload, QueryFusion,
    QueryId, SimCtx, Stage, TlEnv, TrackingLogic, TruthSource,
    VideoAnalytics, SINGLE_QUERY,
};
use crate::engine::ShardedDes;
use crate::metrics::{Ledger, Summary, Timeline};
use crate::obs::{
    span_begin, span_end, Gate, MetricsRegistry, MetricsSnapshot,
    NullSink, ObsSink, QueryPhase, Scope, TraceEvent,
};
use crate::roadnet::{
    generate, partition, place_cameras, Graph, Partition,
};
use crate::sim::{
    backoff_delay, ClockSkews, ComputeModel, EntityWalk, FaultModel,
    GroundTruth, NetModel,
};
use crate::tuning::adapt::{
    AdaptController, AdaptationCommand, AdaptationState,
};
use crate::tuning::budget::BUDGET_INF;
use crate::tuning::{
    drop_at_exec, drop_at_queue, drop_at_transmit, Batcher, BatcherPoll,
    BudgetManager, EventRecord, NobTable, QueuedEvent, Signal, XiModel,
    NOB_MAX_RATE, NOB_RATE_STEP, ONLINE_XI_EMA,
};
use crate::util::{millis, rng, Micros, Rng, SEC};

/// How much longer TL pretends the entity has been unobserved when the
/// spotlight covers a dark camera (graceful degradation, recovery on):
/// the WBFS ball grows by two extra seconds of entity travel, enough to
/// reach the dark camera's neighbours.
const FAULT_WIDEN: Micros = 2 * SEC;

/// Simulation events, ordered by time (then sequence for determinism).
enum Ev {
    /// Camera `cam` captures its next frame.
    FrameTick { cam: usize },
    /// A dataflow event arrives at `task` (post-network).
    Arrive {
        task: usize,
        ev: Event,
        /// (batch sequence, surviving size) tag from the sender — lets
        /// the sink reason about whole batches for accept signals.
        batch: Option<(u64, usize)>,
    },
    /// A batcher auto-submit timer.
    BatchTimer { task: usize, seq: u64 },
    /// A batch finishes executing at `task`.
    ExecDone {
        task: usize,
        batch: Vec<QueuedEvent<Event>>,
        start_obs: Micros,
        xi_est: Micros,
        actual: Micros,
    },
    /// A budget signal arrives at `task`.
    SignalAt { task: usize, sig: Signal },
    /// TL's (de)activation command reaches a camera's FC.
    Control { cam: usize, active: bool },
    /// Periodic TL spotlight evaluation.
    TlTick,
    /// A detection (metadata) reaches TL.
    TlDetection {
        camera: usize,
        captured: Micros,
        detected: bool,
    },
    /// A node or camera flips aliveness (scheduled at each
    /// [`FaultModel::transitions`] time) — the engine diffs state and
    /// applies crash/revival consequences. Never scheduled when the
    /// fault schedule is empty.
    FaultTick,
}

/// State of one executor task (VA/CR; FC and UV are lighter-weight).
struct TaskState {
    stage: Stage,
    node: usize,
    batcher: Batcher<Event>,
    budget: BudgetManager,
    /// ξ *estimator*: drives deadlines, drop gates, NOB lookups and
    /// budget math. Refined online from observed durations when
    /// `online_xi` is set; equal to [`Self::xi_true`] otherwise.
    xi: XiModel,
    /// Frozen nominal cost model — the simulated hardware's ground
    /// truth. *Actual* batch durations are always generated from this
    /// (× jitter × compute slowdown), never from the estimator, so
    /// online refinement converges to (nominal × slowdown) instead of
    /// chasing its own inflated estimates.
    xi_true: XiModel,
    busy: bool,
    timer_seq: u64,
    drop_count: u64,
    /// QF refinements this executor has applied (the feedback edge);
    /// each task receives its own [`Payload::QueryUpdate`] copy after
    /// its own network delay and discards stale deliveries.
    feedback: FeedbackState,
}

/// Results of a DES run.
pub struct RunResult {
    pub summary: Summary,
    pub timeline: Timeline,
    /// Frames carrying the entity that were confirmed by CR and reached
    /// the sink (detections shown to the user).
    pub detections: u64,
    /// Peak size of the TL active set.
    pub peak_active: usize,
    /// Query-embedding refinements performed by the app's QF block
    /// (0 unless the composition enables fusion).
    pub fusion_updates: u64,
    /// Total simulation events dispatched by the sharded event core
    /// ([`ShardedDes`]) — the numerator of the events/sec throughput
    /// metric reported by `benches/hotpath.rs`.
    pub core_events: u64,
    /// End-of-run metrics registry snapshot (sink-independent: the
    /// registry records identically under every [`ObsSink`]).
    pub metrics: MetricsSnapshot,
    /// Engine RNG draws consumed — the observability determinism
    /// contract asserts this is identical across sinks per seed.
    pub rng_draws: u64,
}

/// The discrete-event simulation engine, generic over the trace sink.
/// The [`NullSink`] default monomorphizes every observability hook to
/// nothing — trace-event construction is guarded by
/// `obs.enabled()` (a constant `false` that inlines away), so the
/// default engine is bit-identical to the pre-observability one.
pub struct DesEngine<S: ObsSink = NullSink> {
    cfg: ExperimentConfig,
    topo: Topology,
    graph: Graph,
    gt: GroundTruth,
    net: NetModel,
    /// Per-node time-varying execution slowdown — scales the *actual*
    /// duration of every batch (the estimate side only follows when
    /// `online_xi` feeds observations back into the task ξ models).
    compute: ComputeModel,
    /// `cfg.service.online_xi`, hoisted: executors observe actual batch
    /// durations (and retune NOB tables) when set.
    online_xi: bool,
    /// Schedule-driven failure domains (node crashes, camera outages,
    /// link partitions, message loss) — the factor → ∞ limit of the
    /// dynamism machinery above. An empty schedule compiles to
    /// [`FaultModel::is_static`] and every fault hook short-circuits,
    /// preserving per-seed bit-identity with the fault-free build.
    faults: FaultModel,
    /// Dedicated RNG stream (`0xFA17`) for message-loss draws: separate
    /// from the engine stream so the reported `rng_draws` — part of the
    /// determinism contract — never move unless a loss window is
    /// actually configured.
    fault_rng: Rng,
    /// Fault-retry attempts per event id (bounded by
    /// `recovery.max_retries`).
    retry_counts: FastMap<u64, u32>,
    /// Where each task's arrivals are actually routed: identity until a
    /// *permanent* node crash redirects the dead executor's traffic to
    /// a surviving same-stage peer.
    task_redirect: Vec<usize>,
    /// Node/camera aliveness as of the last fault tick, diffed there to
    /// emit each transition exactly once.
    node_was_up: Vec<bool>,
    cam_was_up: Vec<bool>,
    skews: ClockSkews,
    /// Application blocks (UDFs): the engine only talks to them through
    /// the dataflow traits.
    fc: Box<dyn FilterControl>,
    va: Box<dyn VideoAnalytics>,
    cr: Box<dyn ContentionResolver>,
    qf: Box<dyn QueryFusion>,
    tl: Box<dyn TrackingLogic>,
    tasks: Vec<TaskState>,
    fc_active: Vec<bool>,
    fc_budget: Vec<BudgetManager>,
    fc_xi: XiModel,
    /// Geographic K-way split of the roadnet (K=1 by default); drives
    /// event routing and the failure-migration ring in
    /// [`Self::pick_survivor`].
    part: Partition,
    /// Camera -> shard (the camera's host vertex's shard).
    shard_of_cam: Vec<u32>,
    /// Task -> shard: FC tasks follow their camera, VA/CR instances
    /// round-robin over shards, cloud-tier tasks (TL/UV) sit on the
    /// coordinator shard 0.
    shard_of_task: Vec<u32>,
    core: ShardedDes<Ev>,
    next_event_id: u64,
    next_batch_seq: u64,
    frame_counters: Vec<u64>,
    ledger: Ledger,
    timeline: Timeline,
    /// Sink-side batch accounting: batch seq -> (remaining, slowest u,
    /// slowest event id, Σξ of slowest).
    sink_batches: FastMap<u64, (usize, Micros, u64, Micros)>,
    detections: u64,
    peak_active: usize,
    fusion_updates: u64,
    /// Stamps QF refinements with per-query update sequence numbers
    /// before they are routed upstream (the feedback edge).
    router: FeedbackRouter,
    /// The adaptation plane's single application point: every
    /// [`Payload::Adaptation`] command lands in
    /// [`Self::apply_adaptation`] and nowhere else. FC striding, frame
    /// bytes and VA/CR batch pricing read commanded operating points
    /// back out of it.
    adapt: AdaptationState,
    /// Sink-side accuracy–latency controller (deterministic, RNG-free).
    adapt_ctl: AdaptController,
    /// Hoisted `adapt_ctl.active()`: when false (identity ladder or
    /// adaptation off) every pricing site takes the exact integer
    /// ξ(b) path, bit-identical to the pre-adaptation engine.
    adapt_on: bool,
    /// App-nominal analytics variants per executor stage `[VA, CR]` —
    /// what the adaptation state prices commanded overrides against.
    stage_nominal: [ModelVariant; 2],
    rng: Rng,
    now: Micros,
    /// Trace sink (default [`NullSink`]: compiles to nothing).
    obs: S,
    /// Always-on metrics registry — atomic counters are
    /// sink-independent, so recording them never perturbs determinism.
    metrics: MetricsRegistry,
    /// Last spotlight size emitted as a [`TraceEvent::Spotlight`]
    /// resize (recording sinks only).
    last_spotlight: usize,
    /// Reusable buffers for the per-batch hot path (drop filtering,
    /// staged post-exec events + their (u, π) meta, outgoing
    /// transmissions) and the TL tick (active set + wanted cameras):
    /// allocations circulate instead of being re-made per batch/tick.
    kept_scratch: Vec<QueuedEvent<Event>>,
    staged_scratch: Vec<Event>,
    meta_scratch: Vec<(Micros, Micros, usize)>,
    outgoing_scratch: Vec<Event>,
    active_scratch: Vec<usize>,
    want_scratch: Vec<bool>,
}

/// Single-query ground-truth view for the VA block: one walk, source
/// timestamps are already on the ground-truth clock.
struct SingleTruth<'a>(&'a GroundTruth);

impl TruthSource for SingleTruth<'_> {
    fn interval_index(
        &self,
        _query: QueryId,
        camera: usize,
        captured: Micros,
    ) -> Option<usize> {
        self.0.interval_index(camera, captured)
    }
}

impl DesEngine {
    /// Build the engine for the stock application the config describes
    /// (`cfg.app` composition, `cfg.tl` spotlight).
    pub fn new(cfg: ExperimentConfig) -> Self {
        let app = crate::apps::resolve(&cfg);
        Self::with_app(cfg, &app)
    }

    /// Build the engine for an arbitrary [`AppDefinition`] — the
    /// public composition path; `cfg` keeps platform authority
    /// (batching, drops, budgets), the app supplies every block.
    pub fn with_app(cfg: ExperimentConfig, app: &AppDefinition) -> Self {
        Self::with_app_sink(cfg, app, NullSink)
    }
}

impl<S: ObsSink> DesEngine<S> {
    /// Build the engine with an explicit trace sink (flight recorder,
    /// JSONL export); [`DesEngine::with_app`] is this with [`NullSink`].
    pub fn with_app_sink(
        cfg: ExperimentConfig,
        app: &AppDefinition,
        sink: S,
    ) -> Self {
        let graph = generate(&cfg.workload, cfg.seed);
        let cams = place_cameras(
            &graph,
            cfg.num_cameras,
            0,
            cfg.workload.fov_m,
        );
        let duration = cfg.duration();
        let walk = EntityWalk::simulate(
            &graph,
            0,
            cfg.workload.entity_speed_mps,
            duration + 60 * SEC,
            cfg.seed,
        );
        let gt = GroundTruth::compute(
            &graph,
            &cams,
            &walk,
            duration + 60 * SEC,
            200_000,
        );
        let topo = Topology::schedule(&cfg);
        let net = NetModel::new(&cfg.network, topo.nodes);
        let skews = ClockSkews::random(
            topo.nodes,
            cfg.cluster.clock_skew_ms,
            topo.head_node, // head hosts the sink...
            topo.head_node, // ...and source clocks are the edge devices
            cfg.seed,
        );
        let mut tl = app.make_tl(&TlEnv {
            peak_speed_mps: cfg.tl_peak_speed_mps,
            mean_road_m: cfg.workload.mean_road_m,
            fov_m: cfg.workload.fov_m,
            cameras: &cams,
        });
        if cfg.seed_last_seen {
            // The query includes where the entity was last seen (Fig 1:
            // only C_A starts active). Camera 0 sits on the walk's
            // start vertex by construction.
            tl.on_detection(0, 0, true);
        }

        // Online ξ: executor *estimators* carry an EMA so observed
        // batch durations refine them — the same calibration loop the
        // live engine always runs (`coordinator/live.rs`). Frozen
        // estimators (the baseline) ignore observations entirely. The
        // nominal base models stay untouched either way: they are the
        // simulated hardware, from which actual durations are drawn.
        let online_xi = cfg.service.online_xi;
        let mk_xi = |x: &XiModel| {
            if online_xi {
                x.clone().with_ema(ONLINE_XI_EMA)
            } else {
                x.clone()
            }
        };
        let va_base = XiModel::affine_ms(
            cfg.service.va_alpha_ms,
            cfg.service.va_beta_ms,
        );
        let cr_base = XiModel::affine_ms(
            cfg.service.cr_alpha_ms,
            cfg.service.cr_beta_ms,
        );
        let va_xi = mk_xi(&va_base);
        let cr_xi = mk_xi(&cr_base);
        let fc_xi = XiModel::affine_ms(cfg.service.fc_ms, 0.01);

        let mk_batcher = |xi: &XiModel| -> Batcher<Event> {
            match cfg.batching {
                BatchingKind::Static { size } => Batcher::fixed(size),
                BatchingKind::Dynamic { max } => Batcher::dynamic(max),
                BatchingKind::Nob { max } => Batcher::nob(
                    NobTable::build(xi, NOB_MAX_RATE, NOB_RATE_STEP, max),
                    max,
                ),
            }
        };

        let m_max = match cfg.batching {
            BatchingKind::Static { size } => size,
            BatchingKind::Dynamic { max } | BatchingKind::Nob { max } => max,
        };

        let mut tasks = Vec::with_capacity(topo.tasks.len());
        for (i, info) in topo.tasks.iter().enumerate() {
            let (xi, xi_true) = match info.stage {
                Stage::Va => (va_xi.clone(), va_base.clone()),
                Stage::Cr => (cr_xi.clone(), cr_base.clone()),
                _ => (fc_xi.clone(), fc_xi.clone()),
            };
            tasks.push(TaskState {
                stage: info.stage,
                node: info.node,
                batcher: mk_batcher(&xi),
                // Prime record capacity: event ids reaching one task
                // stride by the active-camera count, so a power-of-two
                // ring would collapse to capacity/gcd usable slots.
                budget: BudgetManager::new(
                    topo.downstream_count(i),
                    m_max,
                    4093,
                ),
                xi,
                xi_true,
                busy: false,
                timer_seq: 0,
                drop_count: 0,
                feedback: FeedbackState::new(),
            });
        }

        let fc_budget = (0..cfg.num_cameras)
            .map(|_| {
                BudgetManager::new(
                    topo.va_part.instances(),
                    m_max,
                    251, // prime, for the same stride reason as above
                )
            })
            .collect();

        let num_cameras = cfg.num_cameras;
        let seed = cfg.seed;
        let compute =
            ComputeModel::new(&cfg.service.compute_events, topo.nodes);
        let faults = FaultModel::new(
            &cfg.service.fault_events,
            topo.nodes,
            num_cameras,
        );
        let nodes = topo.nodes;
        let task_redirect = (0..topo.tasks.len()).collect();
        // Geographic sharding (K=1 by default). Routing is
        // result-neutral — the merge reproduces the single-core
        // dispatch order for any K — so the tables below only decide
        // which shard's heap holds each event (and therefore what
        // counts as a cross-shard handoff).
        let part = partition(&graph, cfg.sharding.shards);
        let shard_of_cam: Vec<u32> = (0..num_cameras)
            .map(|c| {
                cams.get(c)
                    .map_or(0, |cam| part.shard_of_vertex(cam.vertex))
            })
            .collect();
        let shard_of_task: Vec<u32> = topo
            .tasks
            .iter()
            .map(|info| match info.stage {
                Stage::Fc => shard_of_cam[info.instance],
                Stage::Va | Stage::Cr => {
                    (info.instance % part.shards()) as u32
                }
                _ => 0,
            })
            .collect();
        let mut core =
            ShardedDes::with_threads(part.shards(), cfg.sharding.threads);
        if cfg!(feature = "strict-invariants") && part.shards() > 1 {
            core.set_entity_tracking(true);
        }
        // Adaptation plane: one state (the single application point),
        // one sink-side controller. CR's nominal variant rides on the
        // commands; VA derives its own (non-)override from it.
        let adapt = AdaptationState::new(&cfg.adaptation, num_cameras);
        let adapt_ctl = AdaptController::new(
            &cfg.adaptation,
            num_cameras,
            cfg.gamma(),
            app.cr_variant,
        );
        Self {
            cfg,
            topo,
            graph,
            gt,
            net,
            compute,
            online_xi,
            faults,
            fault_rng: rng(seed, 0xFA17),
            retry_counts: FastMap::default(),
            task_redirect,
            node_was_up: vec![true; nodes],
            cam_was_up: vec![true; num_cameras],
            skews,
            fc: app.make_fc(),
            va: app.make_va(),
            cr: app.make_cr(),
            qf: app.make_qf(),
            tl,
            tasks,
            fc_active: vec![true; num_cameras],
            fc_budget,
            fc_xi,
            part,
            shard_of_cam,
            shard_of_task,
            core,
            next_event_id: 0,
            next_batch_seq: 0,
            frame_counters: vec![0; num_cameras],
            ledger: Ledger::new(),
            timeline: Timeline::new(),
            sink_batches: FastMap::default(),
            detections: 0,
            peak_active: num_cameras,
            fusion_updates: 0,
            router: FeedbackRouter::new(),
            adapt_on: adapt_ctl.active(),
            adapt,
            adapt_ctl,
            stage_nominal: [app.va_variant, app.cr_variant],
            rng: rng(seed, 0xDE5),
            now: 0,
            obs: sink,
            metrics: MetricsRegistry::new(),
            last_spotlight: usize::MAX,
            kept_scratch: Vec::new(),
            staged_scratch: Vec::new(),
            meta_scratch: Vec::new(),
            outgoing_scratch: Vec::new(),
            active_scratch: Vec::new(),
            want_scratch: Vec::new(),
        }
    }

    // ---- event plumbing --------------------------------------------------

    /// Geographic routing for the sharded event core: per-camera
    /// events live on the camera's shard, executor-addressed events on
    /// their task's shard, and the control plane (TL spotlight, fault
    /// ticks) on the coordinator shard 0.
    fn shard_of(&self, ev: &Ev) -> u32 {
        match ev {
            Ev::FrameTick { cam } | Ev::Control { cam, .. } => {
                self.shard_of_cam[*cam]
            }
            Ev::Arrive { task, .. }
            | Ev::BatchTimer { task, .. }
            | Ev::ExecDone { task, .. }
            | Ev::SignalAt { task, .. } => self.shard_of_task[*task],
            Ev::TlTick | Ev::TlDetection { .. } | Ev::FaultTick => 0,
        }
    }

    fn push(&mut self, t: Micros, ev: Ev) {
        let shard = self.shard_of(&ev);
        // Entity-ownership bookkeeping (strict-invariants, K>1 only):
        // data events are owned by the shard holding them; probes
        // reuse the slowest event's id and feedback copies (query
        // updates, adaptation commands) are broadcast, so neither has
        // a single owner.
        let entity = if self.core.shards() > 1 {
            match &ev {
                Ev::Arrive { ev, .. }
                    if !ev.header.probe
                        && !matches!(
                            ev.payload,
                            Payload::QueryUpdate(_)
                                | Payload::Adaptation(_)
                        ) =>
                {
                    Some(ev.header.id)
                }
                _ => None,
            }
        } else {
            None
        };
        let msg = self.core.schedule(t, shard, ev);
        if let Some(id) = entity {
            match msg {
                Some(m) => self.core.record_handoff(id, m.from, m.to),
                None => self.core.note_arrival(id, shard),
            }
        }
        if let Some(m) = msg {
            self.metrics.cross_shard_msg();
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::CrossShard {
                        from_shard: m.from,
                        to_shard: m.to,
                        seq: m.seq,
                    },
                );
            }
        }
    }

    fn observe(&self, task: usize) -> Micros {
        // FC tasks read the camera/edge clock; head-node tasks read the
        // sink clock; both are the unskewed reference (κ1 = κn, §4.6.2).
        let info = &self.topo.tasks[task];
        if matches!(info.stage, Stage::Fc) {
            self.now
        } else {
            self.skews.observe(info.node, self.now)
        }
    }

    /// Run to completion; drains in-flight events for `gamma` past the
    /// feed cutoff so late events classify as delayed rather than
    /// in-flight.
    pub fn run(mut self) -> RunResult {
        if self.cfg.seed_last_seen {
            let mut active = std::mem::take(&mut self.active_scratch);
            self.tl.active_set_into(&self.graph, 0, &mut active);
            self.fc_active = vec![false; self.cfg.num_cameras];
            for &cam in &active {
                self.fc_active[cam] = true;
            }
            self.peak_active = self
                .fc_active
                .iter()
                .filter(|&&a| a)
                .count();
            self.active_scratch = active;
        }
        for cam in 0..self.cfg.num_cameras {
            // Stagger camera phases within the first frame interval.
            let phase = self.rng.range_i64(0, (SEC as f64 / self.cfg.fps) as i64);
            self.push(phase, Ev::FrameTick { cam });
        }
        self.push(SEC, Ev::TlTick);
        self.metrics.set_active_queries(1);
        self.metrics.set_shards(self.core.shards());

        if !self.faults.is_static() {
            // One tick per scheduled node/camera transition: crash
            // consequences and revivals happen at the exact virtual
            // instant, not at the next periodic tick.
            let horizon = self.cfg.duration() + 2 * self.cfg.gamma();
            let ticks: Vec<Micros> = self
                .faults
                .transitions()
                .iter()
                .copied()
                .filter(|&t| t <= horizon)
                .collect();
            for t in ticks {
                self.push(t, Ev::FaultTick);
            }
        }

        if self.obs.enabled() {
            // The configured dynamism schedule, stamped at its
            // scheduled virtual times (emitted up front: the steps are
            // known before the run starts).
            self.obs.emit(
                0,
                &TraceEvent::QueryLifecycle {
                    query: SINGLE_QUERY,
                    phase: QueryPhase::Activated,
                },
            );
            for e in &self.cfg.service.compute_events {
                self.obs.emit(
                    crate::util::secs(e.at_sec),
                    &TraceEvent::ComputeFactor {
                        node: e.node.map_or(-1, |n| n as i64),
                        factor: e.factor,
                    },
                );
            }
            for e in &self.cfg.network.events {
                self.obs.emit(
                    crate::util::secs(e.at_sec),
                    &TraceEvent::Bandwidth { bps: e.bandwidth_bps },
                );
            }
        }

        let horizon = self.cfg.duration() + 2 * self.cfg.gamma();
        while let Some((t, ev)) = self.core.pop_until(horizon) {
            self.now = t;
            let sp = span_begin(&self.obs);
            self.dispatch(ev);
            span_end(&self.obs, Scope::Dispatch, sp);
        }

        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::QueryLifecycle {
                    query: SINGLE_QUERY,
                    phase: QueryPhase::Completed,
                },
            );
        }

        RunResult {
            summary: self.ledger.summary(),
            timeline: self.timeline,
            detections: self.detections,
            peak_active: self.peak_active,
            fusion_updates: self.fusion_updates,
            core_events: self.core.dispatched(),
            metrics: self.metrics.snapshot(),
            rng_draws: self.rng.draws(),
        }
    }

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::FrameTick { cam } => self.on_frame_tick(cam),
            Ev::Arrive { task, ev, batch } => self.on_arrive(task, ev, batch),
            Ev::BatchTimer { task, seq } => {
                if self.tasks[task].timer_seq == seq
                    && !self.tasks[task].busy
                {
                    self.try_form_batch(task);
                }
            }
            Ev::ExecDone {
                task,
                batch,
                start_obs,
                xi_est,
                actual,
            } => self.on_exec_done(task, batch, start_obs, xi_est, actual),
            Ev::SignalAt { task, sig } => {
                let t = &mut self.tasks[task];
                t.budget.apply(sig, &t.xi);
            }
            Ev::Control { cam, active } => {
                self.fc_active[cam] = active;
            }
            Ev::TlTick => self.on_tl_tick(),
            Ev::TlDetection {
                camera,
                captured,
                detected,
            } => {
                self.tl.on_detection(camera, captured, detected);
                if detected {
                    // Event-driven contraction: recompute immediately.
                    self.apply_active_set();
                }
            }
            Ev::FaultTick => self.on_fault_tick(),
        }
    }

    // ---- feeds + FC ------------------------------------------------------

    fn on_frame_tick(&mut self, cam: usize) {
        let t = self.now;
        if t < self.cfg.duration() {
            let period = (SEC as f64 / self.cfg.fps) as Micros;
            self.push(t + period, Ev::FrameTick { cam });
        } else {
            return;
        }
        // A dark camera produces nothing: outage frames are never
        // generated (so never ledgered) — unlike node-crash losses,
        // which are generated and then terminate as `lost_to_fault`.
        if !self.faults.camera_alive(cam, t) {
            return;
        }
        // FC user-logic: the block decides whether this frame enters
        // the dataflow, given TL's activation flag. The counter
        // advances per *tick* (not per admitted frame), so stride-based
        // FCs see monotonically increasing frame numbers.
        let frame_no = self.frame_counters[cam];
        self.frame_counters[cam] += 1;
        // Commanded frame-rate: a downshifted rung with stride k admits
        // every k-th tick at the platform layer, so FC user-logic sees
        // the commanded rate. Stride 1 (the identity ladder, and every
        // rung of the stock A/B ladder) skips this entirely.
        if self.adapt_on {
            let stride = self.adapt.stride(cam);
            if stride > 1 && frame_no % stride != 0 {
                return;
            }
        }
        if !self.fc.admit(
            SINGLE_QUERY,
            cam,
            frame_no,
            t,
            self.fc_active[cam],
        ) {
            return;
        }
        let id = self.next_event_id;
        self.next_event_id += 1;
        let present = self.gt.visible(cam, t);
        let mut ev = Event::frame(id, cam, frame_no, t, present);
        self.ledger.generated(id, present);
        self.metrics.generated();
        if self.obs.enabled() {
            self.obs.emit(
                t,
                &TraceEvent::Generated {
                    event: id,
                    query: SINGLE_QUERY,
                    camera: cam as u32,
                },
            );
        }

        // FC drop point 1 (u = 0 at the source task): rejects new frames
        // the moment downstream budgets collapse — the paper's "τ1
        // should reject a newly arriving event" ideal.
        let fc_task = self.topo.fc_task(cam);
        let slot = self.topo.downstream_slot(fc_task, cam);
        if self.cfg.drops_enabled {
            let budget = self.fc_budget[cam].budget_max();
            let xi1 = self.fc_xi.xi(1);
            if budget < BUDGET_INF && drop_at_queue(false, 0, xi1, budget)
            {
                self.record_drop(id, xi1 - budget, xi1);
                return;
            }
        }
        // FC executes (fc_ms) and transmits the frame to its VA.
        let fc_dur = self.fc_xi.xi(1);
        let d = fc_dur; // u = 0, π = ξ_fc
        self.fc_budget[cam].record(
            id,
            EventRecord {
                departure: d,
                queue: 0,
                batch: 1,
                sent_to: slot,
            },
        );
        ev.header.sum_exec += fc_dur;
        let va = self.topo.va_task(cam);
        // Commanded resolution: the frame ships at the rung's scaled
        // size (native rung = exact identity, no f64 arithmetic).
        let frame_bytes = if self.adapt_on {
            self.adapt.scaled_bytes(self.net.frame_bytes, cam)
        } else {
            self.net.frame_bytes
        };
        self.send_data(
            self.topo.node_of(fc_task),
            va,
            frame_bytes,
            t + fc_dur,
            ev,
            None,
            Stage::Fc,
        );
    }

    // ---- executor tasks (VA / CR) ----------------------------------------

    fn on_arrive(
        &mut self,
        task: usize,
        ev: Event,
        batch: Option<(u64, usize)>,
    ) {
        // A permanent crash may have redirected this task's traffic
        // after the message was already in flight: deliver to the
        // survivor, not the corpse.
        let task = self.route(task);
        match self.tasks[task].stage {
            Stage::Uv => self.on_sink_arrive(ev, batch),
            Stage::Va | Stage::Cr => {
                // Feedback edge, adaptation flavor: the first broadcast
                // copy applies at the engine's single application
                // point; later copies discard as stale. Like query
                // updates, commands never touch the batcher, budgets
                // or drop points.
                if let Payload::Adaptation(cmd) = &ev.payload {
                    let cmd = *cmd;
                    self.apply_adaptation(cmd);
                    return;
                }
                // Feedback edge: a QueryUpdate is consumed here — the
                // executor swaps its scoring target (iff the update is
                // fresher than the last applied one) and the event
                // never touches the batcher, budgets or drop points.
                if let Payload::QueryUpdate(emb) = &ev.payload {
                    self.tasks[task].feedback.apply(
                        ev.header.query,
                        ev.header.update_seq,
                        Arc::clone(emb),
                    );
                    return;
                }
                let t_obs = self.observe(task);
                let u = t_obs - ev.header.src_arrival;
                let exempt = ev.header.avoid_drop || ev.header.probe;
                // The event's downstream is already determined by its
                // key (camera), so both the drop decision and the
                // batching deadline can use that slot's budget rather
                // than the conservative max (§4.3.4).
                let slot = self
                    .topo
                    .downstream_slot(task, ev.header.camera);
                let budget = self.tasks[task].budget.budget_for(slot);
                if self.cfg.drops_enabled {
                    // Gate 1 prices one event at the camera's
                    // commanded rel (exactly ξ(1) at the identity).
                    let xi1 = if self.adapt_on {
                        let nom =
                            self.nominal_of(self.tasks[task].stage);
                        self.tasks[task].xi.xi_eff(
                            self.adapt.rel(ev.header.camera, nom),
                        )
                    } else {
                        self.tasks[task].xi.xi(1)
                    };
                    if budget < BUDGET_INF
                        && drop_at_queue(exempt, u, xi1, budget)
                    {
                        let eps = (u + xi1) - budget;
                        self.drop_event(
                            task,
                            ev,
                            Gate::Queue,
                            eps,
                            xi1,
                            1,
                        );
                        return;
                    }
                    // The §4.3.3 exemption observed in the wild: an
                    // avoid-drop/probe event survived a verdict that
                    // would have dropped it.
                    if self.obs.enabled()
                        && exempt
                        && budget < BUDGET_INF
                        && drop_at_queue(false, u, xi1, budget)
                    {
                        let stage = self.tasks[task].stage;
                        self.obs.emit(
                            self.now,
                            &TraceEvent::Exempted {
                                gate: Gate::Queue,
                                stage,
                                event: ev.header.id,
                                query: SINGLE_QUERY,
                            },
                        );
                    }
                }
                let deadline = if budget >= BUDGET_INF {
                    BUDGET_INF
                } else {
                    budget + ev.header.src_arrival
                };
                let id = ev.header.id;
                self.tasks[task].batcher.push(QueuedEvent {
                    item: ev,
                    id,
                    arrival: t_obs,
                    deadline,
                });
                if !self.tasks[task].busy {
                    self.try_form_batch(task);
                }
            }
            _ => {}
        }
    }

    fn try_form_batch(&mut self, task: usize) {
        // A dead executor forms no batches; queued events wait in the
        // batcher for the revival tick (or were re-dispatched when the
        // crash was permanent).
        if !self.faults.node_alive(self.tasks[task].node, self.now) {
            return;
        }
        loop {
            let t_obs = self.observe(task);
            let sp = span_begin(&self.obs);
            let poll = {
                let ts = &mut self.tasks[task];
                ts.batcher.poll(t_obs, &ts.xi)
            };
            span_end(&self.obs, Scope::BatchPoll, sp);
            match poll {
                BatcherPoll::Idle => return,
                BatcherPoll::Timer(at_obs) => {
                    let ts = &mut self.tasks[task];
                    ts.timer_seq += 1;
                    let seq = ts.timer_seq;
                    // Convert the task-clock timer back to true time.
                    let skew = at_obs - t_obs;
                    self.push(
                        self.now + skew.max(0),
                        Ev::BatchTimer { task, seq },
                    );
                    return;
                }
                BatcherPoll::Ready(mut batch) => {
                    // Drop point 2: filter the formed batch (per-event
                    // downstream budgets; the route is key-determined).
                    // The survivor buffer is engine-owned scratch, so
                    // the filter allocates nothing in steady state.
                    if self.cfg.drops_enabled {
                        let b = batch.len();
                        let xib = self.batch_xi(task, &batch);
                        let mut kept =
                            std::mem::take(&mut self.kept_scratch);
                        kept.clear();
                        for qe in batch.drain(..) {
                            let slot = self.topo.downstream_slot(
                                task,
                                qe.item.header.camera,
                            );
                            let budget = self.tasks[task]
                                .budget
                                .budget_for(slot);
                            let u =
                                qe.arrival - qe.item.header.src_arrival;
                            let q = t_obs - qe.arrival;
                            let exempt = qe.item.header.avoid_drop
                                || qe.item.header.probe;
                            if budget < BUDGET_INF
                                && drop_at_exec(exempt, u, q, xib, budget)
                            {
                                let eps = (u + q + xib) - budget;
                                self.drop_event(
                                    task,
                                    qe.item,
                                    Gate::Exec,
                                    eps,
                                    xib,
                                    b as u32,
                                );
                            } else {
                                if self.obs.enabled()
                                    && exempt
                                    && budget < BUDGET_INF
                                    && drop_at_exec(
                                        false, u, q, xib, budget,
                                    )
                                {
                                    let stage = self.tasks[task].stage;
                                    self.obs.emit(
                                        self.now,
                                        &TraceEvent::Exempted {
                                            gate: Gate::Exec,
                                            stage,
                                            event: qe.item.header.id,
                                            query: SINGLE_QUERY,
                                        },
                                    );
                                }
                                kept.push(qe);
                            }
                        }
                        std::mem::swap(&mut batch, &mut kept);
                        self.kept_scratch = kept;
                    }
                    if batch.is_empty() {
                        self.tasks[task].batcher.recycle(batch);
                        continue; // try to form the next batch
                    }
                    let b = batch.len();
                    // Batch pricing under adaptation: both the
                    // estimate and the simulated-hardware truth price
                    // the *effective* size Σ rel(camera) — a
                    // downshifted camera's events genuinely run
                    // cheaper. Inert plane: the exact integer ξ(b)
                    // path, bit-identical to the pre-adaptation
                    // engine.
                    let (xi_est, xi_true) = if self.adapt_on {
                        let rel = self.batch_rel(task, &batch);
                        let ts = &self.tasks[task];
                        (ts.xi.xi_eff(rel), ts.xi_true.xi_eff(rel))
                    } else {
                        let ts = &self.tasks[task];
                        (ts.xi.xi(b), ts.xi_true.xi(b))
                    };
                    let (jitter, node) = {
                        let ts = &self.tasks[task];
                        (self.cfg.service.jitter, ts.node)
                    };
                    if self.obs.enabled() {
                        let stage = self.tasks[task].stage;
                        self.obs.emit(
                            self.now,
                            &TraceEvent::BatchFormed {
                                stage,
                                task: task as u32,
                                size: b as u32,
                            },
                        );
                    }
                    let factor =
                        1.0 + self.rng.range_f64(-jitter, jitter);
                    // Compute dynamism: the *actual* duration is drawn
                    // from the frozen nominal model (the simulated
                    // hardware), scaled by the node's slowdown at
                    // execution start — never from the ξ̂ estimator,
                    // which may itself have been refined online (a
                    // self-referential loop would compound the
                    // slowdown geometrically). Factor 1.0 (no events)
                    // is a bit-exact identity, and the RNG draw count
                    // is unchanged either way.
                    let slow = self.compute.factor_at(node, self.now);
                    let actual = ((xi_true as f64) * factor * slow)
                        .round() as Micros;
                    self.tasks[task].busy = true;
                    self.push(
                        self.now + actual.max(1),
                        Ev::ExecDone {
                            task,
                            batch,
                            start_obs: t_obs,
                            xi_est,
                            actual,
                        },
                    );
                    return;
                }
            }
        }
    }

    fn on_exec_done(
        &mut self,
        task: usize,
        mut batch: Vec<QueuedEvent<Event>>,
        start_obs: Micros,
        xi_est: Micros,
        actual: Micros,
    ) {
        self.tasks[task].busy = false;
        // The executor's node died while this batch was in flight (even
        // if it also restarted before completion popped): nothing it
        // computed survives. Members retry or terminate as
        // `lost_to_fault`; the normal completion path never runs.
        let start_true = self.now - actual.max(1);
        if self.faults.node_down_during(
            self.tasks[task].node,
            start_true,
            self.now,
        ) {
            self.void_batch(task, batch);
            return;
        }
        let b = batch.len();
        let stage = self.tasks[task].stage;
        let batch_seq = self.next_batch_seq;
        self.next_batch_seq += 1;

        // Online ξ recalibration (§4.2): feed the observed
        // (slowdown-scaled) duration into this executor's model and
        // retune its NOB table on material drift — the DES mirror of
        // the live engine's observe call. Deadline math, rate lookups
        // and drop gates all read this model, so they now track the
        // current machine.
        if self.online_xi {
            // Under an active adaptation plane the observation is
            // attributed at the batch's *effective* size (what the
            // actual duration was drawn at), so refinement converges
            // on the per-unit cost, not a rel-deflated copy of it.
            let b_eff = if self.adapt_on {
                self.batch_rel(task, &batch)
            } else {
                b as f64
            };
            let ts = &mut self.tasks[task];
            if self.adapt_on {
                ts.xi.observe_eff(b_eff, actual);
            } else {
                ts.xi.observe(b, actual);
            }
            ts.batcher.retune_nob(&ts.xi);
            self.metrics.xi_observed();
            self.metrics.nob_retune();
            if self.obs.enabled() {
                let (alpha_us, beta_us) =
                    (ts.xi.alpha_us(), ts.xi.beta_us());
                self.obs.emit(
                    self.now,
                    &TraceEvent::XiObserved {
                        stage,
                        task: task as u32,
                        b_eff,
                        actual_us: actual,
                        alpha_us,
                        beta_us,
                    },
                );
                self.obs.emit(
                    self.now,
                    &TraceEvent::NobRetune {
                        stage,
                        task: task as u32,
                    },
                );
            }
        }

        // Timeline: mean queue+exec latency for this batch.
        let mean_q: Micros = batch
            .iter()
            .map(|qe| start_obs - qe.arrival)
            .sum::<Micros>()
            / b as Micros;
        self.timeline.batch_executed(
            self.now,
            stage,
            b,
            mean_q + actual,
        );
        self.metrics.batch_executed(stage, b, mean_q);
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::BatchExecuted {
                    stage,
                    task: task as u32,
                    size: b as u32,
                    est_us: xi_est,
                    actual_us: actual,
                },
            );
        }

        // First pass: per-event bookkeeping (budget 3-tuples, header
        // accumulators) into engine-owned scratch; the emptied batch
        // vec is recycled into the batcher, so the steady state
        // circulates buffers instead of allocating per batch.
        let mut staged = std::mem::take(&mut self.staged_scratch);
        let mut meta = std::mem::take(&mut self.meta_scratch);
        staged.clear();
        meta.clear();
        for qe in batch.drain(..) {
            let mut ev = qe.item;
            let cam = ev.header.camera;
            let q = start_obs - qe.arrival;
            let u = qe.arrival - ev.header.src_arrival;
            let pi = q + actual;
            let d = u + pi;
            let slot = self.topo.downstream_slot(task, cam);
            self.tasks[task].budget.record(
                ev.header.id,
                EventRecord {
                    departure: d,
                    queue: q,
                    batch: b,
                    sent_to: slot,
                },
            );
            ev.header.sum_exec += xi_est;
            ev.header.sum_queue += q;
            staged.push(ev);
            meta.push((u, pi, slot));
        }
        self.tasks[task].batcher.recycle(batch);

        // Module user-logic: one virtual call for the whole batch (the
        // block steps events in arrival order, so the engine RNG stream
        // is identical to per-event dispatch).
        let sp = span_begin(&self.obs);
        {
            let truth = SingleTruth(&self.gt);
            let mut ctx = SimCtx {
                rng: &mut self.rng,
                truth: &truth,
                sem: &self.cfg.semantics,
                seed: self.cfg.seed,
                feedback: &self.tasks[task].feedback,
                adapt: &self.adapt,
            };
            match stage {
                Stage::Va => self.va.step_sim(&mut staged, &mut ctx),
                Stage::Cr => self.cr.step_sim(&mut staged, &mut ctx),
                _ => {}
            }
        }
        span_end(&self.obs, Scope::Scoring, sp);

        // Drop point 3 (per-downstream budget); survivors move to the
        // outgoing scratch.
        let mut outgoing = std::mem::take(&mut self.outgoing_scratch);
        outgoing.clear();
        for (i, ev) in staged.drain(..).enumerate() {
            let (u, pi, slot) = meta[i];
            let exempt = ev.header.avoid_drop || ev.header.probe;
            if self.cfg.drops_enabled {
                let budget = self.tasks[task].budget.budget_for(slot);
                if budget < BUDGET_INF
                    && drop_at_transmit(exempt, u, pi, budget)
                {
                    let eps = (u + pi) - budget;
                    self.drop_event(
                        task,
                        ev,
                        Gate::Transmit,
                        eps,
                        pi,
                        b as u32,
                    );
                    continue;
                }
                if self.obs.enabled()
                    && exempt
                    && budget < BUDGET_INF
                    && drop_at_transmit(false, u, pi, budget)
                {
                    self.obs.emit(
                        self.now,
                        &TraceEvent::Exempted {
                            gate: Gate::Transmit,
                            stage,
                            event: ev.header.id,
                            query: SINGLE_QUERY,
                        },
                    );
                }
            }
            outgoing.push(ev);
        }
        self.staged_scratch = staged;
        self.meta_scratch = meta;

        // Second pass: transmit (batch tag tells the sink the surviving
        // size so accept logic can find the slowest member).
        let out_n = outgoing.len();
        let src_node = self.topo.node_of(task);
        for ev in outgoing.drain(..) {
            let cam = ev.header.camera;
            let (next_task, bytes) = match stage {
                Stage::Va => {
                    (self.topo.cr_task(cam), self.net.candidate_bytes)
                }
                Stage::Cr => (self.topo.uv, self.net.meta_bytes),
                _ => unreachable!("only VA/CR execute batches"),
            };
            // CR forks metadata to TL as well. The fork is best-effort
            // under faults: a partitioned/lossy control plane vanishes
            // it with no retry (the ledgered copy continues to UV).
            if stage == Stage::Cr {
                if let Payload::Detection { detected, .. } = ev.payload {
                    let tl_node = self.topo.node_of(self.topo.tl);
                    if self.channel_ok(src_node, tl_node, self.now) {
                        let tl_arrive = self.net.transfer(
                            src_node,
                            tl_node,
                            self.net.meta_bytes,
                            self.now,
                        );
                        self.push(
                            tl_arrive,
                            Ev::TlDetection {
                                camera: cam,
                                captured: ev.header.captured,
                                detected,
                            },
                        );
                    }
                }
            }
            let tag = if stage == Stage::Cr {
                Some((batch_seq, out_n))
            } else {
                None
            };
            self.send_data(
                src_node, next_task, bytes, self.now, ev, tag, stage,
            );
        }
        self.outgoing_scratch = outgoing;

        // The executor is free: form the next batch.
        self.try_form_batch(task);
    }

    // ---- drops + signals ---------------------------------------------------

    /// Ledger + trace a source-side drop (FC, gate 1: `u = 0`, so
    /// `eps = ξ_fc(1) − budget`).
    fn record_drop(&mut self, id: u64, eps: Micros, xi1: Micros) {
        self.ledger.dropped(id, Stage::Fc);
        self.timeline.dropped(self.now);
        self.metrics.dropped(Gate::Queue);
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::Drop {
                    gate: Gate::Queue,
                    stage: Stage::Fc,
                    event: id,
                    query: SINGLE_QUERY,
                    batch: 1,
                    eps_us: eps,
                    xi_us: xi1,
                },
            );
        }
    }

    /// Drop an event at `task`, ledger it, send reject signals upstream
    /// and forward every k-th drop as a probe (§4.5.2). Takes the event
    /// by value: probes reuse the dropped event instead of cloning it.
    /// `gate`/`xi_us`/`batch` describe the verdict for the trace: the
    /// gate charged `xi_us` against the budget at batch size `batch`
    /// and came up `eps` short.
    fn drop_event(
        &mut self,
        task: usize,
        ev: Event,
        gate: Gate,
        eps: Micros,
        xi_us: Micros,
        batch: u32,
    ) {
        let stage = self.tasks[task].stage;
        self.ledger.dropped(ev.header.id, stage);
        self.timeline.dropped(self.now);
        self.metrics.dropped(gate);
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::Drop {
                    gate,
                    stage,
                    event: ev.header.id,
                    query: SINGLE_QUERY,
                    batch,
                    eps_us: eps,
                    xi_us,
                },
            );
        }
        self.tasks[task].drop_count += 1;

        let cam = ev.header.camera;
        let sig = Signal::Reject {
            event: ev.header.id,
            eps: eps.max(0),
            sum_queue: ev.header.sum_queue.max(1),
        };
        // Upstream tasks on this event's path.
        let path = self.topo.path(cam);
        let my_pos = path
            .iter()
            .position(|&t| t == task)
            .unwrap_or(path.len());
        for &up in path.iter().take(my_pos) {
            let lat = self.net.transfer_estimate(
                self.net.meta_bytes,
                self.now,
            );
            if self.topo.stage_of(up) == Stage::Fc {
                // FC budgets live in the engine (per camera); signals
                // to the edge apply directly (FC state is engine-owned).
                self.fc_budget[cam].apply(sig, &self.fc_xi);
            } else {
                self.push(self.now + lat, Ev::SignalAt { task: up, sig });
            }
        }

        // Probe: forward every k-th dropped event un-droppable so the
        // sink can re-open collapsed budgets.
        if self.cfg.probe_every > 0
            && self.tasks[task].drop_count % self.cfg.probe_every == 0
        {
            let mut probe = ev;
            probe.header.probe = true;
            let (next_task, bytes) = match stage {
                Stage::Va => {
                    (self.topo.cr_task(cam), self.net.candidate_bytes)
                }
                Stage::Cr => (self.topo.uv, self.net.meta_bytes),
                _ => return,
            };
            // Probes skip this task's queue (they carry no payload
            // work). Under faults they are best-effort — the event is
            // already terminally ledgered as dropped, so a partitioned
            // or lossy channel just vanishes the probe.
            let next_task = self.route(next_task);
            let src = self.tasks[task].node;
            let dst = self.topo.node_of(next_task);
            if self.channel_ok(src, dst, self.now) {
                let arrive =
                    self.net.transfer(src, dst, bytes, self.now);
                self.push(
                    arrive,
                    Ev::Arrive {
                        task: next_task,
                        ev: probe,
                        batch: None,
                    },
                );
            }
        }
    }

    // ---- faults + recovery -------------------------------------------------

    /// Where arrivals addressed to `task` actually land (identity until
    /// a permanent crash installs a redirect).
    #[inline]
    fn route(&self, task: usize) -> usize {
        if self.faults.is_static() {
            task
        } else {
            self.task_redirect[task]
        }
    }

    /// Can a message sent `src → dst` at `t` get through the fault
    /// domains? Consults link partitions and — only when loss windows
    /// exist — draws from the dedicated fault RNG stream, so fault-free
    /// (and loss-free) schedules never touch any RNG.
    fn channel_ok(&mut self, src: usize, dst: usize, t: Micros) -> bool {
        if self.faults.is_static() {
            return true;
        }
        if !self.faults.link_up(src, dst, t) {
            return false;
        }
        if self.faults.has_loss() {
            let p = self.faults.loss_prob(t);
            if p > 0.0 && self.fault_rng.range_f64(0.0, 1.0) < p {
                return false;
            }
        }
        true
    }

    /// Transmit a ledgered data event towards `dst_task`, through the
    /// fault domains. With recovery on, a failed send retransmits with
    /// exponential backoff — the channel is re-evaluated at each
    /// attempt's send time (all draws made now, keeping the schedule
    /// deterministic); once attempts are exhausted, or immediately with
    /// recovery off, the event terminates as `lost_to_fault` at the
    /// *sending* stage. The fault-free fast path is one branch and
    /// bit-identical to the pre-fault engine.
    #[allow(clippy::too_many_arguments)]
    fn send_data(
        &mut self,
        src_node: usize,
        dst_task: usize,
        bytes: usize,
        at: Micros,
        ev: Event,
        batch: Option<(u64, usize)>,
        stage: Stage,
    ) {
        let dst_task = self.route(dst_task);
        let dst_node = self.topo.node_of(dst_task);
        if self.faults.is_static() {
            let arrive =
                self.net.transfer(src_node, dst_node, bytes, at);
            self.push(arrive, Ev::Arrive { task: dst_task, ev, batch });
            return;
        }
        let rec = self.cfg.service.recovery;
        let attempts = if rec.enabled { rec.max_retries + 1 } else { 1 };
        let mut t = at;
        for k in 0..attempts {
            if self.channel_ok(src_node, dst_node, t) {
                if k > 0 {
                    self.metrics.fault_retry();
                    if self.obs.enabled() {
                        self.obs.emit(
                            self.now,
                            &TraceEvent::FaultRetry {
                                event: ev.header.id,
                                query: SINGLE_QUERY,
                                attempt: k,
                            },
                        );
                    }
                }
                let arrive =
                    self.net.transfer(src_node, dst_node, bytes, t);
                self.push(
                    arrive,
                    Ev::Arrive { task: dst_task, ev, batch },
                );
                return;
            }
            t += backoff_delay(&rec, k);
        }
        self.lose_event(ev.header.id, stage);
    }

    /// Terminal fault accounting: the event is gone and no retry
    /// remains. A distinct outcome class from gate drops — the
    /// conservation identity becomes generated = on-time + delayed +
    /// dropped + lost-to-fault + in-flight.
    fn lose_event(&mut self, id: u64, stage: Stage) {
        self.ledger.lost_to_fault(id, stage);
        self.metrics.lost_to_fault();
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::LostToFault {
                    event: id,
                    query: SINGLE_QUERY,
                    stage,
                },
            );
        }
    }

    /// The executor died while this batch was in flight: nothing it
    /// computed survives. With recovery on, members re-arrive at the
    /// (possibly redirected) task after exponential backoff, bounded by
    /// `max_retries` per event; otherwise — or once retries are
    /// exhausted — they terminate as `lost_to_fault`.
    fn void_batch(
        &mut self,
        task: usize,
        mut batch: Vec<QueuedEvent<Event>>,
    ) {
        let stage = self.tasks[task].stage;
        let rec = self.cfg.service.recovery;
        for qe in batch.drain(..) {
            let ev = qe.item;
            let id = ev.header.id;
            let attempt = self.retry_counts.get(&id).copied().unwrap_or(0);
            if rec.enabled && attempt < rec.max_retries {
                self.retry_counts.insert(id, attempt + 1);
                self.metrics.fault_retry();
                if self.obs.enabled() {
                    self.obs.emit(
                        self.now,
                        &TraceEvent::FaultRetry {
                            event: id,
                            query: SINGLE_QUERY,
                            attempt: attempt + 1,
                        },
                    );
                }
                let to = self.route(task);
                self.push(
                    self.now + backoff_delay(&rec, attempt),
                    Ev::Arrive { task: to, ev, batch: None },
                );
            } else {
                self.lose_event(id, stage);
            }
        }
        self.tasks[task].batcher.recycle(batch);
        // If the node already revived mid-execution, whatever queued up
        // during the outage resumes now (the call gates on aliveness).
        self.try_form_batch(task);
    }

    /// A scheduled node/camera transition instant: diff aliveness
    /// against the last tick, emit each flip exactly once, and apply
    /// the consequences (orphan drains and redirects on crash, resumed
    /// batch formation on revival, spotlight refresh over dark
    /// cameras).
    fn on_fault_tick(&mut self) {
        for node in 0..self.node_was_up.len() {
            let up = self.faults.node_alive(node, self.now);
            if up == self.node_was_up[node] {
                continue;
            }
            self.node_was_up[node] = up;
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::NodeFault { node: node as u32, up },
                );
            }
            if up {
                self.metrics.node_restart();
                // Revival: whatever queued up during the outage resumes
                // batch formation immediately.
                for task in 0..self.tasks.len() {
                    if self.tasks[task].node == node
                        && !self.tasks[task].busy
                    {
                        self.try_form_batch(task);
                    }
                }
            } else {
                self.metrics.fault_injected();
                self.on_node_down(node);
            }
        }
        let down =
            self.node_was_up.iter().filter(|&&u| !u).count();
        self.metrics.set_nodes_down(down);
        for cam in 0..self.cfg.num_cameras {
            let up = self.faults.camera_alive(cam, self.now);
            if up == self.cam_was_up[cam] {
                continue;
            }
            self.cam_was_up[cam] = up;
            if !up {
                self.metrics.fault_injected();
            }
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::CameraFault { camera: cam as u32, up },
                );
            }
        }
        // Spotlight degradation reacts at the transition instant, not
        // the next periodic TL tick.
        self.apply_active_set();
    }

    /// Crash consequences for every executor on `node`. A task that
    /// will revive keeps its queue in place (formation resumes at the
    /// revival tick); a *permanently* dead task's queue is orphaned —
    /// re-dispatched to a surviving same-stage peer when recovery is
    /// on, written off as `lost_to_fault` otherwise. In-flight batches
    /// are voided separately when their completion pops
    /// ([`FaultModel::node_down_during`]).
    fn on_node_down(&mut self, node: usize) {
        let permanent =
            self.faults.node_revives_at(node, self.now).is_none();
        if !permanent {
            return;
        }
        for task in 0..self.tasks.len() {
            if self.tasks[task].node != node
                || !matches!(
                    self.tasks[task].stage,
                    Stage::Va | Stage::Cr
                )
            {
                continue;
            }
            let stage = self.tasks[task].stage;
            let target = self.pick_survivor(task, stage);
            let recover = self.cfg.service.recovery.enabled;
            if recover {
                if let Some(to) = target {
                    self.task_redirect[task] = to;
                    // Repair chains: traffic already redirected at the
                    // dead task follows it to the survivor.
                    for r in self.task_redirect.iter_mut() {
                        if *r == task {
                            *r = to;
                        }
                    }
                }
            }
            let mut orphans = std::mem::take(&mut self.kept_scratch);
            orphans.clear();
            self.tasks[task].batcher.drain_into(&mut orphans);
            match (recover, target) {
                (true, Some(to)) if !orphans.is_empty() => {
                    self.metrics.redispatched(orphans.len() as u64);
                    if self.obs.enabled() {
                        self.obs.emit(
                            self.now,
                            &TraceEvent::Redispatch {
                                stage,
                                from_task: task as u32,
                                to_task: to as u32,
                                events: orphans.len() as u32,
                            },
                        );
                    }
                    // The coordinator re-dispatches from its own copy
                    // (the dead node cannot send): one control-message
                    // latency, arrival order preserved.
                    let lat = self.net.transfer_estimate(
                        self.net.meta_bytes,
                        self.now,
                    );
                    for qe in orphans.drain(..) {
                        self.push(
                            self.now + lat,
                            Ev::Arrive {
                                task: to,
                                ev: qe.item,
                                batch: None,
                            },
                        );
                    }
                }
                _ => {
                    for qe in orphans.drain(..) {
                        self.lose_event(qe.id, stage);
                    }
                }
            }
            self.kept_scratch = orphans;
        }
    }

    /// Surviving executor of `stage` to adopt `task`'s orphans.
    /// Shard-aware: same-shard instances first, then instances on a
    /// shard *adjacent* to the dead task's (sharing a boundary edge —
    /// the geographic migration targets), then any survivor; ties
    /// break by task id. At K=1 every candidate sits on shard 0, so
    /// this reduces to the previous first-alive rule (bit-identity
    /// with the unsharded engine). Re-dispatched orphans are priced
    /// by the adopting executor's own per-stage ξ model — per
    /// (stage, app) in the multi-query engine — like any batch it
    /// forms.
    fn pick_survivor(&self, task: usize, stage: Stage) -> Option<usize> {
        let home = self.shard_of_task[task];
        (0..self.tasks.len())
            .filter(|&t| {
                t != task
                    && self.tasks[t].stage == stage
                    && self
                        .faults
                        .node_alive(self.tasks[t].node, self.now)
            })
            .min_by_key(|&t| {
                let s = self.shard_of_task[t];
                let ring = if s == home {
                    0
                } else if self.part.adjacent(home, s) {
                    1
                } else {
                    2
                };
                (ring, t)
            })
    }

    // ---- sink (UV) ---------------------------------------------------------

    fn on_sink_arrive(&mut self, ev: Event, batch: Option<(u64, usize)>) {
        // κn = κ1: sink latency is skew-free.
        let latency = self.now - ev.header.src_arrival;
        let gamma = self.cfg.gamma();

        if ev.header.probe {
            // Probe reached the sink: if within γ, re-open budgets.
            if latency <= gamma {
                self.send_accepts(
                    &ev,
                    gamma - latency,
                    ev.header.sum_exec.max(1),
                );
            }
            return;
        }

        let detected = matches!(
            ev.payload,
            Payload::Detection { detected: true, .. }
        );
        if detected && ev.payload.entity_present() == Some(true) {
            self.detections += 1;
            self.metrics.detection();
        }
        if detected && self.qf.on_detection(&ev) {
            // QF user-logic refined the query embedding: close the
            // feedback loop (§2.2, Fig. 2) by routing the fused
            // embedding back to every VA/CR executor.
            self.fusion_updates += 1;
            self.route_refinement(ev.header.id, ev.header.camera);
        }
        self.ledger
            .completed(ev.header.id, latency, gamma, detected);
        self.timeline.completed(self.now, latency);
        self.metrics.completed(latency <= gamma);
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::Completed {
                    event: ev.header.id,
                    query: SINGLE_QUERY,
                    latency_us: latency,
                    on_time: latency <= gamma,
                    detected,
                },
            );
        }

        // Adaptation plane: the sink is where deadline slack is
        // observable, so the controller watches completions here and
        // mints quality commands onto the feedback edge.
        if self.adapt_on {
            if let Some(cmd) = self.adapt_ctl.on_completion(
                ev.header.camera,
                latency,
                self.now,
            ) {
                self.metrics.adapt_minted();
                self.route_adaptation(
                    cmd,
                    ev.header.id,
                    ev.header.camera,
                );
            }
        }

        // Accept logic (§4.5.2): track the slowest event per CR batch;
        // when the batch completes, grow budgets if even the slowest
        // arrived eps_max early.
        if let Some((seq, size)) = batch {
            let entry = self
                .sink_batches
                .entry(seq)
                .or_insert((size, -1, 0, 0));
            if latency > entry.1 {
                entry.1 = latency;
                entry.2 = ev.header.id;
                entry.3 = ev.header.sum_exec.max(1);
            }
            entry.0 -= 1;
            if entry.0 == 0 {
                let (_, slowest_lat, slowest_id, sum_exec) =
                    self.sink_batches.remove(&seq).unwrap();
                let eps = gamma - slowest_lat;
                if eps > millis(self.cfg.eps_max_ms) {
                    let mut probe_ev = ev;
                    probe_ev.header.id = slowest_id;
                    self.send_accepts(&probe_ev, eps, sum_exec);
                }
            }
        }
    }

    /// Route the QF block's current embedding upstream as a
    /// seq-stamped [`Payload::QueryUpdate`], one copy per VA/CR
    /// executor, each after a control-message network delay. Arrival
    /// order is deterministic (task index, then the event core's
    /// global sequence numbers), so seeded runs stay bit-reproducible.
    fn route_refinement(&mut self, trigger: u64, camera: usize) {
        let Some(emb) = self.qf.embedding() else {
            return; // counting-only QF blocks refine nothing routable
        };
        let refinement = self
            .router
            .refine(SINGLE_QUERY, Arc::new(emb.to_vec()));
        self.metrics.refinement();
        if self.obs.enabled() {
            self.obs.emit(
                self.now,
                &TraceEvent::RefinementApplied {
                    query: SINGLE_QUERY,
                    seq: refinement.seq,
                },
            );
        }
        let lat = self
            .net
            .transfer_estimate(self.net.meta_bytes, self.now);
        for task in 0..self.tasks.len() {
            if !matches!(self.tasks[task].stage, Stage::Va | Stage::Cr)
            {
                continue;
            }
            self.push(
                self.now + lat,
                Ev::Arrive {
                    task,
                    ev: refinement.into_event(trigger, camera, self.now),
                    batch: None,
                },
            );
        }
    }

    /// Broadcast an adaptation command upstream on the feedback edge —
    /// one [`Payload::Adaptation`] copy per VA/CR executor, mirroring
    /// [`Self::route_refinement`]. The first copy to arrive applies at
    /// [`Self::apply_adaptation`]; the rest discard as stale (which
    /// exercises the stale counter on every real command).
    fn route_adaptation(
        &mut self,
        cmd: AdaptationCommand,
        trigger: u64,
        camera: usize,
    ) {
        let env = FeedbackEnvelope::Adaptation(cmd);
        let lat = self
            .net
            .transfer_estimate(self.net.meta_bytes, self.now);
        for task in 0..self.tasks.len() {
            if !matches!(self.tasks[task].stage, Stage::Va | Stage::Cr)
            {
                continue;
            }
            self.push(
                self.now + lat,
                Ev::Arrive {
                    task,
                    ev: env.into_event(trigger, camera, self.now),
                    batch: None,
                },
            );
        }
    }

    /// The engine's single application point for adaptation commands —
    /// the only call site of [`AdaptationState::apply`] in this file.
    fn apply_adaptation(&mut self, cmd: AdaptationCommand) {
        if self.adapt.apply(&cmd) {
            self.metrics.adapt_applied();
            self.metrics
                .set_cameras_downshifted(self.adapt.downshifted());
            if self.obs.enabled() {
                self.obs.emit(
                    self.now,
                    &TraceEvent::Adaptation {
                        camera: cmd.camera as u32,
                        seq: cmd.seq,
                        level: cmd.level as u32,
                        variant: cmd.variant.profile().artifact,
                    },
                );
            }
        } else {
            self.metrics.adapt_stale();
        }
    }

    /// App-nominal analytics variant for an executor stage.
    fn nominal_of(&self, stage: Stage) -> ModelVariant {
        match stage {
            Stage::Cr => self.stage_nominal[1],
            _ => self.stage_nominal[0],
        }
    }

    /// Effective batch size under the adaptation plane: Σ of per-event
    /// relative costs. At the identity state every term is exactly
    /// `1.0`, so the sum is exactly `b`.
    fn batch_rel(
        &self,
        task: usize,
        batch: &[QueuedEvent<Event>],
    ) -> f64 {
        let nominal = self.nominal_of(self.tasks[task].stage);
        batch
            .iter()
            .map(|qe| {
                self.adapt.rel(qe.item.header.camera, nominal)
            })
            .sum()
    }

    /// ξ estimate for a prospective batch: the exact integer path when
    /// the adaptation plane is inert, the effective-size path
    /// otherwise (bit-identical at the identity ladder, by the
    /// whole-size ξ_eff property).
    fn batch_xi(
        &self,
        task: usize,
        batch: &[QueuedEvent<Event>],
    ) -> Micros {
        if !self.adapt_on {
            return self.tasks[task].xi.xi(batch.len());
        }
        self.tasks[task].xi.xi_eff(self.batch_rel(task, batch))
    }

    fn send_accepts(&mut self, ev: &Event, eps: Micros, sum_exec: Micros) {
        let cam = ev.header.camera;
        let sig = Signal::Accept {
            event: ev.header.id,
            eps,
            sum_exec,
        };
        let path = self.topo.path(cam);
        for &up in path.iter().take(3) {
            // FC, VA, CR
            if self.topo.stage_of(up) == Stage::Fc {
                self.fc_budget[cam].apply(sig, &self.fc_xi);
            } else {
                let lat = self
                    .net
                    .transfer_estimate(self.net.meta_bytes, self.now);
                self.push(self.now + lat, Ev::SignalAt { task: up, sig });
            }
        }
    }

    // ---- TL ------------------------------------------------------------------

    fn on_tl_tick(&mut self) {
        if self.now < self.cfg.duration() {
            self.push(self.now + SEC, Ev::TlTick);
        }
        self.apply_active_set();
        if self.cfg.obs.per_second_metrics {
            self.metrics.mark_second(self.now / SEC);
        }
    }

    fn apply_active_set(&mut self) {
        // Spotlight expansion reuses the TL's epoch-stamped workspace;
        // the active/wanted buffers are engine scratch — the per-tick
        // allocations this used to make are gone.
        let mut active = std::mem::take(&mut self.active_scratch);
        let sp = span_begin(&self.obs);
        self.tl.active_set_into(&self.graph, self.now, &mut active);
        span_end(&self.obs, Scope::SpotlightExpand, sp);
        // Graceful degradation: a dark camera inside the spotlight can
        // let the entity slip past unseen. With recovery on, TL widens
        // its horizon — re-expanding as if the entity had been
        // unobserved for longer — so surviving neighbours cover the
        // hole. (Dark cameras stay activated but produce nothing.)
        if !self.faults.is_static()
            && self.cfg.service.recovery.enabled
            && active
                .iter()
                .any(|&c| !self.faults.camera_alive(c, self.now))
        {
            self.tl.active_set_into(
                &self.graph,
                self.now + FAULT_WIDEN,
                &mut active,
            );
        }
        self.peak_active = self.peak_active.max(active.len());
        self.timeline.sample_active(self.now, active.len());
        self.metrics.set_active_cameras(active.len());
        if self.obs.enabled() && active.len() != self.last_spotlight {
            self.last_spotlight = active.len();
            self.obs.emit(
                self.now,
                &TraceEvent::Spotlight {
                    query: SINGLE_QUERY,
                    active: active.len() as u32,
                },
            );
        }
        let mut want = std::mem::take(&mut self.want_scratch);
        want.clear();
        want.resize(self.cfg.num_cameras, false);
        for &cam in &active {
            want[cam] = true;
        }
        for cam in 0..self.cfg.num_cameras {
            if want[cam] != self.fc_active[cam] {
                // Control command travels to the edge device.
                let lat = self
                    .net
                    .transfer_estimate(self.net.meta_bytes, self.now);
                self.push(
                    self.now + lat,
                    Ev::Control {
                        cam,
                        active: want[cam],
                    },
                );
            }
        }
        self.want_scratch = want;
        self.active_scratch = active;
    }
}

/// Convenience: run a config end to end with the stock application it
/// describes.
pub fn run(cfg: ExperimentConfig) -> RunResult {
    DesEngine::new(cfg).run()
}

/// Run a user-composed application end to end — the public §2.2 entry
/// point: `cfg` keeps the platform knobs, `app` supplies the blocks.
pub fn run_app(cfg: ExperimentConfig, app: &AppDefinition) -> RunResult {
    DesEngine::with_app(cfg, app).run()
}

/// Run the stock application with an explicit trace sink (flight
/// recorder / JSONL export). Pass a clone of the sink and keep the
/// original: `run` consumes the engine, so readback goes through your
/// retained handle.
pub fn run_with_sink<S: ObsSink>(
    cfg: ExperimentConfig,
    sink: S,
) -> RunResult {
    let app = crate::apps::resolve(&cfg);
    DesEngine::with_app_sink(cfg, &app, sink).run()
}

/// Multi-query experiment mode: N tracking queries arriving as a
/// Poisson process (per `cfg.multi_query`), multiplexed over the shared
/// VA/CR deployment with admission control and fair-share batching.
/// See [`crate::service::engine`] for the engine itself.
pub fn run_multi(
    cfg: ExperimentConfig,
) -> crate::service::MultiQueryResult {
    let mq = cfg.multi_query.clone();
    crate::service::engine::run(cfg, mq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TlKind;

    fn small_cfg() -> ExperimentConfig {
        let mut c = ExperimentConfig::default();
        c.num_cameras = 60;
        c.workload.vertices = 60;
        c.workload.edges = 160;
        c.duration_secs = 60.0;
        c
    }

    #[test]
    fn smoke_run_conserves_events() {
        let mut c = small_cfg();
        c.batching = BatchingKind::Static { size: 1 };
        let r = run(c);
        // The spotlight contracts to ~1 camera once the entity is
        // acquired, so far fewer frames enter the dataflow than the
        // all-active 3600 (60 cams x 60 s).
        assert!(r.summary.generated > 50, "{}", r.summary.generated);
        assert!(
            r.summary.generated < 3600,
            "spotlight never contracted: {}",
            r.summary.generated
        );
        assert!(r.summary.conserved());
        assert!(r.summary.on_time > 0);
    }

    #[test]
    fn streaming_small_network_is_on_time() {
        let mut c = small_cfg();
        c.batching = BatchingKind::Static { size: 1 };
        let r = run(c);
        // 60 cams / 10 CR instances @ 1 fps ~ 6 ev/s < mu = 8.33.
        assert_eq!(r.summary.delayed, 0, "{:?}", r.summary);
        assert_eq!(r.summary.dropped, 0);
    }

    #[test]
    fn dynamic_batching_no_delays() {
        let mut c = small_cfg();
        c.batching = BatchingKind::Dynamic { max: 25 };
        let r = run(c);
        assert!(r.summary.conserved());
        assert_eq!(r.summary.delayed, 0, "{:?}", r.summary);
    }

    #[test]
    fn tracking_detects_entity() {
        let mut c = small_cfg();
        c.batching = BatchingKind::Dynamic { max: 25 };
        let r = run(c);
        assert!(r.detections > 0, "entity never detected");
        assert!(r.summary.true_positives > 0);
    }

    #[test]
    fn spotlight_contracts_below_full_network() {
        let mut c = small_cfg();
        c.batching = BatchingKind::Dynamic { max: 25 };
        let r = run(c);
        let rows = r.timeline.rows();
        // After bootstrap the TL should have contracted the active set
        // well below the full 60 cameras at least part of the time.
        let min_active = rows
            .iter()
            .skip(5)
            .map(|r| r.active_cameras)
            .filter(|&a| a > 0)
            .min()
            .unwrap_or(usize::MAX);
        assert!(min_active < 20, "min active = {min_active}");
    }

    #[test]
    fn deterministic_runs() {
        let a = run(small_cfg());
        let b = run(small_cfg());
        assert_eq!(a.summary.generated, b.summary.generated);
        assert_eq!(a.summary.on_time, b.summary.on_time);
        assert_eq!(a.summary.dropped, b.summary.dropped);
        assert_eq!(a.detections, b.detections);
    }

    #[test]
    fn metrics_registry_agrees_with_ledger() {
        let mut c = small_cfg();
        c.cluster.cr_instances = 2;
        c.tl = TlKind::Base;
        c.batching = BatchingKind::Dynamic { max: 25 };
        c.drops_enabled = true;
        let r = run(c);
        let m = &r.metrics;
        assert_eq!(m.generated, r.summary.generated);
        assert_eq!(m.on_time, r.summary.on_time);
        assert_eq!(m.delayed, r.summary.delayed);
        assert_eq!(m.dropped_total(), r.summary.dropped);
        assert_eq!(m.detections, r.detections);
        assert!(m.batches[0] > 0, "no VA batches recorded");
        assert!(m.batch_hist[0].total() == m.batches[0]);
        assert!(r.rng_draws > 0);
        // Per-second rows were dumped (once per TL tick) and are
        // cumulative; the knob turns them off.
        assert!(r.metrics.seconds.len() >= 59, "{}", r.metrics.seconds.len());
        assert!(r
            .metrics
            .seconds
            .windows(2)
            .all(|w| w[1].generated >= w[0].generated));
        let r2 = {
            let mut c = small_cfg();
            c.obs.per_second_metrics = false;
            run(c)
        };
        assert!(r2.metrics.seconds.is_empty());
    }

    #[test]
    fn node_crash_ab_recovery_conserves_and_helps() {
        use crate::config::{FaultEvent, FaultKind};
        let mk = |enabled: bool| {
            let mut c = small_cfg();
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.tl = TlKind::Base; // steady full-network load
            c.service.fault_events = vec![FaultEvent {
                at_sec: 20.0,
                kind: FaultKind::NodeCrash { node: 1, down_secs: None },
            }];
            c.service.recovery.enabled = enabled;
            c
        };
        let on = run(mk(true));
        let off = run(mk(false));
        assert!(on.summary.conserved(), "{:?}", on.summary);
        assert!(off.summary.conserved(), "{:?}", off.summary);
        // Without recovery, the in-flight batch on the dying node (and
        // its orphaned queue) is written off.
        assert!(off.summary.lost_to_fault > 0, "{:?}", off.summary);
        assert_eq!(
            off.metrics.lost_to_fault, off.summary.lost_to_fault,
            "registry and ledger disagree on fault losses"
        );
        assert!(off.metrics.faults_injected > 0);
        // Recovery re-dispatches orphans to surviving peers, so it
        // never completes fewer events in time at the same seed.
        assert!(
            on.summary.on_time >= off.summary.on_time,
            "recovery on {} < off {}",
            on.summary.on_time,
            off.summary.on_time
        );
        assert_eq!(
            on.summary.generated, off.summary.generated,
            "fault handling must not change the offered load"
        );
    }

    #[test]
    fn camera_outage_stops_generation_deterministically() {
        use crate::config::{FaultEvent, FaultKind};
        let mk = || {
            let mut c = small_cfg();
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.tl = TlKind::Base;
            c.service.fault_events = (0..30)
                .map(|cam| FaultEvent {
                    at_sec: 10.0,
                    kind: FaultKind::CameraOutage {
                        camera: cam,
                        down_secs: Some(20.0),
                    },
                })
                .collect();
            c
        };
        let base = {
            let mut c = mk();
            c.service.fault_events.clear();
            run(c)
        };
        let a = run(mk());
        let b = run(mk());
        // Dark cameras generate nothing, so the offered load shrinks;
        // nothing is "lost" because the frames never existed.
        assert!(a.summary.generated < base.summary.generated);
        assert_eq!(a.summary.lost_to_fault, 0, "{:?}", a.summary);
        assert!(a.summary.conserved());
        // Same schedule + seed => bit-identical fault runs.
        assert_eq!(a.summary.generated, b.summary.generated);
        assert_eq!(a.summary.on_time, b.summary.on_time);
        assert_eq!(a.rng_draws, b.rng_draws);
        assert_eq!(a.detections, b.detections);
    }

    #[test]
    fn sharding_is_result_neutral() {
        // The determinism contract at engine level: any (K, threads)
        // geometry produces bit-identical results for the same seed.
        // The property suite (rust/tests/prop_shard.rs) explores the
        // full plan space; this is the cheap in-tree sentinel.
        let mk = |shards: usize, threads: usize| {
            let mut c = small_cfg();
            c.tl = TlKind::Base;
            c.batching = BatchingKind::Dynamic { max: 25 };
            c.drops_enabled = true;
            c.sharding.shards = shards;
            c.sharding.threads = threads;
            c
        };
        let k1 = run(mk(1, 0));
        let k3 = run(mk(3, 0));
        let k3t = run(mk(3, 3));
        for r in [&k3, &k3t] {
            assert_eq!(r.summary.generated, k1.summary.generated);
            assert_eq!(r.summary.on_time, k1.summary.on_time);
            assert_eq!(r.summary.delayed, k1.summary.delayed);
            assert_eq!(r.summary.dropped, k1.summary.dropped);
            assert_eq!(r.detections, k1.detections);
            assert_eq!(r.core_events, k1.core_events);
            assert_eq!(r.rng_draws, k1.rng_draws);
        }
        // K=1 issues no envelopes; K=3 moves real traffic across
        // boundaries (VA/CR hops round-robin over shards).
        assert_eq!(k1.metrics.cross_shard_msgs, 0);
        assert_eq!(k1.metrics.shards, 1);
        assert!(k3.metrics.cross_shard_msgs > 0);
        assert_eq!(k3.metrics.shards, 3);
        assert_eq!(
            k3.metrics.cross_shard_msgs,
            k3t.metrics.cross_shard_msgs
        );
    }

    #[test]
    fn overload_without_drops_delays_events() {
        // Few CR instances + slow CR => saturation at 60 cams.
        let mut c = small_cfg();
        c.cluster.cr_instances = 2;
        c.tl = TlKind::Base; // keep everything active
        c.batching = BatchingKind::Static { size: 1 };
        let r = run(c);
        // 60 cams over 2 CRs = 30 ev/s vs capacity 8.33/s: meltdown.
        assert!(
            r.summary.delayed > r.summary.on_time / 4,
            "{:?}",
            r.summary
        );
    }

    #[test]
    fn drops_bound_latency_under_overload() {
        let mut c = small_cfg();
        c.cluster.cr_instances = 2;
        c.tl = TlKind::Base;
        c.batching = BatchingKind::Dynamic { max: 25 };
        c.drops_enabled = true;
        let r = run(c);
        assert!(r.summary.dropped > 0, "{:?}", r.summary);
        // Drops keep the surviving events mostly within gamma.
        let delayed_frac = r.summary.delay_rate();
        assert!(delayed_frac < 0.10, "delay rate {delayed_frac}");
        assert!(r.summary.conserved());
    }
}
