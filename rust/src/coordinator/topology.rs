//! Deployment topology — the Master's Scheduler output (§3).
//!
//! Mirrors the paper's setup: one head node plus N compute nodes. FC
//! instances (one per camera) are placed round-robin across compute
//! nodes; VA and CR instances round-robin as well, co-locating a subset
//! of FC/VA/CR per server to cut network transfers; TL and UV run on the
//! head node. The default scheduler is round-robin with a fixed instance
//! count per module type, exactly as in the paper.

use crate::config::ExperimentConfig;
use crate::dataflow::{Partitioner, Stage};

/// One deployed module instance (task).
#[derive(Debug, Clone)]
pub struct TaskInfo {
    pub stage: Stage,
    /// Instance index within its stage.
    pub instance: usize,
    /// Hosting node (0..compute_nodes are compute, `compute_nodes` is
    /// the head node).
    pub node: usize,
}

/// The deployed dataflow: task table plus routing.
#[derive(Debug, Clone)]
pub struct Topology {
    pub tasks: Vec<TaskInfo>,
    /// task index of the first FC (then one per camera).
    fc0: usize,
    va0: usize,
    cr0: usize,
    pub tl: usize,
    pub uv: usize,
    pub num_cameras: usize,
    pub va_part: Partitioner,
    pub cr_part: Partitioner,
    pub head_node: usize,
    pub nodes: usize,
}

impl Topology {
    /// Run the round-robin scheduler for a config.
    pub fn schedule(cfg: &ExperimentConfig) -> Self {
        let compute = cfg.cluster.compute_nodes;
        let head = compute;
        let mut tasks = Vec::new();

        let fc0 = tasks.len();
        for cam in 0..cfg.num_cameras {
            tasks.push(TaskInfo {
                stage: Stage::Fc,
                instance: cam,
                node: cam % compute,
            });
        }
        let va0 = tasks.len();
        for i in 0..cfg.cluster.va_instances {
            tasks.push(TaskInfo {
                stage: Stage::Va,
                instance: i,
                node: i % compute,
            });
        }
        let cr0 = tasks.len();
        for i in 0..cfg.cluster.cr_instances {
            tasks.push(TaskInfo {
                stage: Stage::Cr,
                instance: i,
                node: i % compute,
            });
        }
        let tl = tasks.len();
        tasks.push(TaskInfo {
            stage: Stage::Tl,
            instance: 0,
            node: head,
        });
        let uv = tasks.len();
        tasks.push(TaskInfo {
            stage: Stage::Uv,
            instance: 0,
            node: head,
        });

        Self {
            tasks,
            fc0,
            va0,
            cr0,
            tl,
            uv,
            num_cameras: cfg.num_cameras,
            va_part: Partitioner::new(cfg.cluster.va_instances),
            cr_part: Partitioner::new(cfg.cluster.cr_instances),
            head_node: head,
            nodes: compute + 1,
        }
    }

    pub fn fc_task(&self, cam: usize) -> usize {
        debug_assert!(cam < self.num_cameras);
        self.fc0 + cam
    }

    /// The VA instance serving a camera (key-partitioned).
    pub fn va_task(&self, cam: usize) -> usize {
        self.va0 + self.va_part.route(cam)
    }

    /// The CR instance serving a camera.
    pub fn cr_task(&self, cam: usize) -> usize {
        self.cr0 + self.cr_part.route(cam)
    }

    /// The full latency-pipeline path of a camera's events.
    pub fn path(&self, cam: usize) -> [usize; 4] {
        [
            self.fc_task(cam),
            self.va_task(cam),
            self.cr_task(cam),
            self.uv,
        ]
    }

    pub fn node_of(&self, task: usize) -> usize {
        self.tasks[task].node
    }

    pub fn stage_of(&self, task: usize) -> Stage {
        self.tasks[task].stage
    }

    /// Number of downstream instances a task partitions over (for
    /// per-downstream budgets, §4.3.4).
    pub fn downstream_count(&self, task: usize) -> usize {
        match self.tasks[task].stage {
            Stage::Fc => self.va_part.instances(),
            Stage::Va => self.cr_part.instances(),
            Stage::Cr => 1, // UV
            _ => 1,
        }
    }

    /// Downstream slot index an event from `cam` takes at `task` —
    /// indexes that task's per-downstream budget table.
    pub fn downstream_slot(&self, task: usize, cam: usize) -> usize {
        match self.tasks[task].stage {
            Stage::Fc => self.va_part.route(cam),
            Stage::Va => self.cr_part.route(cam),
            _ => 0,
        }
    }

    pub fn va_tasks(&self) -> std::ops::Range<usize> {
        self.va0..self.cr0
    }

    pub fn cr_tasks(&self) -> std::ops::Range<usize> {
        self.cr0..self.tl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        let mut cfg = ExperimentConfig::default();
        cfg.num_cameras = 100;
        Topology::schedule(&cfg)
    }

    #[test]
    fn paper_instance_counts() {
        let t = topo();
        // 100 FC + 10 VA + 10 CR + TL + UV
        assert_eq!(t.tasks.len(), 100 + 10 + 10 + 2);
        assert_eq!(t.va_tasks().len(), 10);
        assert_eq!(t.cr_tasks().len(), 10);
    }

    #[test]
    fn fc_round_robin_over_compute_nodes() {
        let t = topo();
        assert_eq!(t.node_of(t.fc_task(0)), 0);
        assert_eq!(t.node_of(t.fc_task(1)), 1);
        assert_eq!(t.node_of(t.fc_task(10)), 0);
        // No FC on the head node.
        for cam in 0..100 {
            assert_ne!(t.node_of(t.fc_task(cam)), t.head_node);
        }
    }

    #[test]
    fn tl_uv_on_head() {
        let t = topo();
        assert_eq!(t.node_of(t.tl), t.head_node);
        assert_eq!(t.node_of(t.uv), t.head_node);
    }

    #[test]
    fn path_follows_partitioning() {
        let t = topo();
        for cam in 0..100 {
            let p = t.path(cam);
            assert_eq!(t.stage_of(p[0]), Stage::Fc);
            assert_eq!(t.stage_of(p[1]), Stage::Va);
            assert_eq!(t.stage_of(p[2]), Stage::Cr);
            assert_eq!(t.stage_of(p[3]), Stage::Uv);
            // Stable.
            assert_eq!(p, t.path(cam));
        }
    }

    #[test]
    fn downstream_slots_match_routing() {
        let t = topo();
        for cam in 0..100 {
            let fc = t.fc_task(cam);
            let slot = t.downstream_slot(fc, cam);
            assert_eq!(t.va0_task_check(slot), t.va_task(cam));
        }
    }

    impl Topology {
        fn va0_task_check(&self, slot: usize) -> usize {
            self.va0 + slot
        }
    }
}
