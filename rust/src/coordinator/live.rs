//! Live engine: wall-clock, thread-based serving with real PJRT model
//! execution — Python is nowhere on this path.
//!
//! Workers are OS threads connected by std `mpsc` channels (the
//! in-process stand-in for the paper's ZeroMQ/SysV transport; an async
//! transport is a planned follow-up — this is **not** a tokio engine,
//! despite what earlier crate docs said): camera feeds → VA workers →
//! CR workers → UV sink, with TL consuming CR detections and flipping
//! per-camera active flags. VA/CR workers run the *same* [`Batcher`],
//! drop-point and [`BudgetManager`] logic as the DES engine, but against
//! the real clock and the real AOT-compiled models from
//! [`crate::runtime::ModelPool`].
//!
//! This engine serves exactly one query. The runtime multi-query
//! service front — shared workers, admission control, submit/cancel
//! while serving — is [`crate::service::TrackingService`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::apps::AppDefinition;
use crate::config::{BatchingKind, ExperimentConfig, RecoveryConfig};
use crate::dataflow::{
    AnalyticsBlock, Event, FeedbackEnvelope, FeedbackRouter,
    FeedbackState, FilterControl, Header, Partitioner, Payload,
    QueryFusion, ScoreParams, Stage, TlEnv, TrackingLogic,
    SINGLE_QUERY,
};
use crate::metrics::{Ledger, Summary};
use crate::obs::{
    span_begin, span_end, Gate, MetricsRegistry, MetricsSnapshot,
    NullSink, ObsSink, Scope, TraceEvent,
};
use crate::roadnet::{generate, place_cameras};
use crate::runtime::{ModelOutput, ModelPool};
use crate::sim::{
    backoff_delay, identity_image, EntityWalk, GroundTruth,
    IdentityGallery,
};
use crate::tuning::adapt::{AdaptController, AdaptationState};
use crate::tuning::budget::BUDGET_INF;
use crate::tuning::{
    drop_at_exec, drop_at_queue, Batcher, BatcherPoll, BudgetManager,
    EventRecord, NobTable, QueuedEvent, Signal, XiModel, NOB_MAX_RATE,
    NOB_RATE_STEP, ONLINE_XI_EMA,
};
use crate::util::{Micros, SEC};

/// A request to the model-service thread. The reply returns the image
/// buffer alongside the output so callers can reuse it (one gather
/// buffer round-trips per worker instead of reallocating
/// `batch × IMG_DIM` floats per execution). Each request carries the
/// caller's *current* query embedding — workers swap it when a QF
/// refinement reaches them (the feedback edge), so scoring follows the
/// refined target without restarting the service.
struct ModelReq {
    variant: String,
    images: Vec<f32>,
    query: Arc<Vec<f32>>,
    reply: Sender<(Result<ModelOutput>, Vec<f32>)>,
}

/// The PJRT client is not `Send` (it holds `Rc` internals), so one
/// dedicated thread owns the [`ModelPool`] and serves execution
/// requests over a channel — the in-process analogue of the paper's
/// local gRPC model service that VA/CR call into (§3).
#[derive(Clone)]
pub struct ModelService {
    tx: Sender<ModelReq>,
    query: Arc<Vec<f32>>,
    img_dim: usize,
}

/// Data produced while initializing the model-service thread.
pub struct ModelServiceInit {
    pub va_xi: XiModel,
    pub cr_xi: XiModel,
}

impl ModelService {
    /// Spawn the service thread. The PJRT pool is **loaded inside the
    /// thread** (the client is not `Send`); the thread bootstraps the
    /// query embedding from the entity's query image and calibrates
    /// ξ(b) for both variants before serving.
    pub fn spawn(
        artifacts_dir: std::path::PathBuf,
        va_variant: &str,
        cr_variant: &str,
        extra_variants: &[String],
        buckets: Vec<usize>,
    ) -> Result<(Self, ModelServiceInit)> {
        let (tx, rx) = mpsc::channel::<ModelReq>();
        let (init_tx, init_rx) = mpsc::channel();
        let va_v = va_variant.to_string();
        let cr_v = cr_variant.to_string();
        let extra: Vec<String> = extra_variants.to_vec();
        std::thread::spawn(move || {
            let setup = || -> Result<(ModelPool, Vec<f32>, XiModel, XiModel)> {
                // Nominal variants plus any adaptation downshift
                // targets — loaded up front so a runtime command never
                // hits a missing-artifact lookup mid-serve.
                let mut variants: Vec<&str> = vec![&va_v, &cr_v];
                variants.extend(extra.iter().map(|s| s.as_str()));
                let mut seen: Vec<&str> = Vec::new();
                variants.retain(|v| {
                    if seen.contains(v) {
                        false
                    } else {
                        seen.push(v);
                        true
                    }
                });
                let pool = ModelPool::load(
                    &artifacts_dir,
                    &variants,
                    Some(&buckets),
                )?;
                let qimg = identity_image(ENTITY_IDENTITY, 0, 0.25);
                let query = pool.embed_query(&cr_v, &qimg)?;
                let (va_xi, _) = pool.calibrate_xi(&va_v, 2)?;
                let (cr_xi, _) = pool.calibrate_xi(&cr_v, 2)?;
                Ok((pool, query, va_xi, cr_xi))
            };
            match setup() {
                Ok((pool, query, va_xi, cr_xi)) => {
                    let _ = init_tx.send(Ok((query, va_xi, cr_xi)));
                    for req in rx {
                        // Score against the embedding the caller holds
                        // *now* (possibly QF-refined), not the
                        // bootstrap one.
                        let out = pool.execute(
                            &req.variant,
                            &req.images,
                            &req.query,
                        );
                        let _ = req.reply.send((out, req.images));
                    }
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                }
            }
        });
        let (query, va_xi, cr_xi) = init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("model service died"))??;
        let img_dim = crate::sim::IMG_DIM;
        Ok((
            Self {
                tx,
                query: Arc::new(query),
                img_dim,
            },
            ModelServiceInit { va_xi, cr_xi },
        ))
    }

    /// Execute against `query` (the caller's current — possibly
    /// QF-refined — embedding).
    pub fn execute(
        &self,
        variant: &str,
        images: Vec<f32>,
        query: Arc<Vec<f32>>,
    ) -> Result<ModelOutput> {
        self.execute_reusing(variant, images, query).0
    }

    /// Execute and hand the (emptied-of-purpose) image buffer back so
    /// the caller can refill it for the next batch.
    pub fn execute_reusing(
        &self,
        variant: &str,
        images: Vec<f32>,
        query: Arc<Vec<f32>>,
    ) -> (Result<ModelOutput>, Vec<f32>) {
        let (reply, rx) = mpsc::channel();
        if self
            .tx
            .send(ModelReq {
                variant: variant.to_string(),
                images,
                query,
                reply,
            })
            .is_err()
        {
            return (
                Err(anyhow::anyhow!("model service down")),
                Vec::new(),
            );
        }
        match rx.recv() {
            Ok((out, buf)) => (out, buf),
            Err(_) => (
                Err(anyhow::anyhow!("model service down")),
                Vec::new(),
            ),
        }
    }

    pub fn img_dim(&self) -> usize {
        self.img_dim
    }

    /// The bootstrap query embedding (from the query image).
    pub fn query(&self) -> &[f32] {
        &self.query
    }

    /// Shared handle to the bootstrap embedding — workers start from
    /// this and swap in QF refinements as they arrive.
    pub fn query_arc(&self) -> &Arc<Vec<f32>> {
        &self.query
    }
}

/// Messages on a worker's input channel.
enum Msg {
    Ev(Event),
    Sig(Signal),
    Stop,
}

/// Adapt a QF refinement to the model's feature dimension. A
/// full-dimension embedding (a live QF model's output) replaces the
/// scoring target outright; a lower-dimensional pseudo-embedding (the
/// stock `RnnFusion` keeps an 8-float state) *nudges* the bootstrap
/// target instead — each bootstrap coordinate is shifted by a small
/// multiple of the tiled refinement signal, so the broadcast embedding
/// always satisfies `ModelPool::execute`'s dimension check while still
/// measurably (and deterministically) changing post-refinement scores.
fn fuse_embedding(bootstrap: &[f32], refined: &[f32]) -> Vec<f32> {
    if refined.is_empty() {
        // A refinement with no embedding content keeps the bootstrap
        // target (broadcast as a valid update, not silently lost).
        return bootstrap.to_vec();
    }
    if refined.len() == bootstrap.len() {
        return refined.to_vec();
    }
    const NUDGE: f32 = 0.1;
    bootstrap
        .iter()
        .enumerate()
        .map(|(i, &b)| b + NUDGE * refined[i % refined.len()])
        .collect()
}

/// Output of a live run.
#[derive(Debug)]
pub struct LiveReport {
    pub summary: Summary,
    /// Confirmed entity detections delivered to UV.
    pub detections: u64,
    /// Wall-clock duration of the run (s).
    pub wall_secs: f64,
    /// Frames processed per second of wall time.
    pub throughput: f64,
    /// Peak TL active-set size observed.
    pub peak_active: usize,
    /// Query-embedding refinements performed by the app's QF block and
    /// routed back to the VA/CR workers (0 unless the composition
    /// fuses).
    pub fusion_updates: u64,
    /// Final metrics-registry snapshot (always-on counters/gauges).
    pub metrics: MetricsSnapshot,
}

/// Identity used for the tracked entity's frames.
pub const ENTITY_IDENTITY: u64 = 42;

fn now_us(start: Instant) -> Micros {
    start.elapsed().as_micros() as Micros
}

/// Free-list capacity: bounds idle memory at
/// `POOL_CAP × IMG_DIM × 4` bytes; reclaims beyond it just drop.
const POOL_CAP: usize = 1024;

/// Free-list pool for the per-frame pixel buffers flowing
/// feed → VA → CR as [`Payload::FrameData`]. The feed loop takes
/// cleared buffers here instead of allocating `IMG_DIM` floats per
/// admitted frame; the CR worker — the pixels' last reader — hands
/// each buffer back once the app block has replaced the payload with
/// its detection verdict.
///
/// Reclaim is by [`Arc::try_unwrap`]: a frame still shared elsewhere
/// (a custom block that kept the payload alive, a tee'd consumer)
/// simply falls through and is dropped — never copied, never
/// corrupted — and the next `get` falls back to a fresh allocation.
pub struct FramePool {
    free: Mutex<Vec<Vec<f32>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl FramePool {
    pub fn new() -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Take a cleared buffer — pooled if one is parked, freshly
    /// allocated otherwise.
    pub fn get(&self) -> Vec<f32> {
        match self.free.lock().unwrap().pop() {
            Some(mut buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    /// Park a frame buffer if this `Arc` is its sole holder.
    pub fn reclaim(&self, frame: Arc<Vec<f32>>) {
        if let Ok(buf) = Arc::try_unwrap(frame) {
            let mut free = self.free.lock().unwrap();
            if free.len() < POOL_CAP {
                free.push(buf);
            }
        }
    }

    /// Buffers served from the free list (reuse count).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Buffers served by fresh allocation.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Buffers currently parked in the free list.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

impl Default for FramePool {
    fn default() -> Self {
        Self::new()
    }
}

/// A VA/CR worker: batcher + budgets + real model execution, with the
/// app's analytics block owning the score-to-payload transformation.
/// The model it runs is the *block's* typed variant
/// ([`AnalyticsBlock::variant`]) — chosen per block, not per engine —
/// and it scores against `query_emb`, which QF refinements swap at
/// runtime (the feedback edge).
struct Worker {
    stage: Stage,
    /// Executor index within the stage (trace attribution).
    task: u32,
    block: AnalyticsBlock,
    batcher: Batcher<Event>,
    budget: BudgetManager,
    xi: XiModel,
    score_threshold: f32,
    /// Current query embedding (bootstrap, then the latest applied QF
    /// refinement).
    query_emb: Arc<Vec<f32>>,
    /// Stale-update discard for incoming [`Payload::QueryUpdate`]s.
    feedback: FeedbackState,
    /// Reusable image gather buffer (batch × IMG_DIM floats).
    img_scratch: Vec<f32>,
    /// Reusable post-exec staging buffer (events between bookkeeping
    /// and the block's score transformation).
    staged: Vec<Event>,
    /// Frame `Arc`s remembered across the block call so CR can hand
    /// the pixel buffers back to [`Shared::frames`] (reused, not
    /// reallocated).
    frame_scratch: Vec<Arc<Vec<f32>>>,
}

struct Shared {
    ledger: Mutex<Ledger>,
    detections: AtomicU64,
    fusion_updates: AtomicU64,
    fc_active: Vec<AtomicBool>,
    gamma: Micros,
    drops_enabled: bool,
    /// Bounded-retry policy for model-service calls (a transient
    /// failure backs off and retries; a dead service loses the batch
    /// to `lost_to_fault` instead of panicking the worker).
    recovery: RecoveryConfig,
    start: Instant,
    /// Shared trace sink (every thread holds `Shared`, so one dyn
    /// handle serves the feed loop, the workers, TL and the UV sink).
    obs: Arc<dyn ObsSink>,
    /// Always-on counters/gauges/histograms.
    metrics: MetricsRegistry,
    /// Free-list pool for `Payload::FrameData` pixel buffers
    /// (feed loop gets, CR workers reclaim).
    frames: FramePool,
    /// Adaptation plane: the engine-global resolution/variant state.
    /// Every `Payload::Adaptation` delivery lands in the single
    /// application point inside [`handle_msg`] and nowhere else.
    adapt: Mutex<AdaptationState>,
    /// Hoisted [`AdaptController::active`] — when false, every
    /// adaptation hook on this path is a single untaken branch and the
    /// pre-adaptation expressions run unchanged.
    adapt_on: bool,
}

/// The live serving engine. Runs one [`AppDefinition`]: the app's
/// typed model variants pick the AOT artifacts, its blocks own FC
/// gating, score-to-payload transformation and the spotlight policy.
pub struct LiveEngine {
    cfg: ExperimentConfig,
    artifacts_dir: std::path::PathBuf,
    app: AppDefinition,
    obs: Arc<dyn ObsSink>,
}

impl LiveEngine {
    pub fn new(
        cfg: ExperimentConfig,
        artifacts_dir: std::path::PathBuf,
        app: AppDefinition,
    ) -> Self {
        Self {
            cfg,
            artifacts_dir,
            app,
            obs: Arc::new(NullSink),
        }
    }

    /// Attach a trace sink (the default [`NullSink`] records nothing).
    pub fn with_sink(mut self, sink: Arc<dyn ObsSink>) -> Self {
        self.obs = sink;
        self
    }

    /// Run the tracking application for `cfg.duration_secs` of wall
    /// time and report latency/throughput/accuracy.
    pub fn run(self) -> Result<LiveReport> {
        let cfg = &self.cfg;
        let graph = generate(&cfg.workload, cfg.seed);
        let cams =
            place_cameras(&graph, cfg.num_cameras, 0, cfg.workload.fov_m);
        let duration = cfg.duration();
        let walk = EntityWalk::simulate(
            &graph,
            0,
            cfg.workload.entity_speed_mps,
            duration + 30 * SEC,
            cfg.seed,
        );
        let gt = GroundTruth::compute(
            &graph,
            &cams,
            &walk,
            duration + 30 * SEC,
            200_000,
        );

        // The model-service thread loads the pool, bootstraps the
        // query embedding and calibrates xi(b) from the real
        // executables.
        let buckets = match cfg.batching {
            BatchingKind::Static { size } => {
                vec![1, size.min(32).max(1)]
            }
            BatchingKind::Dynamic { max }
            | BatchingKind::Nob { max } => {
                let mut b: Vec<usize> = [1usize, 2, 4, 8, 16, 25, 32]
                    .into_iter()
                    .filter(|&x| x <= max.max(1))
                    .collect();
                if b.is_empty() {
                    b.push(1);
                }
                b
            }
        };
        // Typed model handles resolve to artifact names here — a bad
        // composition fails at build time, not as a missing-file lookup
        // mid-serve.
        let va_variant = self.app.va_variant.artifact_name();
        let cr_variant = self.app.cr_variant.artifact_name();
        // Adaptation plane: the sink-side controller mints
        // resolution/variant commands from completion slack; commands
        // ride the feedback edge upstream. Downshift artifacts are
        // preloaded so a runtime command never misses a model.
        let adapt_ctl = AdaptController::new(
            &cfg.adaptation,
            cfg.num_cameras,
            cfg.gamma(),
            self.app.cr_variant,
        );
        let adapt_on = adapt_ctl.active();
        let mut extra_variants: Vec<String> = Vec::new();
        if adapt_on {
            for v in [self.app.va_variant, self.app.cr_variant] {
                let d = v.downshifted();
                if d != v {
                    extra_variants
                        .push(d.artifact_name().to_string());
                }
            }
        }
        let (service, init) = ModelService::spawn(
            self.artifacts_dir.clone(),
            va_variant,
            cr_variant,
            &extra_variants,
            buckets,
        )?;
        let (va_xi, cr_xi) = (init.va_xi, init.cr_xi);

        let shared = Arc::new(Shared {
            ledger: Mutex::new(Ledger::new()),
            detections: AtomicU64::new(0),
            fusion_updates: AtomicU64::new(0),
            fc_active: (0..cfg.num_cameras)
                .map(|_| AtomicBool::new(true))
                .collect(),
            gamma: cfg.gamma(),
            drops_enabled: cfg.drops_enabled,
            recovery: cfg.service.recovery,
            start: Instant::now(),
            obs: Arc::clone(&self.obs),
            metrics: MetricsRegistry::new(),
            frames: FramePool::new(),
            adapt: Mutex::new(AdaptationState::new(
                &cfg.adaptation,
                cfg.num_cameras,
            )),
            adapt_on,
        });

        // ---- channel topology -------------------------------------------
        let n_va = cfg.cluster.va_instances.min(4).max(1);
        let n_cr = cfg.cluster.cr_instances.min(4).max(1);
        let va_part = Partitioner::new(n_va);
        let cr_part = Partitioner::new(n_cr);

        let (uv_tx, uv_rx) = mpsc::channel::<Msg>();
        let (tl_tx, tl_rx) = mpsc::channel::<(usize, Micros, bool)>();

        let mut cr_tx = Vec::new();
        let mut cr_handles = Vec::new();
        for i in 0..n_cr {
            let (tx, rx) = mpsc::channel::<Msg>();
            cr_tx.push(tx);
            let mut w = self.mk_worker(
                Stage::Cr,
                AnalyticsBlock::Cr(self.app.make_cr()),
                &cr_xi,
            );
            w.score_threshold = 0.6;
            w.task = i as u32;
            w.query_emb = Arc::clone(service.query_arc());
            let sh = Arc::clone(&shared);
            let uv = uv_tx.clone();
            let tl = tl_tx.clone();
            let svc = service.clone();
            cr_handles.push(std::thread::spawn(move || {
                worker_loop(w, rx, sh, svc, move |ev| {
                    if let Payload::Detection { detected, .. } = ev.payload
                    {
                        let _ = tl.send((
                            ev.header.camera,
                            ev.header.captured,
                            detected,
                        ));
                    }
                    let _ = uv.send(Msg::Ev(ev));
                });
                i
            }));
        }

        let mut va_tx = Vec::new();
        let mut va_handles = Vec::new();
        for i in 0..n_va {
            let (tx, rx) = mpsc::channel::<Msg>();
            va_tx.push(tx);
            let mut w = self.mk_worker(
                Stage::Va,
                AnalyticsBlock::Va(self.app.make_va()),
                &va_xi,
            );
            w.score_threshold = 0.0; // VA forwards everything (1:1)
            w.task = i as u32;
            w.query_emb = Arc::clone(service.query_arc());
            let sh = Arc::clone(&shared);
            let crs = cr_tx.clone();
            let part = cr_part;
            let svc = service.clone();
            va_handles.push(std::thread::spawn(move || {
                worker_loop(w, rx, sh, svc, move |ev| {
                    let _ = crs[part.route(ev.header.camera)]
                        .send(Msg::Ev(ev));
                });
                i
            }));
        }

        // ---- TL thread ----------------------------------------------------
        let tl_handle = {
            let sh = Arc::clone(&shared);
            let mut tl_logic = self.app.make_tl(&TlEnv {
                peak_speed_mps: cfg.tl_peak_speed_mps,
                mean_road_m: cfg.workload.mean_road_m,
                fov_m: cfg.workload.fov_m,
                cameras: &cams,
            });
            if cfg.seed_last_seen {
                tl_logic.on_detection(0, 0, true);
            }
            let graph = graph.clone();
            std::thread::spawn(move || {
                let mut peak = 0usize;
                let mut active: Vec<usize> = Vec::new();
                let mut last_eval = Instant::now();
                loop {
                    match tl_rx.recv_timeout(Duration::from_millis(200)) {
                        Ok((cam, captured, detected)) => {
                            tl_logic.on_detection(cam, captured, detected);
                        }
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                    if last_eval.elapsed() >= Duration::from_millis(500) {
                        last_eval = Instant::now();
                        let t = now_us(sh.start);
                        let prior = active.len();
                        let sp = span_begin(&*sh.obs);
                        tl_logic.active_set_into(&graph, t, &mut active);
                        span_end(
                            &*sh.obs,
                            Scope::SpotlightExpand,
                            sp,
                        );
                        peak = peak.max(active.len());
                        sh.metrics.set_active_cameras(active.len());
                        if sh.obs.enabled() && active.len() != prior {
                            sh.obs.emit(
                                t,
                                &TraceEvent::Spotlight {
                                    query: SINGLE_QUERY,
                                    active: active.len() as u32,
                                },
                            );
                        }
                        let mut want =
                            vec![false; sh.fc_active.len()];
                        for &c in &active {
                            want[c] = true;
                        }
                        for (c, w) in want.iter().enumerate() {
                            sh.fc_active[c]
                                .store(*w, Ordering::Relaxed);
                        }
                    }
                }
                peak
            })
        };

        // ---- UV sink thread -------------------------------------------------
        // The sink owns the app's QF block: refinements are stamped by
        // the FeedbackRouter and broadcast to *every* VA/CR worker as
        // QueryUpdate events (each worker applies the freshest one and
        // scores subsequent batches against it — the feedback edge).
        // QF embeddings that already have the model's feature
        // dimension replace the scoring target wholesale; sim-
        // calibrated pseudo-embeddings (e.g. the stock RnnFusion's
        // 8-dim state) are folded into the bootstrap embedding by
        // [`fuse_embedding`] so the broadcast target always scores
        // through `ModelPool::execute`.
        let uv_handle = {
            let sh = Arc::clone(&shared);
            let va_sig = va_tx.clone();
            let cr_sig = cr_tx.clone();
            let va_part_c = va_part;
            let cr_part_c = cr_part;
            let eps_max = crate::util::millis(cfg.eps_max_ms);
            let qf = self.app.make_qf();
            let bootstrap = Arc::clone(service.query_arc());
            std::thread::spawn(move || {
                let mut qf = qf;
                let mut adapt_ctl = adapt_ctl;
                let mut router = FeedbackRouter::new();
                loop {
                    match uv_rx.recv_timeout(Duration::from_millis(200))
                    {
                        Ok(Msg::Ev(ev)) => {
                            let t = now_us(sh.start);
                            let latency = t - ev.header.src_arrival;
                            if ev.header.probe {
                                continue;
                            }
                            let detected = matches!(
                                ev.payload,
                                Payload::Detection {
                                    detected: true,
                                    ..
                                }
                            );
                            if detected {
                                sh.detections
                                    .fetch_add(1, Ordering::Relaxed);
                                sh.metrics.detection();
                            }
                            sh.ledger.lock().unwrap().completed(
                                ev.header.id,
                                latency,
                                sh.gamma,
                                detected,
                            );
                            sh.metrics
                                .completed(latency <= sh.gamma);
                            if sh.obs.enabled() {
                                sh.obs.emit(
                                    t,
                                    &TraceEvent::Completed {
                                        event: ev.header.id,
                                        query: SINGLE_QUERY,
                                        latency_us: latency,
                                        on_time: latency <= sh.gamma,
                                        detected,
                                    },
                                );
                            }
                            // Adaptation plane: the sink observes
                            // every completion's deadline slack and
                            // mints resolution/variant commands,
                            // routed upstream on the same seq-stamped
                            // feedback edge as QF refinements. One
                            // copy per VA/CR worker; the first
                            // arrival applies to the engine-global
                            // state, the rest discard as stale.
                            if sh.adapt_on {
                                if let Some(cmd) = adapt_ctl
                                    .on_completion(
                                        ev.header.camera,
                                        latency,
                                        t,
                                    )
                                {
                                    sh.metrics.adapt_minted();
                                    let upd =
                                        FeedbackEnvelope::Adaptation(
                                            cmd,
                                        )
                                        .into_event(
                                            ev.header.id,
                                            ev.header.camera,
                                            t,
                                        );
                                    for tx in va_sig
                                        .iter()
                                        .chain(cr_sig.iter())
                                    {
                                        let _ = tx.send(Msg::Ev(
                                            upd.clone(),
                                        ));
                                    }
                                }
                            }
                            if detected && qf.on_detection(&ev) {
                                sh.fusion_updates
                                    .fetch_add(1, Ordering::Relaxed);
                                if let Some(emb) = qf.embedding() {
                                    let fused = fuse_embedding(
                                        &bootstrap, emb,
                                    );
                                    let r = router.refine(
                                        SINGLE_QUERY,
                                        Arc::new(fused),
                                    );
                                    sh.metrics.refinement();
                                    if sh.obs.enabled() {
                                        sh.obs.emit(
                                            t,
                                            &TraceEvent::RefinementApplied {
                                                query: SINGLE_QUERY,
                                                seq: r.seq,
                                            },
                                        );
                                    }
                                    let upd = r.into_event(
                                        ev.header.id,
                                        ev.header.camera,
                                        t,
                                    );
                                    for tx in va_sig
                                        .iter()
                                        .chain(cr_sig.iter())
                                    {
                                        let _ = tx
                                            .send(Msg::Ev(upd.clone()));
                                    }
                                }
                            }
                            // Accept signals on comfortably-early
                            // arrivals.
                            let eps = sh.gamma - latency;
                            if eps > eps_max {
                                let sig = Signal::Accept {
                                    event: ev.header.id,
                                    eps,
                                    sum_exec: ev
                                        .header
                                        .sum_exec
                                        .max(1),
                                };
                                let cam = ev.header.camera;
                                let _ = va_sig[va_part_c.route(cam)]
                                    .send(Msg::Sig(sig));
                                let _ = cr_sig[cr_part_c.route(cam)]
                                    .send(Msg::Sig(sig));
                            }
                        }
                        Ok(_) => {}
                        Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
            })
        };

        // ---- feed loop (main thread) -----------------------------------------
        let mut next_id = 0u64;
        let mut frame_no = vec![0u64; cfg.num_cameras];
        // FC user-logic: the block decides which frames enter, given
        // TL's activation flags.
        let mut fc = self.app.make_fc();
        // Identity embeddings recur (the entity + a bounded background
        // pool): memoise them instead of recomputing per frame.
        let mut gallery = IdentityGallery::new();
        let period =
            Duration::from_micros((1e6 / cfg.fps) as u64);
        let mut next_fire = Instant::now();
        // Adaptation plane: per-camera frame strides, snapshotted once
        // per tick (commands are rare; the hot loop stays lock-free).
        let mut strides: Vec<u64> = vec![1; cfg.num_cameras];
        while shared.start.elapsed()
            < Duration::from_secs_f64(cfg.duration_secs)
        {
            let iter_sp = span_begin(&*shared.obs);
            if shared.adapt_on {
                let ad = shared.adapt.lock().unwrap();
                for (cam, s) in strides.iter_mut().enumerate() {
                    *s = ad.stride(cam);
                }
            }
            for cam in 0..cfg.num_cameras {
                let t = now_us(shared.start);
                let active =
                    shared.fc_active[cam].load(Ordering::Relaxed);
                // The counter advances per tick (not per admitted
                // frame), so stride-based FCs see monotonically
                // increasing frame numbers.
                let fno = frame_no[cam];
                frame_no[cam] += 1;
                // Commanded frame-rate decimation: FC never sees
                // strided-out ticks (mirrors the DES engines'
                // frame-tick gate).
                if shared.adapt_on
                    && strides[cam] > 1
                    && fno % strides[cam] != 0
                {
                    continue;
                }
                if !fc.admit(SINGLE_QUERY, cam, fno, t, active) {
                    continue;
                }
                let present = gt.visible(cam, t);
                // Real pixels: entity frames use the entity identity;
                // negatives use a per-camera/frame background identity.
                let ident = if present {
                    ENTITY_IDENTITY
                } else {
                    1_000 + ((cam as u64) * 131 + fno) % 5_000
                };
                // Pixel buffers come from the frame pool (CR workers
                // reclaim them once scored) — steady-state serving
                // reuses buffers instead of allocating one per frame.
                let mut img = shared.frames.get();
                gallery.image_into(ident, fno, 0.25, &mut img);
                let header = Header::new(next_id, cam, fno, t);
                shared
                    .ledger
                    .lock()
                    .unwrap()
                    .generated(next_id, present);
                shared.metrics.generated();
                if shared.obs.enabled() {
                    shared.obs.emit(
                        t,
                        &TraceEvent::Generated {
                            event: next_id,
                            query: SINGLE_QUERY,
                            camera: cam as u32,
                        },
                    );
                }
                let ev = Event {
                    header,
                    payload: Payload::FrameData(Arc::new(img)),
                };
                let _ =
                    va_tx[va_part.route(cam)].send(Msg::Ev(ev));
                next_id += 1;
            }
            span_end(&*shared.obs, Scope::FeedLoop, iter_sp);
            next_fire += period;
            let now = Instant::now();
            if next_fire > now {
                std::thread::sleep(next_fire - now);
            }
        }

        // Drain: give in-flight events one gamma to finish.
        std::thread::sleep(Duration::from_millis(
            (cfg.gamma_ms as u64).min(3_000),
        ));
        for tx in &va_tx {
            let _ = tx.send(Msg::Stop);
        }
        for h in va_handles {
            let _ = h.join();
        }
        for tx in &cr_tx {
            let _ = tx.send(Msg::Stop);
        }
        for h in cr_handles {
            let _ = h.join();
        }
        drop(uv_tx);
        drop(tl_tx);
        let _ = uv_handle.join();
        let peak_active = tl_handle.join().unwrap_or(0);

        let wall = shared.start.elapsed().as_secs_f64();
        let summary = shared.ledger.lock().unwrap().summary();
        let processed = summary.on_time + summary.delayed;
        Ok(LiveReport {
            detections: shared.detections.load(Ordering::Relaxed),
            throughput: processed as f64 / wall,
            wall_secs: wall,
            peak_active,
            fusion_updates: shared
                .fusion_updates
                .load(Ordering::Relaxed),
            metrics: shared.metrics.snapshot(),
            summary,
        })
    }

    fn mk_worker(
        &self,
        stage: Stage,
        block: AnalyticsBlock,
        xi: &XiModel,
    ) -> Worker {
        let cfg = &self.cfg;
        let batcher = match cfg.batching {
            BatchingKind::Static { size } => Batcher::fixed(size),
            BatchingKind::Dynamic { max } => Batcher::dynamic(max),
            BatchingKind::Nob { max } => Batcher::nob(
                NobTable::build(xi, NOB_MAX_RATE, NOB_RATE_STEP, max),
                max,
            ),
        };
        let m_max = match cfg.batching {
            BatchingKind::Static { size } => size,
            BatchingKind::Dynamic { max }
            | BatchingKind::Nob { max } => max,
        };
        Worker {
            stage,
            task: 0,
            block,
            batcher,
            budget: BudgetManager::new(1, m_max, 2039), // prime ring
            xi: xi.clone().with_ema(ONLINE_XI_EMA),
            score_threshold: 0.5,
            // Callers swap in the model service's bootstrap embedding.
            query_emb: Arc::new(Vec::new()),
            feedback: FeedbackState::new(),
            img_scratch: Vec::new(),
            staged: Vec::new(),
            frame_scratch: Vec::new(),
        }
    }
}

/// The executor loop shared by VA and CR workers. The AOT model it
/// executes is the block's own typed variant — chosen per
/// [`AnalyticsBlock::variant`], not per engine stage.
fn worker_loop(
    mut w: Worker,
    rx: Receiver<Msg>,
    sh: Arc<Shared>,
    svc: ModelService,
    mut forward: impl FnMut(Event),
) {
    let img_dim = svc.img_dim();
    let variant = w.block.variant().artifact_name();
    'outer: loop {
        // Drive the batcher.
        let now = now_us(sh.start);
        let sp = span_begin(&*sh.obs);
        let poll = {
            let xi = w.xi.clone();
            w.batcher.poll(now, &xi)
        };
        span_end(&*sh.obs, Scope::BatchPoll, sp);
        match poll {
            BatcherPoll::Ready(batch) => {
                exec_batch(
                    &mut w, batch, &sh, &svc, variant, img_dim,
                    &mut forward,
                );
                continue;
            }
            BatcherPoll::Timer(at) => {
                let wait = (at - now).max(0) as u64;
                match rx.recv_timeout(Duration::from_micros(
                    wait.min(200_000),
                )) {
                    Ok(msg) => {
                        if !handle_msg(&mut w, msg, &sh) {
                            break 'outer;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            BatcherPoll::Idle => {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(msg) => {
                        if !handle_msg(&mut w, msg, &sh) {
                            break 'outer;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        // Opportunistically drain without blocking.
        while let Ok(msg) = rx.try_recv() {
            if !handle_msg(&mut w, msg, &sh) {
                break 'outer;
            }
        }
    }
    // Final flush: execute whatever is still queued.
    loop {
        let now = now_us(sh.start);
        let xi = w.xi.clone();
        match w.batcher.poll(now + BUDGET_INF / 2, &xi) {
            BatcherPoll::Ready(batch) => exec_batch(
                &mut w,
                batch,
                &sh,
                &svc,
                variant,
                img_dim,
                &mut forward,
            ),
            _ => break,
        }
    }
}

/// Returns false on Stop.
fn handle_msg(w: &mut Worker, msg: Msg, sh: &Arc<Shared>) -> bool {
    match msg {
        Msg::Stop => false,
        Msg::Sig(sig) => {
            w.budget.apply(sig, &w.xi);
            true
        }
        Msg::Ev(ev) => {
            // Feedback edge: consume QueryUpdates here — swap the
            // scoring target iff the update is fresher than the last
            // applied one; never batched, budgeted or dropped. The
            // sink adapts every broadcast to the model's feature
            // dimension ([`fuse_embedding`]), so the length guard is
            // defence in depth: a mis-sized update (a custom broadcast
            // path) is sequenced but cannot reach
            // `ModelPool::execute`, whose dimension check would
            // otherwise panic the worker mid-serve.
            if let Payload::QueryUpdate(emb) = &ev.payload {
                if w.feedback.apply(
                    ev.header.query,
                    ev.header.update_seq,
                    Arc::clone(emb),
                ) && emb.len() == w.query_emb.len()
                {
                    w.query_emb = Arc::clone(emb);
                }
                return true;
            }
            // Adaptation commands ride the same feedback edge and are
            // consumed here — this engine's single application point —
            // never batched, budgeted or dropped. The state is
            // engine-global (commands steer cameras, which every
            // worker shares), so of the per-worker broadcast copies
            // the first arrival applies and the rest discard as
            // stale.
            if let Payload::Adaptation(cmd) = &ev.payload {
                let cmd = *cmd;
                let now = now_us(sh.start);
                let (applied, down) = {
                    let mut ad = sh.adapt.lock().unwrap();
                    let ok = ad.apply(&cmd);
                    (ok, ad.downshifted())
                };
                if applied {
                    sh.metrics.adapt_applied();
                    sh.metrics.set_cameras_downshifted(down);
                    if sh.obs.enabled() {
                        sh.obs.emit(
                            now,
                            &TraceEvent::Adaptation {
                                camera: cmd.camera as u32,
                                seq: cmd.seq,
                                level: cmd.level as u32,
                                variant: cmd
                                    .variant
                                    .profile()
                                    .artifact,
                            },
                        );
                    }
                } else {
                    sh.metrics.adapt_stale();
                }
                return true;
            }
            let now = now_us(sh.start);
            let u = now - ev.header.src_arrival;
            let exempt = ev.header.avoid_drop || ev.header.probe;
            if sh.drops_enabled {
                let budget = w.budget.budget_max();
                // Gate-1 prices the event at the commanded
                // (resolution, variant) cost for its camera; with
                // adaptation off this is exactly ξ(1).
                let xi1 = if sh.adapt_on {
                    let rel = sh.adapt.lock().unwrap().rel(
                        ev.header.camera,
                        w.block.variant(),
                    );
                    w.xi.xi_eff(rel)
                } else {
                    w.xi.xi(1)
                };
                if budget < BUDGET_INF
                    && drop_at_queue(exempt, u, xi1, budget)
                {
                    sh.ledger
                        .lock()
                        .unwrap()
                        .dropped(ev.header.id, w.stage);
                    sh.metrics.dropped(Gate::Queue);
                    if sh.obs.enabled() {
                        sh.obs.emit(
                            now,
                            &TraceEvent::Drop {
                                gate: Gate::Queue,
                                stage: w.stage,
                                event: ev.header.id,
                                query: ev.header.query,
                                batch: 1,
                                eps_us: (u + xi1) - budget,
                                xi_us: xi1,
                            },
                        );
                    }
                    return true;
                }
                if sh.obs.enabled()
                    && exempt
                    && budget < BUDGET_INF
                    && drop_at_queue(false, u, xi1, budget)
                {
                    sh.obs.emit(
                        now,
                        &TraceEvent::Exempted {
                            gate: Gate::Queue,
                            stage: w.stage,
                            event: ev.header.id,
                            query: ev.header.query,
                        },
                    );
                }
            }
            let deadline = {
                let b = w.budget.budget_max();
                if b >= BUDGET_INF {
                    BUDGET_INF
                } else {
                    b + ev.header.src_arrival
                }
            };
            let id = ev.header.id;
            w.batcher.push(QueuedEvent {
                item: ev,
                id,
                arrival: now,
                deadline,
            });
            true
        }
    }
}

fn exec_batch(
    w: &mut Worker,
    mut batch: Vec<QueuedEvent<Event>>,
    sh: &Arc<Shared>,
    svc: &ModelService,
    variant: &str,
    img_dim: usize,
    forward: &mut impl FnMut(Event),
) {
    let start = now_us(sh.start);
    // Drop point 2.
    if sh.drops_enabled {
        let budget = w.budget.budget_max();
        if budget < BUDGET_INF {
            let b0 = batch.len() as u32;
            let xib = w.xi.xi(batch.len());
            let mut kept = Vec::with_capacity(batch.len());
            for qe in batch {
                let u = qe.arrival - qe.item.header.src_arrival;
                let q = start - qe.arrival;
                let exempt =
                    qe.item.header.avoid_drop || qe.item.header.probe;
                if drop_at_exec(exempt, u, q, xib, budget) {
                    sh.ledger
                        .lock()
                        .unwrap()
                        .dropped(qe.item.header.id, w.stage);
                    sh.metrics.dropped(Gate::Exec);
                    if sh.obs.enabled() {
                        sh.obs.emit(
                            start,
                            &TraceEvent::Drop {
                                gate: Gate::Exec,
                                stage: w.stage,
                                event: qe.item.header.id,
                                query: qe.item.header.query,
                                batch: b0,
                                eps_us: (u + q + xib) - budget,
                                xi_us: xib,
                            },
                        );
                    }
                } else {
                    if sh.obs.enabled()
                        && exempt
                        && drop_at_exec(false, u, q, xib, budget)
                    {
                        sh.obs.emit(
                            start,
                            &TraceEvent::Exempted {
                                gate: Gate::Exec,
                                stage: w.stage,
                                event: qe.item.header.id,
                                query: qe.item.header.query,
                            },
                        );
                    }
                    kept.push(qe);
                }
            }
            batch = kept;
        }
    }
    if batch.is_empty() {
        return;
    }
    // Adaptation plane: execute the commanded (possibly downshifted)
    // variant for this batch's camera — `ModelService::spawn`
    // preloaded the downshift artifacts, so the lookup cannot miss
    // mid-serve. With adaptation off the block's nominal artifact runs
    // unchanged.
    let variant: &str = if sh.adapt_on {
        sh.adapt
            .lock()
            .unwrap()
            .variant_for(
                batch[0].item.header.camera,
                w.block.variant(),
            )
            .artifact_name()
    } else {
        variant
    };
    let b = batch.len();
    let queue_sum: Micros =
        batch.iter().map(|qe| (start - qe.arrival).max(0)).sum();
    if sh.obs.enabled() {
        sh.obs.emit(
            start,
            &TraceEvent::BatchFormed {
                stage: w.stage,
                task: w.task,
                size: b as u32,
            },
        );
    }

    // Gather pixels into the worker's reusable buffer and run the real
    // model; the buffer round-trips through the service thread.
    let mut images = std::mem::take(&mut w.img_scratch);
    images.clear();
    images.reserve(b * img_dim);
    for qe in &batch {
        match &qe.item.payload {
            Payload::FrameData(img) => images.extend_from_slice(img),
            _ => images.extend(std::iter::repeat(0f32).take(img_dim)),
        }
    }

    // Real model execution, under bounded retry with exponential
    // backoff: a transient model-service failure is retried up to
    // `recovery.max_retries` times; if every attempt fails the batch
    // is accounted `lost_to_fault` (never silently vanished, never a
    // worker panic). On an execution error the image buffer
    // round-trips back through the reply, so retries re-use the same
    // gather.
    let sp = span_begin(&*sh.obs);
    let max_attempts = if sh.recovery.enabled {
        sh.recovery.max_retries + 1
    } else {
        1
    };
    let mut images = Some(images);
    let mut result: Option<ModelOutput> = None;
    for attempt in 0..max_attempts {
        if attempt > 0 {
            sh.metrics.fault_retry();
            if sh.obs.enabled() {
                sh.obs.emit(
                    now_us(sh.start),
                    &TraceEvent::FaultRetry {
                        event: batch[0].item.header.id,
                        query: batch[0].item.header.query,
                        attempt: attempt - 1,
                    },
                );
            }
            std::thread::sleep(Duration::from_micros(
                backoff_delay(&sh.recovery, attempt - 1) as u64,
            ));
        }
        let (out, buf) = svc.execute_reusing(
            variant,
            images.take().unwrap_or_default(),
            Arc::clone(&w.query_emb),
        );
        match out {
            Ok(o) => {
                w.img_scratch = buf;
                result = Some(o);
                break;
            }
            // `buf` is the original gather unless the service thread
            // itself is gone (then it is empty — and so is any hope
            // of a different outcome, but the bounded loop still
            // terminates promptly).
            Err(_) => images = Some(buf),
        }
    }
    span_end(&*sh.obs, Scope::ModelExec, sp);
    let out = match result {
        Some(o) => o,
        None => {
            let t = now_us(sh.start);
            let mut led = sh.ledger.lock().unwrap();
            for qe in &batch {
                led.lost_to_fault(qe.item.header.id, w.stage);
                sh.metrics.lost_to_fault();
                if sh.obs.enabled() {
                    sh.obs.emit(
                        t,
                        &TraceEvent::LostToFault {
                            event: qe.item.header.id,
                            query: qe.item.header.query,
                            stage: w.stage,
                        },
                    );
                }
            }
            return;
        }
    };
    let end = now_us(sh.start);
    let actual = end - start;
    w.xi.observe(b, actual);
    // ξ drifted (e.g. the node slowed down)? The NOB table's rate →
    // batch lookup follows the refreshed model, like the DES engines.
    w.batcher.retune_nob(&w.xi);
    sh.metrics.xi_observed();
    sh.metrics.nob_retune();
    let xi_est = w.xi.xi(b);
    sh.metrics.batch_executed(
        w.stage,
        b,
        queue_sum / (b.max(1) as Micros),
    );
    if sh.obs.enabled() {
        sh.obs.emit(
            end,
            &TraceEvent::BatchExecuted {
                stage: w.stage,
                task: w.task,
                size: b as u32,
                est_us: xi_est,
                actual_us: actual,
            },
        );
        sh.obs.emit(
            end,
            &TraceEvent::XiObserved {
                stage: w.stage,
                task: w.task,
                b_eff: b as f64,
                actual_us: actual,
                alpha_us: w.xi.alpha_us(),
                beta_us: w.xi.beta_us(),
            },
        );
        sh.obs.emit(
            end,
            &TraceEvent::NobRetune {
                stage: w.stage,
                task: w.task,
            },
        );
    }

    // Per-event bookkeeping into the worker's staging buffers, then one
    // virtual call hands the whole batch + its model scores to the
    // app's block for the payload transformation.
    let mut staged = std::mem::take(&mut w.staged);
    let mut recycle = std::mem::take(&mut w.frame_scratch);
    staged.clear();
    recycle.clear();
    for qe in batch {
        let mut ev = qe.item;
        let q = start - qe.arrival;
        let u = qe.arrival - ev.header.src_arrival;
        w.budget.record(
            ev.header.id,
            EventRecord {
                departure: u + q + actual,
                queue: q,
                batch: b,
                sent_to: 0,
            },
        );
        ev.header.sum_exec += xi_est;
        ev.header.sum_queue += q;
        // CR is the pixels' last reader: remember each frame `Arc` so
        // the buffer can go back to the pool once the block has
        // replaced the payload with its verdict.
        if matches!(w.stage, Stage::Cr) {
            if let Payload::FrameData(img) = &ev.payload {
                recycle.push(Arc::clone(img));
            }
        }
        staged.push(ev);
    }
    let sp = span_begin(&*sh.obs);
    w.block.apply_scores(
        &mut staged,
        &out.scores,
        &ScoreParams {
            threshold: w.score_threshold,
        },
    );
    span_end(&*sh.obs, Scope::Scoring, sp);
    // The stock CR blocks replaced every payload above, so each
    // remembered frame `Arc` is now uniquely held here and its buffer
    // is poolable; a block that kept the payload alive makes
    // `reclaim`'s `try_unwrap` fail closed (buffer dropped, never
    // copied or corrupted).
    for img in recycle.drain(..) {
        sh.frames.reclaim(img);
    }
    w.frame_scratch = recycle;
    for ev in staged.drain(..) {
        forward(ev);
    }
    w.staged = staged;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_pool_reuses_reclaimed_buffers() {
        let pool = FramePool::new();
        let mut a = pool.get();
        assert_eq!(pool.misses(), 1);
        assert_eq!(pool.hits(), 0);
        a.extend_from_slice(&[1.0, 2.0, 3.0]);
        pool.reclaim(Arc::new(a));
        assert_eq!(pool.idle(), 1);

        let b = pool.get();
        assert_eq!(pool.hits(), 1, "second get must reuse the buffer");
        assert_eq!(pool.misses(), 1);
        assert!(b.is_empty(), "pooled buffers come back cleared");
        assert!(b.capacity() >= 3, "reuse keeps the allocation");
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn frame_pool_drops_still_shared_frames() {
        let pool = FramePool::new();
        let frame = Arc::new(vec![1.0f32; 8]);
        let held = Arc::clone(&frame);
        pool.reclaim(frame);
        assert_eq!(
            pool.idle(),
            0,
            "a shared frame must not be pooled"
        );
        assert_eq!(held.len(), 8);
        // Sole-holder reclaim pools it.
        pool.reclaim(held);
        assert_eq!(pool.idle(), 1);
    }
}
